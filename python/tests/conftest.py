import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def f32(rng, *shape):
    return np.asarray(rng.randn(*shape), dtype=np.float32)
