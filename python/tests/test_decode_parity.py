"""Integration parity: the Pallas serving path (prefill + decode_step)
must produce the same logits as the batched jnp eval path — with every
KV-CAR mechanism (AE compression, int8, head reuse) active at once.

This is the contract the rust coordinator relies on: perplexity measured
through eval_loss is exactly the quality of the text the serving path
generates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile.config import GPT2T, TINYLLAMA_T

BOTH = pytest.mark.parametrize("cfg", [GPT2T, TINYLLAMA_T], ids=lambda c: c.name)


def _kvcfg(cfg):
    L, H = cfg.n_layer, cfg.n_kv_head
    return {
        "compress": jnp.ones((L,), jnp.float32).at[0].set(0.0),
        "quant": jnp.float32(1.0),
        "reuse_k": jnp.zeros((L, H), jnp.float32).at[2, 0].set(1.0),
        "reuse_v": jnp.zeros((L, H), jnp.float32).at[3, H - 1].set(1.0),
    }


@BOTH
def test_prefill_decode_matches_eval(cfg):
    params = P.init_params(cfg, 0)
    S, L, kvd = cfg.max_seq, cfg.n_layer, cfg.kv_dim
    rng = np.random.RandomState(1)
    plen, n_decode = 9, 3
    seq = rng.randint(0, cfg.vocab, (S,)).astype(np.int32)
    kv = _kvcfg(cfg)

    tok = jnp.asarray(seq[None, :])
    pmask = jnp.zeros((1, S), jnp.float32).at[0, :plen].set(1.0)
    pf = M.make_prefill(cfg)
    logits_last, k_raw, v_raw, k_lat, v_lat, k_eff, v_eff = pf(
        params, tok, pmask, jnp.int32(plen - 1), kv
    )
    assert k_raw.shape == (L, S, kvd)
    assert k_lat.shape == (L, S, cfg.ae_latent)

    ds = jax.jit(M.make_decode_step(cfg, 1))
    row_ok = (jnp.arange(S) < plen)[None, None, :, None]
    kc = (jnp.zeros((1, L, S, kvd)).at[0].set(k_eff)) * row_ok
    vc = (jnp.zeros((1, L, S, kvd)).at[0].set(v_eff)) * row_ok

    dec_logits = [np.array(logits_last)]
    for t in range(plen, plen + n_decode):
        lg, klat, vlat, kraw, vraw, keff, veff = ds(
            params,
            jnp.asarray([seq[t]]),
            jnp.asarray([t], jnp.int32),
            kc,
            vc,
            kv,
        )
        kc = kc.at[0, :, t, :].set(keff[0])
        vc = vc.at[0, :, t, :].set(veff[0])
        dec_logits.append(np.array(lg[0]))
        assert klat.shape == (1, L, cfg.ae_latent)

    for i, t in enumerate(range(plen - 1, plen + n_decode)):
        em = jnp.zeros((1, S), jnp.float32).at[0, : t + 1].set(1.0)
        lg, _ = M.forward(cfg, params, tok, em, kv, mode="eval")
        np.testing.assert_allclose(
            dec_logits[i], np.array(lg[0, t]), rtol=1e-4, atol=1e-4
        )


@BOTH
def test_prefill_base_matches_base_forward(cfg):
    params = P.init_params(cfg, 0)
    S = cfg.max_seq
    rng = np.random.RandomState(2)
    plen = 17
    seq = rng.randint(0, cfg.vocab, (S,)).astype(np.int32)
    tok = jnp.asarray(seq[None, :])
    pmask = jnp.zeros((1, S), jnp.float32).at[0, :plen].set(1.0)
    logits_last, ks, vs = M.make_prefill_base(cfg)(
        params["base"], tok, pmask, jnp.int32(plen - 1)
    )
    lg, _ = M.forward(cfg, params, tok, pmask, M.baseline_kvcfg(cfg), mode="base")
    np.testing.assert_allclose(
        np.array(logits_last), np.array(lg[0, plen - 1]), rtol=1e-4, atol=1e-4
    )
    assert ks.shape == (cfg.n_layer, S, cfg.kv_dim)


@BOTH
def test_encode_decode_kv_roundtrip_consistency(cfg):
    """encode_kv/decode_kv (the rust cache manager's standalone artifacts)
    must agree with the latents/reconstructions the prefill path produces."""
    params = P.init_params(cfg, 0)
    S, L, kvd = cfg.max_seq, cfg.n_layer, cfg.kv_dim
    rng = np.random.RandomState(3)
    k_raw = jnp.asarray(rng.randn(L, S, kvd).astype(np.float32))
    v_raw = jnp.asarray(rng.randn(L, S, kvd).astype(np.float32))
    zk, zv = M.make_encode_kv(cfg)(params["ae"], k_raw, v_raw)
    kr, vr = M.make_decode_kv(cfg)(params["ae"], zk, zv)
    assert zk.shape == (L, S, cfg.ae_latent)
    assert kr.shape == (L, S, kvd)
    # parity with the ref store-transform
    from compile.kernels import ref

    for l in (0, L - 1):
        enc = {k: v[l] for k, v in params["ae"]["k"]["enc"].items()}
        dec = {k: v[l] for k, v in params["ae"]["k"]["dec"].items()}
        z_want, _ = ref.ae_encode(k_raw[l], enc)
        r_want, _ = ref.ae_decode(z_want, dec)
        np.testing.assert_allclose(np.array(zk[l]), np.array(z_want), rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(np.array(kr[l]), np.array(r_want), rtol=2e-5, atol=2e-4)


@BOTH
def test_batched_prefill_bit_matches_single(cfg):
    """prefill_b packs one admission wave's prompts into [B, S] lanes;
    every live lane must be *bit-identical* (all seven outputs) to a
    {m}_prefill call on that request alone — the contract the rust
    scheduler's wave admission relies on for bitwise equivalence with
    sequential prefill.  Dead lanes (all-zero len_mask, the padding the
    rust side stages for short waves) must be inert."""
    params = P.init_params(cfg, 0)
    S, L = cfg.max_seq, cfg.n_layer
    B = max(cfg.decode_batches)
    rng = np.random.RandomState(7)
    kv = _kvcfg(cfg)
    # mixed prompt lengths, including the plen=1 edge; lane 2 is dead
    plens = [(9, 1, 0, 17) + tuple(rng.randint(1, S) for _ in range(B))][0][:B]
    toks = rng.randint(0, cfg.vocab, (B, S)).astype(np.int32)
    mask = np.zeros((B, S), np.float32)
    last = np.zeros((B,), np.int32)
    for b, p in enumerate(plens):
        if p == 0:  # dead lane: zero tokens, zero mask, last pinned to 0
            toks[b] = 0
        else:
            mask[b, :p] = 1.0
            last[b] = p - 1
    outs_b = M.make_prefill_b(cfg, B)(
        params,
        jnp.asarray(toks),
        jnp.asarray(mask),
        jnp.asarray(last),
        kv,
    )
    assert outs_b[0].shape == (B, cfg.vocab)
    assert outs_b[1].shape == (B, L, S, cfg.kv_dim)
    assert outs_b[3].shape == (B, L, S, cfg.ae_latent)
    pf = M.make_prefill(cfg)
    names = ("logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff")
    for b, p in enumerate(plens):
        if p == 0:
            continue
        outs_1 = pf(
            params,
            jnp.asarray(toks[b : b + 1]),
            jnp.asarray(mask[b : b + 1]),
            jnp.int32(p - 1),
            kv,
        )
        for name, got, want in zip(names, outs_b, outs_1):
            got = np.asarray(got[b])
            want = np.asarray(want)
            assert got.shape == want.shape, (name, b)
            assert (got.view(np.uint32) == want.view(np.uint32)).all(), (
                f"{name} lane {b} (plen {p}) diverges from per-request prefill"
            )


@BOTH
def test_batched_decode_kv_bit_matches_token_decode(cfg):
    """decode_kv_bt packs one watermark row per live sequence into
    [B, L, 1, dl]; every slot must be *bit-identical* to a decode_kv_t
    call on that slot alone — the contract the rust scheduler's batched
    faithful advance relies on for bitwise equivalence with the
    per-sequence path."""
    params = P.init_params(cfg, 0)
    L, dl, kvd = cfg.n_layer, cfg.ae_latent, cfg.kv_dim
    B = max(cfg.decode_batches)
    rng = np.random.RandomState(11)
    k = jnp.asarray(rng.randn(B, L, 1, dl).astype(np.float32))
    v = jnp.asarray(rng.randn(B, L, 1, dl).astype(np.float32))
    kr_b, vr_b = M.make_decode_kv_batched(cfg)(params["ae"], k, v)
    assert kr_b.shape == (B, L, 1, kvd)
    dk = M.make_decode_kv(cfg)
    for b in (0, 1, B - 1):
        kr_t, vr_t = dk(params["ae"], k[b], v[b])
        assert (
            np.asarray(kr_b[b]).view(np.uint32) == np.asarray(kr_t).view(np.uint32)
        ).all(), f"K slot {b} diverges from decode_kv_t"
        assert (
            np.asarray(vr_b[b]).view(np.uint32) == np.asarray(vr_t).view(np.uint32)
        ).all(), f"V slot {b} diverges from decode_kv_t"


@BOTH
def test_prefill_prefix_rows_bit_stable_across_prompts(cfg):
    """Prefix purity — the contract behind cross-request KV sharing
    (rust DESIGN.md §6): a causal transformer's prefill row t is a pure
    function of tokens [0, t], so two prompts agreeing on their first k
    tokens must produce *bit-identical* KV rows [0, k) in every output
    stream.  That is what lets the rust CacheManager's prefix trie
    reference one stored copy of a shared system prompt instead of
    re-storing it per request."""
    params = P.init_params(cfg, 0)
    S = cfg.max_seq
    rng = np.random.RandomState(17)
    k = 12  # shared prefix tokens
    prefix = rng.randint(0, cfg.vocab, (k,))
    kv = _kvcfg(cfg)
    pf = M.make_prefill(cfg)
    outs = []
    for tail_len in (5, 9):
        tail = rng.randint(0, cfg.vocab, (tail_len,))
        plen = k + tail_len
        toks = np.zeros((1, S), np.int32)
        toks[0, :plen] = np.concatenate([prefix, tail])
        mask = np.zeros((1, S), np.float32)
        mask[0, :plen] = 1.0
        outs.append(
            [
                np.asarray(o)
                for o in pf(params, jnp.asarray(toks), jnp.asarray(mask), jnp.int32(plen - 1), kv)
            ]
        )
    names = ("k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff")
    for name, a, b in zip(names, outs[0][1:], outs[1][1:]):
        assert (
            a[:, :k, :].view(np.uint32) == b[:, :k, :].view(np.uint32)
        ).all(), f"{name}: shared prefix rows diverge across prompts"
    # sanity: the divergent tails really diverge, so the probe bites
    assert not (outs[0][1][:, k : k + 5, :] == outs[1][1][:, k : k + 5, :]).all()
