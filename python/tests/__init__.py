# Package marker so `pytest python/` collects from any rootdir: the
# test modules import shared fixtures via `from .conftest import ...`,
# which needs package context.
