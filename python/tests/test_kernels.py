"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes/seeds; assert_allclose everywhere.  These are the
CORE correctness signal for the AOT'd serving path — the decode_step
artifact is built from exactly these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# property sweeps need hypothesis; environments without it (offline
# containers) skip this module instead of failing collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import autoencoder as ae_k
from compile.kernels import linear as lin_k
from compile.kernels import quant as q_k
from compile.kernels import ref

SET = dict(max_examples=12, deadline=None)


def _f32(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def _ae_params(rng, d_in, d_h, d_out):
    return {
        "w1": _f32(rng, d_in, d_h),
        "b1": _f32(rng, d_h),
        "bn_g": _f32(rng, d_h),
        "bn_b": _f32(rng, d_h),
        "bn_mean": _f32(rng, d_h),
        "bn_var": jnp.abs(_f32(rng, d_h)) + 0.3,
        "w2": _f32(rng, d_h, d_out),
        "b2": _f32(rng, d_out),
    }


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    m=st.sampled_from([1, 8, 64, 128, 256]),
    k=st.sampled_from([32, 64, 128]),
    n=st.sampled_from([32, 64, 96, 128, 384]),
    seed=st.integers(0, 2**31 - 1),
    bias=st.booleans(),
)
def test_linear_matches_ref(m, k, n, seed, bias):
    rng = np.random.default_rng(seed)
    x, w = _f32(rng, m, k), _f32(rng, k, n)
    b = _f32(rng, n) if bias else None
    got = lin_k.linear(x, w, b)
    want = ref.linear(x, w, b)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-4)


def test_linear_tiled_grid():
    """Multi-tile grid (all three grid axes > 1) accumulates correctly."""
    rng = np.random.default_rng(7)
    x, w, b = _f32(rng, 256, 256), _f32(rng, 256, 256), _f32(rng, 256)
    got = lin_k.linear(x, w, b, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(
        np.array(got), np.array(ref.linear(x, w, b)), rtol=2e-5, atol=2e-3
    )


def test_linear_rejects_indivisible_tiles():
    x, w = jnp.zeros((100, 64)), jnp.zeros((64, 64))
    with pytest.raises(AssertionError):
        lin_k.linear(x, w, bm=64)


# ---------------------------------------------------------------------------
# fused autoencoder halves
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    m=st.sampled_from([1, 8, 128, 256]),
    dims=st.sampled_from([(128, 96, 64), (64, 48, 32), (32, 96, 128), (64, 64, 64)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ae_half_matches_ref(m, dims, seed):
    d_in, d_h, d_out = dims
    rng = np.random.default_rng(seed)
    x = _f32(rng, m, d_in)
    p = _ae_params(rng, d_in, d_h, d_out)
    got = ae_k.ae_half_from_dict(x, p)
    want, _ = ref.ae_half_apply(x, p)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-4)


def test_ae_roundtrip_shrinks_then_restores_shape():
    rng = np.random.default_rng(0)
    enc = _ae_params(rng, 128, 96, 64)
    dec = _ae_params(rng, 64, 96, 128)
    x = _f32(rng, 16, 128)
    z = ae_k.ae_half_from_dict(x, enc)
    assert z.shape == (16, 64)
    y = ae_k.ae_half_from_dict(z, dec)
    assert y.shape == (16, 128)


def test_ae_leaky_relu_negative_region():
    """Constructed input forcing the BN output negative exercises the
    LeakyReLU slope rather than the identity branch."""
    rng = np.random.default_rng(3)
    p = _ae_params(rng, 8, 8, 8)
    p["bn_b"] = jnp.full((8,), -100.0)  # push everything negative
    x = _f32(rng, 4, 8)
    got = ae_k.ae_half_from_dict(x, p)
    want, _ = ref.ae_half_apply(x, p)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    s=st.sampled_from([4, 32, 128]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 2), (2, 1)]),
    dh=st.sampled_from([16, 32]),
    valid_frac=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_attention_matches_ref(s, heads, dh, valid_frac, seed):
    hq, hkv = heads
    g = hq // hkv
    rng = np.random.default_rng(seed)
    q, k, v = _f32(rng, s, hq, dh), _f32(rng, s, hkv, dh), _f32(rng, s, hkv, dh)
    n_valid = max(1, int(s * valid_frac))
    m = jnp.zeros((s,), jnp.float32).at[:n_valid].set(1.0)
    got = attn_k.causal_attention(q, k, v, m, group_size=g)
    want = ref.causal_attention(q, k, v, group_size=g, length_mask=m)
    np.testing.assert_allclose(
        np.array(got)[:n_valid], np.array(want)[:n_valid], rtol=2e-5, atol=2e-4
    )


@settings(**SET)
@given(
    s=st.sampled_from([4, 32, 128]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 2)]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(s, heads, dh, seed):
    hq, hkv = heads
    g = hq // hkv
    rng = np.random.default_rng(seed)
    q, k, v = _f32(rng, hq, dh), _f32(rng, s, hkv, dh), _f32(rng, s, hkv, dh)
    n_valid = rng.integers(1, s + 1)
    m = jnp.zeros((s,), jnp.float32).at[:n_valid].set(1.0)
    got = attn_k.decode_attention(q, k, v, m, group_size=g)
    want = ref.decode_attention(q, k, v, group_size=g, length_mask=m)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-4)


@settings(**SET)
@given(
    b=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([16, 128]),
    heads=st.sampled_from([(4, 4), (4, 2)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_batched_matches_per_seq(b, s, heads, seed):
    hq, hkv = heads
    g, dh = hq // hkv, 32
    rng = np.random.default_rng(seed)
    q = _f32(rng, b, hq, dh)
    k, v = _f32(rng, b, s, hkv, dh), _f32(rng, b, s, hkv, dh)
    lens = rng.integers(1, s + 1, size=b)
    m = jnp.asarray((np.arange(s)[None, :] < lens[:, None]).astype(np.float32))
    got = attn_k.decode_attention_batched(q, k, v, m, group_size=g)
    for i in range(b):
        want = ref.decode_attention(
            q[i], k[i], v[i], group_size=g, length_mask=m[i]
        )
        np.testing.assert_allclose(
            np.array(got[i]), np.array(want), rtol=2e-5, atol=2e-4
        )


def test_decode_attention_single_valid_token():
    """Mask with exactly one attendable position returns that value row."""
    rng = np.random.default_rng(0)
    q, k = _f32(rng, 4, 32), _f32(rng, 16, 4, 32)
    v = _f32(rng, 16, 4, 32)
    m = jnp.zeros((16,), jnp.float32).at[5].set(1.0)
    got = attn_k.decode_attention(q, k, v, m, group_size=1)
    np.testing.assert_allclose(np.array(got), np.array(v[5]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Eq. 4 quantization
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    m=st.sampled_from([1, 8, 256, 512]),
    f=st.sampled_from([16, 32, 64]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matches_ref(m, f, scale, seed):
    rng = np.random.default_rng(seed)
    x = _f32(rng, m, f) * scale
    q, s, z = q_k.quantize(x)
    qe, se, ze = ref.quantize(x)  # ref keeps dims: [M,1] vs kernel's [M]
    np.testing.assert_allclose(np.array(q), np.array(qe), atol=1e-5)
    np.testing.assert_allclose(np.array(s), np.array(se)[:, 0], rtol=1e-6)
    np.testing.assert_allclose(np.array(z), np.array(ze)[:, 0], atol=1e-5)
    got = q_k.dequantize(q, s, z)
    want = ref.dequantize(qe, se, ze)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(
    m=st.sampled_from([4, 64]),
    f=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_roundtrip_error_bound(m, f, seed):
    """|x - dq(q(x))| <= (max-min)/255 per row — the Eq. 4 step size."""
    rng = np.random.default_rng(seed)
    x = _f32(rng, m, f)
    y = np.array(q_k.quant_dequant(x))
    xn = np.array(x)
    step = (xn.max(axis=1) - xn.min(axis=1)) / 255.0
    err = np.abs(y - xn).max(axis=1)
    assert (err <= step + 1e-6).all()


def test_quant_integer_codes():
    rng = np.random.default_rng(1)
    x = _f32(rng, 8, 32)
    q, _, _ = q_k.quantize(x)
    qn = np.array(q)
    assert (qn == np.round(qn)).all()
    assert qn.min() >= -128 and qn.max() <= 127


def test_quant_constant_row_is_stable():
    """max == min degenerate row must not produce NaN/inf."""
    x = jnp.full((2, 16), 3.25, jnp.float32)
    y = np.array(q_k.quant_dequant(x))
    assert np.isfinite(y).all()
