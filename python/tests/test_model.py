"""L2 model semantics: forward shapes, mask algebra, cache-boundary
equivalences, and the per-mechanism behaviours the paper relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile.config import CONFIGS, GPT2T, TINYLLAMA_T

BOTH = pytest.mark.parametrize("cfg", [GPT2T, TINYLLAMA_T], ids=lambda c: c.name)


def _setup(cfg, b=2, s=24, seed=0):
    params = P.init_params(cfg, seed)
    rng = np.random.RandomState(seed)
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    mask = jnp.ones((b, s), jnp.float32)
    return params, tok, mask


@BOTH
def test_forward_shapes(cfg):
    params, tok, mask = _setup(cfg)
    logits, ys = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="base")
    assert logits.shape == (2, 24, cfg.vocab)
    assert np.isfinite(np.array(logits)).all()


@BOTH
def test_eval_with_zero_masks_equals_base(cfg):
    """The eval path with all masks off is bit-identical to the baseline
    forward — the single-artifact-many-variants design rests on this."""
    params, tok, mask = _setup(cfg)
    lb, _ = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="base")
    le, _ = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="eval")
    np.testing.assert_array_equal(np.array(lb), np.array(le))


@BOTH
def test_compression_changes_logits(cfg):
    params, tok, mask = _setup(cfg)
    kv = M.baseline_kvcfg(cfg)
    lb, _ = M.forward(cfg, params, tok, mask, kv, mode="eval")
    kv2 = dict(kv, compress=jnp.ones((cfg.n_layer,), jnp.float32))
    lc, _ = M.forward(cfg, params, tok, mask, kv2, mode="eval")
    assert np.abs(np.array(lb) - np.array(lc)).max() > 1e-4


@BOTH
def test_quant_flag_changes_compressed_logits_only(cfg):
    params, tok, mask = _setup(cfg)
    kv_c = dict(M.baseline_kvcfg(cfg), compress=jnp.ones((cfg.n_layer,)))
    kv_cq = dict(kv_c, quant=jnp.float32(1.0))
    lc, _ = M.forward(cfg, params, tok, mask, kv_c, mode="eval")
    lq, _ = M.forward(cfg, params, tok, mask, kv_cq, mode="eval")
    assert np.abs(np.array(lc) - np.array(lq)).max() > 0  # quant perturbs
    # without compression the latents never exist: quant flag is inert
    kv_q = dict(M.baseline_kvcfg(cfg), quant=jnp.float32(1.0))
    lb, _ = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="eval")
    lbq, _ = M.forward(cfg, params, tok, mask, kv_q, mode="eval")
    np.testing.assert_array_equal(np.array(lb), np.array(lbq))


@BOTH
def test_padded_positions_do_not_poison_loss(cfg):
    params, tok, _ = _setup(cfg)
    mask = jnp.ones((2, 24), jnp.float32).at[:, 10:].set(0.0)
    kv = dict(M.baseline_kvcfg(cfg), compress=jnp.ones((cfg.n_layer,)))
    logits, _ = M.forward(cfg, params, tok, mask, kv, mode="eval")
    nll, ntok = M.per_seq_nll(logits, tok, mask)
    assert np.isfinite(np.array(nll)).all()
    assert np.array(ntok).tolist() == [9.0, 9.0]


@BOTH
def test_padding_invariance(cfg):
    """Valid-position logits must not depend on what the padding holds."""
    params, tok, _ = _setup(cfg)
    mask = jnp.ones((2, 24), jnp.float32).at[:, 12:].set(0.0)
    tok2 = tok.at[:, 12:].set(0)
    kv = dict(M.baseline_kvcfg(cfg), compress=jnp.ones((cfg.n_layer,)))
    l1, _ = M.forward(cfg, params, tok, mask, kv, mode="eval")
    l2, _ = M.forward(cfg, params, tok2, mask, kv, mode="eval")
    np.testing.assert_allclose(
        np.array(l1[:, :12]), np.array(l2[:, :12]), rtol=1e-5, atol=1e-5
    )


@BOTH
def test_reuse_layer0_row_is_inert_guard(cfg):
    """Reusing into layer 0 (no previous layer) blends against the zero
    carry — callers must keep row 0 at zero; verify nonzero row 0 changes
    the output so rust-side validation is justified."""
    params, tok, mask = _setup(cfg)
    kv = M.baseline_kvcfg(cfg)
    l0, _ = M.forward(cfg, params, tok, mask, kv, mode="eval")
    bad = dict(kv, reuse_k=kv["reuse_k"].at[0, 0].set(1.0))
    l1, _ = M.forward(cfg, params, tok, mask, bad, mode="eval")
    assert np.abs(np.array(l0) - np.array(l1)).max() > 0


@BOTH
def test_reuse_of_identical_layer_is_lossless(cfg):
    """If layer l's K/V projections are copied from layer l-1 and the
    residual stream were identical, reuse would be exact; here we check the
    mechanism directly: with reuse masks on, layer l attends with layer
    l-1's stored tensors (logit delta is nonzero vs baseline but zero when
    the stored tensors coincide by construction)."""
    params, tok, mask = _setup(cfg)
    # make layer 1 K/V projections identical to layer 0 AND make layer 1's
    # input equal layer 0's input by zeroing layer 0's output projections.
    base = dict(params["base"])
    for k in ("wk", "wv", "bk", "bv") if cfg.arch == "gpt2" else ("wk", "wv"):
        base[k] = base[k].at[1].set(base[k][0])
    zero_like = lambda a: a.at[0].set(jnp.zeros_like(a[0]))
    base["wo"] = zero_like(base["wo"])
    if cfg.arch == "gpt2":
        base["bo"] = zero_like(base["bo"])
        base["mlp_w2"] = zero_like(base["mlp_w2"])
        base["mlp_b2"] = zero_like(base["mlp_b2"])
    else:
        base["w_down"] = zero_like(base["w_down"])
    p2 = {"base": base, "ae": params["ae"]}
    kv = M.baseline_kvcfg(cfg)
    l_noreuse, _ = M.forward(cfg, p2, tok, mask, kv, mode="eval")
    full = dict(
        kv,
        reuse_k=kv["reuse_k"].at[1].set(1.0),
        reuse_v=kv["reuse_v"].at[1].set(1.0),
    )
    l_reuse, _ = M.forward(cfg, p2, tok, mask, full, mode="eval")
    np.testing.assert_allclose(
        np.array(l_noreuse), np.array(l_reuse), rtol=1e-5, atol=1e-5
    )


@BOTH
def test_stats_mode_detects_identical_adjacent_layers(cfg):
    """kv_stats L1 distance for a layer whose K/V equals the previous
    layer's must be ~0 — the signal Alg. 2's threshold keys on."""
    params, tok, mask = _setup(cfg)
    base = dict(params["base"])
    for k in ("wk", "wv", "bk", "bv") if cfg.arch == "gpt2" else ("wk", "wv"):
        base[k] = base[k].at[1].set(base[k][0])
    zero_like = lambda a: a.at[0].set(jnp.zeros_like(a[0]))
    base["wo"] = zero_like(base["wo"])
    if cfg.arch == "gpt2":
        base["bo"] = zero_like(base["bo"])
        base["mlp_w2"] = zero_like(base["mlp_w2"])
        base["mlp_b2"] = zero_like(base["mlp_b2"])
    else:
        base["w_down"] = zero_like(base["w_down"])
    p2 = {"base": base, "ae": params["ae"]}
    dk, dv = M.make_kv_stats(cfg)(p2, tok, mask)
    dk, dv = np.array(dk), np.array(dv)
    assert dk[1].max() < 1e-5 and dv[1].max() < 1e-5
    assert dk[2:].min() > 1e-3  # other layers genuinely differ


@BOTH
def test_per_seq_nll_manual(cfg):
    params, tok, mask = _setup(cfg, b=1, s=8)
    logits, _ = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="base")
    nll, ntok = M.per_seq_nll(logits, tok, mask)
    lp = jax.nn.log_softmax(logits, axis=-1)
    manual = -sum(float(lp[0, t, tok[0, t + 1]]) for t in range(7))
    assert abs(float(nll[0]) - manual) < 1e-3
    assert float(ntok[0]) == 7.0


@BOTH
def test_ae_train_mode_uses_batch_stats(cfg):
    """ae_train BN uses batch stats: corrupting running stats must not
    change the ae_train forward, but must change the eval forward."""
    params, tok, mask = _setup(cfg)
    kv = dict(M.baseline_kvcfg(cfg), compress=jnp.ones((cfg.n_layer,)))
    p_bad = jax.tree.map(lambda x: x, params)
    p_bad["ae"]["k"]["enc"]["bn_mean"] = (
        params["ae"]["k"]["enc"]["bn_mean"] + 100.0
    )
    for mode, should_change in (("ae_train", False), ("eval", True)):
        l1, _ = M.forward(cfg, params, tok, mask, kv, mode=mode)
        l2, _ = M.forward(cfg, p_bad, tok, mask, kv, mode=mode)
        delta = np.abs(np.array(l1) - np.array(l2)).max()
        assert (delta > 1e-3) == should_change, (mode, delta)


def test_configs_registry():
    assert set(CONFIGS) == {"gpt2t", "tinyllama_t"}
    for c in CONFIGS.values():
        c.validate()
        assert c.latent_ratio == 0.5  # paper's factor-of-two setting
