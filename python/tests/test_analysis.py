"""hlo_analysis: the L2 profiling tool parses real artifacts sensibly."""

import os

import pytest

from compile import hlo_analysis as HA
from .conftest import ARTIFACTS

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


def test_shape_parsing():
    assert HA.shape_elems("") == 1
    assert HA.shape_elems("8,128,64") == 8 * 128 * 64
    assert HA.first_shape("f32[8,128]{1,0}") == ("f32", [8, 128])
    assert HA.first_shape("(s32[], f32[2,3]{1,0})") == ("s32", [])


def test_analyze_synthetic_module():
    text = """HloModule test
ENTRY main {
  p0 = f32[8,16]{1,0} parameter(0)
  p1 = f32[16,32]{1,0} parameter(1)
  dot.1 = f32[8,32]{1,0} dot(p0, p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT add.2 = f32[8,32]{1,0} add(dot.1, dot.1)
}
"""
    r = HA.analyze_text(text)
    assert r["ops"]["parameter"] == 2
    assert r["ops"]["dot"] == 1
    assert r["dot_flops"] == 2 * 8 * 32 * 16
    assert r["param_bytes"] == (8 * 16 + 16 * 32) * 4
    assert r["fusible_elementwise"] == 1


@needs_artifacts
def test_real_artifacts_have_flops_and_scans():
    import json

    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    entry = man["entries"]["gpt2t_eval_loss"]
    r = HA.analyze_text(open(os.path.join(ARTIFACTS, entry["file"])).read())
    assert r["while_loops"] >= 1, "layer scan should lower to a while loop"
    assert r["dot_flops"] > 1e8, r["dot_flops"]
    assert r["param_bytes"] > 1 << 20


@needs_artifacts
def test_train_step_costs_more_than_eval():
    import json

    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    cost = {}
    for name in ("gpt2t_eval_loss", "gpt2t_train_step"):
        path = os.path.join(ARTIFACTS, man["entries"][name]["file"])
        cost[name] = HA.analyze_text(open(path).read())["dot_flops"]
    # fwd+bwd ~3x fwd in dot flops (NB: while-body flops count once here;
    # both entries scan the same number of layers so the comparison holds)
    assert cost["gpt2t_train_step"] > 1.5 * cost["gpt2t_eval_loss"]
