"""Additional L2 semantics: GQA head mapping, RoPE properties, tied
embeddings, aux-loss bookkeeping — behaviours the rust coordinator's
correctness silently depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile.config import GPT2T, TINYLLAMA_T
from compile.kernels import ref


def test_gqa_group_mapping_in_ref_attention():
    """Query heads h use KV head h // group_size: perturbing KV head 0
    must affect exactly query heads 0..group_size-1."""
    rng = np.random.RandomState(0)
    s, hq, hkv, dh = 6, 4, 2, 8
    q = jnp.asarray(rng.randn(s, hq, dh).astype(np.float32))
    k = jnp.asarray(rng.randn(s, hkv, dh).astype(np.float32))
    v = jnp.asarray(rng.randn(s, hkv, dh).astype(np.float32))
    m = jnp.ones((s,), jnp.float32)
    base = ref.causal_attention(q, k, v, group_size=2, length_mask=m)
    v2 = v.at[:, 0, :].add(10.0)
    out = ref.causal_attention(q, k, v2, group_size=2, length_mask=m)
    delta = np.abs(np.array(out - base)).max(axis=(0, 2))  # per query head
    assert delta[0] > 1.0 and delta[1] > 1.0
    assert delta[2] < 1e-5 and delta[3] < 1e-5


def test_rope_preserves_norm_and_relative_scores():
    """RoPE is a rotation (norm preserved) and q.k depends only on the
    position difference."""
    rng = np.random.RandomState(1)
    dh = 32
    x = jnp.asarray(rng.randn(1, dh).astype(np.float32))
    y = jnp.asarray(rng.randn(1, dh).astype(np.float32))
    for pos in [0, 3, 17]:
        cos, sin = ref.rope_angles(jnp.array([pos]), dh)
        xr = ref.apply_rope(x[None], cos[:, None, :], sin[:, None, :])[0]
        np.testing.assert_allclose(
            np.linalg.norm(np.array(xr)), np.linalg.norm(np.array(x)), rtol=1e-5
        )
    # relative property: <R_a x, R_b y> == <R_{a+d} x, R_{b+d} y>
    def score(pa, pb):
        ca, sa = ref.rope_angles(jnp.array([pa]), dh)
        cb, sb = ref.rope_angles(jnp.array([pb]), dh)
        xr = ref.apply_rope(x[None], ca[:, None, :], sa[:, None, :])[0]
        yr = ref.apply_rope(y[None], cb[:, None, :], sb[:, None, :])[0]
        return float(jnp.sum(xr * yr))

    assert abs(score(2, 5) - score(10, 13)) < 1e-3
    assert abs(score(0, 4) - score(7, 11)) < 1e-3
    # and genuinely position-dependent
    assert abs(score(2, 5) - score(2, 9)) > 1e-3


@pytest.mark.parametrize("cfg", [GPT2T, TINYLLAMA_T], ids=lambda c: c.name)
def test_tied_embeddings(cfg):
    """Logits head is wte^T: doubling a token's embedding row doubles its
    logit everywhere."""
    params = P.init_params(cfg, 0)
    rng = np.random.RandomState(2)
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)
    mask = jnp.ones((1, 8), jnp.float32)
    l1, _ = M.forward(cfg, params, tok, mask, M.baseline_kvcfg(cfg), mode="base")
    target = 123  # token id not in the input (embeddings unaffected)
    assert int((np.array(tok) == target).sum()) == 0
    p2 = jax.tree.map(lambda x: x, params)
    p2["base"]["wte"] = params["base"]["wte"].at[target].multiply(2.0)
    l2, _ = M.forward(cfg, p2, tok, mask, M.baseline_kvcfg(cfg), mode="base")
    r = np.array(l2[..., target]) / np.array(l1[..., target])
    np.testing.assert_allclose(r, 2.0, rtol=1e-4)
    others = np.abs(np.array(l2) - np.array(l1))
    others[..., target] = 0
    assert others.max() < 1e-5


@pytest.mark.parametrize("cfg", [GPT2T], ids=lambda c: c.name)
def test_aux_losses_gated_by_masks(cfg):
    params = P.init_params(cfg, 0)
    rng = np.random.RandomState(3)
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (2, 16)), jnp.int32)
    mask = jnp.ones((2, 16), jnp.float32)
    kv = M.baseline_kvcfg(cfg)
    _, ys = M.forward(cfg, params, tok, mask, kv, mode="eval")
    assert float(jnp.sum(ys["l1_k"])) == 0.0  # no compression -> no recon loss
    assert float(jnp.sum(ys["l1_rk"])) == 0.0  # no reuse -> no reuse loss
    kv2 = dict(kv, compress=jnp.ones((cfg.n_layer,)))
    _, ys2 = M.forward(cfg, params, tok, mask, kv2, mode="eval")
    assert float(jnp.sum(ys2["l1_k"])) > 0.0
    assert np.all(np.array(ys2["l1_k"]) > 0)
    kv3 = dict(kv, reuse_k=kv["reuse_k"].at[2].set(1.0))
    _, ys3 = M.forward(cfg, params, tok, mask, kv3, mode="eval")
    l1_rk = np.array(ys3["l1_rk"])
    assert l1_rk[2] > 0 and l1_rk[1] == 0 and l1_rk[3] == 0


def test_quant_dequant_idempotent_on_grid():
    """Values already on the quantization grid survive exactly."""
    x = jnp.linspace(-1.0, 1.0, 256).reshape(1, 256)
    y = ref.quant_dequant(x)
    z = ref.quant_dequant(y)
    np.testing.assert_allclose(np.array(y), np.array(z), atol=1e-6)
