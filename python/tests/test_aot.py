"""AOT bridge invariants: params binary format round-trip and, when
artifacts have been built, manifest consistency (the contract the rust
runtime parses)."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import params as P
from compile.config import CONFIGS
from .conftest import ARTIFACTS


def test_params_bin_roundtrip(tmp_path):
    cfg = CONFIGS["gpt2t"]
    params = P.init_params(cfg, 42)
    b, j = str(tmp_path / "p.bin"), str(tmp_path / "p.json")
    P.save_params(params, b, j)
    loaded = P.load_params(params, b)
    for (n1, l1), (n2, l2) in zip(P.flat_entries(params), P.flat_entries(loaded)):
        assert n1 == n2
        np.testing.assert_array_equal(np.array(l1), np.array(l2))
    idx = json.load(open(j))
    assert idx["total_bytes"] == os.path.getsize(b)
    names = [e["name"] for e in idx["params"]]
    assert len(names) == len(set(names))
    assert all(n.startswith(("base/", "ae/")) for n in names)


def test_flat_entries_deterministic_order():
    cfg = CONFIGS["tinyllama_t"]
    p1 = P.init_params(cfg, 0)
    p2 = P.init_params(cfg, 1)
    assert [n for n, _ in P.flat_entries(p1)] == [n for n, _ in P.flat_entries(p2)]


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_structure():
    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    assert man["version"] == 1
    assert set(man["models"]) == {"gpt2t", "tinyllama_t"}
    for name, entry in man["entries"].items():
        assert os.path.exists(os.path.join(ARTIFACTS, entry["file"])), name
        for io in entry["inputs"] + entry["outputs"]:
            assert io["dtype"] in ("float32", "int32"), (name, io)
            assert all(isinstance(d, int) for d in io["shape"])


@needs_artifacts
def test_manifest_entry_set_complete():
    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for m, mj in man["models"].items():
        expected = {
            f"{m}_{e}"
            for e in (
                "train_step ae_train_step reuse_ft_step eval_loss kv_stats "
                "prefill prefill_base encode_kv decode_kv"
            ).split()
        }
        expected |= {f"{m}_decode_step_b{b}" for b in mj["decode_batches"]}
        assert expected <= set(man["entries"]), m


@needs_artifacts
def test_manifest_params_match_bin():
    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for m, mj in man["models"].items():
        idx = json.load(open(os.path.join(ARTIFACTS, mj["params_index"])))
        size = os.path.getsize(os.path.join(ARTIFACTS, mj["params_bin"]))
        assert idx["total_bytes"] == size
        # every train-step input named base/* or ae/* exists in the index
        names = {e["name"] for e in idx["params"]}
        ts = man["entries"][f"{m}_train_step"]
        for io in ts["inputs"]:
            if io["name"].startswith(("base/", "ae/")):
                assert io["name"] in names, io["name"]


@needs_artifacts
def test_hlo_text_is_parseable_header():
    man = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for name, entry in man["entries"].items():
        head = open(os.path.join(ARTIFACTS, entry["file"])).read(200)
        assert head.startswith("HloModule"), name
