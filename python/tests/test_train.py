"""Training-step invariants for Algorithms 1 and 2 (paper §IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile import train as T
from compile.config import GPT2T, TINYLLAMA_T

BOTH = pytest.mark.parametrize("cfg", [GPT2T, TINYLLAMA_T], ids=lambda c: c.name)


def _batch(cfg, b=4, s=24, seed=0):
    rng = np.random.RandomState(seed)
    # low-entropy synthetic data so a few steps visibly reduce loss
    tok = np.tile(np.arange(s) % 7, (b, 1)) + rng.randint(0, 3, (b, s))
    tok = jnp.asarray(tok % cfg.vocab, jnp.int32)
    return tok, jnp.ones((b, s), jnp.float32)


@BOTH
def test_base_training_reduces_loss(cfg):
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_train_step(cfg))
    m, v = T.zeros_like_tree(base), T.zeros_like_tree(base)
    step = jnp.int32(0)
    losses = []
    for _ in range(8):
        base, m, v, step, loss = fn(base, ae, m, v, step, tok, mask, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses
    assert int(step) == 8


@BOTH
def test_ae_step_freezes_unselected_layers_exactly(cfg):
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_ae_train_step(cfg))
    m, v = T.zeros_like_tree(ae), T.zeros_like_tree(ae)
    gmask = jnp.zeros((cfg.n_layer,)).at[2].set(1.0)
    ae2, m, v, step, loss, ce, rec = fn(
        base, ae, m, v, jnp.int32(0), tok, mask, gmask, jnp.float32(0.1), jnp.float32(1e-3)
    )
    for name, leaf_old in P.flat_entries(ae):
        leaf_new = dict(P.flat_entries(ae2))[name]
        old, new = np.array(leaf_old), np.array(leaf_new)
        np.testing.assert_array_equal(old[0], new[0], err_msg=name)  # frozen
        np.testing.assert_array_equal(old[3:], new[3:], err_msg=name)
    # the selected layer's encoder weights moved
    d = np.abs(np.array(ae2["k"]["enc"]["w1"][2]) - np.array(ae["k"]["enc"]["w1"][2]))
    assert d.max() > 0


@BOTH
def test_ae_step_updates_bn_stats_only_on_selected_layer(cfg):
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_ae_train_step(cfg))
    m, v = T.zeros_like_tree(ae), T.zeros_like_tree(ae)
    gmask = jnp.zeros((cfg.n_layer,)).at[1].set(1.0)
    ae2, *_ = fn(
        base, ae, m, v, jnp.int32(0), tok, mask, gmask, jnp.float32(0.1), jnp.float32(1e-3)
    )
    for t in ("k", "v"):
        for half in ("enc", "dec"):
            mean_old = np.array(ae[t][half]["bn_mean"])
            mean_new = np.array(ae2[t][half]["bn_mean"])
            assert np.abs(mean_new[1] - mean_old[1]).max() > 0, (t, half)
            np.testing.assert_array_equal(mean_new[0], mean_old[0])


@BOTH
def test_ae_staged_training_reduces_reconstruction(cfg):
    """Alg. 1 stage 1 on one layer: the scaled-L1 reconstruction term must
    fall over a handful of steps."""
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_ae_train_step(cfg))
    m, v = T.zeros_like_tree(ae), T.zeros_like_tree(ae)
    gmask = jnp.zeros((cfg.n_layer,)).at[0].set(1.0)
    step = jnp.int32(0)
    recs = []
    for _ in range(10):
        ae, m, v, step, loss, ce, rec = fn(
            base, ae, m, v, step, tok, mask, gmask, jnp.float32(1.0), jnp.float32(3e-3)
        )
        recs.append(float(rec))
    assert recs[-1] < recs[0] * 0.9, recs


@BOTH
def test_reuse_ft_freezes_ae_and_moves_base(cfg):
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_reuse_ft_step(cfg))
    m, v = T.zeros_like_tree(base), T.zeros_like_tree(base)
    rk = jnp.zeros((cfg.n_layer, cfg.n_kv_head)).at[1].set(1.0)
    base2, m, v, step, loss, ce, rl1 = fn(
        base, ae, m, v, jnp.int32(0), tok, mask,
        jnp.zeros((cfg.n_layer,)), rk, rk, jnp.float32(0.1), jnp.float32(1e-3),
    )
    assert float(rl1) > 0
    d = np.abs(np.array(base2["wq"]) - np.array(base["wq"]))
    assert d.max() > 0


@BOTH
def test_adam_bias_correction_first_step_magnitude(cfg):
    """After one Adam step with lr, |update| ~= lr for nonzero grads
    (bias-corrected first moment / sqrt second moment ~= sign(g))."""
    params = P.init_params(cfg, 0)
    base, ae = params["base"], params["ae"]
    tok, mask = _batch(cfg)
    fn = jax.jit(T.make_train_step(cfg))
    m, v = T.zeros_like_tree(base), T.zeros_like_tree(base)
    lr = 1e-3
    base2, *_ = fn(base, ae, m, v, jnp.int32(0), tok, mask, jnp.float32(lr))
    d = np.abs(np.array(base2["wte"]) - np.array(base["wte"]))
    moved = d[d > 0]
    assert moved.size > 0
    np.testing.assert_allclose(moved.max(), lr, rtol=0.05)


def test_zeros_like_tree():
    t = {"a": jnp.ones((2, 3)), "b": {"c": jnp.ones((4,))}}
    z = T.zeros_like_tree(t)
    assert float(jnp.sum(z["a"])) == 0.0 and z["b"]["c"].shape == (4,)
