"""L2 training steps (paper Algorithms 1 and 2), AOT'd and driven from rust.

Three step functions, each lowered to one HLO artifact; the rust training
driver (``rust/src/train/``) owns the loop, data, schedules, and
checkpoints, and calls these as pure (state, batch, hyper) -> state
transitions:

* ``train_step``    — base-LM pretraining (builds the frozen "pretrained
                      model" Alg. 1 starts from).
* ``ae_train_step`` — Alg. 1: CE + lambda * scaled-L1 reconstruction loss;
                      the per-layer ``gmask`` gates which layers' AEs are
                      (a) active in the forward, (b) gradient-updated, and
                      (c) BN-EMA-updated.  Stage 1 = one-hot masks driven
                      layer-by-layer from rust; stage 2 = the selected set.
* ``reuse_ft_step`` — Alg. 2: CE + lambda * scaled-L1 between actual and
                      reused K/V; base params finetuned, AEs frozen.

Optimizer is Adam (beta1=0.9, beta2=0.999); lr and lambda are runtime
scalars so rust owns the schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .config import ModelConfig

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
BN_MOMENTUM = 0.1


def adam_update(grads, m, v, step, lr):
    """One Adam step over a pytree. ``step`` is the new (1-based) count."""
    t = step.astype(jnp.float32)
    c1 = 1.0 - ADAM_B1**t
    c2 = 1.0 - ADAM_B2**t
    new_m = jax.tree.map(lambda mm, g: ADAM_B1 * mm + (1 - ADAM_B1) * g, m, grads)
    new_v = jax.tree.map(lambda vv, g: ADAM_B2 * vv + (1 - ADAM_B2) * g * g, v, grads)
    upd = jax.tree.map(
        lambda mm, vv: lr * (mm / c1) / (jnp.sqrt(vv / c2) + ADAM_EPS),
        new_m,
        new_v,
    )
    return upd, new_m, new_v


def mean_ce(logits, tokens, len_mask):
    nll, ntok = M.per_seq_nll(logits, tokens, len_mask)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(ntok), 1.0)


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# base pretraining
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig):
    """(base, m, v, step i32, tokens [B,S], len_mask [B,S], lr) ->
    (base', m', v', step', loss)."""
    ae_dummy = None  # forward in "base" mode never touches AE params

    def loss_fn(base, ae, tokens, len_mask):
        params = {"base": base, "ae": ae}
        logits, _ = M.forward(
            cfg, params, tokens, len_mask, M.baseline_kvcfg(cfg), mode="base"
        )
        return mean_ce(logits, tokens, len_mask)

    def train_step(base, ae, m, v, step, tokens, len_mask, lr):
        loss, grads = jax.value_and_grad(loss_fn)(base, ae, tokens, len_mask)
        new_step = step + 1
        upd, m, v = adam_update(grads, m, v, new_step, lr)
        base = jax.tree.map(lambda p, u: p - u, base, upd)
        return base, m, v, new_step, loss

    return train_step


# ---------------------------------------------------------------------------
# Algorithm 1: autoencoder training (staged, mask-driven)
# ---------------------------------------------------------------------------


def _gmask_tree(ae, gmask):
    """Broadcast the per-layer grad mask over every AE leaf ([L, ...])."""
    return jax.tree.map(
        lambda p: gmask.reshape((-1,) + (1,) * (p.ndim - 1)), ae
    )


def make_ae_train_step(cfg: ModelConfig):
    """(base, ae, m, v, step, tokens, len_mask, gmask [L], lam, lr) ->
    (ae', m', v', step', loss, ce, rec).

    Base params are frozen (never updated); AE params are updated only on
    layers where gmask = 1.  BN running stats get an EMA update from the
    batch stats actually used, gated by the same mask.
    """

    def loss_fn(ae, base, tokens, len_mask, gmask, lam):
        params = {"base": base, "ae": ae}
        kvcfg = {
            "compress": gmask,
            "quant": jnp.float32(0.0),
            "reuse_k": jnp.zeros((cfg.n_layer, cfg.n_kv_head), jnp.float32),
            "reuse_v": jnp.zeros((cfg.n_layer, cfg.n_kv_head), jnp.float32),
        }
        logits, ys = M.forward(
            cfg, params, tokens, len_mask, kvcfg, mode="ae_train"
        )
        ce = mean_ce(logits, tokens, len_mask)
        rec = jnp.sum(ys["l1_k"] + ys["l1_v"])  # already gated by compress
        return ce + lam * rec, (ce, rec, ys["bn"])

    def ae_train_step(base, ae, m, v, step, tokens, len_mask, gmask, lam, lr):
        (loss, (ce, rec, bn)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            ae, base, tokens, len_mask, gmask, lam
        )
        new_step = step + 1
        upd, m, v = adam_update(grads, m, v, new_step, lr)
        gm = _gmask_tree(ae, gmask)
        ae = jax.tree.map(lambda p, u, g: p - g * u, ae, upd, gm)
        # EMA on BN running stats, gated per layer
        gcol = gmask[:, None]
        for t in ("k", "v"):
            for half in ("enc", "dec"):
                mean_b, var_b = bn[t][half]
                node = ae[t][half]
                node["bn_mean"] = node["bn_mean"] + gcol * BN_MOMENTUM * (
                    mean_b - node["bn_mean"]
                )
                node["bn_var"] = node["bn_var"] + gcol * BN_MOMENTUM * (
                    var_b - node["bn_var"]
                )
        return ae, m, v, new_step, loss, ce, rec

    return ae_train_step


# ---------------------------------------------------------------------------
# Algorithm 2: inter-layer reuse finetuning
# ---------------------------------------------------------------------------


def make_reuse_ft_step(cfg: ModelConfig):
    """(base, ae, m, v, step, tokens, len_mask, compress [L],
    reuse_k [L,Hkv], reuse_v [L,Hkv], lam, lr) ->
    (base', m', v', step', loss, ce, rl1).

    Finetunes the base model under fixed reuse masks (and, for the
    combined Table-IV configuration, fixed trained AEs) with the paper's
    CE + scaled-L1(actual vs reused) objective.  AEs are frozen.
    """

    def loss_fn(base, ae, tokens, len_mask, compress, reuse_k, reuse_v, lam):
        params = {"base": base, "ae": jax.lax.stop_gradient(ae)}
        kvcfg = {
            "compress": compress,
            "quant": jnp.float32(0.0),
            "reuse_k": reuse_k,
            "reuse_v": reuse_v,
        }
        logits, ys = M.forward(cfg, params, tokens, len_mask, kvcfg, mode="eval")
        ce = mean_ce(logits, tokens, len_mask)
        rl1 = jnp.sum(ys["l1_rk"] + ys["l1_rv"])
        return ce + lam * rl1, (ce, rl1)

    def reuse_ft_step(
        base, ae, m, v, step, tokens, len_mask, compress, reuse_k, reuse_v, lam, lr
    ):
        (loss, (ce, rl1)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            base, ae, tokens, len_mask, compress, reuse_k, reuse_v, lam
        )
        new_step = step + 1
        upd, m, v = adam_update(grads, m, v, new_step, lr)
        base = jax.tree.map(lambda p, u: p - u, base, upd)
        return base, m, v, new_step, loss, ce, rl1

    return reuse_ft_step
