"""L2 profiling: static analysis of the lowered HLO artifacts.

Parses the HLO text the AOT pipeline emits and reports, per entry point:
op histogram, dot FLOPs per call (resolved through an instruction table,
including inside while/fusion subcomputations), parameter and
intermediate buffer bytes, and while-loop (scan) structure.  This is the
"JAX tracer / HLO cost analysis" half of the performance pass
(DESIGN.md §8); EXPERIMENTS.md §Perf quotes its output.

Usage:
    python -m compile.hlo_analysis [--artifacts ../artifacts] [--entry NAME]
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter

# one instruction: "  name = <type> opname(operands...), attrs"
INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?([\w.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\((.*)$"
)
SHAPE_RE = re.compile(r"(f32|s32|pred|u32|s8|bf16)\[([\d,]*)\]")


def shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def first_shape(type_str: str):
    """(dtype, dims list) of the first array shape in a type string."""
    m = SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def analyze_text(text: str) -> dict:
    ops = Counter()
    # name -> dims (first shape of the result type; enough for dot args)
    shapes: dict[str, list[int]] = {}
    instrs = []
    for line in text.splitlines():
        m = INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        ops[op] += 1
        fs = first_shape(type_str)
        if fs:
            shapes[name] = fs[1]
        instrs.append((name, type_str, op, rest))

    flops = 0
    for name, type_str, op, rest in instrs:
        if op != "dot":
            continue
        out = first_shape(type_str)
        cm = re.search(r"lhs_contracting_dims=\{(\d+)\}", rest)
        lhs_name = rest.split(",")[0].strip().lstrip("%")
        lhs = shapes.get(lhs_name)
        if out and cm and lhs:
            cdim = int(cm.group(1))
            if cdim < len(lhs):
                flops += 2 * shape_elems(",".join(map(str, out[1]))) * lhs[cdim]

    param_bytes = 0
    inter_bytes = 0
    for name, type_str, op, rest in instrs:
        fs = first_shape(type_str)
        if not fs:
            continue
        nbytes = shape_elems(",".join(map(str, fs[1]))) * (
            4 if fs[0] in ("f32", "s32", "u32") else 2 if fs[0] == "bf16" else 1
        )
        if op == "parameter":
            param_bytes += nbytes
        else:
            inter_bytes += nbytes

    return {
        "ops": dict(ops),
        "total_ops": sum(ops.values()),
        "dot_flops": flops,
        "param_bytes": param_bytes,
        "intermediate_bytes": inter_bytes,
        "while_loops": ops.get("while", 0),
        "dots": ops.get("dot", 0),
        "fusible_elementwise": sum(
            ops.get(k, 0)
            for k in (
                "add", "multiply", "subtract", "divide", "maximum", "minimum",
                "exponential", "tanh", "rsqrt", "select", "compare",
            )
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--entry", default=None, help="single entry point")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    man = json.load(open(os.path.join(args.artifacts, "manifest.json")))
    entries = man["entries"]
    names = [args.entry] if args.entry else sorted(entries)
    results = {}
    for name in names:
        path = os.path.join(args.artifacts, entries[name]["file"])
        results[name] = analyze_text(open(path).read())
    if args.json:
        print(json.dumps(results, indent=1))
        return
    print(
        f"{'entry':<34}{'ops':>7}{'while':>7}{'dots':>6}{'MFLOP/iter':>12}"
        f"{'params MiB':>12}{'fusible':>9}"
    )
    for name, r in results.items():
        print(
            f"{name:<34}{r['total_ops']:>7}{r['while_loops']:>7}{r['dots']:>6}"
            f"{r['dot_flops'] / 1e6:>12.2f}{r['param_bytes'] / 2**20:>12.2f}"
            f"{r['fusible_elementwise']:>9}"
        )


if __name__ == "__main__":
    main()
