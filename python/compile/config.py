"""Model and compression configuration for the KV-CAR reproduction.

Two tiny-but-real model families mirror the paper's GPT-2 / TinyLlama
pairing (see DESIGN.md §3 for the substitution rationale):

* ``gpt2t``      — GPT-2-style: learned positional embeddings, LayerNorm,
                   GELU MLP, MHA (n_kv_head == n_head), tied embeddings.
* ``tinyllama_t``— TinyLlama-style: RoPE, RMSNorm, SwiGLU MLP, GQA
                   (n_kv_head < n_head), tied embeddings.

The paper-scale configs (``GPT2_774M``, ``TINYLLAMA_1_1B``) are used only
by the rust memory simulator for Figs. 2-3; they are never instantiated
as weights here.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture + KV-CAR hyper-parameters for one model."""

    name: str
    arch: str  # "gpt2" | "llama"
    vocab: int
    n_layer: int
    d_model: int
    n_head: int
    n_kv_head: int
    d_head: int
    ffn_dim: int
    max_seq: int
    # --- KV-CAR autoencoder (paper §IV-A): kv_dim -> ae_hidden -> ae_latent
    ae_hidden: int
    ae_latent: int
    # --- training shapes baked into the AOT'd step artifacts
    train_batch: int = 8
    eval_batch: int = 8
    # decode artifacts are compiled per batch size
    decode_batches: tuple = (1, 8)

    @property
    def kv_dim(self) -> int:
        """Width of the K (or V) tensor that enters the cache per token."""
        return self.n_kv_head * self.d_head

    @property
    def q_dim(self) -> int:
        return self.n_head * self.d_head

    @property
    def group_size(self) -> int:
        """Query heads per KV head (1 for MHA, >1 for GQA)."""
        assert self.n_head % self.n_kv_head == 0
        return self.n_head // self.n_kv_head

    @property
    def latent_ratio(self) -> float:
        """Per-layer KV-cache compression from the autoencoder alone."""
        return self.ae_latent / self.kv_dim

    def validate(self) -> "ModelConfig":
        assert self.arch in ("gpt2", "llama"), self.arch
        assert self.d_model == self.n_head * self.d_head
        assert self.n_head % self.n_kv_head == 0
        assert 0 < self.ae_latent < self.kv_dim
        assert self.ae_hidden >= self.ae_latent
        return self


# Tiny trained-from-scratch stand-ins (DESIGN.md §3).  ae_latent = kv_dim/2
# reproduces the paper's "compress key and value vectors by a factor of
# two" setting.
GPT2T = ModelConfig(
    name="gpt2t",
    arch="gpt2",
    vocab=256,
    n_layer=8,
    d_model=128,
    n_head=4,
    n_kv_head=4,
    d_head=32,
    ffn_dim=512,
    max_seq=128,
    ae_hidden=96,
    ae_latent=64,
).validate()

TINYLLAMA_T = ModelConfig(
    name="tinyllama_t",
    arch="llama",
    vocab=256,
    n_layer=6,
    d_model=128,
    n_head=4,
    n_kv_head=2,
    d_head=32,
    ffn_dim=352,
    max_seq=128,
    ae_hidden=48,
    ae_latent=32,
).validate()

CONFIGS = {c.name: c for c in (GPT2T, TINYLLAMA_T)}


def config_to_json(cfg: ModelConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["kv_dim"] = cfg.kv_dim
    d["q_dim"] = cfg.q_dim
    d["group_size"] = cfg.group_size
    d["decode_batches"] = list(cfg.decode_batches)
    return d
