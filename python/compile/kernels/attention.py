"""Pallas attention kernels for the KV-CAR serving path.

Two kernels, mirroring the paper's decode-phase dataflow (Fig. 1):

* ``causal_attention`` — prefill: full causal self-attention, grid over
  query heads.  On TPU each grid step streams one head's K/V panel
  HBM->VMEM (the threadblock tiling a GPU flash kernel would use becomes
  the BlockSpec over heads here; S<=128 keeps the SxS score tile at 64 KiB,
  so no online-softmax pass is needed at this scale).
* ``decode_attention`` — one query token against the (reconstructed) KV
  cache, grid over query heads with a length mask — this is the kernel on
  the rust hot path via the ``decode_step`` artifact.

GQA is expressed in the index_map: query head h reads KV head
``h // group_size``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _causal_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale: float):
    # blocks: q [S, 1, dh], k/v [S, 1, dh], m [S] -> o [S, 1, dh]
    q = q_ref[:, 0, :]
    k = k_ref[:, 0, :]
    v = v_ref[:, 0, :]
    s = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    neg = jnp.finfo(scores.dtype).min
    keep = (cols <= rows) & (m_ref[...][None, :] > 0)
    scores = jnp.where(keep, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    o_ref[:, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size",))
def causal_attention(q, k, v, length_mask, *, group_size: int = 1):
    """q: [S, Hq, dh], k/v: [S, Hkv, dh], length_mask: [S] -> [S, Hq, dh]."""
    s, hq, dh = q.shape
    scale = 1.0 / (dh**0.5)
    return pl.pallas_call(
        functools.partial(_causal_kernel, scale=scale),
        grid=(hq,),
        in_specs=[
            pl.BlockSpec((s, 1, dh), lambda h: (0, h, 0)),
            pl.BlockSpec((s, 1, dh), lambda h, g=group_size: (0, h // g, 0)),
            pl.BlockSpec((s, 1, dh), lambda h, g=group_size: (0, h // g, 0)),
            pl.BlockSpec((s,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((s, 1, dh), lambda h: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hq, dh), q.dtype),
        interpret=True,
    )(q, k, v, length_mask)


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale: float):
    # blocks: q [1, dh], k/v [S, 1, dh], m [S] -> o [1, dh]
    q = q_ref[0, :]
    k = k_ref[:, 0, :]
    v = v_ref[:, 0, :]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(m_ref[...] > 0, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    o_ref[0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size",))
def decode_attention(q, k, v, length_mask, *, group_size: int = 1):
    """q: [Hq, dh], k/v: [S, Hkv, dh], length_mask: [S] -> [Hq, dh]."""
    hq, dh = q.shape
    s = k.shape[0]
    scale = 1.0 / (dh**0.5)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(hq,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda h: (h, 0)),
            pl.BlockSpec((s, 1, dh), lambda h, g=group_size: (0, h // g, 0)),
            pl.BlockSpec((s, 1, dh), lambda h, g=group_size: (0, h // g, 0)),
            pl.BlockSpec((s,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, dh), q.dtype),
        interpret=True,
    )(q, k, v, length_mask)


def _decode_batched_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale: float):
    # blocks: q [1, 1, dh], k/v [1, S, 1, dh], m [1, S] -> o [1, 1, dh]
    q = q_ref[0, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(m_ref[0, :] > 0, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    o_ref[0, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group_size",))
def decode_attention_batched(q, k, v, length_mask, *, group_size: int = 1):
    """Batched decode attention — the rust serving hot path's kernel.

    q: [B, Hq, dh], k/v: [B, S, Hkv, dh], length_mask: [B, S]
    -> [B, Hq, dh].  Grid (B, Hq); each step streams one sequence's one
    KV-head panel (S x dh) through VMEM.
    """
    b, hq, dh = q.shape
    s = k.shape[1]
    scale = 1.0 / (dh**0.5)
    return pl.pallas_call(
        functools.partial(_decode_batched_kernel, scale=scale),
        grid=(b, hq),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda i, h, g=group_size: (i, 0, h // g, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda i, h, g=group_size: (i, 0, h // g, 0)),
            pl.BlockSpec((1, s), lambda i, h: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i, h: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        interpret=True,
    )(q, k, v, length_mask)
