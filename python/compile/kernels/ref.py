"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package has an exact counterpart here; pytest
(``python/tests/``) asserts allclose between the two across a hypothesis
shape/seed sweep.  The differentiable L2 model (``compile.model``) is built
on these refs so that training steps never need a Pallas VJP, while the
inference entry points call the Pallas kernels and are verified equivalent
through these same functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.01  # LeakyReLU negative slope (paper §IV-A autoencoder)
BN_EPS = 1e-5
Q_LEVELS = 255.0  # Eq. 4 int8 affine range


# ---------------------------------------------------------------------------
# basic blocks
# ---------------------------------------------------------------------------


def linear(x, w, b=None):
    """x @ w (+ b). x: [..., In], w: [In, Out], b: [Out]."""
    y = jnp.matmul(x, w)
    if b is not None:
        y = y + b
    return y


def layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def rmsnorm(x, g, eps=1e-6):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return x * jax.nn.sigmoid(x)


def leaky_relu(x, slope=LEAKY_SLOPE):
    return jnp.where(x >= 0, x, slope * x)


# ---------------------------------------------------------------------------
# rotary position embeddings (llama arch)
# ---------------------------------------------------------------------------


def rope_angles(positions, d_head, base=10000.0):
    """positions: [...]; returns (cos, sin) of shape [..., d_head//2]."""
    half = d_head // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., H, d_head]; cos/sin: broadcastable [..., 1, d_head//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention (oracles for the Pallas kernels)
# ---------------------------------------------------------------------------


def causal_attention(q, k, v, *, group_size=1, length_mask=None):
    """Causal self-attention.

    q: [S, Hq, dh], k/v: [S, Hkv, dh]; GQA maps query head h -> kv head
    h // group_size.  length_mask: [S] 1.0 for valid positions.
    Returns [S, Hq, dh].
    """
    s, hq, dh = q.shape
    kk = jnp.repeat(k, group_size, axis=1)  # [S, Hq, dh]
    vv = jnp.repeat(v, group_size, axis=1)
    scores = jnp.einsum("qhd,khd->hqk", q, kk) / jnp.sqrt(jnp.float32(dh))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(causal[None, :, :], scores, neg)
    if length_mask is not None:
        scores = jnp.where(length_mask[None, None, :] > 0, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, vv)


def decode_attention(q, k, v, *, group_size=1, length_mask=None):
    """Single-token decode attention.

    q: [Hq, dh], k/v: [S, Hkv, dh], length_mask: [S] (1.0 = attendable,
    must include the current position).  Returns [Hq, dh].
    """
    _, dh = q.shape
    kk = jnp.repeat(k, group_size, axis=1)
    vv = jnp.repeat(v, group_size, axis=1)
    scores = jnp.einsum("hd,khd->hk", q, kk) / jnp.sqrt(jnp.float32(dh))
    if length_mask is not None:
        neg = jnp.finfo(scores.dtype).min
        scores = jnp.where(length_mask[None, :] > 0, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hk,khd->hd", p, vv)


# ---------------------------------------------------------------------------
# KV-CAR autoencoder (paper §IV-A): FC -> BatchNorm -> LeakyReLU -> FC
# ---------------------------------------------------------------------------


def bn_apply(x, gamma, beta, mean, var, eps=BN_EPS):
    """Inference-mode batch norm over the feature axis with given stats."""
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def bn_batch_stats(x):
    """Batch statistics over all leading axes. x: [..., F] -> ([F], [F])."""
    flat = x.reshape(-1, x.shape[-1])
    return jnp.mean(flat, axis=0), jnp.var(flat, axis=0)


def ae_half_apply(x, p, *, train=False):
    """One autoencoder half (encoder or decoder): FC -> BN -> LeakyReLU -> FC.

    ``p`` is a dict with w1, b1, bn_g, bn_b, bn_mean, bn_var, w2, b2.
    Returns (y, (mean, var)) — the statistics actually used (batch stats in
    train mode, running stats otherwise) so the caller can maintain the EMA.
    """
    h = linear(x, p["w1"], p["b1"])
    if train:
        mean, var = bn_batch_stats(h)
    else:
        mean, var = p["bn_mean"], p["bn_var"]
    h = bn_apply(h, p["bn_g"], p["bn_b"], mean, var)
    h = leaky_relu(h)
    y = linear(h, p["w2"], p["b2"])
    return y, (mean, var)


def ae_encode(x, enc, *, train=False):
    """[..., kv_dim] -> [..., ae_latent]."""
    return ae_half_apply(x, enc, train=train)


def ae_decode(z, dec, *, train=False):
    """[..., ae_latent] -> [..., kv_dim]."""
    return ae_half_apply(z, dec, train=train)


def ae_roundtrip(x, enc, dec, *, train=False, quant=None):
    """Encode -> (optional int8 sim) -> decode. Returns (recon, stats)."""
    z, est = ae_encode(x, enc, train=train)
    if quant is not None:
        z = jnp.where(quant > 0, quant_dequant(z), z)
    y, dst = ae_decode(z, dec, train=train)
    return y, (est, dst)


# ---------------------------------------------------------------------------
# Eq. 4 int8 affine quantization (per-vector over the last axis)
# ---------------------------------------------------------------------------


def quant_params(x):
    """Per-row scale/zeropoint per Eq. 4. x: [..., F]."""
    xmax = jnp.max(x, axis=-1, keepdims=True)
    xmin = jnp.min(x, axis=-1, keepdims=True)
    scale = Q_LEVELS / jnp.maximum(xmax - xmin, 1e-8)
    zeropoint = -jnp.round(scale * xmin) - 128.0
    return scale, zeropoint


def quantize(x):
    """Returns (q int8-valued f32 in [-128, 127], scale, zeropoint)."""
    scale, zeropoint = quant_params(x)
    q = jnp.clip(jnp.round(scale * x + zeropoint), -128.0, 127.0)
    return q, scale, zeropoint


def dequantize(q, scale, zeropoint):
    return (q - zeropoint) / scale


def quant_dequant(x):
    q, s, z = quantize(x)
    return dequantize(q, s, z)
