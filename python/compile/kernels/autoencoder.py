"""Fused KV-CAR autoencoder Pallas kernel (paper §IV-A).

One kernel evaluates a full autoencoder *half* — ``FC -> BatchNorm(stats) ->
LeakyReLU -> FC`` — per row-block of tokens, so the intermediate hidden
activation never leaves VMEM.  The encoder instance maps ``kv_dim ->
ae_hidden -> ae_latent`` and the decoder ``ae_latent -> ae_hidden ->
kv_dim``; both use inference-mode BatchNorm with running statistics (the
EMA is maintained by the training step on the jnp path — kernels are
inference-only, see ref.py docstring).

VMEM per grid step (f32): bm*(In + H + Out) + In*H + H*Out + 4H + H + Out
floats.  For the gpt2t encoder (In=128, H=96, Out=64, bm=128) that is
~230 KiB; the weight tiles are resident across the row grid so on a real
TPU the HBM traffic is one pass over the tokens, which is what makes the
compress-on-store path cheap relative to the attention GEMMs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ae_half_kernel(
    x_ref, w1_ref, b1_ref, g_ref, be_ref, mu_ref, var_ref, w2_ref, b2_ref, o_ref
):
    h = jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...]
    inv = jax.lax.rsqrt(var_ref[...] + ref.BN_EPS)
    h = (h - mu_ref[...]) * inv * g_ref[...] + be_ref[...]
    h = jnp.where(h >= 0, h, ref.LEAKY_SLOPE * h)
    o_ref[...] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def ae_half(x, w1, b1, bn_g, bn_b, bn_mean, bn_var, w2, b2, *, bm: int = 128):
    """Apply one autoencoder half to a batch of vectors.

    x: [M, In]; returns [M, Out].  M must be a multiple of ``bm`` (or
    smaller than it, in which case the whole batch is one block).
    """
    m, d_in = x.shape
    d_hidden = w1.shape[1]
    d_out = w2.shape[1]
    bm = m if m <= bm else bm
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: (0,) * len(dims))
    return pl.pallas_call(
        _ae_half_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_in), lambda i: (i, 0)),
            full(d_in, d_hidden),
            full(d_hidden),
            full(d_hidden),
            full(d_hidden),
            full(d_hidden),
            full(d_hidden),
            full(d_hidden, d_out),
            full(d_out),
        ],
        out_specs=pl.BlockSpec((bm, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=True,
    )(x, w1, b1, bn_g, bn_b, bn_mean, bn_var, w2, b2)


def ae_half_from_dict(x, p, *, bm: int = 128):
    """Dict-parameter convenience wrapper matching ``ref.ae_half_apply``."""
    return ae_half(
        x,
        p["w1"],
        p["b1"],
        p["bn_g"],
        p["bn_b"],
        p["bn_mean"],
        p["bn_var"],
        p["w2"],
        p["b2"],
        bm=bm,
    )
