"""Eq. 4 int8 affine quantization as Pallas kernels.

Per-vector (last axis) min/max affine quantization of latent KV vectors,
exactly the formulation in the paper's §IV-C.  Elementwise VPU work; the
grid blocks rows so the kernel composes with the autoencoder kernel's
row-block schedule (on TPU the quant epilogue would fuse into the encoder
kernel's flush — kept separate here so the rust cache manager can also
call it standalone via the ``encode_kv``/``decode_kv`` artifacts).

The quantized code is carried as f32 holding integer values in [-128, 127]:
the PJRT interchange stays single-dtype and the rust cache packs it to real
i8 bytes for storage (``rust/src/compress/quant.rs`` mirrors this exactly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import Q_LEVELS


def _quant_kernel(x_ref, q_ref, s_ref, z_ref):
    x = x_ref[...]
    xmax = jnp.max(x, axis=-1, keepdims=True)
    xmin = jnp.min(x, axis=-1, keepdims=True)
    scale = Q_LEVELS / jnp.maximum(xmax - xmin, 1e-8)
    zp = -jnp.round(scale * xmin) - 128.0
    q_ref[...] = jnp.clip(jnp.round(scale * x + zp), -128.0, 127.0)
    s_ref[...] = scale[:, 0]
    z_ref[...] = zp[:, 0]


@functools.partial(jax.jit, static_argnames=("bm",))
def quantize(x, *, bm: int = 256):
    """x: [M, F] -> (q [M, F], scale [M], zeropoint [M])."""
    m, f = x.shape
    bm = m if m <= bm else bm
    assert m % bm == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, f), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, f), x.dtype),
            jax.ShapeDtypeStruct((m,), x.dtype),
            jax.ShapeDtypeStruct((m,), x.dtype),
        ),
        interpret=True,
    )(x)


def _dequant_kernel(q_ref, s_ref, z_ref, o_ref):
    o_ref[...] = (q_ref[...] - z_ref[...][:, None]) / s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("bm",))
def dequantize(q, scale, zeropoint, *, bm: int = 256):
    """Inverse of :func:`quantize`."""
    m, f = q.shape
    bm = m if m <= bm else bm
    assert m % bm == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, f), q.dtype),
        interpret=True,
    )(q, scale, zeropoint)


def quant_dequant(x, *, bm: int = 256):
    q, s, z = quantize(x, bm=bm)
    return dequantize(q, s, z, bm=bm)
