"""Tiled linear (matmul + bias) Pallas kernel.

The workhorse GEMM used by the fused autoencoder kernel's building blocks
and exercised directly by the kernel test-suite.  Tiling is expressed with
BlockSpecs over (rows, cols, reduction) so the same kernel body targets the
MXU on real TPUs; on this CPU image it always runs with ``interpret=True``
(Mosaic custom-calls are not executable on the CPU PJRT plugin — see
DESIGN.md §4).

VMEM budget per grid step (f32): bm*bk + bk*bn + bm*bn + bn floats.  With
the default 128x128x128 tiles that is 3*64 KiB + 512 B ≈ 192 KiB, well
under the ~16 MiB/core VMEM of TPU v4/v5 and MXU-shaped (128x128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Grid (rows, cols, k): accumulate x_tile @ w_tile into acc scratch."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...] + b_ref[...]


def _pick(block: int, dim: int) -> int:
    return dim if dim <= block else block


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def linear(x, w, b=None, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """``x @ w + b`` with 2-D output. x: [M, K], w: [K, N], b: [N] or None."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((n,), dtype=x.dtype)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        "tile sizes must divide dims",
        (m, n, k),
        (bm, bn, bk),
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_linear_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bn,), lambda i, j, ki: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, b)
