"""Parameter initialization and flat binary I/O for the AOT bridge.

Parameters are nested dicts of f32 arrays with all per-layer tensors
*stacked along a leading layer axis* (the MaxText idiom): the transformer
body is a single ``lax.scan`` over that axis, which keeps the HLO small and
the PJRT argument count manageable.

The rust runtime loads the same parameters from ``artifacts/{m}_params.bin``
(concatenated little-endian f32 buffers) + ``{m}_params.json`` (name, shape,
offset — in ``jax.tree_util`` flatten order, which rust re-sorts by name).
Checkpoints written by the rust training driver use the identical format.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def _norm(key, shape, std=0.02):
    return jax.random.normal(key, shape, dtype=jnp.float32) * std


def init_base(cfg: ModelConfig, key) -> dict:
    """Initialize the base transformer (GPT-2 or Llama arch)."""
    L, D, F = cfg.n_layer, cfg.d_model, cfg.ffn_dim
    qd, kvd, S, V = cfg.q_dim, cfg.kv_dim, cfg.max_seq, cfg.vocab
    ks = iter(jax.random.split(key, 32))
    wo_std = 0.02 / np.sqrt(2.0 * L)
    p = {
        "wte": _norm(next(ks), (V, D)),
        "wq": _norm(next(ks), (L, D, qd)),
        "wk": _norm(next(ks), (L, D, kvd)),
        "wv": _norm(next(ks), (L, D, kvd)),
        "wo": _norm(next(ks), (L, qd, D), std=wo_std),
    }
    if cfg.arch == "gpt2":
        p.update(
            {
                "wpe": _norm(next(ks), (S, D)),
                "bq": jnp.zeros((L, qd)),
                "bk": jnp.zeros((L, kvd)),
                "bv": jnp.zeros((L, kvd)),
                "bo": jnp.zeros((L, D)),
                "ln1_g": jnp.ones((L, D)),
                "ln1_b": jnp.zeros((L, D)),
                "ln2_g": jnp.ones((L, D)),
                "ln2_b": jnp.zeros((L, D)),
                "lnf_g": jnp.ones((D,)),
                "lnf_b": jnp.zeros((D,)),
                "mlp_w1": _norm(next(ks), (L, D, F)),
                "mlp_b1": jnp.zeros((L, F)),
                "mlp_w2": _norm(next(ks), (L, F, D), std=wo_std),
                "mlp_b2": jnp.zeros((L, D)),
            }
        )
    else:  # llama
        p.update(
            {
                "rms1_g": jnp.ones((L, D)),
                "rms2_g": jnp.ones((L, D)),
                "rmsf_g": jnp.ones((D,)),
                "w_gate": _norm(next(ks), (L, D, F)),
                "w_up": _norm(next(ks), (L, D, F)),
                "w_down": _norm(next(ks), (L, F, D), std=wo_std),
            }
        )
    return p


def _init_ae_half(key, l, d_in, d_hidden, d_out) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": _norm(k1, (l, d_in, d_hidden), std=1.0 / np.sqrt(d_in)),
        "b1": jnp.zeros((l, d_hidden)),
        "bn_g": jnp.ones((l, d_hidden)),
        "bn_b": jnp.zeros((l, d_hidden)),
        "bn_mean": jnp.zeros((l, d_hidden)),
        "bn_var": jnp.ones((l, d_hidden)),
        "w2": _norm(k2, (l, d_hidden, d_out), std=1.0 / np.sqrt(d_hidden)),
        "b2": jnp.zeros((l, d_out)),
    }


def init_ae(cfg: ModelConfig, key) -> dict:
    """Per-layer K and V autoencoders (paper §IV-A), stacked over layers."""
    L, kvd, H, dl = cfg.n_layer, cfg.kv_dim, cfg.ae_hidden, cfg.ae_latent
    kk, kv = jax.random.split(key)
    out = {}
    for name, k in (("k", kk), ("v", kv)):
        ke, kd = jax.random.split(k)
        out[name] = {
            "enc": _init_ae_half(ke, L, kvd, H, dl),
            "dec": _init_ae_half(kd, L, dl, H, kvd),
        }
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    kb, ka = jax.random.split(jax.random.PRNGKey(seed))
    return {"base": init_base(cfg, kb), "ae": init_ae(cfg, ka)}


# ---------------------------------------------------------------------------
# flat I/O (shared format with rust/src/runtime/params.rs)
# ---------------------------------------------------------------------------


def flat_entries(tree):
    """[(name, leaf)] in jax flatten order; names like base/wq, ae/k/enc/w1."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append((name, leaf))
    return out


def save_params(tree, bin_path: str, json_path: str) -> None:
    entries = flat_entries(tree)
    index, offset = [], 0
    with open(bin_path, "wb") as f:
        for name, leaf in entries:
            arr = np.asarray(leaf, dtype=np.float32)
            f.write(arr.tobytes())
            index.append(
                {"name": name, "shape": list(arr.shape), "offset": offset}
            )
            offset += arr.nbytes
    with open(json_path, "w") as f:
        json.dump({"total_bytes": offset, "params": index}, f, indent=1)


def load_params(tree_like, bin_path: str) -> dict:
    """Load a params.bin written by save_params (or the rust driver)."""
    entries = flat_entries(tree_like)
    raw = np.fromfile(bin_path, dtype=np.float32)
    leaves, offset = [], 0
    for _, leaf in entries:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        leaves.append(jnp.asarray(raw[offset : offset + n].reshape(leaf.shape)))
        offset += n
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
