"""L2: the KV-CAR transformer forward pass and serving entry points.

One scan-over-layers forward implements every paper mechanism behind
runtime-controlled masks, so a *single* AOT artifact per entry point serves
baseline and all compressed variants:

* ``compress`` [L]        — per-layer AE round-trip of K/V at the cache
                            boundary (paper §IV-A).
* ``quant``    []         — Eq. 4 int8 sim applied to the latents.
* ``reuse_k/v`` [L, Hkv]  — per-(layer, head) cross-layer reuse: head h of
                            layer l reads layer l-1's *stored* tensor
                            (paper §IV-A second optimization).  Row 0 must
                            be zero.

Cache-boundary semantics follow Fig. 1 exactly: a token's *own* K/V enters
its layer's attention raw (concatenated after the decoded cache), while
every *past* token is seen through the store transform (AE round-trip /
reuse).  In the batched eval forward this shows up as a diagonal
correction on the score/output matrices; ``decode_step`` gets it for free
by appending the raw row to the reconstructed cache.

Training-mode forwards run on the jnp refs (differentiable); the decode
hot path (``decode_step``) runs on the Pallas kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention as attn_pallas
from .kernels import autoencoder as ae_pallas
from .kernels import ref

MODES = ("base", "eval", "ae_train", "stats")


# ---------------------------------------------------------------------------
# attention with cache-boundary (self-raw) semantics
# ---------------------------------------------------------------------------


def _attn_eval(q, k_eff, v_eff, k_cur, v_cur, *, group_size, len_mask):
    """Causal attention where past keys come from the store transform.

    q: [B,S,Hq,dh]; k_eff/v_eff: stored (transformed) K/V [B,S,Hkv,dh];
    k_cur/v_cur: what each token's own position contributes to *its own*
    layer's attention.  len_mask: [B,S].
    """
    b, s, hq, dh = q.shape
    g = group_size
    rep = lambda x: jnp.repeat(x, g, axis=2)
    kk, vv, kc, vc = rep(k_eff), rep(v_eff), rep(k_cur), rep(v_cur)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * scale
    self_scores = jnp.einsum("bqhd,bqhd->bhq", q, kc) * scale
    eye = jnp.eye(s, dtype=scores.dtype)
    scores = scores * (1.0 - eye) + self_scores[..., None] * eye
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    # The diagonal stays attendable even at padded positions so padded rows
    # never softmax over an all-masked set (NaN poison through 0*NaN).
    keep = causal & ((len_mask[:, None, None, :] > 0) | eye.astype(bool))
    scores = jnp.where(keep, scores, jnp.finfo(scores.dtype).min)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    p_diag = jnp.diagonal(p, axis1=-2, axis2=-1)  # [B,Hq,S]
    out = out + jnp.einsum("bhq,bqhd->bqhd", p_diag, vc - vv)
    return out


def _masked_mean_l1(diff, len_mask):
    """mean |diff| over valid positions. diff: [B,S,...], len_mask: [B,S]."""
    red = tuple(range(2, diff.ndim))
    per_pos = jnp.mean(jnp.abs(diff), axis=red)  # [B,S]
    denom = jnp.maximum(jnp.sum(len_mask), 1.0)
    return jnp.sum(per_pos * len_mask) / denom


def _per_head_l1(k_raw, k_prev, len_mask):
    """Mean |k_l - k_{l-1}| per KV head over valid positions -> [Hkv]."""
    diff = jnp.mean(jnp.abs(k_raw - k_prev), axis=-1)  # [B,S,Hkv]
    denom = jnp.maximum(jnp.sum(len_mask), 1.0)
    return jnp.sum(diff * len_mask[:, :, None], axis=(0, 1)) / denom


# ---------------------------------------------------------------------------
# forward core (scan over layers)
# ---------------------------------------------------------------------------

_PER_LAYER_GPT2 = (
    "wq wk wv wo bq bk bv bo ln1_g ln1_b ln2_g ln2_b "
    "mlp_w1 mlp_b1 mlp_w2 mlp_b2"
).split()
_PER_LAYER_LLAMA = "wq wk wv wo rms1_g rms2_g w_gate w_up w_down".split()


def per_layer_keys(cfg: ModelConfig):
    return _PER_LAYER_GPT2 if cfg.arch == "gpt2" else _PER_LAYER_LLAMA


def forward(cfg, params, tokens, len_mask, kvcfg, *, mode="eval", collect=()):
    """Run the model; returns (logits [B,S,V], aux dict of per-layer ys).

    kvcfg: {"compress": [L], "quant": [], "reuse_k": [L,Hkv],
    "reuse_v": [L,Hkv]} — store transform skipped in mode "base"/"stats".
    collect ⊆ {"kv_raw", "kv_lat", "kv_eff"} adds cache tensors to aux.
    """
    assert mode in MODES, mode
    base, ae = params["base"], params["ae"]
    b, s = tokens.shape
    hkv, dh, g = cfg.n_kv_head, cfg.d_head, cfg.group_size
    kvd = cfg.kv_dim

    h = base["wte"][tokens]
    positions = jnp.arange(s)
    if cfg.arch == "gpt2":
        h = h + base["wpe"][:s][None, :, :]
        cos = sin = None
    else:
        cos, sin = ref.rope_angles(positions, dh)  # [S, dh/2]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]

    xs = {k: base[k] for k in per_layer_keys(cfg)}
    xs["ae"] = ae
    xs["compress"] = kvcfg["compress"]
    xs["reuse_k"] = kvcfg["reuse_k"]
    xs["reuse_v"] = kvcfg["reuse_v"]
    quant = kvcfg["quant"]
    transform = mode in ("eval", "ae_train")
    bn_train = mode == "ae_train"

    def body(carry, lp):
        h, k_prev, v_prev = carry
        if cfg.arch == "gpt2":
            xn = ref.layernorm(h, lp["ln1_g"], lp["ln1_b"])
            q = (xn @ lp["wq"] + lp["bq"]).reshape(b, s, cfg.n_head, dh)
            k_raw = (xn @ lp["wk"] + lp["bk"]).reshape(b, s, hkv, dh)
            v_raw = (xn @ lp["wv"] + lp["bv"]).reshape(b, s, hkv, dh)
        else:
            xn = ref.rmsnorm(h, lp["rms1_g"])
            q = (xn @ lp["wq"]).reshape(b, s, cfg.n_head, dh)
            k_raw = (xn @ lp["wk"]).reshape(b, s, hkv, dh)
            v_raw = (xn @ lp["wv"]).reshape(b, s, hkv, dh)
            q = ref.apply_rope(q, cos, sin)
            k_raw = ref.apply_rope(k_raw, cos, sin)

        aux = {}
        kf = k_raw.reshape(b, s, kvd)
        vf = v_raw.reshape(b, s, kvd)
        if transform:
            c = lp["compress"]
            zk, (k_em, k_ev) = ref.ae_encode(kf, lp["ae"]["k"]["enc"], train=bn_train)
            zv, (v_em, v_ev) = ref.ae_encode(vf, lp["ae"]["v"]["enc"], train=bn_train)
            zk_q = jnp.where(quant > 0, ref.quant_dequant(zk), zk)
            zv_q = jnp.where(quant > 0, ref.quant_dequant(zv), zv)
            k_rec, (k_dm, k_dv) = ref.ae_decode(
                zk_q, lp["ae"]["k"]["dec"], train=bn_train
            )
            v_rec, (v_dm, v_dv) = ref.ae_decode(
                zv_q, lp["ae"]["v"]["dec"], train=bn_train
            )
            if bn_train:
                # stats actually used this step, for the EMA (gated later
                # by the per-layer grad mask in train.ae_train_step).
                aux["bn"] = {
                    "k": {"enc": (k_em, k_ev), "dec": (k_dm, k_dv)},
                    "v": {"enc": (v_em, v_ev), "dec": (v_dm, v_dv)},
                }
            k_store = c * k_rec + (1.0 - c) * kf
            v_store = c * v_rec + (1.0 - c) * vf
            aux["l1_k"] = c * _masked_mean_l1(k_rec - kf, len_mask)
            aux["l1_v"] = c * _masked_mean_l1(v_rec - vf, len_mask)
            if "kv_lat" in collect:
                aux["k_lat"] = zk
                aux["v_lat"] = zv
        else:
            k_store, v_store = kf, vf
            aux["l1_k"] = jnp.float32(0.0)
            aux["l1_v"] = jnp.float32(0.0)

        k_store_h = k_store.reshape(b, s, hkv, dh)
        v_store_h = v_store.reshape(b, s, hkv, dh)

        if mode == "stats":
            aux["dk"] = _per_head_l1(k_raw, k_prev, len_mask)
            aux["dv"] = _per_head_l1(v_raw, v_prev, len_mask)
            carry_k, carry_v = k_raw, v_raw
            k_eff, v_eff, k_cur, v_cur = k_store_h, v_store_h, k_raw, v_raw
        else:
            rk = lp["reuse_k"][None, None, :, None]
            rv = lp["reuse_v"][None, None, :, None]
            k_eff = rk * k_prev + (1.0 - rk) * k_store_h
            v_eff = rv * v_prev + (1.0 - rv) * v_store_h
            k_cur = rk * k_prev + (1.0 - rk) * k_raw
            v_cur = rv * v_prev + (1.0 - rv) * v_raw
            aux["l1_rk"] = _masked_mean_l1(rk * (k_prev - k_store_h), len_mask)
            aux["l1_rv"] = _masked_mean_l1(rv * (v_prev - v_store_h), len_mask)
            carry_k, carry_v = k_eff, v_eff

        if "kv_raw" in collect:
            aux["k_raw"] = kf
            aux["v_raw"] = vf
        if "kv_eff" in collect:
            aux["k_eff"] = k_eff.reshape(b, s, kvd)
            aux["v_eff"] = v_eff.reshape(b, s, kvd)

        att = _attn_eval(
            q, k_eff, v_eff, k_cur, v_cur, group_size=g, len_mask=len_mask
        )
        att = att.reshape(b, s, cfg.q_dim)
        if cfg.arch == "gpt2":
            h = h + att @ lp["wo"] + lp["bo"]
            xn2 = ref.layernorm(h, lp["ln2_g"], lp["ln2_b"])
            mlp = ref.gelu(xn2 @ lp["mlp_w1"] + lp["mlp_b1"])
            h = h + mlp @ lp["mlp_w2"] + lp["mlp_b2"]
        else:
            h = h + att @ lp["wo"]
            xn2 = ref.rmsnorm(h, lp["rms2_g"])
            mlp = ref.silu(xn2 @ lp["w_gate"]) * (xn2 @ lp["w_up"])
            h = h + mlp @ lp["w_down"]
        return (h, carry_k, carry_v), aux

    zeros_kv = jnp.zeros((b, s, hkv, dh), dtype=h.dtype)
    (h, _, _), ys = jax.lax.scan(body, (h, zeros_kv, zeros_kv), xs)

    if cfg.arch == "gpt2":
        h = ref.layernorm(h, base["lnf_g"], base["lnf_b"])
    else:
        h = ref.rmsnorm(h, base["rmsf_g"])
    logits = h @ base["wte"].T
    return logits, ys


# ---------------------------------------------------------------------------
# losses / configs
# ---------------------------------------------------------------------------


def per_seq_nll(logits, tokens, len_mask):
    """Next-token NLL summed per sequence. Returns (nll [B], ntok [B])."""
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = len_mask[:, 1:]
    return -jnp.sum(ll * mask, axis=-1), jnp.sum(mask, axis=-1)


def baseline_kvcfg(cfg: ModelConfig):
    return {
        "compress": jnp.zeros((cfg.n_layer,), jnp.float32),
        "quant": jnp.float32(0.0),
        "reuse_k": jnp.zeros((cfg.n_layer, cfg.n_kv_head), jnp.float32),
        "reuse_v": jnp.zeros((cfg.n_layer, cfg.n_kv_head), jnp.float32),
    }


# ---------------------------------------------------------------------------
# entry points (lowered by aot.py)
# ---------------------------------------------------------------------------


def make_eval_loss(cfg: ModelConfig):
    """(params, tokens [B,S], len_mask [B,S], kvcfg) -> (nll [B], ntok [B])."""

    def eval_loss(params, tokens, len_mask, kvcfg):
        logits, _ = forward(cfg, params, tokens, len_mask, kvcfg, mode="eval")
        return per_seq_nll(logits, tokens, len_mask)

    return eval_loss


def make_kv_stats(cfg: ModelConfig):
    """(params, tokens, len_mask) -> (dk [L,Hkv], dv [L,Hkv]); row 0 is the
    (meaningless) distance to a zero carry and is ignored by rust."""

    def kv_stats(params, tokens, len_mask):
        _, ys = forward(
            cfg, params, tokens, len_mask, baseline_kvcfg(cfg), mode="stats"
        )
        return ys["dk"], ys["dv"]

    return kv_stats


def make_prefill(cfg: ModelConfig):
    """Prompt pass with store-transform semantics (matches eval ppl path).

    (params, tokens [1,S], len_mask [1,S], last i32, kvcfg) ->
    (logits_last [V], k_raw/v_raw [L,S,kvd], k_lat/v_lat [L,S,dl],
     k_eff/v_eff [L,S,kvd])
    """

    def prefill(params, tokens, len_mask, last, kvcfg):
        logits, ys = forward(
            cfg,
            params,
            tokens,
            len_mask,
            kvcfg,
            mode="eval",
            collect=("kv_raw", "kv_lat", "kv_eff"),
        )
        squeeze = lambda a: a[:, 0]  # [L,1,S,*] -> [L,S,*]
        return (
            logits[0, last, :],
            squeeze(ys["k_raw"]),
            squeeze(ys["v_raw"]),
            squeeze(ys["k_lat"]),
            squeeze(ys["v_lat"]),
            squeeze(ys["k_eff"]),
            squeeze(ys["v_eff"]),
        )

    return prefill


def make_prefill_b(cfg: ModelConfig, batch: int):
    """Cross-request batched prefill: one launch per admission wave.

    (params, tokens [B,S], len_mask [B,S], last [B] i32, kvcfg) ->
    (logits_last [B,V], k_raw/v_raw [B,L,S,kvd], k_lat/v_lat [B,L,S,dl],
     k_eff/v_eff [B,L,S,kvd])

    Each lane b is one request's prompt, padded to S with zeros and
    masked by its row of ``len_mask`` (``last[b] = plen_b - 1``).  The
    store transform, reuse resolution, and attention are all per-lane
    maps — ``len_mask`` keeps padded rows out of every cross-position
    reduction and ``_attn_eval`` keeps the diagonal attendable so dead
    lanes (all-zero mask) stay NaN-free — so lane b of the batched call
    is **bit-identical** to a ``{m}_prefill`` call on that request
    alone (asserted in ``python/tests/test_decode_parity.py``).  That
    is the contract that lets the rust scheduler admit a whole wave
    through one launch and still match sequential prefill bitwise.
    """
    b = batch

    def prefill_b(params, tokens, len_mask, last, kvcfg):
        logits, ys = forward(
            cfg,
            params,
            tokens,
            len_mask,
            kvcfg,
            mode="eval",
            collect=("kv_raw", "kv_lat", "kv_eff"),
        )
        # aux tensors stack as [L, B, S, *]; lanes want [B, L, S, *]
        lanes = lambda a: jnp.transpose(a, (1, 0, 2, 3))
        return (
            logits[jnp.arange(b), last, :],
            lanes(ys["k_raw"]),
            lanes(ys["v_raw"]),
            lanes(ys["k_lat"]),
            lanes(ys["v_lat"]),
            lanes(ys["k_eff"]),
            lanes(ys["v_eff"]),
        )

    return prefill_b


def make_prefill_base(cfg: ModelConfig):
    """Baseline (uncompressed) prefill on the Pallas causal-attention
    kernel — the serving fast path when no store transform is active.

    (base_params, tokens [1,S], len_mask [1,S], last) ->
    (logits_last [V], k_raw [L,S,kvd], v_raw [L,S,kvd])
    """
    b = 1
    hkv, dh, kvd = cfg.n_kv_head, cfg.d_head, cfg.kv_dim

    def prefill_base(base, tokens, len_mask, last):
        s = tokens.shape[1]
        h = base["wte"][tokens]
        if cfg.arch == "gpt2":
            h = h + base["wpe"][:s][None, :, :]
            cos = sin = None
        else:
            cos, sin = ref.rope_angles(jnp.arange(s), dh)
            cos, sin = cos[None, :, None, :], sin[None, :, None, :]

        xs = {k: base[k] for k in per_layer_keys(cfg)}

        def body(h, lp):
            if cfg.arch == "gpt2":
                xn = ref.layernorm(h, lp["ln1_g"], lp["ln1_b"])
                q = (xn @ lp["wq"] + lp["bq"]).reshape(b, s, cfg.n_head, dh)
                k = (xn @ lp["wk"] + lp["bk"]).reshape(b, s, hkv, dh)
                v = (xn @ lp["wv"] + lp["bv"]).reshape(b, s, hkv, dh)
            else:
                xn = ref.rmsnorm(h, lp["rms1_g"])
                q = (xn @ lp["wq"]).reshape(b, s, cfg.n_head, dh)
                k = (xn @ lp["wk"]).reshape(b, s, hkv, dh)
                v = (xn @ lp["wv"]).reshape(b, s, hkv, dh)
                q = ref.apply_rope(q, cos, sin)
                k = ref.apply_rope(k, cos, sin)
            att = attn_pallas.causal_attention(
                q[0], k[0], v[0], len_mask[0], group_size=cfg.group_size
            )[None]
            att = att.reshape(b, s, cfg.q_dim)
            if cfg.arch == "gpt2":
                h = h + att @ lp["wo"] + lp["bo"]
                xn2 = ref.layernorm(h, lp["ln2_g"], lp["ln2_b"])
                mlp = ref.gelu(xn2 @ lp["mlp_w1"] + lp["mlp_b1"])
                h = h + mlp @ lp["mlp_w2"] + lp["mlp_b2"]
            else:
                h = h + att @ lp["wo"]
                xn2 = ref.rmsnorm(h, lp["rms2_g"])
                mlp = ref.silu(xn2 @ lp["w_gate"]) * (xn2 @ lp["w_up"])
                h = h + mlp @ lp["w_down"]
            return h, (k.reshape(b, s, kvd)[0], v.reshape(b, s, kvd)[0])

        h, (ks, vs) = jax.lax.scan(body, h, xs)
        if cfg.arch == "gpt2":
            h = ref.layernorm(h, base["lnf_g"], base["lnf_b"])
        else:
            h = ref.rmsnorm(h, base["rmsf_g"])
        logits = h @ base["wte"].T
        return logits[0, last, :], ks, vs

    return prefill_base


def make_decode_step(cfg: ModelConfig, batch: int):
    """One decode step over the reconstructed effective cache (Pallas path).

    (params, token [B], pos [B], k_cache [B,L,S,kvd], v_cache, kvcfg) ->
    (logits [B,V],
     k_lat/v_lat [B,L,dl]      — latents to store for compressed layers,
     k_raw/v_raw [B,L,kvd]     — raw rows to store for uncompressed layers,
     k_eff/v_eff [B,L,kvd]     — reuse-resolved stored rows: what rust
                                  appends to the effective cache buffers)

    Dataflow per the paper's Fig. 1 decode phase: the cache holds decoded
    (reconstructed) past K/V; the current token's raw row is written at
    ``pos`` before attention (decoded-past + raw-current concatenation).
    """
    b = batch
    hkv, dh, kvd, s = cfg.n_kv_head, cfg.d_head, cfg.kv_dim, cfg.max_seq

    def decode_step(params, token, pos, k_cache, v_cache, kvcfg):
        base, ae = params["base"], params["ae"]
        quant = kvcfg["quant"]
        h = base["wte"][token]  # [B,D]
        if cfg.arch == "gpt2":
            h = h + base["wpe"][pos]
            cos = sin = None
        else:
            cos, sin = ref.rope_angles(pos, dh)  # [B, dh/2]
            cos, sin = cos[:, None, :], sin[:, None, :]

        xs = {k: base[k] for k in per_layer_keys(cfg)}
        xs["ae"] = ae
        xs["compress"] = kvcfg["compress"]
        xs["reuse_k"] = kvcfg["reuse_k"]
        xs["reuse_v"] = kvcfg["reuse_v"]
        xs["k_cache"] = jnp.transpose(k_cache, (1, 0, 2, 3))  # [L,B,S,kvd]
        xs["v_cache"] = jnp.transpose(v_cache, (1, 0, 2, 3))
        att_mask = (
            jax.lax.broadcasted_iota(jnp.int32, (b, s), 1) <= pos[:, None]
        ).astype(jnp.float32)

        def body(carry, lp):
            h, k_sc_prev, v_sc_prev = carry  # [B,Hkv,dh] prev stored-current
            if cfg.arch == "gpt2":
                xn = ref.layernorm(h, lp["ln1_g"], lp["ln1_b"])
                q = (xn @ lp["wq"] + lp["bq"]).reshape(b, cfg.n_head, dh)
                k_raw = (xn @ lp["wk"] + lp["bk"]).reshape(b, hkv, dh)
                v_raw = (xn @ lp["wv"] + lp["bv"]).reshape(b, hkv, dh)
            else:
                xn = ref.rmsnorm(h, lp["rms1_g"])
                q = (xn @ lp["wq"]).reshape(b, cfg.n_head, dh)
                k_raw = (xn @ lp["wk"]).reshape(b, hkv, dh)
                v_raw = (xn @ lp["wv"]).reshape(b, hkv, dh)
                q = ref.apply_rope(q, cos, sin)
                k_raw = ref.apply_rope(k_raw, cos, sin)

            kf = k_raw.reshape(b, kvd)
            vf = v_raw.reshape(b, kvd)
            # store transform on the Pallas AE kernels (inference BN)
            zk = ae_pallas.ae_half_from_dict(kf, lp["ae"]["k"]["enc"])
            zv = ae_pallas.ae_half_from_dict(vf, lp["ae"]["v"]["enc"])
            zk_q = jnp.where(quant > 0, ref.quant_dequant(zk), zk)
            zv_q = jnp.where(quant > 0, ref.quant_dequant(zv), zv)
            k_rec = ae_pallas.ae_half_from_dict(zk_q, lp["ae"]["k"]["dec"])
            v_rec = ae_pallas.ae_half_from_dict(zv_q, lp["ae"]["v"]["dec"])
            c = lp["compress"]
            k_store = (c * k_rec + (1.0 - c) * kf).reshape(b, hkv, dh)
            v_store = (c * v_rec + (1.0 - c) * vf).reshape(b, hkv, dh)

            rk = lp["reuse_k"][None, :, None]
            rv = lp["reuse_v"][None, :, None]
            k_cur = rk * k_sc_prev + (1.0 - rk) * k_raw  # attention row
            v_cur = rv * v_sc_prev + (1.0 - rv) * v_raw
            k_sc = rk * k_sc_prev + (1.0 - rk) * k_store  # stored row
            v_sc = rv * v_sc_prev + (1.0 - rv) * v_store

            # write the current row into the effective cache at pos
            kc = lp["k_cache"].reshape(b, s, hkv, dh)
            vc = lp["v_cache"].reshape(b, s, hkv, dh)
            write = jax.vmap(
                lambda buf, row, p: jax.lax.dynamic_update_slice(
                    buf, row[None], (p, 0, 0)
                )
            )
            kc = write(kc, k_cur, pos)
            vc = write(vc, v_cur, pos)

            att = attn_pallas.decode_attention_batched(
                q, kc, vc, att_mask, group_size=cfg.group_size
            )
            att = att.reshape(b, cfg.q_dim)
            if cfg.arch == "gpt2":
                h = h + att @ lp["wo"] + lp["bo"]
                xn2 = ref.layernorm(h, lp["ln2_g"], lp["ln2_b"])
                mlp = ref.gelu(xn2 @ lp["mlp_w1"] + lp["mlp_b1"])
                h = h + mlp @ lp["mlp_w2"] + lp["mlp_b2"]
            else:
                h = h + att @ lp["wo"]
                xn2 = ref.rmsnorm(h, lp["rms2_g"])
                mlp = ref.silu(xn2 @ lp["w_gate"]) * (xn2 @ lp["w_up"])
                h = h + mlp @ lp["w_down"]
            ys = (zk, zv, kf, vf, k_sc.reshape(b, kvd), v_sc.reshape(b, kvd))
            return (h, k_sc, v_sc), ys

        zeros_cur = jnp.zeros((b, hkv, dh), dtype=h.dtype)
        (h, _, _), ys = jax.lax.scan(body, (h, zeros_cur, zeros_cur), xs)
        if cfg.arch == "gpt2":
            h = ref.layernorm(h, base["lnf_g"], base["lnf_b"])
        else:
            h = ref.rmsnorm(h, base["rmsf_g"])
        logits = h @ base["wte"].T  # [B,V]
        swap = lambda a: jnp.transpose(a, (1, 0, 2))  # [L,B,*] -> [B,L,*]
        zk, zv, kf, vf, ke, ve = ys
        return (logits, swap(zk), swap(zv), swap(kf), swap(vf), swap(ke), swap(ve))

    return decode_step


def make_encode_kv(cfg: ModelConfig):
    """Standalone AE encode of raw cache rows (Pallas): used by the rust
    cache manager to compress prefill output or migrate blocks.

    (ae, k_raw [L,S,kvd], v_raw [L,S,kvd]) -> (k_lat, v_lat [L,S,dl])
    """

    def encode_kv(ae, k_raw, v_raw):
        def body(_, lp):
            zk = ae_pallas.ae_half_from_dict(lp["k_rows"], lp["ae"]["k"]["enc"])
            zv = ae_pallas.ae_half_from_dict(lp["v_rows"], lp["ae"]["v"]["enc"])
            return (), (zk, zv)

        xs = {"ae": ae, "k_rows": k_raw, "v_rows": v_raw}
        _, (zk, zv) = jax.lax.scan(body, (), xs)
        return zk, zv

    return encode_kv


def make_decode_kv(cfg: ModelConfig):
    """Standalone AE decode of latent cache rows (Pallas): reconstruction
    on retrieval, used to (re)build the effective cache.

    (ae, k_lat [L,S,dl], v_lat [L,S,dl]) -> (k_rec, v_rec [L,S,kvd])
    """

    def decode_kv(ae, k_lat, v_lat):
        def body(_, lp):
            kr = ae_pallas.ae_half_from_dict(lp["k_lat"], lp["ae"]["k"]["dec"])
            vr = ae_pallas.ae_half_from_dict(lp["v_lat"], lp["ae"]["v"]["dec"])
            return (), (kr, vr)

        xs = {"ae": ae, "k_lat": k_lat, "v_lat": v_lat}
        _, (kr, vr) = jax.lax.scan(body, (), xs)
        return kr, vr

    return decode_kv


def make_decode_kv_batched(cfg: ModelConfig):
    """Cross-sequence batched AE decode for the faithful serving mode.

    (ae, k_lat [B,L,1,dl], v_lat [B,L,1,dl]) -> (k_rec, v_rec [B,L,1,kvd])

    Each decode round reconstructs exactly one pending watermark row per
    live sequence, so the rust scheduler packs those rows into one
    ``[B, L, 1, dl]`` tensor and issues a single decoder call instead of
    B ``decode_kv_t`` calls.  The layout is transposed to ``[L, B, dl]``
    and decoded with the same scan-over-layers / rows-per-layer dataflow
    as ``decode_kv`` — the decoder is a pure per-row map, so slot b of
    the batched call is bit-identical to a ``decode_kv_t`` call on that
    slot alone (the property the rust equivalence tests rely on).
    """

    def decode_kv_bt(ae, k_lat, v_lat):
        # [B, L, 1, dl] -> [L, B, dl]: the B watermark rows of one layer
        # become that layer's row batch
        to_rows = lambda a: jnp.transpose(a[:, :, 0, :], (1, 0, 2))

        def body(_, lp):
            kr = ae_pallas.ae_half_from_dict(lp["k_lat"], lp["ae"]["k"]["dec"])
            vr = ae_pallas.ae_half_from_dict(lp["v_lat"], lp["ae"]["v"]["dec"])
            return (), (kr, vr)

        xs = {"ae": ae, "k_lat": to_rows(k_lat), "v_lat": to_rows(v_lat)}
        _, (kr, vr) = jax.lax.scan(body, (), xs)
        # [L, B, kvd] -> [B, L, 1, kvd]
        back = lambda a: jnp.transpose(a, (1, 0, 2))[:, :, None, :]
        return back(kr), back(vr)

    return decode_kv_bt
