"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); the rust runtime loads the
results via ``HloModuleProto::from_text_file`` and never imports python.

Interchange is HLO text, NOT ``lowered.compile()`` / ``.serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under ``artifacts/``:
  manifest.json          — models, entry points, flattened input/output
                           names + shapes + dtypes (what rust assembles)
  {m}_{entry}.hlo.txt    — one per entry point per model
  {m}_params.bin/.json   — randomly-initialized parameters (rust trains)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import params as P
from . import train as T
from .config import CONFIGS, ModelConfig, config_to_json


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_io(prefix, tree):
    """[(name, shape, dtype)] for one named argument's pytree."""
    out = []
    for name, leaf in P.flat_entries(tree):
        full = f"{prefix}/{name}" if name else prefix
        out.append(
            {
                "name": full,
                "shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype)
                if not hasattr(leaf, "dtype")
                else str(leaf.dtype),
            }
        )
    return out


def lower_entry(fn, named_args, out_names, name, outdir, manifest):
    """Lower ``fn(*values)`` and record flattened I/O in the manifest."""
    values = [v for _, v in named_args]
    # keep_unused: the manifest promises the full flattened input list; XLA
    # must not prune arguments some entry point ignores (e.g. encode_kv
    # never reads decoder weights).
    lowered = jax.jit(fn, keep_unused=True).lower(*values)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)

    inputs = []
    for argname, v in named_args:
        inputs.extend(_flat_io(argname, v))
    out_tree = jax.eval_shape(fn, *values)
    if not isinstance(out_tree, tuple):
        out_tree = (out_tree,)
    assert len(out_tree) == len(out_names), (name, len(out_tree), out_names)
    outputs = []
    for oname, sub in zip(out_names, out_tree):
        outputs.extend(_flat_io(oname, sub))
    manifest["entries"][name] = {
        "file": fname,
        "inputs": inputs,
        "outputs": outputs,
    }
    print(f"  {name}: {len(text)//1024} KiB, {len(inputs)} in / {len(outputs)} out")


def spec_tokens(b, s):
    return jnp.zeros((b, s), jnp.int32)


def spec_mask(b, s):
    return jnp.ones((b, s), jnp.float32)


def build_model(cfg: ModelConfig, outdir: str, manifest: dict, seed: int) -> None:
    print(f"[{cfg.name}] init + lower")
    params = P.init_params(cfg, seed)
    base, ae = params["base"], params["ae"]
    P.save_params(params, os.path.join(outdir, f"{cfg.name}_params.bin"),
                  os.path.join(outdir, f"{cfg.name}_params.json"))

    L, Hkv, S = cfg.n_layer, cfg.n_kv_head, cfg.max_seq
    B = cfg.train_batch
    kvd, dl = cfg.kv_dim, cfg.ae_latent
    zl = jnp.zeros((L,), jnp.float32)
    zlh = jnp.zeros((L, Hkv), jnp.float32)
    scalar = jnp.float32(0.0)
    i0 = jnp.int32(0)

    mj = manifest["models"][cfg.name] = config_to_json(cfg)
    mj["params_bin"] = f"{cfg.name}_params.bin"
    mj["params_index"] = f"{cfg.name}_params.json"

    low = lambda *a, **k: lower_entry(*a, outdir=outdir, manifest=manifest, **k)

    # --- training steps -----------------------------------------------------
    step_fn = T.make_train_step(cfg)
    mb, vb = T.zeros_like_tree(base), T.zeros_like_tree(base)
    low(
        step_fn,
        [("base", base), ("ae", ae), ("m", mb), ("v", vb), ("step", i0),
         ("tokens", spec_tokens(B, S)), ("len_mask", spec_mask(B, S)),
         ("lr", scalar)],
        ["base", "m", "v", "step", "loss"],
        name=f"{cfg.name}_train_step",
    )

    ae_fn = T.make_ae_train_step(cfg)
    ma, va = T.zeros_like_tree(ae), T.zeros_like_tree(ae)
    low(
        ae_fn,
        [("base", base), ("ae", ae), ("m", ma), ("v", va), ("step", i0),
         ("tokens", spec_tokens(B, S)), ("len_mask", spec_mask(B, S)),
         ("gmask", zl), ("lam", scalar), ("lr", scalar)],
        ["ae", "m", "v", "step", "loss", "ce", "rec"],
        name=f"{cfg.name}_ae_train_step",
    )

    rf_fn = T.make_reuse_ft_step(cfg)
    low(
        rf_fn,
        [("base", base), ("ae", ae), ("m", mb), ("v", vb), ("step", i0),
         ("tokens", spec_tokens(B, S)), ("len_mask", spec_mask(B, S)),
         ("compress", zl), ("reuse_k", zlh), ("reuse_v", zlh),
         ("lam", scalar), ("lr", scalar)],
        ["base", "m", "v", "step", "loss", "ce", "rl1"],
        name=f"{cfg.name}_reuse_ft_step",
    )

    # --- evaluation ----------------------------------------------------------
    ev_fn = M.make_eval_loss(cfg)
    ev = lambda base, ae, tokens, len_mask, compress, quant, reuse_k, reuse_v: ev_fn(
        {"base": base, "ae": ae},
        tokens,
        len_mask,
        {"compress": compress, "quant": quant, "reuse_k": reuse_k, "reuse_v": reuse_v},
    )
    low(
        ev,
        [("base", base), ("ae", ae), ("tokens", spec_tokens(cfg.eval_batch, S)),
         ("len_mask", spec_mask(cfg.eval_batch, S)), ("compress", zl),
         ("quant", scalar), ("reuse_k", zlh), ("reuse_v", zlh)],
        ["nll", "ntok"],
        name=f"{cfg.name}_eval_loss",
    )

    st_fn = M.make_kv_stats(cfg)
    st = lambda base, ae, tokens, len_mask: st_fn(
        {"base": base, "ae": ae}, tokens, len_mask
    )
    low(
        st,
        [("base", base), ("ae", ae), ("tokens", spec_tokens(cfg.eval_batch, S)),
         ("len_mask", spec_mask(cfg.eval_batch, S))],
        ["dk", "dv"],
        name=f"{cfg.name}_kv_stats",
    )

    # --- serving -------------------------------------------------------------
    pf_fn = M.make_prefill(cfg)
    pf = lambda base, ae, tokens, len_mask, last, compress, quant, reuse_k, reuse_v: pf_fn(
        {"base": base, "ae": ae},
        tokens,
        len_mask,
        last,
        {"compress": compress, "quant": quant, "reuse_k": reuse_k, "reuse_v": reuse_v},
    )
    low(
        pf,
        [("base", base), ("ae", ae), ("tokens", spec_tokens(1, S)),
         ("len_mask", spec_mask(1, S)), ("last", i0), ("compress", zl),
         ("quant", scalar), ("reuse_k", zlh), ("reuse_v", zlh)],
        ["logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff"],
        name=f"{cfg.name}_prefill",
    )

    # cross-request batched prefill: the serving engine packs one
    # admission wave's prompts into [B, S] lanes and issues a single
    # launch instead of B {m}_prefill calls.  B is the largest compiled
    # decode batch (the wave the batcher can admit at once); smaller
    # waves zero-pad unused lanes (an all-zero len_mask row is inert —
    # see make_prefill_b).  Lane b is bit-identical to {m}_prefill on
    # that request alone, so the wave path needs no accuracy caveats.
    Bw = max(cfg.decode_batches)
    pfw_fn = M.make_prefill_b(cfg, Bw)
    pfw = lambda base, ae, tokens, len_mask, last, compress, quant, reuse_k, reuse_v: pfw_fn(
        {"base": base, "ae": ae},
        tokens,
        len_mask,
        last,
        {"compress": compress, "quant": quant, "reuse_k": reuse_k, "reuse_v": reuse_v},
    )
    low(
        pfw,
        [("base", base), ("ae", ae), ("tokens", spec_tokens(Bw, S)),
         ("len_mask", spec_mask(Bw, S)), ("last", jnp.zeros((Bw,), jnp.int32)),
         ("compress", zl), ("quant", scalar), ("reuse_k", zlh),
         ("reuse_v", zlh)],
        ["logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff"],
        name=f"{cfg.name}_prefill_b",
    )

    pfb_fn = M.make_prefill_base(cfg)
    low(
        pfb_fn,
        [("base", base), ("tokens", spec_tokens(1, S)),
         ("len_mask", spec_mask(1, S)), ("last", i0)],
        ["logits", "k_raw", "v_raw"],
        name=f"{cfg.name}_prefill_base",
    )

    for db in cfg.decode_batches:
        ds_fn = M.make_decode_step(cfg, db)
        ds = lambda base, ae, token, pos, k_cache, v_cache, compress, quant, reuse_k, reuse_v, _f=ds_fn: _f(
            {"base": base, "ae": ae},
            token,
            pos,
            k_cache,
            v_cache,
            {"compress": compress, "quant": quant, "reuse_k": reuse_k, "reuse_v": reuse_v},
        )
        low(
            ds,
            [("base", base), ("ae", ae), ("token", jnp.zeros((db,), jnp.int32)),
             ("pos", jnp.zeros((db,), jnp.int32)),
             ("k_cache", jnp.zeros((db, L, S, kvd), jnp.float32)),
             ("v_cache", jnp.zeros((db, L, S, kvd), jnp.float32)),
             ("compress", zl), ("quant", scalar),
             ("reuse_k", zlh), ("reuse_v", zlh)],
            ["logits", "k_lat", "v_lat", "k_raw", "v_raw", "k_eff", "v_eff"],
            name=f"{cfg.name}_decode_step_b{db}",
        )

    ek_fn = M.make_encode_kv(cfg)
    low(
        ek_fn,
        [("ae", ae), ("k_raw", jnp.zeros((L, S, kvd), jnp.float32)),
         ("v_raw", jnp.zeros((L, S, kvd), jnp.float32))],
        ["k_lat", "v_lat"],
        name=f"{cfg.name}_encode_kv",
    )

    dk_fn = M.make_decode_kv(cfg)
    low(
        dk_fn,
        [("ae", ae), ("k_lat", jnp.zeros((L, S, dl), jnp.float32)),
         ("v_lat", jnp.zeros((L, S, dl), jnp.float32))],
        ["k_rec", "v_rec"],
        name=f"{cfg.name}_decode_kv",
    )

    # token-granular decoder for the incremental effective-cache path:
    # the serving engine reconstructs one new row per decode step, so it
    # runs the AE decoder on a [L, 1, dl] slice instead of [L, S, dl]
    # (falls back to the padded full entry when this one is absent).
    low(
        dk_fn,
        [("ae", ae), ("k_lat", jnp.zeros((L, 1, dl), jnp.float32)),
         ("v_lat", jnp.zeros((L, 1, dl), jnp.float32))],
        ["k_rec", "v_rec"],
        name=f"{cfg.name}_decode_kv_t",
    )

    # cross-sequence batched decoder for the faithful serving mode: the
    # scheduler packs every live sequence's pending watermark row into one
    # [B, 1, dl] slot per layer and issues a single call per decode round
    # instead of B decode_kv_t calls.  B is the largest compiled decode
    # batch; smaller rounds zero-pad unused slots (same policy as
    # decode_step_b{B}).
    dkb_fn = M.make_decode_kv_batched(cfg)
    Bmax = max(cfg.decode_batches)
    low(
        dkb_fn,
        [("ae", ae), ("k_lat", jnp.zeros((Bmax, L, 1, dl), jnp.float32)),
         ("v_lat", jnp.zeros((Bmax, L, 1, dl), jnp.float32))],
        ["k_rec", "v_rec"],
        name=f"{cfg.name}_decode_kv_bt",
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", default="gpt2t,tinyllama_t")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)
    manifest = {"version": 1, "models": {}, "entries": {}}
    for name in args.models.split(","):
        build_model(CONFIGS[name], outdir, manifest, args.seed)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {outdir}/manifest.json ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
