//! Quickstart: load the AOT artifacts, train the tiny GPT-2-style model
//! briefly, then serve a few requests with KV-CAR compression on and
//! report the measured cache savings.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! (~2 minutes on CPU.  For the full experiment driver see
//! `examples/e2e_train_serve.rs`.)

use kvcar::coordinator::{GenRequest, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::train::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let mut engine = Engine::new(&dir)?;
    println!("loaded manifest: {} entry points", engine.manifest.entries.len());

    // 1. pretrain the tiny base model on the wiki-like corpus
    let mut trainer = Trainer::new(
        &mut engine,
        "gpt2t",
        TrainConfig {
            verbose: false,
            ..Default::default()
        },
    )?;
    let mut wiki = corpus::wiki(0);
    println!("pretraining 120 steps ...");
    let log = trainer.pretrain(&mut wiki, 120)?;
    println!(
        "  loss {:.3} -> {:.3}  ({} ms)",
        log.first(),
        log.last(),
        log.wall_ms
    );

    // 2. train autoencoders on the first half of the layers (Alg. 1)
    let spec = trainer.spec.clone();
    let layers: Vec<usize> = (0..spec.n_layer / 2).collect();
    println!("training autoencoders on layers {layers:?} ...");
    trainer.ae_stage1(&mut wiki, &layers, 20)?;
    let s2 = trainer.ae_stage2(&mut wiki, &layers, 40)?;
    println!("  joint stage loss {:.3} -> {:.3}", s2.first(), s2.last());
    let store = trainer.store.clone();

    // 3. serve with the compressed cache
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    println!(
        "serving with {} AE layers (modeled savings {:.1}%)",
        plan.n_ae_layers(),
        plan_savings(&spec, &plan) * 100.0
    );
    let cfg = ServeConfig {
        max_batch: 4,
        ..ServeConfig::new(plan)
    };
    let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg)?;
    serving.store = merge_params(serving.store, store);

    let mut prompts = corpus::wiki(7);
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest::greedy(i, &prompts.tokens(24), 24))
        .collect();
    let responses = serving.run(reqs)?;
    for r in &responses {
        println!(
            "  req {} -> {:?}",
            r.id,
            String::from_utf8_lossy(&r.output)
        );
    }
    serving.metrics.print_summary("quickstart");

    // 4. measured vs modeled savings
    let spec_check = ModelSpec::from_manifest(&serving.engine.manifest.raw, "gpt2t")?;
    assert_eq!(spec_check.n_layer, spec.n_layer);
    let ps = serving.cache.pool_stats();
    println!(
        "cache: peak {} bytes live, {} recycled allocations",
        ps.peak_live_bytes, ps.recycles
    );
    Ok(())
}

/// Overlay trained params (base/, ae/) onto a serving store.
fn merge_params(
    mut into: kvcar::runtime::Store,
    from: kvcar::runtime::Store,
) -> kvcar::runtime::Store {
    let names: Vec<String> = from
        .names()
        .filter(|n| n.starts_with("base/") || n.starts_with("ae/"))
        .cloned()
        .collect();
    for n in names {
        into.insert(&n, from.get(&n).unwrap().clone());
    }
    into
}
