//! paper_repro — regenerate every table and figure of the KV-CAR paper
//! on the substituted substrate (DESIGN.md §3,§6).
//!
//!   cargo run --release --example paper_repro -- <cmd> [--fast]
//!
//!   table2   AE compression: ppl (wiki, c4) + 0-shot acc (piqa, wino)
//!            for both models, baseline vs compressed, with savings
//!   table3   head replacement on gpt2t/wiki at six selection levels
//!   table4   heads-only vs heads+AE (wiki ppl, piqa acc)
//!   table5   piqa acc: Base / AE / AE+Int8 for both models
//!   fig2     A40 OOM frontier, paper-scale GPT-2 774M
//!   fig3     A40 OOM frontier, paper-scale TinyLlama 1.1B
//!   all      everything above in sequence
//!
//! Absolute numbers use the tiny trained-from-scratch models, so they are
//! not the paper's; the claims under reproduction are the *shapes*: who
//! wins, roughly by how much, and where the cliffs are.  Paper values are
//! printed alongside for comparison.  Checkpoints cache under
//! checkpoints/ so repeated invocations skip training.

use anyhow::Result;
use kvcar::compress::planner::{to_masks, with_selection};
use kvcar::compress::similarity::{HeadDistances, Selection};
use kvcar::data::corpus;
use kvcar::data::tasks::Task;
use kvcar::eval::{perplexity, zero_shot};
use kvcar::memsim::{frontier, FigureCompression, GpuModel, FIGURE_BATCHES};
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine, Store};
use kvcar::train::{StageLog, TrainConfig, Trainer};
use kvcar::util::cli::Args;
use std::path::PathBuf;

struct Steps {
    pretrain: usize,
    stage1: usize,
    stage2: usize,
    reuse_ft: usize,
    eval_batches: usize,
    zs_items: usize,
}

impl Steps {
    fn new(fast: bool) -> Steps {
        if fast {
            Steps {
                pretrain: 80,
                stage1: 10,
                stage2: 20,
                reuse_ft: 12,
                eval_batches: 3,
                zs_items: 60,
            }
        } else {
            Steps {
                pretrain: 800,
                stage1: 30,
                stage2: 60,
                reuse_ft: 40,
                eval_batches: 8,
                zs_items: 200,
            }
        }
    }
}

struct Pipeline {
    engine: Engine,
    model: String,
    spec: ModelSpec,
    ckpt: PathBuf,
    steps: Steps,
}

impl Pipeline {
    fn new(model: &str, steps: Steps) -> Result<Pipeline> {
        let engine = Engine::new(&artifacts_dir())?;
        let spec = ModelSpec::from_manifest(&engine.manifest.raw, model)?;
        Ok(Pipeline {
            engine,
            model: model.to_string(),
            spec,
            ckpt: PathBuf::from("checkpoints"),
            steps,
        })
    }

    fn have(&self, tag: &str) -> bool {
        self.ckpt
            .join(format!("{}_{tag}.bin", self.model))
            .exists()
    }

    fn quiet_cfg() -> TrainConfig {
        TrainConfig {
            verbose: false,
            ..Default::default()
        }
    }

    /// Pretrain (once) and stage-1 AEs on every layer (once).
    fn ensure_base(&mut self) -> Result<()> {
        if !self.have("pretrained") {
            println!("[{}] pretraining {} steps ...", self.model, self.steps.pretrain);
            let mut tr = Trainer::new(&mut self.engine, &self.model, Self::quiet_cfg())?;
            let mut c = corpus::wiki(0);
            let log = tr.pretrain(&mut c, self.steps.pretrain)?;
            println!("  loss {:.3} -> {:.3}", log.first(), log.last());
            tr.checkpoint(&self.ckpt, "pretrained")?;
        }
        if !self.have("ae1") {
            println!("[{}] Alg.1 stage 1 on all layers ...", self.model);
            let mut tr = Trainer::new(&mut self.engine, &self.model, Self::quiet_cfg())?;
            tr.restore(&self.ckpt, "pretrained")?;
            let mut c = corpus::wiki(1);
            let layers: Vec<usize> = (0..self.spec.n_layer).collect();
            let logs = tr.ae_stage1(&mut c, &layers, self.steps.stage1)?;
            let rec0: f32 = logs.iter().map(StageLog::first).sum::<f32>() / logs.len() as f32;
            let rec1: f32 = logs.iter().map(StageLog::last).sum::<f32>() / logs.len() as f32;
            println!("  mean per-layer loss {rec0:.3} -> {rec1:.3}");
            tr.checkpoint(&self.ckpt, "ae1")?;
        }
        Ok(())
    }

    /// Stage-2 joint finetune for "AE on first k layers"; cached per k.
    fn ensure_ae_k(&mut self, k: usize) -> Result<String> {
        let tag = format!("ae_k{k}");
        if !self.have(&tag) {
            self.ensure_base()?;
            let mut tr = Trainer::new(&mut self.engine, &self.model, Self::quiet_cfg())?;
            tr.restore(&self.ckpt, "ae1")?;
            let mut c = corpus::wiki(2);
            let layers: Vec<usize> = (0..k).collect();
            tr.ae_stage2(&mut c, &layers, self.steps.stage2)?;
            tr.checkpoint(&self.ckpt, &tag)?;
        }
        Ok(tag)
    }

    /// Continued-training control: the reuse-finetune step with inert
    /// masks, so reuse rows are compared against a baseline that saw the
    /// same extra optimization steps (otherwise finetuning itself would
    /// mask the compression penalty).
    fn ensure_ctrl(&mut self) -> Result<()> {
        let plan = self.none_plan();
        self.ensure_reuse("ctrl", &plan, "pretrained")
    }

    /// Reuse finetune under a fixed plan; cached per tag.
    fn ensure_reuse(&mut self, tag: &str, plan: &CompressionPlan, from: &str) -> Result<()> {
        if !self.have(tag) {
            let mut tr = Trainer::new(&mut self.engine, &self.model, Self::quiet_cfg())?;
            tr.restore(&self.ckpt, from)?;
            let mut c = corpus::wiki(3);
            tr.reuse_finetune(&mut c, &to_masks(plan), self.steps.reuse_ft)?;
            tr.checkpoint(&self.ckpt, tag)?;
        }
        Ok(())
    }

    fn store_for(&mut self, tag: &str) -> Result<Store> {
        let mut store = Store::new();
        self.engine.load_params(&self.model, &mut store)?;
        store.load_params(
            &self.ckpt.join(format!("{}_{tag}.bin", self.model)),
            &self.ckpt.join(format!("{}_{tag}.json", self.model)),
        )?;
        Ok(store)
    }

    fn ppl(&mut self, tag: &str, dataset: &str, plan: &CompressionPlan) -> Result<f64> {
        let mut store = self.store_for(tag)?;
        let mut c = corpus::by_name(dataset, 77).unwrap();
        let batches = self.steps.eval_batches;
        perplexity(
            &mut self.engine,
            &mut store,
            &self.spec.clone(),
            &self.model.clone(),
            &mut c,
            batches,
            &to_masks(plan),
        )
    }

    fn acc(&mut self, tag: &str, task: Task, plan: &CompressionPlan) -> Result<f64> {
        let mut store = self.store_for(tag)?;
        let items = self.steps.zs_items;
        let r = zero_shot(
            &mut self.engine,
            &mut store,
            &self.spec.clone(),
            &self.model.clone(),
            task,
            items,
            77,
            &to_masks(plan),
        )?;
        Ok(r.accuracy())
    }

    fn head_distances(&mut self, tag: &str) -> Result<HeadDistances> {
        let mut tr = Trainer::new(&mut self.engine, &self.model, Self::quiet_cfg())?;
        tr.restore(&self.ckpt, tag)?;
        let mut c = corpus::wiki(5);
        tr.analyze_heads(&mut c, 3)
    }

    fn none_plan(&self) -> CompressionPlan {
        CompressionPlan::none(self.spec.n_layer, self.spec.n_kv_head)
    }
}

// ---------------------------------------------------------------------------
// Table II
// ---------------------------------------------------------------------------

fn table2(fast: bool) -> Result<()> {
    println!("\n================ TABLE II — autoencoder KV compression ================");
    println!(
        "{:<12} {:<10} {:<11} {:>9} {:>12} {:>9}   paper",
        "model", "benchmark", "metric", "baseline", "compressed", "savings"
    );
    // compressed layer counts scaled from the paper's fractions
    // (gpt2: 10/12 wiki, 4/12 c4, 10/12 piqa, 10/12 wino ->
    //  gpt2t 8L: 7, 3, 7, 7 ; tinyllama 22L: 11, 6, 5, 22 ->
    //  tinyllama_t 6L: 3, 2, 1, 6)
    let cases: [(&str, [(usize, &str); 4], [&str; 4]); 2] = [
        (
            "gpt2t",
            [(7, "wiki"), (3, "c4"), (7, "piqa"), (7, "wino")],
            [
                "21.4 -> 23.3 (41.6%)",
                "34.61 -> 37.3 (25%)",
                "0.6262 -> 0.6055 (41.6%)",
                "0.5083 -> 0.5067 (41.6%)",
            ],
        ),
        (
            "tinyllama_t",
            [(3, "wiki"), (2, "c4"), (1, "piqa"), (6, "wino")],
            [
                "10.29 -> 12.33 (25%)",
                "15.69 -> 16.02 (13.6%)",
                "0.6485 -> 0.6322 (11.4%)",
                "0.5241 -> 0.5130 (50%)",
            ],
        ),
    ];
    for (model, rows, paper) in cases {
        let mut p = Pipeline::new(model, Steps::new(fast))?;
        p.ensure_base()?;
        for ((k, bench), paper_note) in rows.iter().zip(paper.iter()) {
            let tag = p.ensure_ae_k(*k)?;
            let plan_c = CompressionPlan::ae_first_layers(&p.spec, *k);
            let plan_0 = p.none_plan();
            let savings = plan_savings(&p.spec, &plan_c) * 100.0;
            match *bench {
                "wiki" | "c4" => {
                    let base = p.ppl(&tag, bench, &plan_0)?;
                    let comp = p.ppl(&tag, bench, &plan_c)?;
                    println!(
                        "{model:<12} {bench:<10} {:<11} {base:>9.3} {:>12} {savings:>8.1}%   {paper_note}",
                        "perplexity",
                        format!("{comp:.3} ({k}L)"),
                    );
                }
                task => {
                    let t = Task::by_name(task).unwrap();
                    let base = p.acc(&tag, t, &plan_0)?;
                    let comp = p.acc(&tag, t, &plan_c)?;
                    println!(
                        "{model:<12} {bench:<10} {:<11} {base:>9.4} {:>12} {savings:>8.1}%   {paper_note}",
                        "accuracy",
                        format!("{comp:.4} ({k}L)"),
                    );
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table III
// ---------------------------------------------------------------------------

fn table3(fast: bool) -> Result<()> {
    println!("\n========= TABLE III — head replacement (gpt2t on wiki-like) =========");
    let mut p = Pipeline::new("gpt2t", Steps::new(fast))?;
    p.ensure_base()?;
    p.ensure_ctrl()?;
    let base_ppl = p.ppl("ctrl", "wiki", &p.none_plan())?;
    let (l, h) = (p.spec.n_layer, p.spec.n_kv_head);
    let hd = p.head_distances("pretrained")?;

    // paper selects 19K/25V/36KV of 144 heads; scaled to our 28
    // reusable K heads that is ~4K, ~5V, ~4K+4V
    let configs: Vec<(&str, Selection, &str)> = vec![
        (
            "all key and value",
            Selection::all_alternating(l, h, true, true),
            "21.4 -> 30.8 (50%)",
        ),
        (
            "all key",
            Selection::all_alternating(l, h, true, false),
            "21.4 -> 26.4 (25%)",
        ),
        (
            "all value",
            Selection::all_alternating(l, h, false, true),
            "21.4 -> 26.4 (25%)",
        ),
        ("4 key (top-sim)", hd.select_top(4, 0), "21.4 -> 21.8 (6.6%)"),
        ("5 value (top-sim)", hd.select_top(0, 5), "21.4 -> 23.3 (8.7%)"),
        (
            "4 key + 4 value",
            hd.select_top(4, 4),
            "21.4 -> 23.9 (12.5%)",
        ),
    ];
    println!(
        "{:<22} {:>9} {:>11} {:>9}   paper",
        "heads replaced", "baseline", "compressed", "savings"
    );
    for (name, sel, paper_note) in configs {
        let plan = with_selection(p.none_plan(), &sel);
        let tag = format!("reuse_{}", name.replace([' ', '+', '(', ')', '-'], "_"));
        p.ensure_reuse(&tag, &plan, "pretrained")?;
        let ppl = p.ppl(&tag, "wiki", &plan)?;
        let savings = plan_savings(&p.spec, &plan) * 100.0;
        println!(
            "{name:<22} {base_ppl:>9.3} {ppl:>11.3} {savings:>8.1}%   {paper_note}"
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV
// ---------------------------------------------------------------------------

fn table4(fast: bool) -> Result<()> {
    println!("\n==== TABLE IV — heads alone vs heads + autoencoders (gpt2t) ====");
    let mut p = Pipeline::new("gpt2t", Steps::new(fast))?;
    p.ensure_base()?;
    p.ensure_ctrl()?;
    let hd = p.head_distances("pretrained")?;
    let sel = hd.select_top(4, 4);

    // heads only
    let plan_h = with_selection(p.none_plan(), &sel);
    p.ensure_reuse("t4_heads", &plan_h, "pretrained")?;

    // heads + AE on almost all layers (the paper's 47.85% configuration)
    let k = p.spec.n_layer - 1;
    let ae_tag = p.ensure_ae_k(k)?;
    let plan_hae = with_selection(CompressionPlan::ae_first_layers(&p.spec, k), &sel);
    p.ensure_reuse("t4_heads_ae", &plan_hae, &ae_tag)?;

    let base_ppl = p.ppl("ctrl", "wiki", &p.none_plan())?;
    let base_acc = p.acc("ctrl", Task::Piqa, &p.none_plan())?;
    println!(
        "{:<10} {:>10} {:>11} {:>9}   paper",
        "dataset", "baseline", "compressed", "savings"
    );
    let rows = [
        ("wiki", "t4_heads", &plan_h, true, "21.4 -> 23.9 (12.5%)"),
        ("wiki", "t4_heads_ae", &plan_hae, true, "21.4 -> 23.9 (47.85%)"),
        ("piqa", "t4_heads", &plan_h, false, "0.6262 -> 0.5892 (12.5%)"),
        ("piqa", "t4_heads_ae", &plan_hae, false, "0.6262 -> 0.5936 (47.85%)"),
    ];
    for (ds, tag, plan, is_ppl, paper_note) in rows {
        let savings = plan_savings(&p.spec, plan) * 100.0;
        if is_ppl {
            let v = p.ppl(tag, ds, plan)?;
            println!(
                "{ds:<10} {base_ppl:>10.3} {v:>11.3} {savings:>8.1}%   {paper_note}"
            );
        } else {
            let v = p.acc(tag, Task::Piqa, plan)?;
            println!(
                "{ds:<10} {base_acc:>10.4} {v:>11.4} {savings:>8.1}%   {paper_note}"
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table V
// ---------------------------------------------------------------------------

fn table5(fast: bool) -> Result<()> {
    println!("\n====== TABLE V — PIQA accuracy: Base / AE / AE+Int8 ======");
    println!(
        "{:<22} {:>8} {:>8} {:>8}   paper",
        "model / task", "Base", "AE", "AE+Q"
    );
    let cases = [
        ("gpt2t", 7usize, "0.6262 / 0.6055 / 0.6039"),
        ("tinyllama_t", 1, "0.6485 / 0.6322 / 0.6219"),
    ];
    for (model, k, paper_note) in cases {
        let mut p = Pipeline::new(model, Steps::new(fast))?;
        p.ensure_base()?;
        let tag = p.ensure_ae_k(k)?;
        let plan0 = p.none_plan();
        let plan_ae = CompressionPlan::ae_first_layers(&p.spec, k);
        let plan_aeq = CompressionPlan::ae_first_layers(&p.spec, k).with_quant();
        let base = p.acc(&tag, Task::Piqa, &plan0)?;
        let ae = p.acc(&tag, Task::Piqa, &plan_ae)?;
        let aeq = p.acc(&tag, Task::Piqa, &plan_aeq)?;
        println!(
            "{:<22} {base:>8.4} {ae:>8.4} {aeq:>8.4}   {paper_note}",
            format!("{model} PIQA ({k}L)")
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Figures 2 and 3
// ---------------------------------------------------------------------------

fn figure(spec: ModelSpec, deltas: &[(usize, FigureCompression, i64)]) {
    let gpu = GpuModel::a40_for(&spec);
    println!(
        "\n==== max seq length vs batch before OOM — {} on A40 ====",
        spec.name
    );
    print!("{:>8}", "batch");
    for c in FigureCompression::all() {
        print!("{:>18}", c.label());
    }
    println!();
    for &b in &FIGURE_BATCHES {
        print!("{b:>8}");
        for c in FigureCompression::all() {
            print!("{:>18}", frontier(&gpu, &spec, c.ratio(), &[b])[0].max_seq);
        }
        println!();
    }
    println!("paper's §V-B deltas vs ours:");
    for &(b, c, paper_delta) in deltas {
        let base = frontier(&gpu, &spec, FigureCompression::Baseline.ratio(), &[b])[0].max_seq;
        let comp = frontier(&gpu, &spec, c.ratio(), &[b])[0].max_seq;
        let ours = comp as i64 - base as i64;
        println!(
            "  batch {b:>3}, {:<16}: +{ours} tokens (paper: +{paper_delta})",
            c.label()
        );
    }
}

fn fig2() {
    figure(
        kvcar::model::gpt2_774m(),
        &[
            (64, FigureCompression::Pct75, 5248),
            (64, FigureCompression::Pct50, 2752),
            (32, FigureCompression::Pct25, 1920),
        ],
    );
}

fn fig3() {
    figure(
        kvcar::model::tinyllama_1_1b(),
        &[
            (32, FigureCompression::Pct75, 3776),
            (16, FigureCompression::Pct50, 2880),
            (16, FigureCompression::Pct25, 1728),
        ],
    );
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let fast = args.bool("fast");
    match args.command.as_deref() {
        Some("table2") => table2(fast)?,
        Some("table3") => table3(fast)?,
        Some("table4") => table4(fast)?,
        Some("table5") => table5(fast)?,
        Some("fig2") => fig2(),
        Some("fig3") => fig3(),
        Some("all") | None => {
            table2(fast)?;
            table3(fast)?;
            table4(fast)?;
            table5(fast)?;
            fig2();
            fig3();
        }
        Some(other) => anyhow::bail!("unknown subcommand {other}"),
    }
    Ok(())
}
