//! End-to-end driver: the full KV-CAR lifecycle on a real (small)
//! workload, proving every layer composes.  Recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example e2e_train_serve [-- --fast]
//!
//! Phases:
//!   1. pretrain the tiny GPT-2-style model on the wiki-like corpus,
//!      logging the loss curve (trained from rust over the AOT'd
//!      train-step artifact — python never runs);
//!   2. Alg. 1: per-layer AE training then joint finetune;
//!   3. Alg. 2: head-similarity analysis and reuse finetune;
//!   4. quality: ppl + zero-shot accuracy, baseline vs AE vs AE+reuse
//!      vs AE+int8;
//!   5. serving: batched requests through the coordinator under baseline
//!      and compressed plans — latency/throughput + measured cache bytes.

use anyhow::Result;
use kvcar::compress::planner::{to_masks, with_selection};
use kvcar::coordinator::{GenRequest, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::data::tasks::Task;
use kvcar::eval::{perplexity, zero_shot};
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::runtime::{artifacts_dir, Engine, Store};
use kvcar::train::{TrainConfig, Trainer};
use kvcar::util::cli::Args;

const MODEL: &str = "gpt2t";

fn main() -> Result<()> {
    let args = Args::from_env();
    let fast = args.bool("fast");
    let (pre_steps, s1, s2, ft) = if fast { (80, 10, 20, 12) } else { (300, 30, 80, 40) };
    let eval_batches = if fast { 3 } else { 8 };
    let zs_items = if fast { 60 } else { 200 };

    let mut engine = Engine::new(&artifacts_dir())?;
    println!("=== phase 1: pretraining ({pre_steps} steps) ===");
    let mut tr = Trainer::new(
        &mut engine,
        MODEL,
        TrainConfig {
            verbose: false,
            ..Default::default()
        },
    )?;
    let spec = tr.spec.clone();
    let mut wiki = corpus::wiki(0);
    let log = tr.pretrain(&mut wiki, pre_steps)?;
    print!("loss curve: ");
    for (i, l) in log.losses.iter().enumerate() {
        if i % (pre_steps / 10).max(1) == 0 || i + 1 == log.losses.len() {
            print!("{l:.3} ");
        }
    }
    println!("\n  ({} ms, final loss {:.3})", log.wall_ms, log.last());

    println!("\n=== phase 2: Alg. 1 autoencoder training ===");
    let ae_layers: Vec<usize> = (0..spec.n_layer - 1).collect();
    let logs = tr.ae_stage1(&mut wiki, &ae_layers, s1)?;
    for l in &logs {
        println!("  {}: {:.3} -> {:.3}", l.stage, l.first(), l.last());
    }
    let j = tr.ae_stage2(&mut wiki, &ae_layers, s2)?;
    println!("  joint: {:.3} -> {:.3}", j.first(), j.last());

    println!("\n=== phase 3: Alg. 2 head analysis + reuse finetune ===");
    let hd = tr.analyze_heads(&mut wiki, 3)?;
    println!("  adjacent-layer K-head L1 distances:");
    for l in 1..hd.n_layer {
        let row: Vec<String> = hd.dk[l].iter().map(|d| format!("{d:.3}")).collect();
        println!("    layer {l}: [{}]", row.join(", "));
    }
    let sel = hd.select_top(3, 3);
    println!(
        "  selected {} K heads, {} V heads for reuse",
        sel.count_k(),
        sel.count_v()
    );
    let plan_combined = with_selection(
        CompressionPlan::ae_first_layers(&spec, spec.n_layer - 1),
        &sel,
    );
    let ftl = tr.reuse_finetune(&mut wiki, &to_masks(&plan_combined), ft)?;
    println!("  reuse finetune: {:.3} -> {:.3}", ftl.first(), ftl.last());
    let trained = tr.store.clone();

    println!("\n=== phase 4: quality under compression plans ===");
    let plans: Vec<(&str, CompressionPlan)> = vec![
        ("baseline", CompressionPlan::none(spec.n_layer, spec.n_kv_head)),
        (
            "AE (half layers)",
            CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2),
        ),
        (
            "AE (all-1 layers)",
            CompressionPlan::ae_first_layers(&spec, spec.n_layer - 1),
        ),
        (
            "AE + int8",
            CompressionPlan::ae_first_layers(&spec, spec.n_layer - 1).with_quant(),
        ),
        ("AE + reuse", plan_combined.clone()),
    ];
    println!(
        "{:<20} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "plan", "wiki ppl", "c4 ppl", "piqa", "wino", "savings"
    );
    let mut store = mk_store(&mut engine, &trained)?;
    for (name, plan) in &plans {
        let masks = to_masks(plan);
        let mut w = corpus::wiki(99);
        let mut c4 = corpus::c4(99);
        let ppl_w = perplexity(&mut engine, &mut store, &spec, MODEL, &mut w, eval_batches, &masks)?;
        let ppl_c = perplexity(&mut engine, &mut store, &spec, MODEL, &mut c4, eval_batches, &masks)?;
        let piqa = zero_shot(&mut engine, &mut store, &spec, MODEL, Task::Piqa, zs_items, 5, &masks)?;
        let wino = zero_shot(&mut engine, &mut store, &spec, MODEL, Task::Wino, zs_items, 5, &masks)?;
        println!(
            "{name:<20} {ppl_w:>9.3} {ppl_c:>9.3} {:>9.4} {:>9.4} {:>8.1}%",
            piqa.accuracy(),
            wino.accuracy(),
            plan_savings(&spec, plan) * 100.0
        );
    }

    println!("\n=== phase 5: serving baseline vs compressed ===");
    let n_req = if fast { 6 } else { 16 };
    for (name, plan) in [
        ("baseline", CompressionPlan::none(spec.n_layer, spec.n_kv_head)),
        ("AE+reuse+int8", {
            let mut p = plan_combined.clone();
            p.quant_int8 = true;
            p
        }),
    ] {
        let cfg = ServeConfig {
            max_batch: 8,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(&mut engine, MODEL, cfg)?;
        overlay(&mut serving.store, &trained);
        let mut prompts = corpus::wiki(42);
        let reqs: Vec<GenRequest> = (0..n_req)
            .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(24), 32))
            .collect();
        let t0 = std::time::Instant::now();
        let responses = serving.run(reqs)?;
        let wall = t0.elapsed();
        println!(
            "\n[{name}] modeled savings {:.1}%",
            plan_savings(&spec, &plan) * 100.0
        );
        println!(
            "  sample: {:?}",
            String::from_utf8_lossy(&responses[0].output)
        );
        serving.metrics.print_summary(name);
        let ps = serving.cache.pool_stats();
        println!(
            "  measured cache peak: {} bytes ({:.1} tok/s end-to-end)",
            ps.peak_live_bytes,
            serving.metrics.tokens_generated as f64 / wall.as_secs_f64()
        );
    }
    println!("\ne2e complete.");
    Ok(())
}

fn mk_store(engine: &mut Engine, trained: &Store) -> Result<Store> {
    let mut store = Store::new();
    engine.load_params(MODEL, &mut store)?;
    overlay(&mut store, trained);
    Ok(store)
}

fn overlay(into: &mut Store, from: &Store) {
    let names: Vec<String> = from
        .names()
        .filter(|n| n.starts_with("base/") || n.starts_with("ae/"))
        .cloned()
        .collect();
    for n in names {
        into.insert(&n, from.get(&n).unwrap().clone());
    }
}
