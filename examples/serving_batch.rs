//! Serving-focused example: a Poisson-arrival workload through the
//! threaded server front-end, baseline vs compressed, plus the paper's
//! system claim at the coordinator level — under a fixed cache budget,
//! compression admits a larger concurrent batch.
//!
//!   cargo run --release --example serving_batch [-- --requests 24]

use anyhow::Result;
use kvcar::coordinator::batcher::{plan_round, request_cache_bytes, BatcherConfig};
use kvcar::coordinator::{GenRequest, Sampling, ServeConfig};
use kvcar::data::corpus;
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::server::Server;
use kvcar::util::cli::Args;
use kvcar::util::rng::Rng;
use std::time::Duration;

const MODEL: &str = "tinyllama_t";

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 24);
    let max_new = args.usize("max-new", 24);
    let rate_per_sec = args.f64("rate", 4.0);

    let spec = {
        let engine = Engine::new(&artifacts_dir())?;
        ModelSpec::from_manifest(&engine.manifest.raw, MODEL)?
    };

    for (label, plan) in [
        (
            "baseline",
            CompressionPlan::none(spec.n_layer, spec.n_kv_head),
        ),
        (
            "AE all layers + int8",
            CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
        ),
    ] {
        println!(
            "\n=== {label} (modeled savings {:.1}%) ===",
            plan_savings(&spec, &plan) * 100.0
        );
        let server = Server::start(
            artifacts_dir(),
            MODEL.into(),
            ServeConfig {
                max_batch: 8,
                seed: 9,
                ..ServeConfig::new(plan)
            },
        )?;
        let handle = server.handle();

        // Poisson arrivals from client threads
        let mut rng = Rng::new(13);
        let mut prompts = corpus::wiki(13);
        let mut joins = Vec::new();
        let mut delay = Duration::ZERO;
        for i in 0..n_requests {
            delay += Duration::from_secs_f64(rng.exponential(rate_per_sec));
            let req = GenRequest {
                id: i as u64,
                prompt: prompts.tokens(20),
                max_new_tokens: max_new,
                sampling: Sampling::Temperature(0.8),
                stop_byte: None,
                // None = "stamp on receipt": the worker stamps the
                // request when it arrives after the simulated client
                // delay, so queue_latency measures server-side wait
                arrival: None,
            };
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                std::thread::sleep(delay);
                h.generate(req)
            }));
        }
        let mut total_tokens = 0usize;
        let mut worst_ms = 0.0f64;
        for j in joins {
            let r = j.join().unwrap()?;
            total_tokens += r.generated_tokens;
            let ms = (r.queue_latency + r.prefill_latency + r.decode_latency).as_secs_f64() * 1e3;
            worst_ms = worst_ms.max(ms);
        }
        let m = handle.metrics()?;
        m.print_summary(label);
        println!("  client view: {total_tokens} tokens, worst request latency {worst_ms:.0} ms");
        server.shutdown();
    }

    // --- admission-control view of the paper's batch-size claim ---------
    println!("\n=== admission under a fixed cache budget (coordinator math) ===");
    let base = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let comp = CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant();
    let per_req = request_cache_bytes(&spec, &base, 20, max_new);
    let budget = per_req * 3; // room for 3 uncompressed requests
    let waiting: Vec<(usize, usize)> = (0..16).map(|_| (20, max_new)).collect();
    for (label, plan) in [("baseline", &base), ("compressed", &comp)] {
        let cfg = BatcherConfig {
            max_batch: 16,
            decode_batches: vec![1, 8],
            cache_budget: Some(budget),
        };
        let p = plan_round(&cfg, &spec, plan, 0, 0, &waiting);
        println!("  {label:<12} admits {:>2} concurrent requests", p.admit);
    }
    Ok(())
}
