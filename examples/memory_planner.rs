//! Memory planner: Eq. 3 calculator and compression-plan explorer.
//!
//! Reproduces the paper's §II-B worked example (GPT-2 Medium, fp16,
//! L=2048, B=8 -> ~1.61 GB), then walks the KV-CAR mechanisms over the
//! paper-scale models showing per-layer storage maps, modeled savings,
//! and the A40 OOM frontier each plan buys.
//!
//!   cargo run --release --example memory_planner [-- --model gpt2-774m]

use kvcar::compress::similarity::Selection;
use kvcar::compress::planner::with_selection;
use kvcar::kvcache::{CacheConfig, Side, StoreKind};
use kvcar::memsim::GpuModel;
use kvcar::model::memory::{
    baseline_bytes_per_token, kv_bytes_per_token, kv_cache_bytes, plan_savings, CompressionPlan,
};
use kvcar::model::{gpt2_774m, gpt2_medium, tinyllama_1_1b, ModelSpec};
use kvcar::util::cli::Args;

fn gb(x: u64) -> f64 {
    x as f64 / 1e9
}

fn show_plan(spec: &ModelSpec, name: &str, plan: &CompressionPlan) {
    let per_tok = kv_bytes_per_token(spec, plan);
    let base = baseline_bytes_per_token(spec);
    println!(
        "\n== {name}: {}/tok vs {} baseline -> savings {:.2}%",
        per_tok,
        base,
        plan_savings(spec, plan) * 100.0
    );
    let cfg = CacheConfig::new(spec.clone(), plan.clone());
    print!("   layer map: ");
    for l in 0..spec.n_layer.min(24) {
        let c = match cfg.store_kind(l, Side::K) {
            StoreKind::FullAlias => 'A',
            StoreKind::Latent => 'L',
            StoreKind::Heads(h) if h.len() == spec.n_kv_head => '.',
            StoreKind::Heads(_) => 'p',
        };
        print!("{c}");
    }
    if spec.n_layer > 24 {
        print!("… ({} layers)", spec.n_layer);
    }
    println!("   (. raw, L latent, A alias, p partial heads)");
    let gpu = GpuModel::a40_for(spec);
    for b in [8usize, 32, 64] {
        println!(
            "   A40 max seq @ batch {:>3}: {}",
            b,
            gpu.max_seq_len(spec, plan, b)
        );
    }
}

fn main() {
    let args = Args::from_env();

    // --- the paper's Eq. 3 worked example -------------------------------
    let med = gpt2_medium();
    let none = CompressionPlan::none(med.n_layer, med.n_kv_head);
    let bytes = kv_cache_bytes(&med, &none, 2048, 8);
    println!("Eq. 3 worked example (paper §II-B):");
    println!(
        "  GPT-2 Medium, fp16, L_seq=2048, B=8  ->  {:.2} GB (paper: ~1.61 GB)",
        gb(bytes)
    );
    println!(
        "  model weights: {:.2} GB  ->  cache/model ratio {:.2}x (paper: ~2.33x)",
        gb(med.weight_bytes()),
        bytes as f64 / med.weight_bytes() as f64
    );

    // --- plan explorer over a paper-scale model -------------------------
    let spec = match args.str("model", "gpt2-774m").as_str() {
        "tinyllama-1.1b" => tinyllama_1_1b(),
        _ => gpt2_774m(),
    };
    println!("\nplan explorer — {} (fp16 serving)", spec.name);

    show_plan(&spec, "baseline", &CompressionPlan::none(spec.n_layer, spec.n_kv_head));
    show_plan(
        &spec,
        "AE on half the layers",
        &CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2),
    );
    show_plan(
        &spec,
        "AE on all layers",
        &CompressionPlan::ae_first_layers(&spec, spec.n_layer),
    );
    show_plan(
        &spec,
        "AE everywhere + int8 latents",
        &CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
    );
    let sel = Selection::all_alternating(spec.n_layer, spec.n_kv_head, true, true);
    show_plan(
        &spec,
        "all K+V heads reused on alternating layers",
        &with_selection(CompressionPlan::none(spec.n_layer, spec.n_kv_head), &sel),
    );
    let combined = with_selection(
        CompressionPlan::ae_first_layers(&spec, spec.n_layer),
        &Selection::all_alternating(spec.n_layer, spec.n_kv_head, true, false),
    );
    show_plan(&spec, "combined: AE + alternating K reuse", &combined);
}
