//! Scenario-harness tests over the deterministic mock backend
//! (DESIGN.md §8): the bit-identical determinism contract of the
//! standard matrix, feature-off legs, transactional fault handling
//! (mid-wave prefill failure, budget exhaustion at admission), and the
//! template-cache pressure valve — all audited round-by-round by the
//! whole-stack invariant checker.
//!
//! Everything here runs the `MockEngine`, so the suite is green with no
//! artifacts present and exercises the identical scheduler code paths
//! the artifact engine drives.

use kvcar::coordinator::trace::{Arrival, TraceConfig};
use kvcar::coordinator::{
    check_round, run_scenario, scenario_spec, standard_matrix, FaultPlan, GenRequest, Scenario,
    ScenarioReport, ServeConfig, ServingEngine,
};
use kvcar::model::memory::CompressionPlan;
use kvcar::runtime::MockEngine;

fn run(sc: &Scenario) -> ScenarioReport {
    let mut engine = MockEngine::new(scenario_spec());
    run_scenario(&mut engine, "mock", sc).expect("scenario must pass its invariants")
}

#[test]
fn standard_matrix_is_bit_reproducible() {
    for sc in standard_matrix() {
        let a = run(&sc);
        let b = run(&sc);
        // the whole report — token digests, invariant trajectory, and
        // every virtual-clock timing figure — must be bit-identical
        assert_eq!(a, b, "scenario '{}' is not deterministic", sc.name);
        assert_eq!(
            a.completed + a.rejected.len() + a.quarantined.len(),
            sc.trace.n_requests,
            "scenario '{}' lost requests",
            sc.name
        );
        assert_eq!(
            a.invariant_checks, a.rounds,
            "scenario '{}' skipped an invariant audit",
            sc.name
        );
        assert!(
            a.faults_injected >= 1,
            "scenario '{}' never fired its fault plan",
            sc.name
        );
        assert!(a.virtual_ms > 0.0 && a.throughput_tok_s > 0.0);
        assert!(a.ttft_p99_ms >= a.ttft_p50_ms);
    }
}

#[test]
fn long_context_tail_thrashes_the_host_tier() {
    let sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "long_context_tail")
        .unwrap();
    let r = run(&sc);
    assert!(
        r.parks >= 1 && r.resumes >= 1,
        "tight budget must force park/resume traffic, got {} parks / {} resumes",
        r.parks,
        r.resumes
    );
}

#[test]
fn duplicate_storm_admits_by_sharing() {
    let sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "adversarial_duplicate_storm")
        .unwrap();
    let r = run(&sc);
    // one distinct prompt: all but the first admission of each wave
    // must ride the prefix trie with zero launches
    assert!(
        r.shared_admissions >= sc.trace.n_requests as u64 / 2,
        "duplicate storm shared only {} of {} admissions",
        r.shared_admissions,
        sc.trace.n_requests
    );
}

#[test]
fn feature_off_legs_hold_invariants_and_are_reproducible() {
    for leg in ["prefix_sharing", "resident_cache", "batched_prefill"] {
        for mut sc in standard_matrix() {
            match leg {
                "prefix_sharing" => sc.prefix_sharing = false,
                "resident_cache" => sc.resident_cache = false,
                _ => sc.batched_prefill = false,
            }
            let a = run(&sc);
            let b = run(&sc);
            assert_eq!(a, b, "scenario '{}' with {leg} off drifted", sc.name);
            assert_eq!(
                a.completed + a.rejected.len() + a.quarantined.len(),
                sc.trace.n_requests,
                "scenario '{}' with {leg} off lost requests",
                sc.name
            );
        }
    }
}

#[test]
fn feature_off_legs_preserve_token_streams() {
    // with faults stripped (fault position depends on launch counts,
    // which the legs legitimately change), every feature-off leg must
    // produce bit-identical token streams — the flags are perf knobs,
    // never semantics
    for mut sc in standard_matrix() {
        sc.faults = FaultPlan::none();
        let base = run(&sc);
        for leg in ["prefix_sharing", "resident_cache", "batched_prefill"] {
            let mut off = sc.clone();
            match leg {
                "prefix_sharing" => off.prefix_sharing = false,
                "resident_cache" => off.resident_cache = false,
                _ => off.batched_prefill = false,
            }
            let r = run(&off);
            assert_eq!(
                r.tokens_digest, base.tokens_digest,
                "scenario '{}' token streams drifted with {leg} off",
                sc.name
            );
            assert_eq!(r.completed, base.completed);
        }
    }
}

#[test]
fn budget_exhaustion_rejects_all_and_leaks_nothing() {
    // a pool ceiling below a single request's first block: every
    // admission wave must fail, roll back without leaking a sequence
    // (the per-round invariant audit inside run_scenario proves it),
    // and the supervisor must retry under backoff, exhaust the ladder
    // (nothing to shed/demote/park), and reject every request with a
    // typed error instead of hanging
    let mut sc = Scenario::new(
        "budget_exhaustion",
        TraceConfig {
            n_requests: 4,
            arrival: Arrival::Batch,
            prompt_len_range: (8, 12),
            max_new_range: (2, 4),
            temperature: None,
            distinct_prompts: None,
            seed: 7,
        },
    );
    sc.faults.admission_budget_tokens = Some(1);
    let r = run(&sc);
    assert_eq!(r.completed, 0);
    assert_eq!(r.rejected, vec![0, 1, 2, 3]);
    assert!(r.faults_injected >= 4);
    let again = run(&sc);
    assert_eq!(r, again);
}

#[test]
fn midwave_prefill_fault_rolls_back_ingest_and_retries_identically() {
    let spec = scenario_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, 1);
    let reqs = || -> Vec<GenRequest> {
        [
            b"the fox ran over ice".as_slice(),
            b"a stone in the river",
            b"cold wind in the pines",
        ]
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest::greedy(i as u64, p, 5))
        .collect()
    };
    // reference outputs from a fault-free engine
    let want: Vec<Vec<u8>> = {
        let mut engine = MockEngine::new(spec.clone());
        let mut serving =
            ServingEngine::new(&mut engine, "mock", ServeConfig::new(plan.clone())).unwrap();
        let mut out = serving.run(reqs()).unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.output).collect()
    };
    // same workload, first prefill launch fails mid-wave
    let mut engine = MockEngine::new(spec);
    assert!(engine.inject_launch_fault("prefill", 1));
    let mut serving = ServingEngine::new(&mut engine, "mock", ServeConfig::new(plan)).unwrap();
    let mut state = serving.begin(reqs());
    assert!(serving.step(&mut state).is_err(), "armed fault must surface");
    // transactional rollback: no sequence ingested, nothing pinned or
    // parked, the full wave back in the queue — and the whole-stack
    // audit agrees
    assert_eq!(serving.cache.n_sequences(), 0, "failed wave leaked sequences");
    assert_eq!(state.n_waiting(), 3);
    assert_eq!(state.n_active(), 0);
    serving
        .cache
        .prefix_integrity(&serving.waves.pinned_leaves())
        .expect("failed wave corrupted prefix refcounts");
    check_round(&serving, &state, true).expect("failed wave broke a whole-stack invariant");
    // the retry (fault is one-shot) must complete with outputs
    // bit-identical to the fault-free run
    while serving.step(&mut state).unwrap() {}
    let mut got = serving.finish(state);
    got.sort_by_key(|r| r.id);
    let got: Vec<Vec<u8>> = got.into_iter().map(|r| r.output).collect();
    assert_eq!(got, want, "post-rollback retry diverged from the clean run");
}

#[test]
fn persistent_fault_quarantines_one_sequence_and_spares_survivors() {
    // the ISSUE acceptance bar: a backend that keeps failing the same
    // decode launch past the retry budget must cost exactly the
    // attributed sequence — quarantined with a typed error — while
    // every survivor's token stream stays bitwise identical to the
    // fault-free run, and the retry/backoff timeline is bit-reproducible
    let sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "flapping_backend")
        .unwrap();
    let faulty = run(&sc);
    let mut twin = sc.clone();
    twin.faults = FaultPlan::none();
    let clean = run(&twin);

    assert_eq!(
        faulty.quarantined.len(),
        1,
        "a 6-failure flap against a 3-retry budget must quarantine exactly one sequence, got {:?}",
        faulty.quarantined
    );
    assert!(
        faulty.retries >= 3,
        "the quarantined sequence must have burned its full retry budget first, got {} retries",
        faulty.retries
    );
    assert!(
        faulty.backoff_ms > 0.0,
        "retries must charge backoff on the virtual clock"
    );
    assert_eq!(
        faulty.completed + faulty.quarantined.len(),
        sc.trace.n_requests,
        "every non-quarantined request must still finish"
    );

    // blast radius: survivors' outputs are bitwise equal to the clean run
    let clean_digests: std::collections::HashMap<u64, u64> =
        clean.output_digests.iter().copied().collect();
    let victim = faulty.quarantined[0];
    for (id, digest) in &faulty.output_digests {
        if *id == victim {
            continue;
        }
        assert_eq!(
            clean_digests.get(id),
            Some(digest),
            "survivor {id} diverged from the fault-free run"
        );
    }

    // retry/backoff timings (virtual_ms, backoff_ms, every digest) are
    // bit-reproducible across seeded runs
    assert_eq!(faulty, run(&sc), "faulted run is not bit-reproducible");
}

#[test]
fn corrupted_unpark_is_caught_by_checksum_and_quarantined() {
    // a bit flipped in a parked payload must never reach the decode
    // path: the CRC gate on unpark catches it, the sequence is
    // quarantined with a Corruption error, and nothing leaks
    let sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "corrupted_unpark")
        .unwrap();
    let r = run(&sc);
    assert!(
        r.checksum_failures >= 1,
        "the armed corruption never tripped the CRC gate"
    );
    assert_eq!(
        r.quarantined.len() as u64,
        r.checksum_failures,
        "every checksum failure must map to exactly one quarantine"
    );
    assert_eq!(
        r.completed + r.rejected.len() + r.quarantined.len(),
        sc.trace.n_requests
    );
    assert_eq!(r, run(&sc));
}

#[test]
fn sustained_pressure_walks_the_degradation_ladder() {
    // admission pressure beyond the pool budget must degrade gracefully
    // — shed templates, demote cold rows, park, reject with a retry
    // hint — rather than panic or spin; the ladder's actions are
    // metered and the whole trajectory is deterministic
    let sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "sustained_pressure")
        .unwrap();
    let r = run(&sc);
    assert!(
        r.retries >= 1,
        "pressure must first be absorbed by the retry budget"
    );
    let ladder_actions =
        r.template_sheds + r.demotions + r.parks + r.rejected.len() as u64 + r.quarantined.len() as u64;
    assert!(
        ladder_actions >= 1,
        "sustained exhaustion must climb the degradation ladder"
    );
    assert_eq!(
        r.completed + r.rejected.len() + r.quarantined.len(),
        sc.trace.n_requests
    );
    assert_eq!(r, run(&sc));
}

#[test]
fn template_pressure_valve_survives_capacity_one() {
    // capacity-one template cache under a 3-distinct-prompt storm: the
    // valve sheds templates every wave, but may never free a prefix
    // chain a planned Cached lane still references — prefix_integrity
    // runs inside run_scenario after every round and would catch it
    let mut sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "template_storm")
        .unwrap();
    sc.template_capacity = Some(1);
    let r = run(&sc);
    assert_eq!(
        r.completed + r.rejected.len() + r.quarantined.len(),
        sc.trace.n_requests
    );
    assert!(
        r.shared_admissions > 0,
        "even a capacity-one cache must share within-wave duplicates"
    );
    assert_eq!(r, run(&sc));
}
