//! Integration cross-check: the cache manager's *measured* byte
//! accounting (`seq_stored_bytes` / `seq_baseline_bytes`) equals the
//! Eq. 3 analytical model in `model::memory` for every plan family the
//! paper evaluates — baseline, AE, AE+int8, and cross-layer reuse.
//!
//! Pure rust (no artifacts needed): appends run real block traffic
//! through the store and the model side prices the same plan.

use kvcar::kvcache::{CacheConfig, CacheManager};
use kvcar::model::memory::{
    baseline_bytes_per_token, kv_bytes_per_token, plan_savings, CompressionPlan,
};
use kvcar::model::{Arch, ModelSpec};
use kvcar::util::rng::Rng;

fn spec() -> ModelSpec {
    ModelSpec {
        name: "acct".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 6,
        d_model: 64,
        n_head: 8,
        n_kv_head: 8,
        d_head: 8,
        ffn_dim: 128,
        max_seq: 128,
        ae_hidden: 48,
        ae_latent: 32,
        bytes_per_el: 4, // the runtime store encodes f32 by default
    }
}

/// Append `n` random tokens and assert measured == modeled bytes.
fn assert_accounting(plan: CompressionPlan, n: usize) {
    let spec = spec();
    let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
    assert_eq!(n % m.cfg.block_size, 0, "use block-aligned lengths");
    let id = m.create_sequence();
    let mut rng = Rng::new(0xACC7);
    let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
    for _ in 0..n {
        let kl: Vec<f32> = (0..l * dl).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let kr: Vec<f32> = (0..l * kvd).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        m.append_token(id, &kl, &kl, &kr, &kr).unwrap();
    }
    let measured = m.seq_stored_bytes(id);
    let modeled = kv_bytes_per_token(&spec, &plan) * n;
    assert_eq!(
        measured, modeled,
        "stored bytes diverge from Eq. 3 accounting (plan {plan:?})"
    );
    let measured_base = m.seq_baseline_bytes(id);
    let modeled_base = baseline_bytes_per_token(&spec) * n;
    assert_eq!(
        measured_base, modeled_base,
        "baseline bytes diverge from Eq. 3"
    );
    // the realized savings match the analytical "Memory Savings" column
    let realized = 1.0 - measured as f64 / measured_base as f64;
    let analytical = plan_savings(&spec, &plan);
    assert!(
        (realized - analytical).abs() < 1e-12,
        "savings diverge: measured {realized} vs Eq. 3 {analytical}"
    );
}

#[test]
fn baseline_plan_matches_model() {
    let s = spec();
    assert_accounting(CompressionPlan::none(s.n_layer, s.n_kv_head), 32);
}

#[test]
fn ae_plan_matches_model() {
    let s = spec();
    assert_accounting(CompressionPlan::ae_first_layers(&s, s.n_layer), 32);
}

#[test]
fn ae_int8_plan_matches_model() {
    let s = spec();
    assert_accounting(
        CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant(),
        32,
    );
}

#[test]
fn reuse_plan_matches_model() {
    let s = spec();
    let mut plan = CompressionPlan::none(s.n_layer, s.n_kv_head);
    // alternating full-layer reuse + a few partial heads
    for l in (1..s.n_layer).step_by(2) {
        plan.reuse_k[l] = vec![true; s.n_kv_head];
        plan.reuse_v[l] = vec![true; s.n_kv_head];
    }
    plan.reuse_k[2][0] = true;
    plan.reuse_v[4][3] = true;
    assert_accounting(plan, 48);
}

#[test]
fn mixed_ae_reuse_int8_matches_model() {
    let s = spec();
    let mut plan = CompressionPlan::ae_first_layers(&s, 3).with_quant();
    plan.reuse_k[3] = vec![true; s.n_kv_head];
    plan.reuse_v[5][1] = true;
    assert_accounting(plan, 16);
}

#[test]
fn plan_family_savings_are_ordered() {
    // AE+int8 < AE < baseline stored bytes, as the paper's Table II/III
    // orderings require — measured on real block traffic
    let s = spec();
    let mut sizes = Vec::new();
    for plan in [
        CompressionPlan::none(s.n_layer, s.n_kv_head),
        CompressionPlan::ae_first_layers(&s, s.n_layer),
        CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant(),
    ] {
        let mut m = CacheManager::new(CacheConfig::new(s.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(1);
        let (l, dl, kvd) = (s.n_layer, s.ae_latent, s.kv_dim());
        for _ in 0..32 {
            let kl: Vec<f32> = (0..l * dl).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let kr: Vec<f32> = (0..l * kvd).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            m.append_token(id, &kl, &kl, &kr, &kr).unwrap();
        }
        sizes.push(m.seq_stored_bytes(id));
    }
    assert!(sizes[2] < sizes[1] && sizes[1] < sizes[0], "{sizes:?}");
}
