//! Fault-position property sweep (DESIGN.md §9): a one-shot launch
//! fault injected at **every** `(kind, nth)` position of a fixed
//! scenario must be absorbed by the supervisor's retry budget with no
//! externally visible damage — invariants audited clean after every
//! round (including the failed one), no sequence leaked, no request
//! rejected or quarantined, and every token stream bitwise identical
//! to the fault-free run.
//!
//! This is the sweep form of the transactional-rollback claim: the
//! mid-wave test in `scenarios.rs` proves it at one position; this
//! proves no position is special.

use kvcar::coordinator::trace::{Arrival, TraceConfig};
use kvcar::coordinator::{run_scenario, scenario_spec, Scenario, ScenarioReport};
use kvcar::runtime::MockEngine;

/// Small fixed workload: greedy (so token streams are comparable),
/// batch arrival, few enough launches that a 20-position sweep covers
/// every real launch plus a tail of never-firing positions.
fn sweep_scenario() -> Scenario {
    Scenario::new(
        "fault_sweep",
        TraceConfig {
            n_requests: 6,
            arrival: Arrival::Batch,
            prompt_len_range: (8, 12),
            max_new_range: (4, 6),
            temperature: None,
            distinct_prompts: None,
            seed: 97,
        },
    )
}

fn run(sc: &Scenario) -> ScenarioReport {
    let mut engine = MockEngine::new(scenario_spec());
    run_scenario(&mut engine, "mock", sc)
        .expect("every fault position must pass the per-round invariant audit")
}

#[test]
fn one_shot_fault_at_every_position_recovers_bitwise() {
    let clean = run(&sweep_scenario());
    assert_eq!(clean.completed, sweep_scenario().trace.n_requests);

    for kind in ["prefill", "decode"] {
        let mut fired = 0u64;
        for nth in 1..=20u64 {
            let mut sc = sweep_scenario();
            match kind {
                "prefill" => sc.faults.prefill_launch = Some(nth),
                _ => sc.faults.decode_launch = Some(nth),
            }
            let r = run(&sc);
            // one-shot is always within the retry budget: the fault
            // may cost virtual time, never a request
            assert_eq!(
                r.completed,
                sc.trace.n_requests,
                "{kind} fault at launch {nth} lost requests: rejected {:?}, quarantined {:?}",
                r.rejected,
                r.quarantined
            );
            // and never a token: every stream bitwise-equal to the
            // fault-free run
            assert_eq!(
                r.output_digests, clean.output_digests,
                "{kind} fault at launch {nth} perturbed a token stream"
            );
            fired += u64::from(r.faults_injected >= 1);
        }
        assert!(
            fired >= 1,
            "no {kind} fault position ever fired — the sweep tested nothing"
        );
    }
}
