//! Wave-based admission prefill, verified without artifacts (pure-rust
//! mock prefiller):
//!
//! * a batched admission wave is **bitwise-identical** to sequential
//!   per-request prefills — stored compressed streams, decode
//!   watermarks, effective-cache contents, and greedy first tokens —
//!   across random compression plans;
//! * a wave of B <= capacity requests costs exactly **one** prefill
//!   launch (the one-launch-per-wave law, via mock call counters);
//! * the fallback ladder: a mock without the batched entry
//!   (`wave_capacity() == None`) admits through the per-request rung
//!   and still produces bit-identical results;
//! * the over-budget head-of-line case: when the batcher admits
//!   nothing and nothing is live, the scheduler's `admit.max(1)`
//!   forces the head request through, which the wave planner serves as
//!   a lone per-request prefill.

use kvcar::coordinator::batcher::{plan_round, request_cache_bytes, BatcherConfig};
use kvcar::coordinator::prefill::{LaneWiseMockPrefiller, PrefillWave};
use kvcar::coordinator::EffectiveCache;
use kvcar::kvcache::{CacheConfig, CacheManager, Side};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::prop_assert;
use kvcar::util::prop::check;
use std::collections::HashMap;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "wave".into(),
        arch: Arch::Gpt2,
        vocab: 96,
        n_layer: 4,
        d_model: 32,
        n_head: 4,
        n_kv_head: 4,
        d_head: 8,
        ffn_dim: 64,
        max_seq: 48,
        ae_hidden: 24,
        ae_latent: 16,
        bytes_per_el: 4,
    }
}

fn greedy(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    prop_assert!(a.len() == b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit divergence at {i}: {x} vs {y}"
        );
    }
    Ok(())
}

#[test]
fn wave_admission_bitwise_matches_sequential_across_plans() {
    check(25, |rng| {
        let spec = tiny_spec();
        let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
        let n = rng.range(2, 7);
        let prompts: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let len = rng.range(1, spec.max_seq - 1);
                (0..len).map(|_| rng.below(256) as u8).collect()
            })
            .collect();
        let lanes: Vec<&[u8]> = prompts.iter().map(|p| p.as_slice()).collect();

        // two identical worlds: one admits the wave batched, the other
        // forces the per-request ladder rung (capacity None)
        let mut m_wav = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
        let mut m_seq = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs_wav: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut effs_seq: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut mock_wav = LaneWiseMockPrefiller::for_spec(&spec)
            .with_capacity(Some(rng.range(2, 9)));
        let mut mock_seq = LaneWiseMockPrefiller::for_spec(&spec).with_capacity(None);
        let mut pw_wav = PrefillWave::new();
        let mut pw_seq = PrefillWave::new();
        let seed = rng.bool(0.5); // in-graph seeding and faithful both hold
        let adm_wav = pw_wav
            .admit_wave(&mut m_wav, &mut effs_wav, &spec, seed, false, &lanes, &mut mock_wav)
            .map_err(|e| e.to_string())?;
        let adm_seq = pw_seq
            .admit_wave(&mut m_seq, &mut effs_seq, &spec, seed, false, &lanes, &mut mock_seq)
            .map_err(|e| e.to_string())?;
        prop_assert!(mock_seq.wave_calls == 0, "capacity None must never batch");
        prop_assert!(
            pw_seq.stats.launches == n as u64,
            "per-request rung costs one launch per request"
        );

        for ((w, s), prompt) in adm_wav.iter().zip(&adm_seq).zip(&prompts) {
            prop_assert!(w.cache_id == s.cache_id, "admission order must match");
            let id = w.cache_id;
            // sampled first tokens: greedy over bit-identical logits
            assert_bits_eq(&w.logits, &s.logits, "lane logits")?;
            prop_assert!(
                greedy(&w.logits) == greedy(&s.logits),
                "greedy first tokens diverge"
            );
            // watermarks
            prop_assert!(
                m_wav.decoded_upto(id) == m_seq.decoded_upto(id),
                "decode watermarks diverge"
            );
            prop_assert!(
                m_wav.seq_len(id) == Some(prompt.len()) && m_seq.seq_len(id) == Some(prompt.len()),
                "prompt rows must be ingested"
            );
            // stored compressed streams, stream by stream
            prop_assert!(
                m_wav.seq_stored_bytes(id) == m_seq.seq_stored_bytes(id),
                "stored bytes diverge"
            );
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let a = format!("{:?}", m_wav.stored_rows(id, layer, side));
                    let b = format!("{:?}", m_seq.stored_rows(id, layer, side));
                    prop_assert!(a == b, "stream ({layer}, {side:?}) diverges");
                }
            }
            // effective-cache scratch (seeded rows or all-zero faithful)
            let ew = &effs_wav[&id];
            let es = &effs_seq[&id];
            assert_bits_eq(&ew.k, &es.k, "effective K")?;
            assert_bits_eq(&ew.v, &es.v, "effective V")?;
        }
        Ok(())
    });
}

#[test]
fn wave_of_b_requests_costs_one_launch() {
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let mut effs = HashMap::new();
    let mut mock = LaneWiseMockPrefiller::for_spec(&spec).with_capacity(Some(8));
    let mut pw = PrefillWave::new();
    let prompts: Vec<&[u8]> = vec![b"aaaa", b"bb", b"cccccc", b"dd", b"e"];
    let admitted = pw
        .admit_wave(&mut cache, &mut effs, &spec, true, false, &prompts, &mut mock)
        .unwrap();
    assert_eq!(admitted.len(), 5);
    assert_eq!(mock.wave_calls, 1, "one wave, one launch");
    assert_eq!(mock.single_calls, 0);
    assert_eq!(pw.stats.waves, 1);
    assert_eq!(pw.stats.launches, 1);
    assert_eq!(pw.stats.batched_lanes, 5);
    assert_eq!(pw.stats.fallback_prefills, 0);
    // a second wave of one request takes the cheaper per-request rung
    let lone: Vec<&[u8]> = vec![b"zz"];
    pw.admit_wave(&mut cache, &mut effs, &spec, true, false, &lone, &mut mock)
        .unwrap();
    assert_eq!(mock.wave_calls, 1);
    assert_eq!(mock.single_calls, 1);
    assert_eq!(pw.stats.launches, 2);
    assert_eq!(pw.stats.fallback_prefills, 1);
    // an empty wave costs nothing
    pw.admit_wave(&mut cache, &mut effs, &spec, true, false, &[], &mut mock)
        .unwrap();
    assert_eq!(pw.stats.waves, 2);
    assert_eq!(pw.stats.launches, 2);
}

#[test]
fn over_budget_head_of_line_forces_one_admission_through_wave_planner() {
    // the scheduler's `admit.max(1)` rule at the planner level: a
    // budget too small for even one request admits 0, but when nothing
    // is live the head request must run anyway — as a lone per-request
    // prefill, not a padded batched launch
    let spec = tiny_spec();
    let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let one = request_cache_bytes(&spec, &plan, 16, 16);
    let bcfg = BatcherConfig {
        max_batch: 8,
        decode_batches: vec![1, 8],
        cache_budget: Some(one / 2),
    };
    let waiting = vec![(16usize, 16usize); 4];
    let p = plan_round(&bcfg, &spec, &plan, 0, 0, &waiting);
    assert_eq!(p.admit, 0, "budget below one request must admit none");
    assert_eq!(p.wave_s, 0, "no admissions, no wave bucket");
    let admit = if p.admit == 0 { p.admit.max(1) } else { p.admit };

    let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let mut effs = HashMap::new();
    let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
    let mut pw = PrefillWave::new();
    let prompt: &[u8] = b"head of line must run";
    let wave: Vec<&[u8]> = vec![prompt; admit];
    let admitted = pw
        .admit_wave(&mut cache, &mut effs, &spec, true, false, &wave, &mut mock)
        .unwrap();
    assert_eq!(admitted.len(), 1, "forced head-of-line admission");
    assert_eq!(mock.single_calls, 1, "lone admission takes the per-request rung");
    assert_eq!(mock.wave_calls, 0);
    assert_eq!(cache.seq_len(admitted[0].cache_id), Some(prompt.len()));
    assert_eq!(cache.decoded_upto(admitted[0].cache_id), Some(prompt.len()));
}

#[test]
fn capacity_chunking_matches_unchunked_results_bitwise() {
    // 7 prompts at capacity 3: chunks of 3 + 3 + a lone remainder —
    // the chunked path must still be bitwise-equal to capacity-8 one-shot
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, 2);
    let prompts: Vec<Vec<u8>> = (0..7u8)
        .map(|i| (0..=i).map(|j| j * 17 + i).collect())
        .collect();
    let lanes: Vec<&[u8]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut worlds = Vec::new();
    for cap in [Some(3), Some(8)] {
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec).with_capacity(cap);
        let mut pw = PrefillWave::new();
        pw.admit_wave(&mut cache, &mut effs, &spec, true, false, &lanes, &mut mock)
            .unwrap();
        worlds.push((cache, effs, mock.wave_calls, mock.single_calls, pw.stats));
    }
    assert_eq!((worlds[0].2, worlds[0].3), (2, 1), "3+3+lone remainder");
    assert_eq!((worlds[1].2, worlds[1].3), (1, 0), "one-shot at cap 8");
    assert_eq!(worlds[0].4.launches, 3);
    assert_eq!(worlds[1].4.launches, 1);
    for id in worlds[0].1.keys() {
        let (a, b) = (&worlds[0].1[id], &worlds[1].1[id]);
        assert_eq!(
            a.k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "chunked effective K diverges from one-shot"
        );
        for layer in 0..spec.n_layer {
            for side in [Side::K, Side::V] {
                assert_eq!(
                    format!("{:?}", worlds[0].0.stored_rows(*id, layer, side)),
                    format!("{:?}", worlds[1].0.stored_rows(*id, layer, side)),
                    "chunked stream diverges from one-shot"
                );
            }
        }
    }
}
