//! End-to-end pipeline integration over real artifacts:
//! pretrain -> AE stages -> head analysis -> serve, plus the
//! faithful-vs-incremental effective-cache equivalence that validates
//! the coordinator's reconstruction path.
//!
//! Kept small (tens of steps) — the full-scale run lives in
//! `examples/e2e_train_serve.rs` and EXPERIMENTS.md.

use kvcar::compress::planner::{to_masks, with_selection};
use kvcar::coordinator::{GenRequest, Sampling, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::train::{TrainConfig, Trainer};

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn quiet() -> TrainConfig {
    TrainConfig {
        verbose: false,
        ..TrainConfig::default()
    }
}

#[test]
fn train_pipeline_losses_improve() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let mut tr = Trainer::new(&mut engine, "gpt2t", quiet()).unwrap();
    let mut c = corpus::wiki(11);

    // stage 0: pretraining reduces CE
    let log = tr.pretrain(&mut c, 40).unwrap();
    assert!(
        log.last() < log.first() * 0.7,
        "pretrain did not learn: {} -> {}",
        log.first(),
        log.last()
    );

    // Alg. 1 stage 1 on two layers: per-layer runs converge
    let s1 = tr.ae_stage1(&mut c, &[0, 1], 15).unwrap();
    for log in &s1 {
        assert!(
            log.last() < log.first(),
            "{}: {} -> {}",
            log.stage,
            log.first(),
            log.last()
        );
    }

    // Alg. 1 stage 2 joint
    let s2 = tr.ae_stage2(&mut c, &[0, 1], 15).unwrap();
    assert!(s2.last() <= s2.first() * 1.05);

    // Alg. 2: similarity analysis produces usable distances
    let hd = tr.analyze_heads(&mut c, 2).unwrap();
    let sel = hd.select_top(1, 1);
    assert_eq!(sel.count_k(), 1);
    assert_eq!(sel.count_v(), 1);

    // Alg. 2: reuse finetune runs and keeps loss finite
    let plan = with_selection(
        CompressionPlan::none(tr.spec.n_layer, tr.spec.n_kv_head),
        &sel,
    );
    let ft = tr.reuse_finetune(&mut c, &to_masks(&plan), 10).unwrap();
    assert!(ft.last().is_finite());
    assert!(ft.last() < ft.first() * 1.2);
}

#[test]
fn serve_baseline_and_compressed_produce_tokens() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "tinyllama_t").unwrap();
    for ae_layers in [0, spec.n_layer] {
        let cfg = ServeConfig {
            plan: CompressionPlan::ae_first_layers(&spec, ae_layers),
            max_batch: 4,
            seed: 1,
            per_step_reconstruct: false,
        };
        let mut serving = ServingEngine::new(&mut engine, "tinyllama_t", cfg).unwrap();
        let reqs: Vec<GenRequest> = (0..3)
            .map(|i| GenRequest::greedy(i, b"the furry cat ", 8))
            .collect();
        let out = serving.run(reqs).unwrap();
        assert_eq!(out.len(), 3);
        for r in &out {
            assert_eq!(r.generated_tokens, 8);
            assert_eq!(r.output.len(), 8);
        }
        assert_eq!(serving.metrics.requests_completed, 3);
        assert!(serving.metrics.tokens_generated >= 24);
        // all cache memory released at retire
        assert_eq!(serving.cache.pool_stats().live_bytes, 0);
    }
}

#[test]
fn compressed_cache_measures_smaller() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let mut peaks = Vec::new();
    for plan in [
        CompressionPlan::none(spec.n_layer, spec.n_kv_head),
        CompressionPlan::ae_first_layers(&spec, spec.n_layer),
        CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
    ] {
        let cfg = ServeConfig {
            plan,
            max_batch: 2,
            seed: 2,
            per_step_reconstruct: false,
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let reqs = vec![GenRequest::greedy(0, b"the grey rock stands .", 12)];
        serving.run(reqs).unwrap();
        peaks.push(serving.cache.pool_stats().peak_live_bytes);
    }
    assert!(
        peaks[1] < peaks[0] * 3 / 5,
        "AE cache not smaller: {peaks:?}"
    );
    assert!(peaks[2] < peaks[1] / 2, "int8 not smaller: {peaks:?}");
}

#[test]
fn faithful_reconstruction_matches_incremental() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    // mixed plan: AE on half the layers, one reused head pair, no quant
    // (quant packing is validated separately; f32 keeps this exact)
    let mut plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    plan.reuse_k[3][0] = true;
    plan.reuse_v[2][1] = true;
    let prompt = b"the wild foxes hide and the mossy stones stand .";
    let mut outs = Vec::new();
    for faithful in [false, true] {
        let cfg = ServeConfig {
            plan: plan.clone(),
            max_batch: 1,
            seed: 3,
            per_step_reconstruct: faithful,
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let out = serving
            .run(vec![GenRequest::greedy(0, prompt, 10)])
            .unwrap();
        outs.push(out[0].output.clone());
    }
    assert_eq!(
        outs[0], outs[1],
        "incremental vs per-step-reconstruct outputs diverge"
    );
}

#[test]
fn park_resume_rebuilds_effective_cache() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let cfg = ServeConfig {
        plan: CompressionPlan::ae_first_layers(&spec, 2),
        max_batch: 1,
        seed: 9,
        per_step_reconstruct: false,
    };
    let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
    // build a cached sequence directly through the public cache handle
    let id = serving.cache.create_sequence();
    let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
    let mut rng = kvcar::util::rng::Rng::new(13);
    let n = 12;
    for _ in 0..n {
        let kl: Vec<f32> = (0..l * dl).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let kr: Vec<f32> = (0..l * kvd).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        serving.cache.append_token(id, &kl, &kl, &kr, &kr).unwrap();
    }
    let mut tier = kvcar::kvcache::tier::HostTier::new();
    let park_cost = serving.park_sequence(id, &mut tier).unwrap();
    assert!(tier.is_parked(id));
    assert!(park_cost > std::time::Duration::ZERO);
    assert_eq!(serving.cache.decoded_upto(id), Some(0)); // watermark invalidated
    // double-park must be rejected, not silently double-counted
    assert!(serving.park_sequence(id, &mut tier).is_err());
    let resume_cost = serving.resume_sequence(id, &mut tier).unwrap();
    assert!(!tier.is_parked(id));
    assert!(resume_cost > std::time::Duration::ZERO);
    // resume rebuilt the effective cache in full: watermark back at len
    assert_eq!(serving.cache.decoded_upto(id), Some(n));
    assert!(serving.resume_sequence(id, &mut tier).is_err()); // not parked
}

#[test]
fn server_thread_front_end() {
    if !have_artifacts() {
        return;
    }
    let spec_plan;
    {
        let engine = Engine::new(&artifacts_dir()).unwrap();
        let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
        spec_plan = CompressionPlan::ae_first_layers(&spec, 2);
    }
    let server = kvcar::server::Server::start(
        artifacts_dir(),
        "gpt2t".into(),
        ServeConfig {
            plan: spec_plan,
            max_batch: 4,
            seed: 4,
            per_step_reconstruct: false,
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut joins = Vec::new();
    for i in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.generate(GenRequest {
                id: i,
                prompt: b"the quick birds ".to_vec(),
                max_new_tokens: 6,
                sampling: Sampling::Greedy,
                stop_byte: None,
            })
            .unwrap()
        }));
    }
    for (i, j) in joins.into_iter().enumerate() {
        let r = j.join().unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.generated_tokens, 6);
    }
    let m = handle.metrics().unwrap();
    assert_eq!(m.requests_completed, 4);
    server.shutdown();
}
