//! End-to-end pipeline integration over real artifacts:
//! pretrain -> AE stages -> head analysis -> serve, plus the
//! faithful-vs-incremental effective-cache equivalence that validates
//! the coordinator's reconstruction path.
//!
//! Kept small (tens of steps) — the full-scale run lives in
//! `examples/e2e_train_serve.rs` and EXPERIMENTS.md.

use kvcar::compress::planner::{to_masks, with_selection};
use kvcar::coordinator::{GenRequest, Sampling, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::train::{TrainConfig, Trainer};

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn quiet() -> TrainConfig {
    TrainConfig {
        verbose: false,
        ..TrainConfig::default()
    }
}

#[test]
fn train_pipeline_losses_improve() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let mut tr = Trainer::new(&mut engine, "gpt2t", quiet()).unwrap();
    let mut c = corpus::wiki(11);

    // stage 0: pretraining reduces CE
    let log = tr.pretrain(&mut c, 40).unwrap();
    assert!(
        log.last() < log.first() * 0.7,
        "pretrain did not learn: {} -> {}",
        log.first(),
        log.last()
    );

    // Alg. 1 stage 1 on two layers: per-layer runs converge
    let s1 = tr.ae_stage1(&mut c, &[0, 1], 15).unwrap();
    for log in &s1 {
        assert!(
            log.last() < log.first(),
            "{}: {} -> {}",
            log.stage,
            log.first(),
            log.last()
        );
    }

    // Alg. 1 stage 2 joint
    let s2 = tr.ae_stage2(&mut c, &[0, 1], 15).unwrap();
    assert!(s2.last() <= s2.first() * 1.05);

    // Alg. 2: similarity analysis produces usable distances
    let hd = tr.analyze_heads(&mut c, 2).unwrap();
    let sel = hd.select_top(1, 1);
    assert_eq!(sel.count_k(), 1);
    assert_eq!(sel.count_v(), 1);

    // Alg. 2: reuse finetune runs and keeps loss finite
    let plan = with_selection(
        CompressionPlan::none(tr.spec.n_layer, tr.spec.n_kv_head),
        &sel,
    );
    let ft = tr.reuse_finetune(&mut c, &to_masks(&plan), 10).unwrap();
    assert!(ft.last().is_finite());
    assert!(ft.last() < ft.first() * 1.2);
}

#[test]
fn serve_baseline_and_compressed_produce_tokens() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "tinyllama_t").unwrap();
    for ae_layers in [0, spec.n_layer] {
        // serving defaults on purpose: resident staging + f16 raw rows
        // must produce well-formed tokens end to end
        let cfg = ServeConfig {
            max_batch: 4,
            seed: 1,
            ..ServeConfig::new(CompressionPlan::ae_first_layers(&spec, ae_layers))
        };
        let mut serving = ServingEngine::new(&mut engine, "tinyllama_t", cfg).unwrap();
        let reqs: Vec<GenRequest> = (0..3)
            .map(|i| GenRequest::greedy(i, b"the furry cat ", 8))
            .collect();
        let out = serving.run(reqs).unwrap();
        assert_eq!(out.len(), 3);
        for r in &out {
            assert_eq!(r.generated_tokens, 8);
            assert_eq!(r.output.len(), 8);
        }
        assert_eq!(serving.metrics.requests_completed, 3);
        assert!(serving.metrics.tokens_generated >= 24);
        // all cache memory released at retire
        assert_eq!(serving.cache.pool_stats().live_bytes, 0);
    }
}

#[test]
fn compressed_cache_measures_smaller() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let mut peaks = Vec::new();
    for plan in [
        CompressionPlan::none(spec.n_layer, spec.n_kv_head),
        CompressionPlan::ae_first_layers(&spec, spec.n_layer),
        CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
    ] {
        // f32 raw rows pinned so the measured byte ratios isolate the
        // compression plans (f16 would shrink the baseline itself)
        let cfg = ServeConfig {
            max_batch: 2,
            seed: 2,
            raw_format: kvcar::kvcache::Format::F32,
            ..ServeConfig::new(plan)
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let reqs = vec![GenRequest::greedy(0, b"the grey rock stands .", 12)];
        serving.run(reqs).unwrap();
        peaks.push(serving.cache.pool_stats().peak_live_bytes);
    }
    assert!(
        peaks[1] < peaks[0] * 3 / 5,
        "AE cache not smaller: {peaks:?}"
    );
    assert!(peaks[2] < peaks[1] / 2, "int8 not smaller: {peaks:?}");
}

#[test]
fn faithful_reconstruction_matches_incremental() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    // mixed plan: AE on half the layers, one reused head pair, no quant
    // (quant packing is validated separately; f32 keeps this exact)
    let mut plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    plan.reuse_k[3][0] = true;
    plan.reuse_v[2][1] = true;
    let prompt = b"the wild foxes hide and the mossy stones stand .";
    let mut outs = Vec::new();
    for faithful in [false, true] {
        // f32 raw rows: the faithful path re-reads stored head-subset
        // rows, so bit-exact agreement with in-graph needs lossless raw
        let cfg = ServeConfig {
            max_batch: 1,
            seed: 3,
            per_step_reconstruct: faithful,
            raw_format: kvcar::kvcache::Format::F32,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let out = serving
            .run(vec![GenRequest::greedy(0, prompt, 10)])
            .unwrap();
        outs.push(out[0].output.clone());
    }
    assert_eq!(
        outs[0], outs[1],
        "incremental vs per-step-reconstruct outputs diverge"
    );
}

#[test]
fn resident_staging_matches_copy_path_and_stages_o_new_rows() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    let prompt = b"the wild foxes hide and the mossy stones stand .";
    let (n_seq, max_new) = (3usize, 8usize);
    let (l, kvd) = (spec.n_layer, spec.kv_dim());
    for faithful in [false, true] {
        let mut outs = Vec::new();
        let mut staged = Vec::new();
        for resident in [true, false] {
            let cfg = ServeConfig {
                max_batch: n_seq,
                seed: 11,
                per_step_reconstruct: faithful,
                resident_cache: resident,
                raw_format: kvcar::kvcache::Format::F32,
                ..ServeConfig::new(plan.clone())
            };
            let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
            let reqs: Vec<GenRequest> = (0..n_seq as u64)
                .map(|i| GenRequest::greedy(i, prompt, max_new))
                .collect();
            let out = serving.run(reqs).unwrap();
            outs.push(out.iter().map(|r| r.output.clone()).collect::<Vec<_>>());
            let m = &serving.metrics;
            staged.push(m.staged_kv_bytes);
            if resident {
                // the staged-bytes cost law: after each slot's initial
                // fill, every steady round stages exactly one new row
                // per live sequence per side — 2·B·L·kvd·4 bytes —
                // regardless of context length or compiled batch width
                let steady = (m.decode_rounds - 1) * (2 * n_seq * l * kvd * 4) as u64;
                assert_eq!(
                    m.staged_kv_bytes, steady,
                    "resident path must stage O(B*L*kvd) per steady round (faithful={faithful})"
                );
                assert_eq!(m.slot_rebuilds, n_seq as u64, "one slot fill per admission");
                assert_eq!(m.capacity_switches, 0, "steady workload must not flap rungs");
            }
        }
        // identical greedy tokens: the resident mirror feeds the decode
        // step bitwise-identical k/v inputs, so logits cannot diverge
        assert_eq!(
            outs[0], outs[1],
            "resident staging diverges from the copy path (faithful={faithful})"
        );
        assert!(
            staged[0] * 8 < staged[1],
            "resident path must stage far fewer bytes: {} vs {}",
            staged[0],
            staged[1]
        );
    }
}

#[test]
fn device_residency_and_buffer_cache_modes_are_bitwise_identical() {
    // three execution modes over the same workload must emit identical
    // greedy tokens: (1) the default buffered path with device-resident
    // delta uploads, (2) buffered with residency off (full re-upload on
    // every version bump — the reference the delta path degrades to),
    // and (3) literal-per-call execution with no buffer cache at all.
    // Greedy argmax over logits is the strictest end-to-end observer:
    // a single stale or mis-patched device row would flip a token.
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    let prompt = b"the wild foxes hide and the mossy stones stand .";
    for faithful in [false, true] {
        let mut outs = Vec::new();
        let mut input_bytes = Vec::new();
        for (residency, buffered) in [(true, true), (false, true), (false, false)] {
            engine.use_buffer_cache = buffered;
            let cfg = ServeConfig {
                max_batch: 3,
                seed: 17,
                per_step_reconstruct: faithful,
                device_residency: residency,
                raw_format: kvcar::kvcache::Format::F32,
                ..ServeConfig::new(plan.clone())
            };
            let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
            let reqs: Vec<GenRequest> = (0..3u64)
                .map(|i| GenRequest::greedy(i, prompt, 8))
                .collect();
            let out = serving.run(reqs).unwrap();
            outs.push(out.iter().map(|r| r.output.clone()).collect::<Vec<_>>());
            let m = &serving.metrics;
            // the byte meters must be live on every mode
            assert!(m.input_bytes > 0, "no input bytes counted (buffered={buffered})");
            assert!(m.output_bytes > 0, "no output bytes counted (buffered={buffered})");
            if buffered && residency {
                // regions were synced through the residency path; with
                // the PJRT binding unable to patch in place, every sync
                // is a counted full-upload fallback, never a stale skip
                assert!(m.resident_bytes_uploaded > 0);
                assert!(m.full_uploads > 0);
            }
            input_bytes.push(m.input_bytes);
        }
        assert_eq!(
            outs[0], outs[1],
            "delta-upload residency diverges from full re-upload (faithful={faithful})"
        );
        assert_eq!(
            outs[1], outs[2],
            "buffered execution diverges from literal-per-call (faithful={faithful})"
        );
        // the buffered modes keep parameters device-resident, so they
        // must move strictly fewer host->device bytes than literal mode
        assert!(
            input_bytes[0] < input_bytes[2] && input_bytes[1] < input_bytes[2],
            "buffer cache must save upload bytes: {input_bytes:?}"
        );
    }
    engine.use_buffer_cache = true;
}

#[test]
fn batched_faithful_decode_issues_one_decoder_call_per_round() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let has_bt = engine.manifest.entries.contains_key("gpt2t_decode_kv_bt");
    let has_pb = engine.manifest.entries.contains_key("gpt2t_prefill_b");
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    let prompt = b"the wild foxes hide and the mossy stones stand .";
    let (b, max_new) = (3usize, 6usize);

    // reference: the same workload through the in-graph path
    let mut outs = Vec::new();
    let mut faithful_execs = 0;
    for faithful in [false, true] {
        let cfg = ServeConfig {
            max_batch: b,
            seed: 5,
            per_step_reconstruct: faithful,
            raw_format: kvcar::kvcache::Format::F32,
            // identical prompts + sharing would dedup admission to one
            // launch and break the exact execution-count law below;
            // this test pins the pre-sharing baseline (sharing has its
            // own laws in tests/prefix_sharing.rs)
            prefix_sharing: false,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let exec0 = serving.engine.stats().executions;
        let reqs: Vec<GenRequest> = (0..b as u64)
            .map(|i| GenRequest::greedy(i, prompt, max_new))
            .collect();
        let out = serving.run(reqs).unwrap();
        outs.push(out.iter().map(|r| r.output.clone()).collect::<Vec<_>>());
        if faithful {
            faithful_execs = serving.engine.stats().executions - exec0;
            if has_bt {
                // decode rounds after the first: ONE batched decoder call
                // each (max_new - 1 rounds total, first is the bulk
                // prompt reconstruction fallback)
                let rounds = (max_new - 1) as u64;
                assert_eq!(
                    serving.batched.stats.batched_calls,
                    rounds - 1,
                    "steady-state rounds must issue exactly one decoder call"
                );
                assert_eq!(
                    serving.batched.stats.batched_rows,
                    (rounds - 1) * b as u64
                );
                // fallbacks: only the per-sequence prompt rebuilds
                assert_eq!(serving.batched.stats.fallback_advances, b as u64);
                // engine accounting: the admission wave's prefill
                // launches (one batched launch, or b per-request ones
                // on older artifact sets) + round 1 (b bulk decode_kv
                // + 1 decode_step) + (rounds-1) * (decode_kv_bt +
                // decode_step)
                let prefills = if has_pb { 1 } else { b as u64 };
                assert_eq!(
                    faithful_execs,
                    prefills + (b + 1) as u64 + (rounds - 1) * 2,
                    "faithful decode must scale in O(1) launches per round"
                );
            }
        }
    }
    assert_eq!(outs[0], outs[1], "batched faithful diverges from in-graph");
    // and strictly fewer launches than the per-sequence faithful law
    // (b prefills + rounds * (b decoder calls + 1 step)) when batched
    if has_bt {
        let per_seq = (b + (max_new - 1) * (b + 1)) as u64;
        assert!(
            faithful_execs < per_seq,
            "batched path must beat per-sequence launches: {faithful_execs} vs {per_seq}"
        );
    }
}

#[test]
fn wave_admission_single_launch_and_identical_outputs() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let has_pb = engine.manifest.entries.contains_key("gpt2t_prefill_b");
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    // distinct prompts per lane so cross-lane leakage could not hide
    let prompts: [&[u8]; 3] = [
        b"the wild foxes hide and wait .",
        b"a small stone sits very still",
        b"rivers run over the old roots .",
    ];
    let mut outs = Vec::new();
    let mut execs = Vec::new();
    let mut launches = Vec::new();
    for batched in [true, false] {
        let cfg = ServeConfig {
            max_batch: 3,
            seed: 21,
            batched_prefill: batched,
            raw_format: kvcar::kvcache::Format::F32,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let exec0 = serving.engine.stats().executions;
        let reqs: Vec<GenRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| GenRequest::greedy(i as u64, p, 6))
            .collect();
        let out = serving.run(reqs).unwrap();
        outs.push(out.iter().map(|r| r.output.clone()).collect::<Vec<_>>());
        execs.push(serving.engine.stats().executions - exec0);
        launches.push((
            serving.metrics.prefill_waves,
            serving.metrics.prefill_launches,
            serving.waves.stats.batched_lanes,
        ));
        // one admission wave either way; launch counts differ below
        assert_eq!(serving.metrics.prefill_waves, 1);
        assert_eq!(serving.metrics.wave_admitted.total(), 3);
    }
    // lane b of prefill_b is bit-identical to a per-request prefill, so
    // the generated tokens cannot depend on the admission path
    assert_eq!(
        outs[0], outs[1],
        "batched admission diverges from per-request prefill"
    );
    // forced per-request ladder: one launch per admitted request
    assert_eq!(launches[1].1, 3);
    assert_eq!(launches[1].2, 0, "disabled wave path must not batch");
    if has_pb {
        // the one-launch-per-wave law, via both the planner counter and
        // the engine's execution accounting (2 launches saved on 3 lanes)
        assert_eq!(launches[0].1, 1, "one admission wave, one prefill launch");
        assert_eq!(launches[0].2, 3);
        assert_eq!(
            execs[1] - execs[0],
            2,
            "wave admission must save admitted-1 launches"
        );
    }
}

#[test]
fn prefix_sharing_saves_launches_with_identical_outputs() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    // a template-heavy burst: 6 requests over 2 distinct prompts
    let prompts: [&[u8]; 2] = [
        b"the wild foxes hide and wait by the mossy stones .",
        b"the wild foxes hide and wait by the open river .",
    ];
    let mut outs = Vec::new();
    let mut launches = Vec::new();
    for sharing in [true, false] {
        let cfg = ServeConfig {
            max_batch: 6,
            seed: 31,
            prefix_sharing: sharing,
            raw_format: kvcar::kvcache::Format::F32,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
        let reqs: Vec<GenRequest> = (0..6u64)
            .map(|i| GenRequest::greedy(i, prompts[i as usize % 2], 5))
            .collect();
        let out = serving.run(reqs).unwrap();
        outs.push(out.iter().map(|r| r.output.clone()).collect::<Vec<_>>());
        launches.push(serving.metrics.prefill_launches);
        if sharing {
            // 4 of the 6 requests replay an identical clamped prompt
            assert_eq!(serving.metrics.shared_admissions, 4);
            // the two distinct prompts share their leading chunks once
            assert!(serving.cache.prefix_stats().chunk_hits > 0);
            assert!(serving.cache.prefix_stats().shared_bytes > 0);
        }
        // every sequence retired cleanly; only pinned template chains
        // may keep shared bytes warm
        assert_eq!(serving.tier.parked_count(), 0);
    }
    assert!(
        launches[0] < launches[1],
        "sharing must save prefill launches: {} vs {}",
        launches[0],
        launches[1]
    );
    // prefill is a pure function of the clamped prompt: outputs are
    // bitwise independent of the sharing axis
    assert_eq!(outs[0], outs[1], "prefix sharing changed generated tokens");
}

#[test]
fn tight_budget_parks_resumes_and_completes() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let plan = CompressionPlan::ae_first_layers(&spec, 2);
    // 47-byte prompt: two admitted sequences fit the worst-case check
    // (2 * 55U <= 110U) but outgrow the budget with round headroom
    // (96U measured + 2*16U > 110U), so the batcher must park the
    // lowest-priority one and bring it back.  Output equality vs a
    // never-parked run is asserted bitwise at the cache level in
    // tests/batched_faithful.rs (compiled decode_step graphs differ by
    // batch size here, so token-level cross-run comparison would test
    // XLA numerics, not the parking path)
    let prompt = b"the grey rock stands and the small birds sing .";
    let budget =
        kvcar::coordinator::batcher::request_cache_bytes(&spec, &plan, prompt.len(), 8) * 2;
    let reqs = |n: usize| -> Vec<GenRequest> {
        (0..n as u64).map(|i| GenRequest::greedy(i, prompt, 8)).collect()
    };
    // f32 raw rows: the budget below is sized from the f32 modeled rate.
    // Sharing off: the identical prompts would otherwise dedup their
    // prefix bytes, shrinking the working set below the pressure point
    // this test is tuned to hit
    let cfg = ServeConfig {
        max_batch: 3,
        seed: 7,
        cache_budget: Some(budget),
        raw_format: kvcar::kvcache::Format::F32,
        prefix_sharing: false,
        ..ServeConfig::new(plan.clone())
    };
    let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
    let out = serving.run(reqs(3)).unwrap();
    // every request completes in full despite the pressure
    assert_eq!(out.len(), 3);
    for r in &out {
        assert_eq!(r.generated_tokens, 8);
    }
    assert!(
        serving.metrics.auto_parks > 0,
        "tight budget must trigger admission-control parking"
    );
    assert_eq!(
        serving.metrics.auto_parks, serving.metrics.auto_resumes,
        "every parked sequence must resume and finish"
    );
    assert!(serving.tier.stats.bytes_out > 0, "real bytes must have moved");
    assert_eq!(serving.tier.stats.bytes_in, serving.tier.stats.bytes_out);
    assert_eq!(serving.tier.parked_count(), 0);
    assert_eq!(serving.cache.pool_stats().live_bytes, 0);
}

#[test]
fn park_resume_rebuilds_effective_cache() {
    if !have_artifacts() {
        return;
    }
    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    let cfg = ServeConfig {
        max_batch: 1,
        seed: 9,
        ..ServeConfig::new(CompressionPlan::ae_first_layers(&spec, 2))
    };
    let mut serving = ServingEngine::new(&mut engine, "gpt2t", cfg).unwrap();
    // build a cached sequence directly through the public cache handle
    let id = serving.cache.create_sequence();
    let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
    let mut rng = kvcar::util::rng::Rng::new(13);
    let n = 12;
    for _ in 0..n {
        let kl: Vec<f32> = (0..l * dl).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let kr: Vec<f32> = (0..l * kvd).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        serving.cache.append_token(id, &kl, &kl, &kr, &kr).unwrap();
    }
    // snapshot the compressed store before the tier round-trip
    let mut before = Vec::new();
    for layer in 0..spec.n_layer {
        for side in [kvcar::kvcache::Side::K, kvcar::kvcache::Side::V] {
            before.push(format!(
                "{:?}",
                serving.cache.stored_rows(id, layer, side).unwrap()
            ));
        }
    }
    let device_bytes = serving.cache.seq_stored_bytes(id);
    let park_cost = serving.park_sequence(id).unwrap();
    assert!(serving.tier.is_parked(id));
    assert!(park_cost > std::time::Duration::ZERO);
    assert_eq!(serving.cache.decoded_upto(id), Some(0)); // watermark invalidated
    // the spill is a real move: device blocks freed, host holds the bytes
    assert_eq!(serving.cache.seq_stored_bytes(id), 0);
    assert!(serving.tier.parked_bytes(id).unwrap() > 0);
    // double-park must be rejected, not silently double-counted
    assert!(serving.park_sequence(id).is_err());
    let resume_cost = serving.resume_sequence(id).unwrap();
    assert!(!serving.tier.is_parked(id));
    assert!(resume_cost > std::time::Duration::ZERO);
    // resume rebuilt the effective cache in full: watermark back at len
    assert_eq!(serving.cache.decoded_upto(id), Some(n));
    assert!(serving.resume_sequence(id).is_err()); // not parked
    // the restored compressed store is bit-identical
    assert_eq!(serving.cache.seq_stored_bytes(id), device_bytes);
    for (i, (layer, side)) in (0..spec.n_layer)
        .flat_map(|l| [kvcar::kvcache::Side::K, kvcar::kvcache::Side::V].map(|s| (l, s)))
        .enumerate()
    {
        assert_eq!(
            format!("{:?}", serving.cache.stored_rows(id, layer, side).unwrap()),
            before[i],
            "stream ({layer}, {side:?}) diverges after the tier round-trip"
        );
    }
}

#[test]
fn server_thread_front_end() {
    if !have_artifacts() {
        return;
    }
    let spec_plan;
    {
        let engine = Engine::new(&artifacts_dir()).unwrap();
        let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
        spec_plan = CompressionPlan::ae_first_layers(&spec, 2);
    }
    let server = kvcar::server::Server::start(
        artifacts_dir(),
        "gpt2t".into(),
        ServeConfig {
            max_batch: 4,
            seed: 4,
            ..ServeConfig::new(spec_plan)
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut joins = Vec::new();
    for i in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            h.generate(GenRequest {
                id: i,
                prompt: b"the quick birds ".to_vec(),
                max_new_tokens: 6,
                sampling: Sampling::Greedy,
                stop_byte: None,
                arrival: None,
            })
            .unwrap()
        }));
    }
    for (i, j) in joins.into_iter().enumerate() {
        let r = j.join().unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.generated_tokens, 6);
    }
    let m = handle.metrics().unwrap();
    assert_eq!(m.requests_completed, 4);
    server.shutdown();
}
