//! Sharded serving integration suite: the sharded scenario matrix is
//! bitwise-pinned against single-worker runs, the delta law is proved
//! on a controlled ping-pong migration (a return trip ships only the
//! groups appended since the replica basis was taken), shared prefix
//! chunks are shown to ship at most once per worker ever, and a
//! property-style interleaving of admit/migrate/park/resume/drain/
//! retire across 2–4 workers re-audits every cluster invariant after
//! every operation.

use kvcar::coordinator::{
    run_scenario, run_sharded, scenario_spec, sharded_matrix, Clock, GenRequest, MigrationOutcome,
    Router, RouterConfig, Sampling, ServeConfig, ServingEngine, ShardedReport, ShardedScenario,
    Stamp,
};
use kvcar::kvcache::CacheConfig;
use kvcar::model::memory::CompressionPlan;
use kvcar::runtime::{ExecBackend, MockEngine};

fn base_cfg() -> ServeConfig {
    let spec = scenario_spec();
    ServeConfig::new(CompressionPlan::ae_first_layers(&spec, 1))
}

fn bytes_per_token() -> usize {
    let spec = scenario_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, 1);
    CacheConfig::new(spec, plan).bytes_per_token()
}

fn prompt_bytes(seed: usize, len: usize) -> Vec<u8> {
    (0..len).map(|i| ((seed * 31 + i * 7) % 64) as u8).collect()
}

fn request(id: u64, prompt: Vec<u8>, max_new: usize, arrival_ms: Option<u64>) -> GenRequest {
    GenRequest {
        id,
        prompt,
        max_new_tokens: max_new,
        sampling: Sampling::Greedy,
        stop_byte: None,
        arrival: arrival_ms.map(Stamp::from_ms),
    }
}

/// The never-migrated reference: the same workload on one worker.
fn single_outputs(cfg: ServeConfig, requests: Vec<GenRequest>) -> Vec<(u64, Vec<u8>)> {
    let mut engine = MockEngine::new(scenario_spec());
    let mut serving = ServingEngine::new(&mut engine, "mock", cfg).expect("single-worker engine");
    serving.set_clock(Clock::virtual_default());
    serving
        .run(requests)
        .expect("single-worker run")
        .into_iter()
        .map(|r| (r.id, r.output))
        .collect()
}

fn run_matrix_scenario(sc: &ShardedScenario) -> ShardedReport {
    let mut engines: Vec<MockEngine> =
        (0..sc.n_workers).map(|_| MockEngine::new(scenario_spec())).collect();
    let backends: Vec<&mut dyn ExecBackend> =
        engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
    run_sharded(backends, "mock", sc).expect("sharded scenario must pass its cluster audits")
}

fn audit(router: &Router<'_>, n: usize, round: u64) {
    if let Err(v) = router.check(false) {
        panic!("cluster invariants violated (n={n}, round {round}):\n{v}");
    }
}

#[test]
fn sharded_matrix_is_bitwise_identical_to_single_worker() {
    for sc in sharded_matrix() {
        let r = run_matrix_scenario(&sc);
        let mut engine = MockEngine::new(scenario_spec());
        let control = run_scenario(&mut engine, "mock", &sc.base).expect("single-worker control");
        assert_eq!(
            r.completed,
            sc.base.trace.n_requests,
            "'{}' must complete every request",
            r.name
        );
        assert_eq!(
            r.tokens_digest, control.tokens_digest,
            "'{}' token streams diverged from the single-worker run",
            r.name
        );
        assert_eq!(
            r.output_digests, control.output_digests,
            "'{}' per-request digests diverged from the single-worker run",
            r.name
        );
        assert_eq!(
            r.migrations,
            r.forced_migrations + r.rebalance_migrations + r.drain_migrations,
            "'{}' committed a migration nothing initiated",
            r.name
        );
        assert_eq!(
            r.full_bytes,
            r.delta_bytes + r.bytes_saved,
            "'{}' delta-law denominator must decompose",
            r.name
        );
        match r.name.as_str() {
            "sharded_nomad" => {
                assert!(
                    r.forced_migrations >= 3,
                    "the nomad must hop at least 3 times, hopped {}",
                    r.forced_migrations
                );
                // the delta law on the wire: return trips hit the
                // replica basis, so less than the full payload shipped
                assert!(r.bytes_saved > 0, "nomad return trips never hit a replica basis");
                assert!(
                    r.delta_bytes < r.full_bytes,
                    "re-migration must ship less than the full sequence ({} vs {})",
                    r.delta_bytes,
                    r.full_bytes
                );
                assert_eq!(r.chunk_bytes, 0, "the nomad runs without prefix sharing");
            }
            "sharded_shared_prefix_drain" => {
                assert!(r.migrations >= 1, "the drain scenario never migrated");
                assert!(
                    r.chunks_in + r.chunks_deduped >= 1,
                    "shared-prefix migrations must account their chunks"
                );
            }
            "sharded_corrupt_transfer" => {
                assert_eq!(
                    r.corruption_rollbacks, 2,
                    "both armed corruptions must be caught by the delta CRCs and rolled back"
                );
                assert!(
                    r.forced_migrations >= 1,
                    "clean hops after the armed corruptions must commit"
                );
            }
            _ => {}
        }
    }
}

#[test]
fn sharded_runs_are_deterministic() {
    for sc in sharded_matrix() {
        let a = run_matrix_scenario(&sc);
        let b = run_matrix_scenario(&sc);
        assert_eq!(a, b, "'{}' must reproduce bit for bit", sc.base.name);
    }
}

#[test]
fn remigration_ships_only_groups_appended_since_the_basis() {
    let mut engines: Vec<MockEngine> = (0..2).map(|_| MockEngine::new(scenario_spec())).collect();
    let backends: Vec<&mut dyn ExecBackend> =
        engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
    let mut cfg = base_cfg();
    cfg.max_batch = 2;
    let req = request(11, prompt_bytes(3, 24), 20, None);
    let control = single_outputs(cfg.clone(), vec![req.clone()]);
    let rcfg = RouterConfig {
        auto_rebalance: false,
        ..RouterConfig::default()
    };
    let mut router = Router::new(backends, "mock", cfg, rcfg).expect("router");
    router.set_clock(&Clock::virtual_default());
    router.begin(vec![req]);
    // grow the sequence past one full 16-row delta group of own suffix
    for round in 0..10u64 {
        assert!(router.step().expect("round"), "sequence finished before the first migration");
        audit(&router, 2, round);
    }
    let src = (0..2).find(|&w| !router.live_requests(w).is_empty()).expect("a live sequence");
    let dst = 1 - src;
    let (_, cache_id) = *router.live_requests(src).first().expect("live sequence on src");
    let MigrationOutcome::Committed {
        delta_bytes: d1,
        bytes_saved: s1,
        ..
    } = router.migrate(src, dst, cache_id, false).expect("first migration")
    else {
        panic!("first migration must commit");
    };
    assert!(d1 > 0, "the first trip must ship the suffix");
    assert_eq!(s1, 0, "no replica basis exists yet: the full suffix must ship");
    audit(&router, 2, 10);
    // append more tokens on the destination, then send it home
    for round in 10..14u64 {
        assert!(router.step().expect("round"), "sequence finished before the return trip");
        audit(&router, 2, round);
    }
    let (_, back) = *router.live_requests(dst).first().expect("live sequence on dst");
    let MigrationOutcome::Committed {
        delta_bytes: d2,
        bytes_saved: s2,
        ..
    } = router.migrate(dst, src, back, false).expect("return migration")
    else {
        panic!("return migration must commit");
    };
    assert!(s2 > 0, "the source's retained replica must supply the stable groups");
    assert!(
        d2 < d1,
        "the grown sequence's return trip must ship less than its first trip ({d2} vs {d1})"
    );
    assert!(
        d2 + s2 > d1,
        "the full payload must have grown between the trips ({} vs {d1})",
        d2 + s2
    );
    audit(&router, 2, 14);
    let mut round = 14u64;
    while router.step().expect("round") {
        round += 1;
        audit(&router, 2, round);
        assert!(round < 256, "run did not converge");
    }
    let out: Vec<(u64, Vec<u8>)> = router.finish().into_iter().map(|r| (r.id, r.output)).collect();
    assert_eq!(out, control, "two migrations must not perturb a single future token");
    assert_eq!(router.stats().migrations, 2);
}

#[test]
fn shared_prefix_chunks_ship_at_most_once_per_worker_ever() {
    let mut engines: Vec<MockEngine> = (0..2).map(|_| MockEngine::new(scenario_spec())).collect();
    let backends: Vec<&mut dyn ExecBackend> =
        engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
    let mut cfg = base_cfg();
    cfg.max_batch = 4;
    let prompt = prompt_bytes(9, 24);
    let requests: Vec<GenRequest> =
        (0..4).map(|i| request(i, prompt.clone(), 16, None)).collect();
    let control = single_outputs(cfg.clone(), requests.clone());
    let rcfg = RouterConfig {
        auto_rebalance: false,
        ..RouterConfig::default()
    };
    let mut router = Router::new(backends, "mock", cfg, rcfg).expect("router");
    router.set_clock(&Clock::virtual_default());
    router.begin(requests);
    for round in 0..3u64 {
        assert!(router.step().expect("round"), "sequences finished too early");
        audit(&router, 2, round);
    }
    // pick the victim on the worker with the most sharers, so the
    // chain stays alive on the source after the victim leaves
    let src = (0..2).max_by_key(|&w| router.live_requests(w).len()).unwrap();
    let dst = 1 - src;
    let (req_id, cache_id) = *router.live_requests(src).first().expect("live sequence on src");
    assert!(
        router.engine(src).cache.seq_prefix_leaf(cache_id).is_some(),
        "a shared 24-token prompt must hold a block-aligned prefix chain"
    );
    let find = |router: &Router<'_>, w: usize| {
        router.live_requests(w).iter().find(|(r, _)| *r == req_id).map(|&(_, c)| c)
    };
    // trip 1: the chain is accounted on the destination, shipped or
    // (if the destination's own sharers already built it) deduped
    let in0 = router.engine(dst).metrics.migration_chunks_in;
    let dd0 = router.engine(dst).metrics.migration_chunks_deduped;
    let MigrationOutcome::Committed { .. } =
        router.migrate(src, dst, cache_id, false).expect("first migration")
    else {
        panic!("first migration must commit");
    };
    let shipped = router.engine(dst).metrics.migration_chunks_in - in0;
    let deduped = router.engine(dst).metrics.migration_chunks_deduped - dd0;
    assert!(shipped + deduped >= 1, "the chain must be accounted on delivery");
    audit(&router, 2, 3);
    assert!(router.step().expect("round"), "victim finished too early");
    audit(&router, 2, 4);
    // trip 2 (return): the source still holds the chain — no bytes
    let back = find(&router, dst).expect("victim live on destination");
    let MigrationOutcome::Committed { chunk_bytes: cb2, .. } =
        router.migrate(dst, src, back, false).expect("return migration")
    else {
        panic!("return migration must commit");
    };
    assert_eq!(cb2, 0, "the return trip must not re-ship a chain the source holds");
    audit(&router, 2, 4);
    assert!(router.step().expect("round"), "victim finished too early");
    audit(&router, 2, 5);
    // trip 3 (same direction as trip 1): the delivered ledger makes a
    // repeat delivery free, no matter what happened in between
    let again = find(&router, src).expect("victim live on source");
    let in_before = router.engine(dst).metrics.migration_chunks_in;
    let dd_before = router.engine(dst).metrics.migration_chunks_deduped;
    let MigrationOutcome::Committed { chunk_bytes: cb3, .. } =
        router.migrate(src, dst, again, false).expect("third migration")
    else {
        panic!("third migration must commit");
    };
    assert_eq!(cb3, 0, "a chunk ships at most once per worker, ever");
    assert_eq!(
        router.engine(dst).metrics.migration_chunks_in,
        in_before,
        "the repeat delivery must not travel"
    );
    assert!(
        router.engine(dst).metrics.migration_chunks_deduped > dd_before,
        "the repeat delivery must be counted as deduped"
    );
    let mut round = 5u64;
    while router.step().expect("round") {
        round += 1;
        audit(&router, 2, round);
        assert!(round < 256, "run did not converge");
    }
    let out: Vec<(u64, Vec<u8>)> = router.finish().into_iter().map(|r| (r.id, r.output)).collect();
    assert_eq!(out, control, "chunk dedup must not perturb a single token");
}

fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

#[test]
fn interleaved_migrate_park_drain_keeps_every_invariant_and_output() {
    let bpt = bytes_per_token();
    // a budget two mid-sized sequences overflow, so park/resume churn
    // interleaves with the forced migrations and the drain
    let budget = Some(64 * bpt);
    let requests: Vec<GenRequest> = (0..9u64)
        .map(|i| {
            let len = 18 + (i as usize * 5) % 7;
            let max_new = 8 + (i as usize * 3) % 7;
            request(i, prompt_bytes(40 + i as usize, len), max_new, Some(i * 5))
        })
        .collect();
    let mut cfg = base_cfg();
    cfg.max_batch = 4;
    cfg.cache_budget = budget;
    let control = single_outputs(cfg.clone(), requests.clone());
    let (mut moves_total, mut parks_total, mut resumes_total) = (0u64, 0u64, 0u64);
    for n in 2..=4usize {
        let mut engines: Vec<MockEngine> =
            (0..n).map(|_| MockEngine::new(scenario_spec())).collect();
        let backends: Vec<&mut dyn ExecBackend> =
            engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
        let rcfg = RouterConfig {
            auto_rebalance: false,
            ..RouterConfig::default()
        };
        let mut router = Router::new(backends, "mock", cfg.clone(), rcfg).expect("router");
        router.set_clock(&Clock::virtual_default());
        router.begin(requests.clone());
        let mut rng: u64 = 0x243F_6A88_85A3_08D3 ^ ((n as u64) << 7);
        let mut drained: Option<usize> = None;
        let mut rounds = 0u64;
        loop {
            rounds += 1;
            assert!(rounds < 4096, "cluster (n={n}) did not converge");
            let more = router.step().unwrap_or_else(|e| panic!("step failed (n={n}): {e:?}"));
            audit(&router, n, rounds);
            if !more {
                break;
            }
            if rounds == 4 {
                let w = (lcg(&mut rng) as usize) % n;
                router.drain(w).expect("drain");
                drained = Some(w);
                audit(&router, n, rounds);
            }
            if rounds == 9 {
                if let Some(w) = drained.take() {
                    router.undrain(w);
                }
            }
            // hop one pseudo-random live sequence every round
            let candidates: Vec<(usize, u64)> = (0..n)
                .flat_map(|w| router.live_requests(w).into_iter().map(move |(_, c)| (w, c)))
                .collect();
            if !candidates.is_empty() {
                let (src, cache_id) = candidates[(lcg(&mut rng) as usize) % candidates.len()];
                let mut dst = (src + 1 + (lcg(&mut rng) as usize) % (n - 1)) % n;
                if Some(dst) == drained {
                    dst = (dst + 1) % n;
                }
                if dst != src && Some(dst) != drained {
                    match router.migrate(src, dst, cache_id, false).expect("migrate") {
                        MigrationOutcome::Committed { .. } => moves_total += 1,
                        MigrationOutcome::RolledBack { fault } => {
                            panic!("clean migration rolled back (n={n}): {}", fault.msg)
                        }
                    }
                    audit(&router, n, rounds);
                }
            }
        }
        let out: Vec<(u64, Vec<u8>)> =
            router.finish().into_iter().map(|r| (r.id, r.output)).collect();
        assert_eq!(out, control, "sharded outputs (n={n}) diverged from the single-worker run");
        for w in 0..n {
            let m = &router.engine(w).metrics;
            parks_total += m.auto_parks;
            resumes_total += m.auto_resumes;
        }
        moves_total += router.stats().drain_migrations;
    }
    assert!(moves_total >= 1, "the interleave never migrated anything");
    assert!(parks_total >= 1, "the budget never forced a park anywhere");
    assert!(resumes_total >= 1, "no parked sequence ever resumed");
}

#[test]
fn mixed_rung_migration_stays_bit_faithful_and_delta_efficient() {
    // heterogeneous-rung wire transfers: under a genuinely partitioned
    // adaptive manifest (raw-f32 sink block, int8 cold region, plan-rung
    // tail) the same ping-pong as above must still commit through the
    // per-group CRCs, still satisfy the delta law on the return trip,
    // and must not perturb one token versus the never-migrated
    // single-worker run under the identical manifest
    use kvcar::compress::strategy::{PlanManifest, RegionSpec, Rung};
    let spec = scenario_spec();
    let manifest = PlanManifest {
        plan: CompressionPlan::ae_first_layers(&spec, 1),
        regions: vec![
            RegionSpec { start: 0, end: Some(16), rung: Rung::RawF32 },
            RegionSpec { start: 16, end: Some(32), rung: Rung::Int8 },
            RegionSpec { start: 32, end: None, rung: Rung::Plan },
        ],
    };
    manifest.validate(16).expect("mixed manifest must validate");
    let mut engines: Vec<MockEngine> = (0..2).map(|_| MockEngine::new(scenario_spec())).collect();
    let backends: Vec<&mut dyn ExecBackend> =
        engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
    let mut cfg = base_cfg();
    cfg.max_batch = 2;
    cfg.adaptive_plan = Some(manifest);
    let req = request(17, prompt_bytes(5, 24), 20, None);
    let control = single_outputs(cfg.clone(), vec![req.clone()]);
    let rcfg = RouterConfig {
        auto_rebalance: false,
        ..RouterConfig::default()
    };
    let mut router = Router::new(backends, "mock", cfg, rcfg).expect("router");
    router.set_clock(&Clock::virtual_default());
    router.begin(vec![req]);
    for round in 0..10u64 {
        assert!(router.step().expect("round"), "sequence finished before the first migration");
        audit(&router, 2, round);
    }
    let src = (0..2).find(|&w| !router.live_requests(w).is_empty()).expect("a live sequence");
    let dst = 1 - src;
    let (_, cache_id) = *router.live_requests(src).first().expect("live sequence on src");
    let MigrationOutcome::Committed { delta_bytes: d1, bytes_saved: s1, .. } =
        router.migrate(src, dst, cache_id, false).expect("first migration")
    else {
        panic!("first mixed-rung migration must commit");
    };
    assert!(d1 > 0, "the first trip must ship the mixed-rung suffix");
    assert_eq!(s1, 0, "no replica basis exists yet: the full suffix must ship");
    audit(&router, 2, 10);
    for round in 10..14u64 {
        assert!(router.step().expect("round"), "sequence finished before the return trip");
        audit(&router, 2, round);
    }
    let (_, back) = *router.live_requests(dst).first().expect("live sequence on dst");
    let MigrationOutcome::Committed { delta_bytes: d2, bytes_saved: s2, .. } =
        router.migrate(dst, src, back, false).expect("return migration")
    else {
        panic!("mixed-rung return migration must commit");
    };
    assert!(s2 > 0, "stable mixed-rung groups must come from the replica basis");
    assert!(
        d2 < d1,
        "the return trip must ship only groups churned since the basis ({d2} vs {d1})"
    );
    audit(&router, 2, 14);
    let mut round = 14u64;
    while router.step().expect("round") {
        round += 1;
        audit(&router, 2, round);
        assert!(round < 256, "run did not converge");
    }
    let out: Vec<(u64, Vec<u8>)> = router.finish().into_iter().map(|r| (r.id, r.output)).collect();
    assert_eq!(
        out, control,
        "mixed-rung migrations must not perturb a single token versus the \
         never-migrated run under the same manifest"
    );
    assert_eq!(router.stats().migrations, 2);
}
