//! Cross-request shared-prefix reuse, verified without artifacts (pure
//! rust mock prefiller/decoder — DESIGN.md §6):
//!
//! * **bitwise equivalence** — a prefix-shared admission (template
//!   replays, refcounted chunk chains, copy-on-write effective seeds)
//!   produces byte-identical state to the unshared baseline: stored
//!   streams, decode watermarks, staged effective rows, and
//!   first-token logits, across random compression plans;
//! * **the distinct-prompts law** — a burst of N requests over D
//!   distinct prompts costs prefill launches and prefix cache bytes
//!   proportional to D, not N;
//! * **refcount safety** — randomly interleaved admit / park / resume /
//!   retire over randomly shared prompts never leaks or double-frees a
//!   prefix chunk (the trie's refcounts are re-derived from first
//!   principles after every step);
//! * **tier composition** — a parked-and-resumed sharer rebuilds an
//!   effective cache bitwise identical to a never-parked sharer's.

use kvcar::coordinator::effective::RowWiseMockDecoder;
use kvcar::coordinator::prefill::{LaneWiseMockPrefiller, PrefillWave};
use kvcar::coordinator::EffectiveCache;
use kvcar::kvcache::{CacheConfig, CacheManager, ParkedBytes, Side};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::prop_assert;
use kvcar::util::prop::check;
use kvcar::util::rng::Rng;
use std::collections::HashMap;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "prefix".into(),
        arch: Arch::Gpt2,
        vocab: 96,
        n_layer: 3,
        d_model: 24,
        n_head: 3,
        n_kv_head: 3,
        d_head: 8,
        ffn_dim: 48,
        max_seq: 48,
        ae_hidden: 16,
        ae_latent: 12,
        bytes_per_el: 4,
    }
}

/// Manager with a small block size so multi-chunk chains are exercised.
fn manager(spec: &ModelSpec, plan: CompressionPlan) -> CacheManager {
    let mut cfg = CacheConfig::new(spec.clone(), plan);
    cfg.block_size = 8;
    CacheManager::new(cfg)
}

/// A pool of prompts over two shared prefixes plus unshared stragglers.
fn prompt_pool(rng: &mut Rng, spec: &ModelSpec) -> Vec<Vec<u8>> {
    let mut pool = Vec::new();
    for _ in 0..2 {
        let plen = rng.range(8, 20); // 1..2 shared chunks at block 8
        let prefix: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
        for _ in 0..2 {
            let mut p = prefix.clone();
            let tail = rng.range(1, spec.max_seq - 1 - p.len());
            p.extend((0..tail).map(|_| rng.below(256) as u8));
            pool.push(p);
        }
    }
    pool.push((0..rng.range(1, 12)).map(|_| rng.below(256) as u8).collect());
    pool
}

fn staged_rows(eff: &EffectiveCache, spec: &ModelSpec, side: Side) -> Vec<u32> {
    let n = spec.n_layer * spec.max_seq * spec.kv_dim();
    let mut buf = vec![0.0f32; n];
    eff.sync_rows_into(side, &mut buf, 0, spec.max_seq);
    buf.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn shared_admissions_bitwise_match_unshared_baseline() {
    // the acceptance-criterion equivalence: sharing changes launch and
    // byte counts, never bytes of state — across random plans, random
    // prompt families (shared prefixes + exact duplicates), random wave
    // splits, and both serving modes
    check(20, |rng| {
        let spec = tiny_spec();
        let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
        let pool = prompt_pool(rng, &spec);
        // request stream: sample from the pool with replacement so
        // exact duplicates occur alongside prefix-only overlaps
        let n = rng.range(4, 10);
        let reqs: Vec<&[u8]> = (0..n).map(|_| pool[rng.below(pool.len())].as_slice()).collect();
        let seed = rng.bool(0.5); // in-graph seeding and faithful both hold

        let mut m_sh = manager(&spec, plan.clone());
        let mut m_un = manager(&spec, plan);
        let mut effs_sh: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut effs_un: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut mock_sh = LaneWiseMockPrefiller::for_spec(&spec);
        let mut mock_un = LaneWiseMockPrefiller::for_spec(&spec);
        let mut pw_sh = PrefillWave::new();
        let mut pw_un = PrefillWave::new();

        // same random wave split for both worlds
        let mut adm_sh = Vec::new();
        let mut adm_un = Vec::new();
        let mut at = 0;
        while at < reqs.len() {
            let to = rng.range(at, reqs.len()) + 1;
            let wave = &reqs[at..to];
            adm_sh.extend(
                pw_sh
                    .admit_wave(&mut m_sh, &mut effs_sh, &spec, seed, true, wave, &mut mock_sh)
                    .map_err(|e| e.to_string())?,
            );
            adm_un.extend(
                pw_un
                    .admit_wave(&mut m_un, &mut effs_un, &spec, seed, false, wave, &mut mock_un)
                    .map_err(|e| e.to_string())?,
            );
            at = to;
        }
        prop_assert!(adm_sh.len() == n && adm_un.len() == n);
        prop_assert!(
            pw_sh.stats.launches <= pw_un.stats.launches,
            "sharing must never launch more"
        );

        for (k, (a, b)) in adm_sh.iter().zip(&adm_un).enumerate() {
            // first-token logits replay bitwise
            prop_assert!(
                a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits()),
                "request {k}: logits diverge under sharing"
            );
            let plen = reqs[k].len().clamp(1, spec.max_seq - 1);
            prop_assert!(
                m_sh.seq_len(a.cache_id) == Some(plen)
                    && m_un.seq_len(b.cache_id) == Some(plen),
                "request {k}: ingested rows diverge"
            );
            prop_assert!(
                m_sh.decoded_upto(a.cache_id) == m_un.decoded_upto(b.cache_id),
                "request {k}: watermarks diverge"
            );
            // stored streams, chain-spanning reads included
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let x = format!("{:?}", m_sh.stored_rows(a.cache_id, layer, side));
                    let y = format!("{:?}", m_un.stored_rows(b.cache_id, layer, side));
                    prop_assert!(x == y, "request {k}: stream ({layer}, {side:?}) diverges");
                }
            }
            // effective rows as the decode step would stage them
            // (copy-on-write templates source through sync_rows_into)
            for side in [Side::K, Side::V] {
                prop_assert!(
                    staged_rows(&effs_sh[&a.cache_id], &spec, side)
                        == staged_rows(&effs_un[&b.cache_id], &spec, side),
                    "request {k}: staged effective rows diverge ({side:?})"
                );
            }
        }

        // byte law: the shared world stores every distinct chunk once
        // (pool bytes include the refcounted chunk blocks), so it can
        // never hold more than the duplicate-everything baseline
        prop_assert!(
            m_sh.pool_stats().live_bytes <= m_un.pool_stats().live_bytes,
            "sharing must never store more bytes"
        );
        // cleanup is leak-free
        for a in &adm_sh {
            m_sh.free_sequence(a.cache_id);
        }
        pw_sh.clear_templates(&mut m_sh);
        m_sh.prefix_integrity(&[]).map_err(|e| e.to_string())?;
        prop_assert!(m_sh.pool_stats().live_bytes == 0, "bytes leaked");
        Ok(())
    });
}

#[test]
fn burst_launches_and_prefix_bytes_scale_with_distinct_prompts() {
    // the headline law: 12 requests over 3 distinct prompts sharing a
    // 2-chunk prefix cost one launch (3 lanes <= capacity) and store
    // the shared prefix exactly once
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let mut rng = Rng::new(41);
    let prefix: Vec<u8> = (0..16).map(|_| rng.below(256) as u8).collect();
    let distinct: Vec<Vec<u8>> = (0..3u8)
        .map(|d| {
            let mut p = prefix.clone();
            p.extend_from_slice(&[d + 1, d * 3 + 7, 200 - d]);
            p
        })
        .collect();
    let reqs: Vec<&[u8]> = (0..12).map(|i| distinct[i % 3].as_slice()).collect();

    let mut shared = manager(&spec, plan.clone());
    let mut unshared = manager(&spec, plan);
    let (mut effs_a, mut effs_b) = (HashMap::new(), HashMap::new());
    let mut mock_a = LaneWiseMockPrefiller::for_spec(&spec);
    let mut mock_b = LaneWiseMockPrefiller::for_spec(&spec);
    let mut pw_a = PrefillWave::new();
    let mut pw_b = PrefillWave::new();
    let adm = pw_a
        .admit_wave(&mut shared, &mut effs_a, &spec, true, true, &reqs, &mut mock_a)
        .unwrap();
    pw_b.admit_wave(&mut unshared, &mut effs_b, &spec, true, false, &reqs, &mut mock_b)
        .unwrap();

    // launches ∝ distinct prompts: 3 lanes -> one batched launch; the
    // unshared baseline pays 12 lanes -> 8 + 4 -> two launches of 12
    assert_eq!(pw_a.stats.launches, 1);
    assert_eq!(pw_a.stats.shared_admissions, 9);
    assert_eq!(mock_a.wave_calls, 1);
    assert_eq!(pw_b.stats.launches, 2);
    assert_eq!(pw_b.stats.batched_lanes, 12);

    // prefix bytes ∝ distinct prompts: the 2-chunk prefix is stored
    // once; each distinct prompt's tail is stored once and shared by
    // its 4 copies... (copies attach, they do not re-store)
    let stats = shared.prefix_stats();
    assert!(stats.shared_bytes > 0);
    // 19-token prompts at block 8: the 16-token shared prefix is the
    // two full chunks, stored once by the first launched lane; the
    // other two distinct prompts hit both (their 3-token tails differ
    // past the aligned boundary and stay private)
    assert_eq!(stats.chunk_misses, 2, "the shared prefix stores once");
    assert_eq!(stats.chunk_hits, 4, "the other distinct prompts reuse it");
    let tail_bytes: usize = adm.iter().map(|a| shared.seq_stored_bytes(a.cache_id)).sum();
    assert!(
        stats.shared_bytes + tail_bytes < unshared.pool_stats().live_bytes / 2,
        "shared world must hold far fewer bytes than O(N) storage"
    );
    // every copy of a prompt reads the same chain
    assert_eq!(
        shared.seq_shared_bytes(adm[0].cache_id),
        shared.seq_shared_bytes(adm[3].cache_id)
    );
}

#[test]
fn interleaved_admit_park_resume_retire_never_leaks_or_double_frees() {
    // the refcount property test: after every step the trie's counts
    // must re-derive exactly from the live sequences + template pins,
    // and the terminal state must hold zero bytes
    check(15, |rng| {
        let spec = tiny_spec();
        let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
        let pool = prompt_pool(rng, &spec);
        let seed = rng.bool(0.5);
        let mut m = manager(&spec, plan);
        let mut effs: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut pw = PrefillWave::with_template_capacity(3); // force evictions
        let mut live: Vec<u64> = Vec::new();
        let mut parked: Vec<(u64, ParkedBytes)> = Vec::new();

        for _ in 0..30 {
            match rng.below(4) {
                0 => {
                    let k = rng.range(1, 4);
                    let wave: Vec<&[u8]> =
                        (0..k).map(|_| pool[rng.below(pool.len())].as_slice()).collect();
                    let adm = pw
                        .admit_wave(&mut m, &mut effs, &spec, seed, true, &wave, &mut mock)
                        .map_err(|e| e.to_string())?;
                    live.extend(adm.iter().map(|a| a.cache_id));
                }
                1 if !live.is_empty() => {
                    let id = live.swap_remove(rng.below(live.len()));
                    let bytes = m.extract_sequence_bytes(id).map_err(|e| e.to_string())?;
                    parked.push((id, bytes));
                }
                2 if !parked.is_empty() => {
                    let (id, bytes) = parked.swap_remove(rng.below(parked.len()));
                    m.restore_sequence_bytes(id, &bytes).map_err(|e| e.to_string())?;
                    live.push(id);
                }
                _ => {
                    // retire a live or parked sequence (retiring while
                    // parked must release the prefix refs too)
                    if !live.is_empty() && (parked.is_empty() || rng.bool(0.5)) {
                        let id = live.swap_remove(rng.below(live.len()));
                        m.free_sequence(id);
                        effs.remove(&id);
                    } else if !parked.is_empty() {
                        let (id, _) = parked.swap_remove(rng.below(parked.len()));
                        m.free_sequence(id);
                        effs.remove(&id);
                    }
                }
            }
            m.prefix_integrity(&pw.pinned_leaves()).map_err(|e| e.to_string())?;
        }
        // drain everything: no chunk and no block may survive
        for id in live.drain(..) {
            m.free_sequence(id);
        }
        for (id, _) in parked.drain(..) {
            m.free_sequence(id);
        }
        pw.clear_templates(&mut m);
        m.prefix_integrity(&[]).map_err(|e| e.to_string())?;
        prop_assert!(m.prefix_stats().nodes_live == 0, "prefix chunks leaked");
        prop_assert!(m.pool_stats().live_bytes == 0, "block bytes leaked");
        Ok(())
    });
}

#[test]
fn parked_sharer_rebuilds_bitwise_identical_effective_cache() {
    // tier composition: park + resume of one sharer, then a faithful
    // rebuild, must equal the never-parked sharer's rebuild bitwise —
    // the shared chain fed both
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, 2);
    let mut m = manager(&spec, plan);
    let mut effs = HashMap::new();
    let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
    let mut pw = PrefillWave::new();
    let mut rng = Rng::new(57);
    let prompt: Vec<u8> = (0..21).map(|_| rng.below(256) as u8).collect();
    let reqs: Vec<&[u8]> = vec![&prompt, &prompt];
    let adm = pw
        .admit_wave(&mut m, &mut effs, &spec, false, true, &reqs, &mut mock)
        .unwrap();
    let (a, b) = (adm[0].cache_id, adm[1].cache_id);
    assert!(m.seq_prefix_rows(b) > 0, "sharers must share the chain");

    let bytes = m.extract_sequence_bytes(a).unwrap();
    assert_eq!(bytes.prefix_rows, m.seq_prefix_rows(a));
    m.restore_sequence_bytes(a, &bytes).unwrap();

    let mut dec = RowWiseMockDecoder::for_spec(&spec);
    let mut eff_a = EffectiveCache::new(&spec);
    let mut eff_b = EffectiveCache::new(&spec);
    eff_a.rebuild_full(&mut m, a, &mut dec).unwrap();
    eff_b.rebuild_full(&mut m, b, &mut dec).unwrap();
    for side in [Side::K, Side::V] {
        assert_eq!(
            staged_rows(&eff_a, &spec, side),
            staged_rows(&eff_b, &spec, side),
            "resumed sharer diverges from never-parked sharer ({side:?})"
        );
    }
}
