//! Differential pins for the adaptive per-layer/per-head/per-row-region
//! compression policy (DESIGN.md §11):
//!
//! * A **uniform** `PlanManifest` served through the adaptive path is
//!   **bitwise identical** to the legacy single-rung path — token
//!   digests, invariant-trajectory digests, ladder counters, and every
//!   virtual-clock timing figure — across the whole standard scenario
//!   matrix and the whole sharded matrix.
//! * A **mixed** manifest's rows read back bitwise equal to per-region
//!   single-rung oracle stores, and the measured stored bytes always
//!   equal what the plan layout law predicts.
//! * Mixed-rung sequences round-trip the host tier (CRC-verified) and
//!   survive regional ladder demotion bit-identically.
//! * Sustained admission pressure under a partitioned manifest walks a
//!   **per-region** demotion ladder, deterministically, with the
//!   plan-coherence invariant audited after every round.

use kvcar::compress::planner::candidate_manifests;
use kvcar::compress::strategy::{PlanManifest, RegionSpec, Rung};
use kvcar::coordinator::{
    run_scenario, scenario_spec, sharded_matrix, standard_matrix, Scenario, ScenarioReport,
};
use kvcar::kvcache::tier::HostTier;
use kvcar::kvcache::{CacheConfig, CacheManager, Format, Side, StoredRows};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::prop_assert;
use kvcar::runtime::{ExecBackend, MockEngine};
use kvcar::util::prop::check;
use kvcar::util::rng::Rng;

const BS: usize = 16; // scenario_spec block size (CacheConfig::new default)

/// The plan `run_scenario` builds internally for every matrix entry —
/// adaptive legs embed the same plan so budgets and digests compare.
fn matrix_plan(spec: &ModelSpec) -> CompressionPlan {
    CompressionPlan::ae_first_layers(spec, (spec.n_layer / 2).max(1))
}

/// A genuinely partitioned manifest over the scenario spec: the sink
/// block pinned raw f32, a cold early region at int8, the tail at the
/// plan's own rung.
fn partitioned_manifest(spec: &ModelSpec) -> PlanManifest {
    let m = PlanManifest {
        plan: matrix_plan(spec),
        regions: vec![
            RegionSpec { start: 0, end: Some(BS), rung: Rung::RawF32 },
            RegionSpec { start: BS, end: Some(2 * BS), rung: Rung::Int8 },
            RegionSpec { start: 2 * BS, end: None, rung: Rung::Plan },
        ],
    };
    m.validate(BS).expect("partitioned manifest must validate");
    m
}

fn run(sc: &Scenario) -> ScenarioReport {
    let mut engine = MockEngine::new(scenario_spec());
    run_scenario(&mut engine, "mock", sc).expect("scenario must pass its invariants")
}

fn gauss(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// A manager under `ccfg` holding one sequence of `n` seeded gaussian
/// rows (same seed ⇒ bit-identical appended data across managers).
fn filled_manager(ccfg: CacheConfig, n: usize, seed: u64) -> (CacheManager, u64) {
    let spec = ccfg.spec.clone();
    let mut m = CacheManager::new(ccfg);
    let id = m.create_sequence();
    let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
    let mut rng = Rng::new(seed);
    let k_lat = gauss(&mut rng, l * n * dl);
    let v_lat = gauss(&mut rng, l * n * dl);
    let k_raw = gauss(&mut rng, l * n * kvd);
    let v_raw = gauss(&mut rng, l * n * kvd);
    m.append_rows(id, n, n, &k_lat, &v_lat, &k_raw, &v_raw)
        .expect("append rows");
    (m, id)
}

/// Decoded f32 contents of rows `[start, end)` of one stream, `None`
/// for fully-aliased streams.
fn rows(m: &CacheManager, id: u64, layer: usize, side: Side, start: usize, end: usize) -> Option<Vec<f32>> {
    match m.stored_rows(id, layer, side).expect("stored rows") {
        StoredRows::Alias => None,
        StoredRows::Latent(v) => {
            let epr = m.cfg.spec.ae_latent;
            Some(v[start * epr..end * epr].to_vec())
        }
        StoredRows::Heads(v, heads) => {
            let epr = heads.len() * m.cfg.spec.d_head;
            Some(v[start * epr..end * epr].to_vec())
        }
    }
}

/// Every stream's decoded rows `[start, end)`, in wire order.
fn all_rows(m: &CacheManager, id: u64, start: usize, end: usize) -> Vec<Option<Vec<f32>>> {
    (0..m.cfg.spec.n_layer)
        .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
        .map(|(l, s)| rows(m, id, l, s, start, end))
        .collect()
}

#[test]
fn uniform_manifest_is_bitwise_identical_to_the_legacy_path() {
    // the tentpole pin: a uniformly-Plan-rung manifest through the
    // adaptive path must reproduce the legacy single-rung path *report
    // for report* — tokens, invariant fingerprints (which fold the
    // regional-demotion counter), parks, retries, and every timing
    // figure — across the whole standard matrix, faults included
    let spec = scenario_spec();
    for sc in standard_matrix() {
        let legacy = run(&sc);
        let mut adaptive = sc.clone();
        adaptive.adaptive_plan = Some(PlanManifest::uniform(matrix_plan(&spec)));
        let pinned = run(&adaptive);
        assert_eq!(
            legacy, pinned,
            "scenario '{}' diverged under a uniform manifest",
            sc.name
        );
    }
}

#[test]
fn uniform_manifest_pin_holds_across_sharded_serving() {
    // same pin, whole cluster: uniform manifests must not perturb one
    // bit of any sharded report — migrations, delta bytes, digests
    for sc in sharded_matrix() {
        let run_one = |sc: &kvcar::coordinator::ShardedScenario| {
            let mut engines: Vec<MockEngine> =
                (0..sc.n_workers).map(|_| MockEngine::new(scenario_spec())).collect();
            let backends: Vec<&mut dyn ExecBackend> =
                engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
            kvcar::coordinator::run_sharded(backends, "mock", sc)
                .expect("sharded scenario must pass its cluster audits")
        };
        let legacy = run_one(&sc);
        let mut adaptive = sc.clone();
        adaptive.base.adaptive_plan =
            Some(PlanManifest::uniform(matrix_plan(&scenario_spec())));
        let pinned = run_one(&adaptive);
        assert_eq!(
            legacy, pinned,
            "sharded scenario '{}' diverged under a uniform manifest",
            sc.base.name
        );
    }
}

#[test]
fn uniform_offplan_rungs_match_their_single_rung_twins() {
    // a uniformly rung-R manifest must store byte-for-byte what a
    // legacy config pinned to R's format stores: same stored bytes,
    // same predicted bytes, same park payload, same decoded rows
    let spec = scenario_spec();
    let n = 40;
    for (rung, fmt) in [
        (Rung::RawF32, Format::F32),
        (Rung::RawF16, Format::F16),
        (Rung::Int8, Format::Int8),
    ] {
        let plan = matrix_plan(&spec);
        let mut adaptive_cfg = CacheConfig::new(spec.clone(), plan.clone());
        adaptive_cfg.regions = PlanManifest::uniform_rung(plan.clone(), rung).regions;
        let mut twin_cfg = CacheConfig::new(spec.clone(), plan);
        twin_cfg.raw_format = fmt;
        twin_cfg.latent_format = fmt;
        let (mut a, aid) = filled_manager(adaptive_cfg, n, 7);
        let (mut t, tid) = filled_manager(twin_cfg, n, 7);
        assert_eq!(
            a.seq_stored_bytes(aid),
            t.seq_stored_bytes(tid),
            "{rung:?}: stored bytes diverge from the single-rung twin"
        );
        assert_eq!(
            a.seq_predicted_bytes(aid),
            a.seq_stored_bytes(aid),
            "{rung:?}: the bytes law must hold on the adaptive store"
        );
        assert_eq!(
            all_rows(&a, aid, 0, n),
            all_rows(&t, tid, 0, n),
            "{rung:?}: decoded rows diverge from the single-rung twin"
        );
        let pa = a.extract_sequence_bytes(aid).expect("extract adaptive");
        let pt = t.extract_sequence_bytes(tid).expect("extract twin");
        assert_eq!(pa, pt, "{rung:?}: park payloads diverge from the single-rung twin");
    }
}

#[test]
fn mixed_regions_read_back_as_their_single_rung_oracles() {
    // property: an arbitrary 3-region manifest's rows decode
    // region-by-region bitwise equal to uniform single-rung oracle
    // stores fed the same data, and measured bytes always equal the
    // layout law's prediction
    let spec = scenario_spec();
    let rungs = [Rung::Plan, Rung::RawF32, Rung::RawF16, Rung::Int8];
    check(24, |rng| {
        let picks = [rungs[rng.below(4)], rungs[rng.below(4)], rungs[rng.below(4)]];
        let n = rng.range(2 * BS + 1, spec.max_seq);
        let seed = rng.below(1 << 30) as u64;
        let plan = matrix_plan(&spec);
        let manifest = PlanManifest {
            plan: plan.clone(),
            regions: vec![
                RegionSpec { start: 0, end: Some(BS), rung: picks[0] },
                RegionSpec { start: BS, end: Some(2 * BS), rung: picks[1] },
                RegionSpec { start: 2 * BS, end: None, rung: picks[2] },
            ],
        };
        manifest.validate(BS).map_err(|e| e.to_string())?;
        let mut mixed_cfg = CacheConfig::new(spec.clone(), plan.clone());
        mixed_cfg.regions = manifest.regions.clone();
        let (mixed, mid) = filled_manager(mixed_cfg, n, seed);
        prop_assert!(
            mixed.seq_predicted_bytes(mid) == mixed.seq_stored_bytes(mid),
            "bytes law broken: predicted {} vs stored {} (rungs {picks:?}, n {n})",
            mixed.seq_predicted_bytes(mid),
            mixed.seq_stored_bytes(mid)
        );
        let bounds = [(0, BS), (BS, 2 * BS), (2 * BS, n)];
        for (r, &(start, end)) in bounds.iter().enumerate() {
            let oracle_cfg = {
                let mut c = CacheConfig::new(spec.clone(), plan.clone());
                c.regions = PlanManifest::uniform_rung(plan.clone(), picks[r]).regions;
                c
            };
            let (oracle, oid) = filled_manager(oracle_cfg, n, seed);
            prop_assert!(
                all_rows(&mixed, mid, start, end) == all_rows(&oracle, oid, start, end),
                "region {r} ({picks:?}, rows [{start},{end})) diverges from its \
                 single-rung oracle"
            );
        }
        Ok(())
    });
}

#[test]
fn mixed_rung_sequences_roundtrip_the_host_tier_bit_identically() {
    // heterogeneous park/unpark through the CRC-verified tier path: a
    // mixed-rung sequence with a ladder-demoted span must restore every
    // stream bit-identically, spans and bytes law included
    let spec = scenario_spec();
    let n = 44;
    let manifest = partitioned_manifest(&spec);
    let mut ccfg = CacheConfig::new(spec.clone(), manifest.plan.clone());
    ccfg.regions = manifest.regions.clone();
    let (mut m, id) = filled_manager(ccfg, n, 21);
    // churn one row group through the regional ladder so the payload
    // carries a live demoted span on top of the static regions
    let freed = m.demote_region(id, 2 * BS, 2 * BS + BS).expect("regional demotion");
    assert!(freed > 0, "demoting an f32-stored block must free bytes");
    assert_eq!(m.seq_demoted_spans(id), vec![(2 * BS, 2 * BS + BS)]);
    let before = all_rows(&m, id, 0, n);
    let before_bytes = m.seq_stored_bytes(id);
    assert_eq!(m.seq_predicted_bytes(id), before_bytes);

    let parked = m.extract_sequence_bytes(id).expect("extract");
    assert_eq!(parked.demoted_spans, vec![(2 * BS, 2 * BS + BS)]);
    let mut tier = HostTier::new();
    tier.park(id, parked.clone());
    assert_eq!(m.seq_stored_bytes(id), 0, "device must be empty while parked");
    let (back, _cost) = tier
        .unpark_verified(id)
        .expect("checksum must verify")
        .expect("sequence must be parked");
    assert_eq!(back, parked, "tier transfer must be byte-faithful");
    m.restore_sequence_bytes(id, &back).expect("restore");
    assert_eq!(all_rows(&m, id, 0, n), before, "restored rows diverge");
    assert_eq!(m.seq_stored_bytes(id), before_bytes);
    assert_eq!(m.seq_demoted_spans(id), vec![(2 * BS, 2 * BS + BS)]);
    assert_eq!(m.seq_predicted_bytes(id), m.seq_stored_bytes(id));
}

#[test]
fn regional_demotion_is_block_aligned_and_keeps_the_bytes_law() {
    // the per-region ladder rung: the coldest promotable region is
    // block-aligned, demoting it re-encodes exactly those rows to int8
    // (bitwise equal to an all-int8 oracle there), leaves every other
    // row untouched, and the bytes law survives the whole walk
    let spec = scenario_spec();
    let n = 48;
    let manifest = partitioned_manifest(&spec);
    let mut ccfg = CacheConfig::new(spec.clone(), manifest.plan.clone());
    ccfg.regions = manifest.regions.clone();
    let (mut m, id) = filled_manager(ccfg, n, 33);

    let (start, end) = m
        .coldest_promotable_region(id, 2)
        .expect("an f32-stored sequence must have a promotable region");
    assert_eq!(start % BS, 0, "region start must be block-aligned");
    assert_eq!(end % BS, 0, "region end must be block-aligned");
    assert!(end > start && end - start <= 2 * BS, "region capped at max_blocks");
    // snapshot the rows the demotion must NOT touch before it runs
    let head = (start > 0).then(|| all_rows(&m, id, 0, start));
    let tail = (end < n).then(|| all_rows(&m, id, end, n));
    let freed = m.demote_region(id, start, end).expect("demote region");
    assert!(freed > 0, "first demotion must free bytes");
    assert_eq!(m.seq_demoted_spans(id), vec![(start, end)]);
    assert_eq!(m.seq_predicted_bytes(id), m.seq_stored_bytes(id));

    // demoted rows match the all-int8 oracle; all others are untouched
    let int8_cfg = {
        let mut c = CacheConfig::new(spec.clone(), manifest.plan.clone());
        c.regions = PlanManifest::uniform_rung(manifest.plan.clone(), Rung::Int8).regions;
        c
    };
    let (oracle, oid) = filled_manager(int8_cfg, n, 33);
    assert_eq!(
        all_rows(&m, id, start, end),
        all_rows(&oracle, oid, start, end),
        "demoted rows must re-encode exactly as the int8 rung would"
    );
    if let Some(head) = head {
        assert_eq!(all_rows(&m, id, 0, start), head, "rows before the region changed");
    }
    if let Some(tail) = tail {
        assert_eq!(all_rows(&m, id, end, n), tail, "rows after the region changed");
    }

    // repeated pressure walks the sequence cold-to-hot until nothing
    // is left to promote; the bytes law holds at every step and the
    // spans merge into one block-aligned cover of the whole sequence
    let mut guard = 0;
    while let Some((s, e)) = m.coldest_promotable_region(id, 2) {
        m.demote_region(id, s, e).expect("demote region");
        assert_eq!(m.seq_predicted_bytes(id), m.seq_stored_bytes(id));
        guard += 1;
        assert!(guard <= 8, "the regional walk must terminate");
    }
    assert_eq!(
        m.seq_demoted_spans(id),
        vec![(0, n)],
        "the exhausted walk must leave one merged span over every row"
    );
    assert_eq!(
        all_rows(&m, id, 0, n),
        all_rows(&oracle, oid, 0, n),
        "a fully-walked sequence must match the all-int8 oracle everywhere"
    );
}

#[test]
fn pressure_with_a_partitioned_manifest_demotes_per_region() {
    // §9 ladder × adaptive: sustained admission pressure under a
    // genuinely partitioned manifest must walk a *per-region* demotion
    // ladder — every demotion is regional — deterministically, with
    // the plan-coherence invariant (stored == predicted bytes for
    // every live sequence) audited inside run_scenario every round
    if std::env::var("KVCAR_NO_ADAPTIVE_PLAN").is_ok() {
        // the kill-switch leg ignores manifests by design, so the
        // per-region ladder cannot fire; that leg's contract (adaptive
        // off == legacy) is pinned by the uniform-manifest tests above
        return;
    }
    let spec = scenario_spec();
    let mut sc = standard_matrix()
        .into_iter()
        .find(|s| s.name == "sustained_pressure")
        .unwrap();
    // no templates to shed and no shared prefixes: the ladder's first
    // escalation lands on the demote rung with fully-owned sequences
    sc.template_capacity = Some(0);
    sc.prefix_sharing = false;
    sc.adaptive_plan = Some(partitioned_manifest(&spec));
    let a = run(&sc);
    let b = run(&sc);
    assert_eq!(a, b, "the regional ladder trajectory must be deterministic");
    assert_eq!(
        a.demotions, a.region_demotions,
        "under a partitioned manifest every ladder demotion must be per-region"
    );
    assert!(
        a.region_demotions >= 1,
        "sustained pressure must trigger at least one per-region demotion \
         (demotions {}, parks {}, rejected {})",
        a.demotions,
        a.parks,
        a.rejected.len()
    );
    assert!(a.retries >= 1, "pressure must first be absorbed by the retry budget");
    assert_eq!(
        a.completed + a.rejected.len() + a.quarantined.len(),
        sc.trace.n_requests,
        "every request must resolve"
    );
}

#[test]
fn candidate_manifests_roundtrip_json_and_malformed_inputs_reject() {
    // serde integration over the real sweep candidates: exact
    // round-trips for every candidate, typed rejections for malformed
    // manifests (the exhaustive property fuzz lives in
    // `compress::strategy`'s own tests)
    let spec = scenario_spec();
    for (label, m) in candidate_manifests(&spec, BS) {
        let back = PlanManifest::from_json(&m.to_json())
            .unwrap_or_else(|e| panic!("candidate {label} failed to round-trip: {e}"));
        assert_eq!(m, back, "candidate {label} round-trip must be exact");
        back.validate(BS)
            .unwrap_or_else(|e| panic!("candidate {label} invalid after round-trip: {e}"));
    }
    let good = partitioned_manifest(&spec).to_json();
    assert!(PlanManifest::from_json(&good).is_ok());
    // unknown rung token
    let bad_rung = good.replace("\"int8\"", "\"int9\"");
    assert!(
        PlanManifest::from_json(&bad_rung).is_err(),
        "an unknown rung token must be rejected"
    );
    // overlapping / misaligned regions are rejected by validate
    let overlapping = PlanManifest {
        plan: matrix_plan(&spec),
        regions: vec![
            RegionSpec { start: 0, end: Some(2 * BS), rung: Rung::RawF32 },
            RegionSpec { start: BS, end: None, rung: Rung::Int8 },
        ],
    };
    assert!(overlapping.validate(BS).is_err(), "overlapping regions must be rejected");
    let gapped = PlanManifest {
        plan: matrix_plan(&spec),
        regions: vec![
            RegionSpec { start: 0, end: Some(BS), rung: Rung::RawF32 },
            RegionSpec { start: 2 * BS, end: None, rung: Rung::Int8 },
        ],
    };
    assert!(gapped.validate(BS).is_err(), "a row gap between regions must be rejected");
}
