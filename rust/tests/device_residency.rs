//! Device-residency cost law over the full mock pipeline (no
//! artifacts): `SlotArena` staging declares dirty row spans, and the
//! engine-side [`BufferCache`] consumes them so a steady decode round
//! moves O(B·L·kvd) host→device bytes — **independent of S** — while
//! the device mirror stays bitwise identical to the staged tensor.
//! `tests/pipeline_integration.rs` asserts the same equivalence at the
//! logits level over real artifacts; this suite pins the byte law,
//! which needs a patch-capable backend ([`MirrorBackend::patching`])
//! the PJRT binding does not offer yet.

use kvcar::coordinator::effective::RowWiseMockDecoder;
use kvcar::coordinator::resident::{K_CACHE, V_CACHE};
use kvcar::coordinator::{EffectiveCache, ServeMetrics, SlotArena};
use kvcar::kvcache::{CacheConfig, CacheManager};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::runtime::{BufferCache, DType, EngineStats, IoSpec, MirrorBackend, Store};
use kvcar::util::rng::Rng;
use std::collections::HashMap;

fn tiny_spec(max_seq: usize) -> ModelSpec {
    ModelSpec {
        name: "devres".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 3,
        d_model: 16,
        n_head: 2,
        n_kv_head: 2,
        d_head: 4,
        ffn_dim: 32,
        max_seq,
        ae_hidden: 8,
        ae_latent: 4,
        bytes_per_el: 4,
    }
}

fn append_random_token(m: &mut CacheManager, id: u64, rng: &mut Rng) {
    let spec = m.cfg.spec.clone();
    let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    let kl = mk(rng, spec.n_layer * spec.ae_latent);
    let vl = mk(rng, spec.n_layer * spec.ae_latent);
    let kr = mk(rng, spec.n_layer * spec.kv_dim());
    let vr = mk(rng, spec.n_layer * spec.kv_dim());
    m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
}

/// One serving world at sequence capacity `s`: cache manager, effective
/// caches, slot arena, store, and the engine-side buffer cache with a
/// mirror device.
struct World {
    spec: ModelSpec,
    m: CacheManager,
    dec: RowWiseMockDecoder,
    effs: HashMap<u64, EffectiveCache>,
    ids: Vec<u64>,
    arena: SlotArena,
    store: Store,
    met: ServeMetrics,
    cache: BufferCache<Vec<u8>>,
    dev: MirrorBackend,
    stats: EngineStats,
    rng: Rng,
    b: usize,
}

impl World {
    fn new(b: usize, s: usize, prompt: usize, dev: MirrorBackend) -> World {
        let spec = tiny_spec(s);
        let mut m = CacheManager::new(CacheConfig::new(
            spec.clone(),
            CompressionPlan::ae_first_layers(&spec, 1),
        ));
        let mut rng = Rng::new(11);
        let mut effs = HashMap::new();
        let mut ids = Vec::new();
        for _ in 0..b {
            let id = m.create_sequence();
            effs.insert(id, EffectiveCache::new(&spec));
            for _ in 0..prompt {
                append_random_token(&mut m, id, &mut rng);
            }
            ids.push(id);
        }
        let mut cache = BufferCache::new();
        cache.ensure_entry("decode", 2);
        World {
            dec: RowWiseMockDecoder::for_spec(&spec),
            spec,
            m,
            effs,
            ids,
            arena: SlotArena::new(),
            store: Store::new(),
            met: ServeMetrics::default(),
            cache,
            dev,
            stats: EngineStats::default(),
            rng,
            b,
        }
    }

    fn dims(&self) -> (usize, usize, usize) {
        (self.spec.n_layer, self.spec.max_seq, self.spec.kv_dim())
    }

    /// Append+advance one token per live sequence, stage the round, and
    /// sync both regions into the device cache.  `residency` and
    /// `chunk_rows` are passed straight through to `sync_input`.
    fn round(&mut self, append: bool, residency: bool, chunk_rows: usize) {
        let dims = self.dims();
        if append {
            for &id in &self.ids {
                append_random_token(&mut self.m, id, &mut self.rng);
            }
        }
        for &id in &self.ids {
            let eff = self.effs.get_mut(&id).unwrap();
            eff.advance(&mut self.m, id, &mut self.dec).unwrap();
        }
        let marks: Vec<(u64, usize)> = self
            .ids
            .iter()
            .map(|&id| (id, self.m.decoded_upto(id).unwrap()))
            .collect();
        self.arena
            .stage_round(&mut self.store, &marks, &self.effs, self.b, dims, &mut self.met)
            .unwrap();
        self.stats.buffers_evicted += self.cache.sweep_stale(&self.store);
        self.cache.ensure_entry("decode", 2);
        let (l, s, kvd) = dims;
        for (i, name) in [K_CACHE, V_CACHE].into_iter().enumerate() {
            let io = IoSpec {
                name: name.to_string(),
                shape: vec![self.b, l, s, kvd],
                dtype: DType::F32,
            };
            let t = self.store.get(name).unwrap().clone();
            self.cache
                .sync_input(
                    &mut self.dev,
                    "decode",
                    i,
                    &io,
                    &t,
                    &self.store,
                    residency,
                    chunk_rows,
                    &mut self.stats,
                )
                .unwrap();
        }
    }

    /// Assert each device mirror is byte-identical to its staged store
    /// tensor (what a real device would execute against).
    fn assert_mirrors_bitwise(&self, what: &str) {
        for (i, name) in [K_CACHE, V_CACHE].into_iter().enumerate() {
            let host = self.store.get(name).unwrap().to_le_bytes();
            let mirror = self.cache.buffer("decode", i).unwrap();
            assert_eq!(mirror, &host, "{what}: device copy of {name} diverged");
        }
    }
}

fn staged_region_bytes(w: &World) -> u64 {
    let (l, s, kvd) = w.dims();
    2 * (w.b * l * s * kvd * 4) as u64
}

#[test]
fn steady_round_uploads_o_new_rows_independent_of_s() {
    // the acceptance law: with a patch-capable device, a steady decode
    // round uploads exactly one new row per live sequence per side —
    // 2·B·L·kvd·4 bytes — no matter how long the compiled sequence
    // capacity S is.  chunk_rows = 1 keeps chunk quantization out of
    // the arithmetic.
    let b = 4usize;
    let mut per_round_by_s = Vec::new();
    for s in [64usize, 256] {
        let mut w = World::new(b, s, 6, MirrorBackend::patching());
        w.round(false, true, 1); // admission round: full upload expected
        assert_eq!(w.stats.full_uploads, 2, "first sight of K and V uploads whole");
        assert_eq!(w.stats.input_bytes, staged_region_bytes(&w));
        w.assert_mirrors_bitwise("admission round");
        let mut per_round = Vec::new();
        for round in 0..3 {
            let before = w.stats.resident_bytes_uploaded;
            w.round(true, true, 1);
            w.assert_mirrors_bitwise(&format!("S={s} round {round}"));
            per_round.push(w.stats.resident_bytes_uploaded - before);
        }
        let (l, _, kvd) = w.dims();
        let row_law = 2 * (b * l * kvd * 4) as u64;
        for (round, &got) in per_round.iter().enumerate() {
            assert_eq!(got, row_law, "S={s} round {round} must upload one row/seq/side");
        }
        assert_eq!(w.stats.full_uploads, 2, "steady rounds never re-upload whole");
        assert!(w.stats.resident_bytes_skipped > 0, "the resident bulk must not travel");
        per_round_by_s.push(per_round[0]);
    }
    assert_eq!(
        per_round_by_s[0], per_round_by_s[1],
        "steady upload bytes must be independent of S (O(B·L·kvd), not O(B·L·S·kvd))"
    );
}

#[test]
fn residency_off_uploads_full_tensor_every_round() {
    // the reference leg: with delta uploads disabled every round moves
    // the whole 2·B·L·S·kvd·4 tensor pair, and the mirrors still match
    // bitwise — this is the law the `device_residency` win is measured
    // against (S× more bytes per steady round).
    let mut w = World::new(2, 64, 4, MirrorBackend::patching());
    let full = staged_region_bytes(&w);
    w.round(false, false, 1);
    for round in 0..3 {
        let before = w.stats.input_bytes;
        w.round(true, false, 1);
        assert_eq!(w.stats.input_bytes - before, full, "round {round} must move it all");
        w.assert_mirrors_bitwise(&format!("reference round {round}"));
    }
    assert_eq!(w.dev.patches, 0, "the reference path never patches");
    let (l, _, kvd) = w.dims();
    let row_law = 2 * (w.b * l * kvd * 4) as u64;
    assert_eq!(full / row_law, 64, "the delta path wins exactly S× per steady round");
}

#[test]
fn patchless_device_falls_back_to_full_uploads_and_stays_correct() {
    // today's PJRT binding cannot patch device buffers in place: the
    // delta path must degrade to whole-buffer uploads (counted in
    // full_uploads) without ever serving stale rows
    let mut w = World::new(2, 32, 4, MirrorBackend::default());
    for round in 0..3 {
        w.round(round > 0, true, 1);
        w.assert_mirrors_bitwise(&format!("patchless round {round}"));
    }
    assert_eq!(w.dev.patches, 0);
    assert_eq!(w.stats.full_uploads, 3 * 2, "every round re-uploads both regions");
    assert_eq!(w.stats.input_bytes, 3 * staged_region_bytes(&w));
}

#[test]
fn rung_switch_evicts_stale_device_buffers() {
    // a capacity-rung switch reallocates the [b, L, S, kvd] regions:
    // the sweep must drop the dead buffers (they would otherwise stay
    // pinned forever) and the next sync re-uploads the new allocation
    let mut w = World::new(2, 32, 4, MirrorBackend::patching());
    w.round(false, true, 1);
    w.round(true, true, 1);
    assert_eq!(w.stats.buffers_evicted, 0);
    // retire one sequence and drop to rung b = 1
    let gone = w.ids.pop().unwrap();
    w.arena.release(gone);
    w.effs.remove(&gone);
    w.m.free_sequence(gone);
    w.b = 1;
    w.round(true, true, 1);
    assert_eq!(w.stats.buffers_evicted, 2, "old rung's K and V buffers must go");
    assert_eq!(w.met.capacity_switches, 1);
    assert_eq!(w.stats.full_uploads, 2 + 2, "new allocation re-uploads whole once");
    w.assert_mirrors_bitwise("post-switch");
    // and the new rung is steady again: one row per sequence per side
    let before = w.stats.resident_bytes_uploaded;
    w.round(true, true, 1);
    let (l, _, kvd) = w.dims();
    assert_eq!(w.stats.resident_bytes_uploaded - before, 2 * (l * kvd * 4) as u64);
}
