//! Threaded server front-end over the mock backend: exactly-once
//! response delivery under concurrent clients, a clean shutdown drain
//! (every accepted request answered, `shutdown` joins), and fail-fast
//! submits once the worker is gone.  No artifacts required.

use kvcar::coordinator::{scenario_spec, GenRequest, ServeConfig};
use kvcar::model::memory::CompressionPlan;
use kvcar::runtime::{ExecBackend, MockEngine};
use kvcar::server::Server;
use std::time::Duration;

fn start_mock(max_batch: usize) -> Server {
    let spec = scenario_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, 1);
    let cfg = ServeConfig {
        max_batch,
        seed: 5,
        ..ServeConfig::new(plan)
    };
    Server::start_with("mock".into(), cfg, move || {
        Ok(Box::new(MockEngine::new(spec)) as Box<dyn ExecBackend>)
    })
    .expect("mock server must start")
}

#[test]
fn concurrent_clients_each_get_their_response_exactly_once() {
    let server = start_mock(8);
    let handle = server.handle();
    let n = 12u64;
    let mut joins = Vec::new();
    for i in 0..n {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let prompt = vec![b'a' + (i % 7) as u8; 8 + (i as usize % 5)];
            h.generate(GenRequest::greedy(i, &prompt, 4)).unwrap()
        }));
    }
    let mut seen = std::collections::BTreeSet::new();
    for (i, j) in joins.into_iter().enumerate() {
        let r = j.join().unwrap();
        // each client got its own request's response, exactly once
        assert_eq!(r.id, i as u64);
        assert!(seen.insert(r.id), "response {} delivered twice", r.id);
        assert_eq!(r.generated_tokens, 4);
        assert_eq!(r.output.len(), 4);
    }
    assert_eq!(seen.len(), n as usize);
    let m = handle.metrics().unwrap();
    assert_eq!(m.requests_completed, n);
    // the worker stamps arrivals on receipt, so every admission carries
    // a real TTFT sample
    assert_eq!(m.ttft.len(), n as usize);
    server.shutdown();
}

#[test]
fn shutdown_drains_the_gathered_wave_and_joins() {
    let server = start_mock(4);
    let handle = server.handle();
    let mut clients = Vec::new();
    for i in 0..4u64 {
        let h = handle.clone();
        clients.push(std::thread::spawn(move || {
            h.generate(GenRequest::greedy(i, b"drain me please", 6))
        }));
    }
    // land the shutdown while the worker is (likely) mid-gather.  The
    // drain contract holds under EVERY interleaving: each client either
    // gets its complete response (request accepted before the Shutdown)
    // or a fail-fast error (channel closed first) — and `shutdown` must
    // join.  The old worker dropped a Shutdown observed mid-gather and
    // hung this join forever.
    std::thread::sleep(Duration::from_millis(1));
    server.shutdown();
    for (i, c) in clients.into_iter().enumerate() {
        match c.join().unwrap() {
            Ok(r) => {
                assert_eq!(r.id, i as u64);
                assert_eq!(r.generated_tokens, 6, "drained response was truncated");
            }
            Err(_) => {} // never accepted: failed fast, nothing hung
        }
    }
}

#[test]
fn submits_after_shutdown_fail_fast() {
    let server = start_mock(2);
    let handle = server.handle();
    handle
        .generate(GenRequest::greedy(0, b"warm the worker", 2))
        .unwrap();
    server.shutdown();
    // the channel is closed once the worker exits: new submits error
    // instead of blocking
    assert!(handle.generate(GenRequest::greedy(1, b"too late", 2)).is_err());
    assert!(handle.metrics().is_err());
}
