//! Batch-first faithful decode + encoded-byte tier transfers, verified
//! without artifacts (pure-rust mock decoder):
//!
//! * `BatchedAdvance` is **bitwise-identical** to per-sequence
//!   `EffectiveCache::advance` across alias / latent / heads / int8
//!   plans, and issues exactly **one** batched decoder call per round
//!   for B > 1 live sequences.
//! * Tier spill/fill moves the real encoded bytes
//!   (`CacheManager::extract_sequence_bytes` / `restore_sequence_bytes`)
//!   and round-trips bit-identically through `HostTier::park`/`unpark`.
//! * Admission-control parking (`batcher::plan_parking`) under a tight
//!   budget parks the lowest-priority sequence, the round still
//!   completes, and resume reproduces bit-identical effective-cache
//!   contents versus a never-parked run.

use kvcar::coordinator::batcher::{plan_parking, round_headroom_bytes};
use kvcar::coordinator::effective::RowWiseMockDecoder;
use kvcar::coordinator::{BatchedAdvance, EffectiveCache};
use kvcar::kvcache::tier::HostTier;
use kvcar::kvcache::{CacheConfig, CacheManager};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::prop_assert;
use kvcar::util::prop::check;
use kvcar::util::rng::Rng;
use std::collections::HashMap;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "batched".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 5,
        d_model: 48,
        n_head: 6,
        n_kv_head: 6,
        d_head: 8,
        ffn_dim: 96,
        max_seq: 64,
        ae_hidden: 32,
        ae_latent: 24,
        bytes_per_el: 4,
    }
}

/// One token's worth of random storage rows, identical across managers
/// fed from the same rng stream.
fn token_rows(rng: &mut Rng, spec: &ModelSpec) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    (
        mk(rng, spec.n_layer * spec.ae_latent),
        mk(rng, spec.n_layer * spec.ae_latent),
        mk(rng, spec.n_layer * spec.kv_dim()),
        mk(rng, spec.n_layer * spec.kv_dim()),
    )
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    prop_assert!(a.len() == b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit divergence at {i}: {x} vs {y}"
        );
    }
    Ok(())
}

#[test]
fn batched_advance_bitwise_matches_per_sequence_across_plans() {
    check(25, |rng| {
        let spec = tiny_spec();
        let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
        let b = rng.range(2, 7);
        // two identical worlds fed the same token stream
        let mut m_bat = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
        let mut m_seq = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs_bat: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut effs_seq: HashMap<u64, EffectiveCache> = HashMap::new();
        let mut ids = Vec::new();
        for _ in 0..b {
            let id1 = m_bat.create_sequence();
            let id2 = m_seq.create_sequence();
            assert_eq!(id1, id2);
            ids.push(id1);
            effs_bat.insert(id1, EffectiveCache::new(&spec));
            effs_seq.insert(id1, EffectiveCache::new(&spec));
        }
        let mut dec_bat = RowWiseMockDecoder::for_spec(&spec)
            .with_capacity(Some(rng.range(2, 9)));
        let mut dec_seq = RowWiseMockDecoder::for_spec(&spec).with_capacity(None);
        let mut planner = BatchedAdvance::new();

        // mixed prompt lengths so the first round exercises the bulk
        // fallback while later rounds batch
        for (i, &id) in ids.iter().enumerate() {
            for _ in 0..(i % 3 + 1) {
                let (kl, vl, kr, vr) = token_rows(rng, &spec);
                m_bat.append_token(id, &kl, &vl, &kr, &vr).unwrap();
                m_seq.append_token(id, &kl, &vl, &kr, &vr).unwrap();
            }
        }
        let rounds = rng.range(3, 10);
        for _ in 0..rounds {
            planner
                .advance_round(&mut m_bat, &mut effs_bat, &ids, &mut dec_bat)
                .map_err(|e| e.to_string())?;
            for &id in &ids {
                effs_seq
                    .get_mut(&id)
                    .unwrap()
                    .advance(&mut m_seq, id, &mut dec_seq)
                    .map_err(|e| e.to_string())?;
            }
            for &id in &ids {
                let (kl, vl, kr, vr) = token_rows(rng, &spec);
                m_bat.append_token(id, &kl, &vl, &kr, &vr).unwrap();
                m_seq.append_token(id, &kl, &vl, &kr, &vr).unwrap();
            }
        }
        // drain the last appended row too
        planner
            .advance_round(&mut m_bat, &mut effs_bat, &ids, &mut dec_bat)
            .map_err(|e| e.to_string())?;
        for &id in &ids {
            let eff_s = effs_seq.get_mut(&id).unwrap();
            eff_s.advance(&mut m_seq, id, &mut dec_seq).map_err(|e| e.to_string())?;
            let eff_b = &effs_bat[&id];
            assert_bits_eq(&eff_b.k, &eff_s.k, "effective K")?;
            assert_bits_eq(&eff_b.v, &eff_s.v, "effective V")?;
            // per-sequence work accounting is identical on both paths
            prop_assert!(
                eff_b.stats.rows_decoded == eff_s.stats.rows_decoded,
                "row accounting diverges"
            );
        }
        prop_assert!(dec_seq.batch_calls == 0, "capacity None must never batch");
        Ok(())
    });
}

#[test]
fn batched_round_issues_exactly_one_decoder_call() {
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let mut effs: HashMap<u64, EffectiveCache> = HashMap::new();
    let mut rng = Rng::new(3);
    let b = 4;
    let ids: Vec<u64> = (0..b)
        .map(|_| {
            let id = m.create_sequence();
            effs.insert(id, EffectiveCache::new(&spec));
            let (kl, vl, kr, vr) = token_rows(&mut rng, &spec);
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
            id
        })
        .collect();
    let mut dec = RowWiseMockDecoder::for_spec(&spec).with_capacity(Some(8));
    let mut planner = BatchedAdvance::new();
    // first advance: every sequence has exactly one pending row -> one call
    let rounds = 5;
    for _ in 0..rounds {
        let n = planner.advance_round(&mut m, &mut effs, &ids, &mut dec).unwrap();
        assert_eq!(n, b as usize);
        for &id in &ids {
            let (kl, vl, kr, vr) = token_rows(&mut rng, &spec);
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
        }
    }
    assert_eq!(
        dec.batch_calls, rounds,
        "B > 1 live sequences must cost exactly one decoder call per round"
    );
    assert_eq!(dec.seq_calls, 0, "no per-sequence calls in steady state");
    assert_eq!(planner.stats.batched_calls, rounds);
    assert_eq!(planner.stats.batched_rows, rounds * b);
    assert_eq!(planner.stats.fallback_advances, 0);
    // a no-op round (nothing pending) issues nothing
    let n = planner.advance_round(&mut m, &mut effs, &ids, &mut dec).unwrap();
    assert_eq!(n, b as usize); // drains the tokens appended above
    assert_eq!(planner.advance_round(&mut m, &mut effs, &ids, &mut dec).unwrap(), 0);
    assert_eq!(dec.batch_calls, rounds + 1);
}

#[test]
fn capacity_chunking_and_lone_rows_fall_back() {
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let mut effs: HashMap<u64, EffectiveCache> = HashMap::new();
    let mut rng = Rng::new(4);
    let ids: Vec<u64> = (0..5)
        .map(|_| {
            let id = m.create_sequence();
            effs.insert(id, EffectiveCache::new(&spec));
            let (kl, vl, kr, vr) = token_rows(&mut rng, &spec);
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
            id
        })
        .collect();
    // capacity 2 over 5 single-row sequences: groups of 2 + 2 + a lone
    // remainder that goes through the cheaper per-sequence path
    let mut dec = RowWiseMockDecoder::for_spec(&spec).with_capacity(Some(2));
    let mut planner = BatchedAdvance::new();
    planner.advance_round(&mut m, &mut effs, &ids, &mut dec).unwrap();
    assert_eq!(dec.batch_calls, 2);
    assert_eq!(dec.seq_calls, 1);
    assert_eq!(planner.stats.fallback_advances, 1);
    // capacity None: everything per-sequence
    for &id in &ids {
        let (kl, vl, kr, vr) = token_rows(&mut rng, &spec);
        m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
    }
    let mut dec_none = RowWiseMockDecoder::for_spec(&spec).with_capacity(None);
    planner.advance_round(&mut m, &mut effs, &ids, &mut dec_none).unwrap();
    assert_eq!(dec_none.batch_calls, 0);
    assert_eq!(dec_none.seq_calls, 5);
}

#[test]
fn tier_roundtrip_preserves_effective_cache_bitwise() {
    // spill -> host tier -> fill -> rebuild must reproduce the exact
    // effective cache of a sequence that was never parked
    check(20, |rng| {
        let spec = tiny_spec();
        let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        let mut eff = EffectiveCache::new(&spec);
        let n = rng.range(2, 40);
        for _ in 0..n {
            let (kl, vl, kr, vr) = token_rows(rng, &spec);
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
        }
        eff.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;

        let mut tier = HostTier::new();
        let parked = m.extract_sequence_bytes(id).map_err(|e| e.to_string())?;
        let host_bytes = parked.payload.len();
        tier.park(id, parked);
        prop_assert!(tier.parked_bytes(id) == Some(host_bytes));
        prop_assert!(m.seq_stored_bytes(id) == 0, "device must be empty while parked");

        let (back, _cost) = tier.unpark(id).ok_or("unpark failed")?;
        m.restore_sequence_bytes(id, &back).map_err(|e| e.to_string())?;
        let mut resumed = EffectiveCache::new(&spec);
        resumed.rebuild_full(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        assert_bits_eq(&eff.k, &resumed.k, "resumed effective K")?;
        assert_bits_eq(&eff.v, &resumed.v, "resumed effective V")?;
        Ok(())
    });
}

#[test]
fn admission_parking_under_tight_budget_completes_and_restores_bitwise() {
    // the satellite scenario end-to-end at the cache/batcher level:
    // two sequences under a budget with room for one -> the batcher
    // parks the lowest-priority one, the survivor keeps appending and
    // advancing (the round completes), and resume reproduces the parked
    // sequence's effective cache bit-identically vs a never-parked run
    let spec = tiny_spec();
    let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    let mut rng = Rng::new(11);

    // control world: both sequences live forever, no budget
    let mut ctl = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
    // pressured world: same stream of tokens
    let mut mem = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
    let a = ctl.create_sequence();
    let b = ctl.create_sequence();
    assert_eq!(mem.create_sequence(), a);
    assert_eq!(mem.create_sequence(), b);
    for _ in 0..10 {
        for id in [a, b] {
            let t = token_rows(&mut rng, &spec);
            ctl.append_token(id, &t.0, &t.1, &t.2, &t.3).unwrap();
            mem.append_token(id, &t.0, &t.1, &t.2, &t.3).unwrap();
        }
    }

    // budget fits one sequence + headroom but not two
    let headroom = round_headroom_bytes(&spec, &plan, mem.cfg.block_size);
    let one = mem.seq_stored_bytes(a);
    let budget = one + 2 * headroom;
    // equal stored bytes and equal remaining work: the cost-aware policy
    // tie-breaks to LIFO, so the lowest-priority sequence parks
    let live = [
        (a, mem.seq_stored_bytes(a), 4usize),
        (b, mem.seq_stored_bytes(b), 4usize),
    ];
    let victims = plan_parking(budget, headroom, &live);
    assert_eq!(victims, vec![b], "lowest-priority sequence must park");

    let mut tier = HostTier::new();
    let parked = mem.extract_sequence_bytes(b).unwrap();
    tier.park(b, parked);
    assert!(mem.seq_stored_bytes(a) + headroom <= budget, "pressure relieved");

    // the round still completes: the survivor appends and advances
    let mut dec = RowWiseMockDecoder::for_spec(&spec);
    let mut eff_a = EffectiveCache::new(&spec);
    for _ in 0..4 {
        let t = token_rows(&mut rng, &spec);
        ctl.append_token(a, &t.0, &t.1, &t.2, &t.3).unwrap();
        mem.append_token(a, &t.0, &t.1, &t.2, &t.3).unwrap();
        eff_a.advance(&mut mem, a, &mut dec).unwrap();
    }
    assert_eq!(mem.seq_len(a), Some(14));

    // resume: bit-identical store and effective cache vs the control
    let (back, _) = tier.unpark(b).unwrap();
    mem.restore_sequence_bytes(b, &back).unwrap();
    let mut dec2 = RowWiseMockDecoder::for_spec(&spec);
    let mut eff_resumed = EffectiveCache::new(&spec);
    eff_resumed.rebuild_full(&mut mem, b, &mut dec2).unwrap();
    let mut eff_ctl = EffectiveCache::new(&spec);
    eff_ctl.rebuild_full(&mut ctl, b, &mut dec2).unwrap();
    assert_eq!(
        eff_resumed.k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        eff_ctl.k.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "resumed effective K diverges from the never-parked control"
    );
    assert_eq!(
        eff_resumed.v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        eff_ctl.v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "resumed effective V diverges from the never-parked control"
    );
}
