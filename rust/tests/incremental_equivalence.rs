//! Randomized equivalence for the incremental retrieval path: chunked,
//! watermark-driven `EffectiveCache::advance` calls are **bit-identical**
//! to a one-shot `rebuild_full` for every plan kind — full-alias layers,
//! AE latents, head subsets, int8 packing, and arbitrary mixes.
//!
//! Runs without artifacts: the AE decoder is a deterministic pure-rust
//! mock (row-wise, so chunked calls compose exactly like the real
//! per-row decoder MLP).

use kvcar::coordinator::effective::RowWiseMockDecoder;
use kvcar::coordinator::EffectiveCache;
use kvcar::kvcache::{CacheConfig, CacheManager};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::prop_assert;
use kvcar::util::prop::check;
use kvcar::util::rng::Rng;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "equiv".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 5,
        d_model: 48,
        n_head: 6,
        n_kv_head: 6,
        d_head: 8,
        ffn_dim: 96,
        max_seq: 64,
        ae_hidden: 32,
        ae_latent: 24,
        bytes_per_el: 4,
    }
}

fn random_plan(rng: &mut Rng, spec: &ModelSpec) -> CompressionPlan {
    CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head)
}

fn append_random_token(m: &mut CacheManager, id: u64, rng: &mut Rng) {
    let spec = m.cfg.spec.clone();
    let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    let kl = mk(rng, spec.n_layer * spec.ae_latent);
    let vl = mk(rng, spec.n_layer * spec.ae_latent);
    let kr = mk(rng, spec.n_layer * spec.kv_dim());
    let vr = mk(rng, spec.n_layer * spec.kv_dim());
    m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) -> std::result::Result<(), String> {
    prop_assert!(a.len() == b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit divergence at {i}: {x} vs {y}"
        );
    }
    Ok(())
}

#[test]
fn incremental_advances_bitwise_match_full_rebuild() {
    check(30, |rng| {
        let spec = tiny_spec();
        let plan = random_plan(rng, &spec);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        // incremental: random-sized append/advance chunks (watermark
        // splits the decode at arbitrary boundaries)
        let mut inc = EffectiveCache::new(&spec);
        let total = rng.range(1, spec.max_seq);
        let mut appended = 0;
        while appended < total {
            let chunk = rng.range(1, 5).min(total - appended);
            for _ in 0..chunk {
                append_random_token(&mut m, id, rng);
            }
            appended += chunk;
            let n = inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
            prop_assert!(n == chunk, "advance decoded {n}, expected {chunk}");
        }
        // watermark: re-advancing with nothing new decodes nothing
        let n = inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        prop_assert!(n == 0, "no-op advance decoded {n} rows");
        prop_assert!(
            inc.stats.rows_decoded == total as u64,
            "each row must be decoded exactly once ({} for len {total})",
            inc.stats.rows_decoded
        );
        prop_assert!(inc.stats.full_rebuilds == 0, "incremental path did a full rebuild");

        // one-shot full rebuild into a fresh scratch
        let mut full = EffectiveCache::new(&spec);
        full.rebuild_full(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        assert_bits_eq(&inc.k, &full.k, "effective K")?;
        assert_bits_eq(&inc.v, &full.v, "effective V")?;
        Ok(())
    });
}

#[test]
fn eviction_resume_rebuild_matches_continuous_incremental() {
    check(15, |rng| {
        let spec = tiny_spec();
        let plan = random_plan(rng, &spec);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        // a sequence that advanced incrementally its whole life
        let mut inc = EffectiveCache::new(&spec);
        let total = rng.range(4, 40);
        for _ in 0..total {
            append_random_token(&mut m, id, rng);
            inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        }
        // eviction: scratch dropped, watermark invalidated; resume does
        // one full rebuild (the tier.rs path)
        m.reset_decoded(id);
        let mut resumed = EffectiveCache::new(&spec);
        let n = resumed
            .advance(&mut m, id, &mut dec)
            .map_err(|e| e.to_string())?;
        prop_assert!(n == total, "resume advance must rebuild all {total} rows, got {n}");
        assert_bits_eq(&inc.k, &resumed.k, "resumed K (advance)")?;
        assert_bits_eq(&inc.v, &resumed.v, "resumed V (advance)")?;

        let mut rebuilt = EffectiveCache::new(&spec);
        rebuilt
            .rebuild_full(&mut m, id, &mut dec)
            .map_err(|e| e.to_string())?;
        prop_assert!(rebuilt.stats.full_rebuilds == 1, "rebuild_full must count itself");
        assert_bits_eq(&inc.k, &rebuilt.k, "resumed K (rebuild_full)")?;
        assert_bits_eq(&inc.v, &rebuilt.v, "resumed V (rebuild_full)")?;
        Ok(())
    });
}
