//! Randomized equivalence for the incremental retrieval path: chunked,
//! watermark-driven `EffectiveCache::advance` calls are **bit-identical**
//! to a one-shot `rebuild_full` for every plan kind — full-alias layers,
//! AE latents, head subsets, int8 packing, and arbitrary mixes.
//!
//! Runs without artifacts: the AE decoder is a deterministic pure-rust
//! mock (row-wise, so chunked calls compose exactly like the real
//! per-row decoder MLP).
//!
//! Also home of the store-resident staging laws (`coordinator::
//! resident`): a steady decode round stages O(B·L·kvd) k/v bytes (one
//! row per live sequence) against the copy path's O(B·L·S·kvd), the
//! staged tensors are bitwise identical on both paths, and slot
//! transitions (retire / admit / vacated-slot zeroing) are paid once,
//! not per round.

use kvcar::coordinator::effective::RowWiseMockDecoder;
use kvcar::coordinator::{stage_copy_round, EffectiveCache, ServeMetrics, SlotArena};
use kvcar::kvcache::{CacheConfig, CacheManager};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::prop_assert;
use kvcar::runtime::Store;
use kvcar::util::prop::check;
use kvcar::util::rng::Rng;
use std::collections::HashMap;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "equiv".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 5,
        d_model: 48,
        n_head: 6,
        n_kv_head: 6,
        d_head: 8,
        ffn_dim: 96,
        max_seq: 64,
        ae_hidden: 32,
        ae_latent: 24,
        bytes_per_el: 4,
    }
}

fn random_plan(rng: &mut Rng, spec: &ModelSpec) -> CompressionPlan {
    CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head)
}

fn append_random_token(m: &mut CacheManager, id: u64, rng: &mut Rng) {
    let spec = m.cfg.spec.clone();
    let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    let kl = mk(rng, spec.n_layer * spec.ae_latent);
    let vl = mk(rng, spec.n_layer * spec.ae_latent);
    let kr = mk(rng, spec.n_layer * spec.kv_dim());
    let vr = mk(rng, spec.n_layer * spec.kv_dim());
    m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) -> std::result::Result<(), String> {
    prop_assert!(a.len() == b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit divergence at {i}: {x} vs {y}"
        );
    }
    Ok(())
}

#[test]
fn incremental_advances_bitwise_match_full_rebuild() {
    check(30, |rng| {
        let spec = tiny_spec();
        let plan = random_plan(rng, &spec);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        // incremental: random-sized append/advance chunks (watermark
        // splits the decode at arbitrary boundaries)
        let mut inc = EffectiveCache::new(&spec);
        let total = rng.range(1, spec.max_seq);
        let mut appended = 0;
        while appended < total {
            let chunk = rng.range(1, 5).min(total - appended);
            for _ in 0..chunk {
                append_random_token(&mut m, id, rng);
            }
            appended += chunk;
            let n = inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
            prop_assert!(n == chunk, "advance decoded {n}, expected {chunk}");
        }
        // watermark: re-advancing with nothing new decodes nothing
        let n = inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        prop_assert!(n == 0, "no-op advance decoded {n} rows");
        prop_assert!(
            inc.stats.rows_decoded == total as u64,
            "each row must be decoded exactly once ({} for len {total})",
            inc.stats.rows_decoded
        );
        prop_assert!(inc.stats.full_rebuilds == 0, "incremental path did a full rebuild");

        // one-shot full rebuild into a fresh scratch
        let mut full = EffectiveCache::new(&spec);
        full.rebuild_full(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        assert_bits_eq(&inc.k, &full.k, "effective K")?;
        assert_bits_eq(&inc.v, &full.v, "effective V")?;
        Ok(())
    });
}

/// Assert two store tensors hold bit-identical f32 contents.
fn assert_store_tensors_eq(a: &Store, b: &Store, name: &str, what: &str) {
    let ta = a.get(name).unwrap().as_f32().unwrap();
    let tb = b.get(name).unwrap().as_f32().unwrap();
    assert_eq!(ta.len(), tb.len(), "{what}: {name} length mismatch");
    for (i, (x, y)) in ta.iter().zip(tb).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: {name} diverges at element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn resident_staging_cost_law_b4_s256_and_bitwise_copy_equivalence() {
    // The store-resident effective cache's acceptance law: at B = 4,
    // S = 256, a steady-state decode round stages exactly one new row
    // per live sequence per side — 2·B·L·kvd·4 bytes — while the
    // legacy copy path moves the full 2·B·L·S·kvd·4 every round; and
    // the staged `k_cache`/`v_cache` tensors are **bitwise identical**
    // on both paths.  The decode-step logits are a pure function of
    // (k_cache, v_cache, token, pos), so identical staging implies
    // identical logits; the artifact-level logits assertion is
    // `tests/pipeline_integration.rs::
    // resident_staging_matches_copy_path_and_stages_o_new_rows`.
    let mut spec = tiny_spec();
    spec.max_seq = 256;
    let mut plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
    plan.reuse_k[1][0] = true;
    plan.reuse_v[3][1] = true;
    let b = 4usize;
    let prompt = 8usize;
    let (l, s, kvd) = (spec.n_layer, spec.max_seq, spec.kv_dim());
    let dims = (l, s, kvd);
    let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let mut dec = RowWiseMockDecoder::for_spec(&spec);
    let mut effs: HashMap<u64, EffectiveCache> = HashMap::new();
    let mut rng = Rng::new(42);
    let mut ids = Vec::new();
    for _ in 0..b {
        let id = m.create_sequence();
        effs.insert(id, EffectiveCache::new(&spec));
        for _ in 0..prompt {
            append_random_token(&mut m, id, &mut rng);
        }
        ids.push(id);
    }
    let (mut store_res, mut store_copy) = (Store::new(), Store::new());
    let (mut met_res, mut met_copy) = (ServeMetrics::default(), ServeMetrics::default());
    let mut arena = SlotArena::new();
    let row_law = (2 * b * l * kvd * 4) as u64; // K+V, one row per sequence
    let copy_law = (2 * b * l * s * kvd * 4) as u64; // full tensor pair
    let rounds = 5;
    for round in 0..rounds {
        if round > 0 {
            for &id in &ids {
                append_random_token(&mut m, id, &mut rng);
            }
        }
        for &id in &ids {
            effs.get_mut(&id).unwrap().advance(&mut m, id, &mut dec).unwrap();
        }
        let before_res = met_res.staged_kv_bytes;
        let before_copy = met_copy.staged_kv_bytes;
        let marks: Vec<(u64, usize)> = ids
            .iter()
            .map(|&id| (id, m.decoded_upto(id).unwrap()))
            .collect();
        arena
            .stage_round(&mut store_res, &marks, &effs, b, dims, &mut met_res)
            .unwrap();
        stage_copy_round(&mut store_copy, &effs, &ids, b, dims, &mut met_copy).unwrap();
        let what = format!("round {round}");
        assert_store_tensors_eq(&store_res, &store_copy, "k_cache", &what);
        assert_store_tensors_eq(&store_res, &store_copy, "v_cache", &what);
        assert_eq!(met_copy.staged_kv_bytes - before_copy, copy_law);
        if round == 0 {
            assert_eq!(met_res.staged_kv_bytes, 0, "round 0 is slot fills, not syncs");
            assert_eq!(met_res.slot_rebuilds, b as u64, "one fill per admitted sequence");
            assert_eq!(
                met_res.slot_rebuild_bytes,
                (2 * b * l * prompt * kvd * 4) as u64,
                "slot fills cover exactly the prompt rows (fresh region needs no zeroing)"
            );
        } else {
            assert_eq!(
                met_res.staged_kv_bytes - before_res,
                row_law,
                "steady round {round} must stage exactly one row per sequence per side"
            );
            assert_eq!(met_res.slot_rebuilds, b as u64, "no rebuilds in steady state");
        }
    }
    // the headline ratio: per steady round the resident path moves S×
    // fewer k/v staging bytes (256× here)
    assert_eq!(copy_law / row_law, s as u64);
    assert_eq!(met_res.capacity_switches, 0);
}

#[test]
fn resident_slot_lifecycle_retire_admit_and_zero_once() {
    // slot transitions: a retired sequence's slot is zeroed exactly
    // once (not per round), bystanders never restage old rows, a new
    // admission reuses the freed slot, and every held slot stays
    // bitwise identical to the copy path's buffer for the same owner
    let spec = tiny_spec();
    let (l, s, kvd) = (spec.n_layer, spec.max_seq, spec.kv_dim());
    let dims = (l, s, kvd);
    let seq_elems = l * s * kvd;
    let b = 3usize;
    let mut m = CacheManager::new(CacheConfig::new(
        spec.clone(),
        CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2),
    ));
    let mut dec = RowWiseMockDecoder::for_spec(&spec);
    let mut effs: HashMap<u64, EffectiveCache> = HashMap::new();
    let mut rng = Rng::new(7);
    let new_seq = |m: &mut CacheManager,
                   effs: &mut HashMap<u64, EffectiveCache>,
                   rng: &mut Rng,
                   rows: usize| {
        let id = m.create_sequence();
        effs.insert(id, EffectiveCache::new(&spec));
        for _ in 0..rows {
            append_random_token(m, id, rng);
        }
        id
    };
    let x = new_seq(&mut m, &mut effs, &mut rng, 4);
    let y = new_seq(&mut m, &mut effs, &mut rng, 4);
    let z = new_seq(&mut m, &mut effs, &mut rng, 4);
    let (mut store_res, mut store_copy) = (Store::new(), Store::new());
    let (mut met_res, mut met_copy) = (ServeMetrics::default(), ServeMetrics::default());
    let mut arena = SlotArena::new();
    let round = |m: &mut CacheManager,
                 effs: &mut HashMap<u64, EffectiveCache>,
                 arena: &mut SlotArena,
                 store_res: &mut Store,
                 store_copy: &mut Store,
                 met_res: &mut ServeMetrics,
                 met_copy: &mut ServeMetrics,
                 dec: &mut RowWiseMockDecoder,
                 rng: &mut Rng,
                 ids: &[u64]| {
        for &id in ids {
            append_random_token(m, id, rng);
            effs.get_mut(&id).unwrap().advance(m, id, dec).unwrap();
        }
        let marks: Vec<(u64, usize)> =
            ids.iter().map(|&id| (id, m.decoded_upto(id).unwrap())).collect();
        arena.stage_round(store_res, &marks, effs, b, dims, met_res).unwrap();
        stage_copy_round(store_copy, effs, ids, b, dims, met_copy).unwrap();
        // per-owner slot equality (slots may be permuted vs the copy
        // path's enumeration order; decode_step treats slots
        // independently, so per-slot equality is the logits guarantee)
        let kr = store_res.get("k_cache").unwrap().as_f32().unwrap();
        let kc = store_copy.get("k_cache").unwrap().as_f32().unwrap();
        for (idx, &id) in ids.iter().enumerate() {
            let slot = arena.slot_of(id).unwrap();
            let a = &kr[slot * seq_elems..(slot + 1) * seq_elems];
            let c = &kc[idx * seq_elems..(idx + 1) * seq_elems];
            for (i, (p, q)) in a.iter().zip(c).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "seq {id} slot {slot} differs at {i}");
            }
        }
        kr.to_vec()
    };
    // two settled rounds with three live sequences
    for _ in 0..2 {
        round(
            &mut m, &mut effs, &mut arena, &mut store_res, &mut store_copy, &mut met_res,
            &mut met_copy, &mut dec, &mut rng, &[x, y, z],
        );
    }
    assert_eq!(met_res.slot_rebuilds, 3);
    let y_slot = arena.slot_of(y).unwrap();
    let (x_slot, z_slot) = (arena.slot_of(x).unwrap(), arena.slot_of(z).unwrap());

    // retire y: bystanders keep their slots, the vacated slot zeroes
    // exactly once, and later rounds pay nothing for it
    arena.release(y);
    effs.remove(&y);
    m.free_sequence(y);
    let rebuilds_before = met_res.slot_rebuild_bytes;
    let kr = round(
        &mut m, &mut effs, &mut arena, &mut store_res, &mut store_copy, &mut met_res,
        &mut met_copy, &mut dec, &mut rng, &[x, z],
    );
    assert_eq!(arena.slot_of(x), Some(x_slot), "bystander slots must not move");
    assert_eq!(arena.slot_of(z), Some(z_slot), "bystander slots must not move");
    assert_eq!(
        met_res.slot_rebuild_bytes - rebuilds_before,
        (2 * seq_elems * 4) as u64,
        "vacated slot must be zeroed exactly once (K and V)"
    );
    assert!(
        kr[y_slot * seq_elems..(y_slot + 1) * seq_elems]
            .iter()
            .all(|&v| v == 0.0),
        "vacated slot must read as zero padding"
    );
    let rebuilds_after_zero = met_res.slot_rebuild_bytes;
    let staged_before = met_res.staged_kv_bytes;
    round(
        &mut m, &mut effs, &mut arena, &mut store_res, &mut store_copy, &mut met_res,
        &mut met_copy, &mut dec, &mut rng, &[x, z],
    );
    assert_eq!(
        met_res.slot_rebuild_bytes, rebuilds_after_zero,
        "a clean dead slot must not be re-zeroed every round"
    );
    assert_eq!(
        met_res.staged_kv_bytes - staged_before,
        (2 * 2 * l * kvd * 4) as u64,
        "two live sequences stage exactly one row each per side"
    );

    // a new admission reuses the freed slot; nobody else moves or pays
    let w = new_seq(&mut m, &mut effs, &mut rng, 3);
    let staged_before = met_res.staged_kv_bytes;
    round(
        &mut m, &mut effs, &mut arena, &mut store_res, &mut store_copy, &mut met_res,
        &mut met_copy, &mut dec, &mut rng, &[x, z, w],
    );
    assert_eq!(arena.slot_of(w), Some(y_slot), "admission must take the freed slot");
    assert_eq!(arena.slot_of(x), Some(x_slot));
    assert_eq!(arena.slot_of(z), Some(z_slot));
    assert_eq!(met_res.slot_rebuilds, 4, "only the new admission rebuilds");
    assert_eq!(
        met_res.staged_kv_bytes - staged_before,
        (2 * 2 * l * kvd * 4) as u64,
        "bystanders stage one row each; the admission is rebuild-accounted"
    );
}

#[test]
fn eviction_resume_rebuild_matches_continuous_incremental() {
    check(15, |rng| {
        let spec = tiny_spec();
        let plan = random_plan(rng, &spec);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        // a sequence that advanced incrementally its whole life
        let mut inc = EffectiveCache::new(&spec);
        let total = rng.range(4, 40);
        for _ in 0..total {
            append_random_token(&mut m, id, rng);
            inc.advance(&mut m, id, &mut dec).map_err(|e| e.to_string())?;
        }
        // eviction: scratch dropped, watermark invalidated; resume does
        // one full rebuild (the tier.rs path)
        m.reset_decoded(id);
        let mut resumed = EffectiveCache::new(&spec);
        let n = resumed
            .advance(&mut m, id, &mut dec)
            .map_err(|e| e.to_string())?;
        prop_assert!(n == total, "resume advance must rebuild all {total} rows, got {n}");
        assert_bits_eq(&inc.k, &resumed.k, "resumed K (advance)")?;
        assert_bits_eq(&inc.v, &resumed.v, "resumed V (advance)")?;

        let mut rebuilt = EffectiveCache::new(&spec);
        rebuilt
            .rebuild_full(&mut m, id, &mut dec)
            .map_err(|e| e.to_string())?;
        prop_assert!(rebuilt.stats.full_rebuilds == 1, "rebuild_full must count itself");
        assert_bits_eq(&inc.k, &rebuilt.k, "resumed K (rebuild_full)")?;
        assert_bits_eq(&inc.v, &rebuilt.v, "resumed V (rebuild_full)")?;
        Ok(())
    });
}
