//! Runtime integration: every manifest entry compiles; eval/encode/decode
//! artifacts execute with real parameters and produce sane numbers.
//!
//! Requires `make artifacts`; tests no-op (with a notice) when the
//! artifacts directory is missing so `cargo test` stays runnable on a
//! fresh checkout.

use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine, Store, Tensor};

fn engine_or_skip() -> Option<(Engine, Store, ModelSpec)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    let mut engine = Engine::new(&dir).expect("engine");
    let mut store = Store::new();
    let n = engine.load_params("gpt2t", &mut store).expect("params");
    assert!(n > 50, "expected many params, got {n}");
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, "gpt2t").unwrap();
    Some((engine, store, spec))
}

fn push_masks(store: &mut Store, spec: &ModelSpec, compress_layers: usize, quant: f32) {
    let l = spec.n_layer;
    let h = spec.n_kv_head;
    let mut compress = vec![0.0f32; l];
    for c in compress.iter_mut().take(compress_layers) {
        *c = 1.0;
    }
    store.insert("compress", Tensor::f32(vec![l], compress));
    store.insert("quant", Tensor::scalar_f32(quant));
    store.insert("reuse_k", Tensor::zeros_f32(vec![l, h]));
    store.insert("reuse_v", Tensor::zeros_f32(vec![l, h]));
}

#[test]
fn all_entries_compile() {
    let Some((mut engine, _, _)) = engine_or_skip() else {
        return;
    };
    let names: Vec<String> = engine.manifest.entries.keys().cloned().collect();
    for name in names {
        engine.load(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
    assert!(engine.stats.compiles >= 20);
}

#[test]
fn eval_loss_baseline_vs_compressed() {
    let Some((mut engine, mut store, spec)) = engine_or_skip() else {
        return;
    };
    let (b, s) = (8, spec.max_seq);
    let mut corpus = kvcar::data::corpus::wiki(0);
    let tb = kvcar::data::batch::lm_batch(&mut corpus, b, s);
    store.insert("tokens", Tensor::i32(vec![b, s], tb.tokens.clone()));
    store.insert("len_mask", Tensor::f32(vec![b, s], tb.mask.clone()));

    push_masks(&mut store, &spec, 0, 0.0);
    let out = engine.execute("gpt2t_eval_loss", &store).unwrap();
    let nll_base = out[0].1.as_f32().unwrap().to_vec();
    let ntok = out[1].1.as_f32().unwrap().to_vec();
    assert!(nll_base.iter().all(|x| x.is_finite() && *x > 0.0));
    assert!(ntok.iter().all(|&x| x == (s - 1) as f32));

    push_masks(&mut store, &spec, spec.n_layer, 0.0);
    let out = engine.execute("gpt2t_eval_loss", &store).unwrap();
    let nll_comp = out[0].1.as_f32().unwrap();
    // untrained AEs wreck the model: compressed nll must differ (and
    // typically be much worse)
    let diff: f32 = nll_base
        .iter()
        .zip(nll_comp)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1.0, "compression had no effect: {diff}");
}

#[test]
fn encode_decode_kv_roundtrip_shapes() {
    let Some((mut engine, mut store, spec)) = engine_or_skip() else {
        return;
    };
    let (l, s, kvd, dl) = (spec.n_layer, spec.max_seq, spec.kv_dim(), spec.ae_latent);
    let mut rng = kvcar::util::rng::Rng::new(7);
    let mk = |n: usize, rng: &mut kvcar::util::rng::Rng| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    store.insert("k_raw", Tensor::f32(vec![l, s, kvd], mk(l * s * kvd, &mut rng)));
    store.insert("v_raw", Tensor::f32(vec![l, s, kvd], mk(l * s * kvd, &mut rng)));
    let out = engine.execute("gpt2t_encode_kv", &store).unwrap();
    assert_eq!(out[0].0, "k_lat");
    assert_eq!(out[0].1.shape(), &[l, s, dl]);
    store.insert("k_lat", out[0].1.clone());
    store.insert("v_lat", out[1].1.clone());
    let out = engine.execute("gpt2t_decode_kv", &store).unwrap();
    assert_eq!(out[0].0, "k_rec");
    assert_eq!(out[0].1.shape(), &[l, s, kvd]);
    assert!(out[0].1.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn decode_kv_t_matches_full_decode_rows() {
    // The incremental effective-cache path decodes one token through
    // `decode_kv_t` ([L,1,dl]) while prompt reconstruction and
    // eviction-resume go through the padded full `decode_kv` ([L,S,dl]).
    // The LatentDecoder contract requires the two independently-lowered
    // programs to agree per row, or incrementally-advanced scratch would
    // diverge from a post-resume rebuild.  Skips (like every artifact
    // test) when artifacts are missing, and when the artifact set
    // predates the `_t` entry.
    let Some((mut engine, mut store, spec)) = engine_or_skip() else {
        return;
    };
    if !engine.manifest.entries.contains_key("gpt2t_decode_kv_t") {
        eprintln!("skipping: artifacts predate decode_kv_t (re-run `make artifacts`)");
        return;
    }
    let (l, s, dl, kvd) = (spec.n_layer, spec.max_seq, spec.ae_latent, spec.kv_dim());
    let mut rng = kvcar::util::rng::Rng::new(11);
    let mk = |n: usize, rng: &mut kvcar::util::rng::Rng| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    let k_lat = mk(l * s * dl, &mut rng);
    let v_lat = mk(l * s * dl, &mut rng);
    store.insert("k_lat", Tensor::f32(vec![l, s, dl], k_lat.clone()));
    store.insert("v_lat", Tensor::f32(vec![l, s, dl], v_lat.clone()));
    let full = engine.execute("gpt2t_decode_kv", &store).unwrap();
    let k_full = full[0].1.as_f32().unwrap().to_vec();
    let v_full = full[1].1.as_f32().unwrap().to_vec();

    for t in [0usize, 1, s / 2, s - 1] {
        let slice = |lat: &[f32]| -> Vec<f32> {
            (0..l)
                .flat_map(|layer| lat[layer * s * dl + t * dl..][..dl].to_vec())
                .collect()
        };
        store.insert("k_lat", Tensor::f32(vec![l, 1, dl], slice(&k_lat)));
        store.insert("v_lat", Tensor::f32(vec![l, 1, dl], slice(&v_lat)));
        let one = engine.execute("gpt2t_decode_kv_t", &store).unwrap();
        for (name, row, all) in [
            ("k_rec", one[0].1.as_f32().unwrap(), &k_full),
            ("v_rec", one[1].1.as_f32().unwrap(), &v_full),
        ] {
            for layer in 0..l {
                let a = &row[layer * kvd..(layer + 1) * kvd];
                let b = &all[layer * s * kvd + t * kvd..][..kvd];
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "{name} t={t} layer={layer}: decode_kv_t diverges from \
                         decode_kv ({x:e} vs {y:e}) — the incremental path would \
                         not be bit-identical to rebuild_full on this backend"
                    );
                }
            }
        }
    }
}

#[test]
fn kv_stats_shapes_and_positivity() {
    let Some((mut engine, mut store, spec)) = engine_or_skip() else {
        return;
    };
    let (b, s) = (8, spec.max_seq);
    let mut corpus = kvcar::data::corpus::wiki(3);
    let tb = kvcar::data::batch::lm_batch(&mut corpus, b, s);
    store.insert("tokens", Tensor::i32(vec![b, s], tb.tokens));
    store.insert("len_mask", Tensor::f32(vec![b, s], tb.mask));
    let out = engine.execute("gpt2t_kv_stats", &store).unwrap();
    let dk = out[0].1.as_f32().unwrap();
    assert_eq!(out[0].1.shape(), &[spec.n_layer, spec.n_kv_head]);
    // rows 1.. are genuine distances: strictly positive
    assert!(dk[spec.n_kv_head..].iter().all(|&x| x > 0.0));
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some((mut engine, mut store, spec)) = engine_or_skip() else {
        return;
    };
    store.insert("tokens", Tensor::i32(vec![1, 4], vec![0; 4])); // wrong shape
    store.insert("len_mask", Tensor::f32(vec![1, 4], vec![1.0; 4]));
    push_masks(&mut store, &spec, 0, 0.0);
    let err = engine.execute("gpt2t_eval_loss", &store).unwrap_err();
    assert!(format!("{err:#}").contains("shape mismatch"));
}
