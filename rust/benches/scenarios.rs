//! Scenario-matrix bench: serves the standard scenario matrix
//! (DESIGN.md §8 — admission/template/budget workloads plus the §9
//! chaos trio) through the deterministic mock backend and emits
//! machine-readable `BENCH_scenarios.json` (override with
//! `KVCAR_BENCH_JSON`) with per-scenario TTFT and tok/s p50/p99 —
//! every figure on the **virtual clock**, so the numbers are a pure
//! function of the scenario and run-over-run deltas measure scheduler
//! policy changes, not machine noise.  When a previous file exists its
//! numbers are reported as deltas before being replaced, mirroring
//! `BENCH_decode_hotpath.json`.
//!
//! When AOT artifacts are present the same matrix additionally runs
//! against the real engine (reported as `gpt2t/...` rows and the
//! `engine_scenarios` section); without artifacts the mock leg alone
//! runs, so the bench never skips entirely.
//!
//! The sharded matrix (DESIGN.md §10) runs the same harness over an
//! N-worker router with forced mid-generation migrations: its rows
//! report committed migrations by initiator, the delta law on the wire
//! (payload bytes shipped vs bytes the destinations' replica bases
//! supplied), shared-prefix chunk traffic, and per-worker TTFT
//! percentiles — the `sharded_scenarios` section of the JSON.

use kvcar::coordinator::{
    run_scenario, run_sharded, scenario_spec, sharded_matrix, standard_matrix, Scenario,
    ScenarioReport, ShardedReport, ShardedScenario,
};
use kvcar::runtime::{artifacts_dir, Engine, ExecBackend, MockEngine};
use kvcar::util::json::{self, Json};

fn json_path() -> String {
    std::env::var("KVCAR_BENCH_JSON").unwrap_or_else(|_| "BENCH_scenarios.json".into())
}

/// Run one scenario and print its human-readable row.
fn run_one(engine: &mut dyn ExecBackend, model: &str, sc: &Scenario, tag: &str) -> ScenarioReport {
    let r = run_scenario(engine, model, sc).expect("scenario must pass its invariants");
    println!(
        "bench scenarios/{tag}{:<28} ttft p50 {:>7.2} p99 {:>7.2} ms  tok/s p50 {:>7.1} p99 {:>7.1}  \
         ({} rounds, {} faults, {} retries, {} rejected, {} quarantined, {:.1} virtual ms)",
        r.name,
        r.ttft_p50_ms,
        r.ttft_p99_ms,
        r.tok_s_p50,
        r.tok_s_p99,
        r.rounds,
        r.faults_injected,
        r.retries,
        r.rejected.len(),
        r.quarantined.len(),
        r.virtual_ms,
    );
    r
}

/// Run one sharded scenario across fresh mock workers and print its
/// human-readable rows (one summary line, one wire line).
fn run_one_sharded(sc: &ShardedScenario) -> ShardedReport {
    let mut engines: Vec<MockEngine> =
        (0..sc.n_workers).map(|_| MockEngine::new(scenario_spec())).collect();
    let backends: Vec<&mut dyn ExecBackend> =
        engines.iter_mut().map(|e| e as &mut dyn ExecBackend).collect();
    let r = run_sharded(backends, "mock", sc).expect("sharded scenario must pass its audits");
    let kib = |b: u64| b as f64 / 1024.0;
    println!(
        "bench scenarios/{:<28} {} workers  {:>3} migrations ({} forced, {} rebalance, {} drain, \
         {} rolled back)  ({} rounds, {:.1} virtual ms)",
        r.name,
        r.n_workers,
        r.migrations,
        r.forced_migrations,
        r.rebalance_migrations,
        r.drain_migrations,
        r.corruption_rollbacks,
        r.rounds,
        r.virtual_ms,
    );
    let worst = r.worker_ttft_ms.iter().map(|&(_, p99)| p99).fold(0.0f64, f64::max);
    println!(
        "bench scenarios/{:<28} wire: {:.1} KiB delta shipped vs {:.1} KiB basis-resident \
         ({:.1} KiB full), {:.1} KiB chunks ({} in, {} deduped)  worst worker ttft p99 {:.2} ms",
        r.name,
        kib(r.delta_bytes),
        kib(r.bytes_saved),
        kib(r.full_bytes),
        kib(r.chunk_bytes),
        r.chunks_in,
        r.chunks_deduped,
        worst,
    );
    r
}

fn scenario_json(r: &ScenarioReport) -> Json {
    json::obj(vec![
        ("name", json::s(&r.name)),
        ("completed", json::num(r.completed as f64)),
        ("rejected", json::num(r.rejected.len() as f64)),
        ("rounds", json::num(r.rounds as f64)),
        ("invariant_checks", json::num(r.invariant_checks as f64)),
        ("faults_injected", json::num(r.faults_injected as f64)),
        ("ttft_p50_ms", json::num(r.ttft_p50_ms)),
        ("ttft_p99_ms", json::num(r.ttft_p99_ms)),
        ("tok_s_p50", json::num(r.tok_s_p50)),
        ("tok_s_p99", json::num(r.tok_s_p99)),
        ("throughput_tok_s", json::num(r.throughput_tok_s)),
        ("virtual_ms", json::num(r.virtual_ms)),
        ("parks", json::num(r.parks as f64)),
        ("resumes", json::num(r.resumes as f64)),
        ("shared_admissions", json::num(r.shared_admissions as f64)),
        // supervisor recovery counters (DESIGN.md §9)
        ("retries", json::num(r.retries as f64)),
        ("backoff_ms", json::num(r.backoff_ms)),
        ("quarantines", json::num(r.quarantined.len() as f64)),
        ("demotions", json::num(r.demotions as f64)),
        ("region_demotions", json::num(r.region_demotions as f64)),
        ("checksum_failures", json::num(r.checksum_failures as f64)),
        ("template_sheds", json::num(r.template_sheds as f64)),
        // digests as hex strings: u64 does not round-trip through the
        // f64-backed Json number type
        ("tokens_digest", json::s(&format!("{:016x}", r.tokens_digest))),
        (
            "invariant_digest",
            json::s(&format!("{:016x}", r.invariant_digest)),
        ),
    ])
}

fn sharded_json(r: &ShardedReport) -> Json {
    json::obj(vec![
        ("name", json::s(&r.name)),
        ("n_workers", json::num(r.n_workers as f64)),
        ("completed", json::num(r.completed as f64)),
        ("rounds", json::num(r.rounds as f64)),
        ("invariant_checks", json::num(r.invariant_checks as f64)),
        ("migrations", json::num(r.migrations as f64)),
        ("forced_migrations", json::num(r.forced_migrations as f64)),
        (
            "rebalance_migrations",
            json::num(r.rebalance_migrations as f64),
        ),
        ("drain_migrations", json::num(r.drain_migrations as f64)),
        (
            "corruption_rollbacks",
            json::num(r.corruption_rollbacks as f64),
        ),
        // the delta law on the wire: shipped + saved == full
        ("delta_bytes", json::num(r.delta_bytes as f64)),
        ("bytes_saved", json::num(r.bytes_saved as f64)),
        ("full_bytes", json::num(r.full_bytes as f64)),
        ("chunk_bytes", json::num(r.chunk_bytes as f64)),
        ("chunks_in", json::num(r.chunks_in as f64)),
        ("chunks_deduped", json::num(r.chunks_deduped as f64)),
        ("throughput_tok_s", json::num(r.throughput_tok_s)),
        ("virtual_ms", json::num(r.virtual_ms)),
        (
            "worker_ttft_ms",
            json::arr(r.worker_ttft_ms.iter().map(|&(p50, p99)| {
                json::obj(vec![
                    ("p50_ms", json::num(p50)),
                    ("p99_ms", json::num(p99)),
                ])
            })),
        ),
        ("tokens_digest", json::s(&format!("{:016x}", r.tokens_digest))),
        (
            "invariant_digest",
            json::s(&format!("{:016x}", r.invariant_digest)),
        ),
    ])
}

/// Compare against the previous run's file (the cross-PR trajectory).
/// Virtual-clock figures only move when scheduler policy or the cost
/// model changes, so any delta here is a real behavior change.
fn report_deltas(prev: &Json, reports: &[ScenarioReport]) {
    let Some(prev_rows) = prev.get("scenarios").and_then(Json::as_arr) else {
        return;
    };
    for r in reports {
        let Some(old) = prev_rows
            .iter()
            .find(|p| p.get("name").and_then(Json::as_str) == Some(r.name.as_str()))
        else {
            continue;
        };
        for (field, new_v) in [
            ("ttft_p99_ms", r.ttft_p99_ms),
            ("tok_s_p50", r.tok_s_p50),
            ("throughput_tok_s", r.throughput_tok_s),
            ("retries", r.retries as f64),
            ("backoff_ms", r.backoff_ms),
            ("quarantines", r.quarantined.len() as f64),
        ] {
            if let Some(old_v) = old.get(field).and_then(Json::as_f64) {
                if old_v > 0.0 && (old_v - new_v).abs() > 1e-9 {
                    println!(
                        "bench scenarios/{:<28} vs previous: {field} {:+.1}% ({:.3} -> {:.3})",
                        r.name,
                        100.0 * (new_v - old_v) / old_v,
                        old_v,
                        new_v,
                    );
                }
            }
        }
    }
}

/// Run-over-run deltas for the sharded rows: the wire figures
/// (delta/saved/chunk bytes) and migration counts move only when the
/// migration protocol or the placement policy changes.
fn report_sharded_deltas(prev: &Json, reports: &[ShardedReport]) {
    let Some(prev_rows) = prev.get("sharded_scenarios").and_then(Json::as_arr) else {
        return;
    };
    for r in reports {
        let Some(old) = prev_rows
            .iter()
            .find(|p| p.get("name").and_then(Json::as_str) == Some(r.name.as_str()))
        else {
            continue;
        };
        for (field, new_v) in [
            ("migrations", r.migrations as f64),
            ("delta_bytes", r.delta_bytes as f64),
            ("bytes_saved", r.bytes_saved as f64),
            ("chunk_bytes", r.chunk_bytes as f64),
            ("throughput_tok_s", r.throughput_tok_s),
            ("virtual_ms", r.virtual_ms),
        ] {
            if let Some(old_v) = old.get(field).and_then(Json::as_f64) {
                if old_v > 0.0 && (old_v - new_v).abs() > 1e-9 {
                    println!(
                        "bench scenarios/{:<28} vs previous: {field} {:+.1}% ({:.3} -> {:.3})",
                        r.name,
                        100.0 * (new_v - old_v) / old_v,
                        old_v,
                        new_v,
                    );
                }
            }
        }
    }
}

fn main() {
    let matrix = standard_matrix();
    let mut reports = Vec::new();
    for sc in &matrix {
        let mut engine = MockEngine::new(scenario_spec());
        reports.push(run_one(&mut engine, "mock", sc, ""));
    }

    // sharded leg: fresh mock workers per scenario, same virtual clock
    let sharded: Vec<ShardedReport> = sharded_matrix().iter().map(run_one_sharded).collect();

    // artifact-gated real-engine leg: identical harness and virtual
    // clock over the PJRT artifact backend — launch faults included
    // (the engine arms them through the same `ExecBackend` contract
    // and fails the launch before compiling or uploading anything)
    let mut engine_reports = Vec::new();
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let mut engine = Engine::new(&dir).expect("artifact engine must load");
        for sc in &matrix {
            engine_reports.push(run_one(&mut engine, "gpt2t", sc, "gpt2t/"));
        }
    } else {
        println!("bench scenarios: artifacts absent; real-engine leg skipped (mock leg above)");
    }

    let path = json_path();
    match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(prev) => {
                report_deltas(&prev, &reports);
                report_sharded_deltas(&prev, &sharded);
            }
            Err(e) => println!("bench scenarios: previous {path} unreadable ({e}); no deltas"),
        },
        // absent baseline is the normal first-run case, not an error
        Err(_) => println!("bench scenarios: no previous run ({path}); deltas start next run"),
    }
    let j = json::obj(vec![
        ("version", json::num(1.0)),
        ("bench", json::s("scenarios")),
        ("backend", json::s("mock")),
        ("scenarios", json::arr(reports.iter().map(scenario_json))),
        (
            "engine_scenarios",
            json::arr(engine_reports.iter().map(scenario_json)),
        ),
        (
            "sharded_scenarios",
            json::arr(sharded.iter().map(sharded_json)),
        ),
    ]);
    match std::fs::write(&path, j.to_string()) {
        Ok(()) => println!("bench scenarios: wrote {path}"),
        Err(e) => eprintln!("bench scenarios: could not write {path}: {e}"),
    }
}
