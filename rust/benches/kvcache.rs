//! Cache-manager hot-path benches: append/read throughput per storage
//! format and plan, allocator recycle behaviour.  These are the L3
//! per-token costs the serving loop pays (EXPERIMENTS.md §Perf).

use kvcar::kvcache::{CacheConfig, CacheManager, Side, StreamRows};
use kvcar::model::memory::CompressionPlan;
use kvcar::model::{Arch, ModelSpec};
use kvcar::util::bench::{black_box, Bench};
use kvcar::util::rng::Rng;

fn spec() -> ModelSpec {
    ModelSpec {
        name: "bench".into(),
        arch: Arch::Gpt2,
        vocab: 256,
        n_layer: 8,
        d_model: 128,
        n_head: 4,
        n_kv_head: 4,
        d_head: 32,
        ffn_dim: 512,
        max_seq: 128,
        ae_hidden: 96,
        ae_latent: 64,
        bytes_per_el: 4,
    }
}

fn rows(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn bench_append(label: &str, plan: CompressionPlan) {
    let spec = spec();
    let mut rng = Rng::new(1);
    let kl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let vl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let kr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let vr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let mut mgr = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let r = Bench::new(&format!("kvcache/append_token/{label}")).run(|| {
        let id = mgr.create_sequence();
        for _ in 0..64 {
            mgr.append_token(id, &kl, &vl, &kr, &vr).unwrap();
        }
        mgr.free_sequence(id);
    });
    r.print_throughput(64.0, "tok");
}

fn bench_read(label: &str, plan: CompressionPlan) {
    let spec = spec();
    let mut rng = Rng::new(2);
    let kl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let vl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let kr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let vr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let mut mgr = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let id = mgr.create_sequence();
    for _ in 0..128 {
        mgr.append_token(id, &kl, &vl, &kr, &vr).unwrap();
    }
    let r = Bench::new(&format!("kvcache/stored_rows/{label}")).run(|| {
        for l in 0..spec.n_layer {
            black_box(mgr.stored_rows(id, l, Side::K).unwrap());
            black_box(mgr.stored_rows(id, l, Side::V).unwrap());
        }
    });
    r.print_throughput((spec.n_layer * 2 * 128) as f64, "row");
}

/// Bulk prefill ingest: one `append_rows` call for 64 tokens (the
/// streaming path) vs 64 `append_token` calls (bench_append above).
fn bench_append_bulk(label: &str, plan: CompressionPlan) {
    let spec = spec();
    let mut rng = Rng::new(3);
    let n = 64usize;
    let kl = rows(&mut rng, spec.n_layer * n * spec.ae_latent);
    let vl = rows(&mut rng, spec.n_layer * n * spec.ae_latent);
    let kr = rows(&mut rng, spec.n_layer * n * spec.kv_dim());
    let vr = rows(&mut rng, spec.n_layer * n * spec.kv_dim());
    let mut mgr = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let r = Bench::new(&format!("kvcache/append_rows/{label}")).run(|| {
        let id = mgr.create_sequence();
        mgr.append_rows(id, n, n, &kl, &vl, &kr, &vr).unwrap();
        mgr.free_sequence(id);
    });
    r.print_throughput(n as f64, "tok");
}

/// Zero-copy retrieval: decode every stream straight into a reused
/// buffer through the `stream` view (vs `stored_rows`' owned Vecs).
fn bench_stream(label: &str, plan: CompressionPlan) {
    let spec = spec();
    let mut rng = Rng::new(4);
    let kl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let vl = rows(&mut rng, spec.n_layer * spec.ae_latent);
    let kr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let vr = rows(&mut rng, spec.n_layer * spec.kv_dim());
    let mut mgr = CacheManager::new(CacheConfig::new(spec.clone(), plan));
    let id = mgr.create_sequence();
    for _ in 0..128 {
        mgr.append_token(id, &kl, &vl, &kr, &vr).unwrap();
    }
    let mut out = vec![0.0f32; 128 * spec.kv_dim()];
    let r = Bench::new(&format!("kvcache/stream_decode/{label}")).run(|| {
        for l in 0..spec.n_layer {
            for side in [Side::K, Side::V] {
                let view = match mgr.stream(id, l, side).unwrap() {
                    StreamRows::Alias => continue,
                    StreamRows::Latent(v) => v,
                    StreamRows::Heads(v, _) => v,
                };
                let n = view.len() * view.elements_per_row();
                view.decode_range_into(0, view.len(), &mut out[..n]);
                black_box(&out[..n]);
            }
        }
    });
    r.print_throughput((spec.n_layer * 2 * 128) as f64, "row");
}

fn main() {
    let s = spec();
    bench_append("raw_f32", CompressionPlan::none(s.n_layer, s.n_kv_head));
    bench_append("latent", CompressionPlan::ae_first_layers(&s, s.n_layer));
    bench_append(
        "latent_int8",
        CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant(),
    );
    let mut reuse = CompressionPlan::none(s.n_layer, s.n_kv_head);
    for l in (1..s.n_layer).step_by(2) {
        reuse.reuse_k[l] = vec![true; s.n_kv_head];
        reuse.reuse_v[l] = vec![true; s.n_kv_head];
    }
    bench_append("alternating_alias", reuse.clone());

    bench_append_bulk("raw_f32", CompressionPlan::none(s.n_layer, s.n_kv_head));
    bench_append_bulk(
        "latent_int8",
        CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant(),
    );

    bench_read("raw_f32", CompressionPlan::none(s.n_layer, s.n_kv_head));
    bench_read("latent_int8", CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant());

    bench_stream("raw_f32", CompressionPlan::none(s.n_layer, s.n_kv_head));
    bench_stream(
        "latent_int8",
        CompressionPlan::ae_first_layers(&s, s.n_layer).with_quant(),
    );
}
