//! End-to-end decode hot path over real artifacts: per-round latency for
//! batch 1 and 8 under baseline / AE / AE+int8 / faithful-reconstruct
//! plans, plus prefill latency.  The headline L3 numbers for
//! EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable rows, the run emits a machine-readable
//! `BENCH_decode_hotpath.json` (override with `KVCAR_BENCH_JSON`) so the
//! perf trajectory — in particular the faithful-reconstruct round mean,
//! the path the incremental effective-cache refactor targets — is
//! tracked across PRs.  When a previous file exists its numbers are
//! reported as deltas before being replaced.
//!
//! Skips (exit 0, file untouched) when artifacts are missing.

use kvcar::coordinator::{GenRequest, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::util::bench::fmt_ns;
use kvcar::util::json::{self, Json};

const MODEL: &str = "gpt2t";

struct CaseResult {
    label: String,
    batch: usize,
    faithful: bool,
    mean_ms: f64,
    p99_ms: f64,
    tok_s: f64,
}

fn run_case(
    engine: &mut Engine,
    label: &str,
    plan: CompressionPlan,
    batch: usize,
    faithful: bool,
    rounds: usize,
) -> CaseResult {
    let cfg = ServeConfig {
        plan,
        max_batch: batch,
        seed: 3,
        per_step_reconstruct: faithful,
        cache_budget: None,
    };
    let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(5);
    // warmup: pay XLA compilation outside the measured window
    let warm: Vec<GenRequest> = (0..batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(8), 2))
        .collect();
    serving.run(warm).unwrap();
    serving.metrics = Default::default();
    let reqs: Vec<GenRequest> = (0..batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(16), rounds))
        .collect();
    let t0 = std::time::Instant::now();
    let out = serving.run(reqs).unwrap();
    let wall = t0.elapsed();
    let tokens: usize = out.iter().map(|r| r.generated_tokens).sum();
    let per_round = serving.metrics.decode_step_latency.mean_ms();
    let p99 = serving.metrics.decode_step_latency.percentile_ms(99.0);
    let tok_s = tokens as f64 / wall.as_secs_f64();
    println!(
        "bench decode_hotpath/{label:<36} round mean={:>10} p99={:>10}  {:>8.1} tok/s (b={batch})",
        fmt_ns(per_round * 1e6),
        fmt_ns(p99 * 1e6),
        tok_s,
    );
    CaseResult {
        label: label.to_string(),
        batch,
        faithful,
        mean_ms: per_round,
        p99_ms: p99,
        tok_s,
    }
}

fn json_path() -> String {
    std::env::var("KVCAR_BENCH_JSON").unwrap_or_else(|_| "BENCH_decode_hotpath.json".into())
}

/// Compare against the previous run's file (the cross-PR trajectory).
fn report_deltas(prev: &Json, cases: &[CaseResult]) {
    let Some(prev_cases) = prev.get("cases").and_then(Json::as_arr) else {
        return;
    };
    for c in cases {
        let old = prev_cases.iter().find_map(|p| {
            (p.get("label").and_then(Json::as_str) == Some(c.label.as_str()))
                .then(|| p.get("round_mean_ms").and_then(Json::as_f64))
                .flatten()
        });
        if let Some(old_mean) = old {
            if old_mean > 0.0 {
                println!(
                    "bench decode_hotpath/{:<36} vs previous: {:+.1}% round mean ({:.3} -> {:.3} ms)",
                    c.label,
                    100.0 * (c.mean_ms - old_mean) / old_mean,
                    old_mean,
                    c.mean_ms,
                );
            }
        }
    }
}

fn write_json(cases: &[CaseResult], prefill_mean_ms: f64, prefill_p99_ms: f64, rounds: usize) {
    let path = json_path();
    match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(prev) => report_deltas(&prev, cases),
            Err(e) => println!(
                "bench decode_hotpath: previous {path} unreadable ({e}); skipping deltas"
            ),
        },
        // absent baseline is the normal first-run case, not an error:
        // say so instead of silently comparing against nothing
        Err(_) => println!(
            "bench decode_hotpath: no previous run ({path} absent); deltas start next run"
        ),
    }
    let j = json::obj(vec![
        ("version", json::num(1.0)),
        ("bench", json::s("decode_hotpath")),
        ("model", json::s(MODEL)),
        ("rounds", json::num(rounds as f64)),
        (
            "cases",
            json::arr(cases.iter().map(|c| {
                json::obj(vec![
                    ("label", json::s(&c.label)),
                    ("batch", json::num(c.batch as f64)),
                    ("faithful", Json::Bool(c.faithful)),
                    ("round_mean_ms", json::num(c.mean_ms)),
                    ("round_p99_ms", json::num(c.p99_ms)),
                    ("tok_per_s", json::num(c.tok_s)),
                ])
            })),
        ),
        (
            "prefill_64tok",
            json::obj(vec![
                ("mean_ms", json::num(prefill_mean_ms)),
                ("p99_ms", json::num(prefill_p99_ms)),
            ]),
        ),
    ]);
    match std::fs::write(&path, j.to_string()) {
        Ok(()) => println!("bench decode_hotpath: wrote {path}"),
        Err(e) => eprintln!("bench decode_hotpath: could not write {path}: {e}"),
    }
}

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_hotpath: skipped (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, MODEL).unwrap();
    let rounds = std::env::var("KVCAR_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);

    let none = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let ae = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let aeq = CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant();

    let mut cases = Vec::new();
    for b in [1usize, 8] {
        cases.push(run_case(&mut engine, &format!("baseline/b{b}"), none.clone(), b, false, rounds));
        cases.push(run_case(&mut engine, &format!("ae_all/b{b}"), ae.clone(), b, false, rounds));
        cases.push(run_case(&mut engine, &format!("ae_int8/b{b}"), aeq.clone(), b, false, rounds));
    }
    // faithful per-step reconstruction — the decode-on-retrieval dataflow
    // the incremental effective-cache path optimizes; tracked across PRs.
    // b8 exercises the batch-first path: one {m}_decode_kv_bt launch per
    // round instead of one decode_kv_t launch per live sequence
    cases.push(run_case(&mut engine, "ae_all_faithful/b1", ae.clone(), 1, true, rounds));
    cases.push(run_case(&mut engine, "ae_int8_faithful/b1", aeq.clone(), 1, true, rounds));
    cases.push(run_case(&mut engine, "ae_all_faithful/b8", ae.clone(), 8, true, rounds));
    cases.push(run_case(&mut engine, "ae_int8_faithful/b8", aeq.clone(), 8, true, rounds));

    // prefill latency
    let cfg = ServeConfig {
        plan: ae,
        max_batch: 1,
        seed: 1,
        per_step_reconstruct: false,
        cache_budget: None,
    };
    let mut serving = ServingEngine::new(&mut engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(6);
    for _ in 0..8 {
        let reqs = vec![GenRequest::greedy(0, &prompts.tokens(64), 1)];
        serving.run(reqs).unwrap();
    }
    let prefill_mean = serving.metrics.prefill_latency.mean_ms();
    let prefill_p99 = serving.metrics.prefill_latency.percentile_ms(99.0);
    println!(
        "bench decode_hotpath/prefill_64tok                 mean={:>10} p99={:>10}",
        fmt_ns(prefill_mean * 1e6),
        fmt_ns(prefill_p99 * 1e6),
    );
    write_json(&cases, prefill_mean, prefill_p99, rounds);
}
