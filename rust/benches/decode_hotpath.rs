//! End-to-end decode hot path over real artifacts: per-round latency for
//! batch 1 and 8 under baseline / AE / AE+int8 / faithful-reconstruct
//! plans, plus prefill latency.  The headline L3 numbers for
//! EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable rows, the run emits a machine-readable
//! `BENCH_decode_hotpath.json` (override with `KVCAR_BENCH_JSON`) so the
//! perf trajectory — in particular the faithful-reconstruct round mean,
//! the path the incremental effective-cache refactor targets — is
//! tracked across PRs.  When a previous file exists its numbers are
//! reported as deltas before being replaced.  Each case also reports
//! `staged_bytes_per_round` (the k/v staging volume the store-resident
//! effective cache shrinks ~S×; the `staging` section holds the
//! resident-vs-copy ratio), the `f16_raw` section the bytes/accuracy
//! delta of the f16 raw-row default against f32, the `burst_admission`
//! section the launch counts and amortized prefill cost of wave-based
//! admission vs the per-request ladder, and the `shared_prefix` section
//! the distinct-prompts law of cross-request prefix sharing (launches
//! saved, shared-once vs private cache bytes, chunk hit rate), and the
//! `device_residency` section the host→device traffic of keeping the
//! resident k/v regions on device between rounds (uploaded bytes/round
//! and skip ratio with delta uploads on vs off, plus a simulated
//! patch-capable device pinning the O(B·L·kvd) steady-round law the
//! PJRT binding cannot realize in place yet).
//!
//! Skips (exit 0, file untouched) when artifacts are missing.

use kvcar::coordinator::{GenRequest, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::kvcache::Format;
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::runtime::{
    artifacts_dir, BufferCache, DType, Engine, EngineStats, IoSpec, MirrorBackend, Store,
};
use kvcar::util::bench::fmt_ns;
use kvcar::util::json::{self, Json};

const MODEL: &str = "gpt2t";

struct CaseResult {
    label: String,
    batch: usize,
    faithful: bool,
    resident: bool,
    raw_format: &'static str,
    mean_ms: f64,
    p99_ms: f64,
    tok_s: f64,
    /// steady-path k/v staging bytes per decode round (the quantity the
    /// store-resident effective cache shrinks from O(B·L·S·kvd) to
    /// O(B·L·kvd); regressions show up here before they show in latency)
    staged_bytes_per_round: f64,
    /// one-off slot-transition bytes over the whole run (fills + zeroing)
    slot_rebuild_bytes: u64,
    /// peak compressed device-cache bytes (raw-format comparisons)
    peak_cache_bytes: usize,
    /// generated tokens per request (accuracy comparisons across formats)
    outputs: Vec<Vec<u8>>,
}

struct CaseCfg {
    batch: usize,
    faithful: bool,
    resident: bool,
    raw: Format,
}

fn run_case(
    engine: &mut Engine,
    label: &str,
    plan: CompressionPlan,
    c: CaseCfg,
    rounds: usize,
) -> CaseResult {
    let cfg = ServeConfig {
        max_batch: c.batch,
        seed: 3,
        per_step_reconstruct: c.faithful,
        resident_cache: c.resident,
        raw_format: c.raw,
        // sharing off so each case keeps its historical meaning (the
        // corpus can repeat windows; zero-launch admissions would skew
        // prefill numbers) — the shared_prefix section measures sharing
        prefix_sharing: false,
        ..ServeConfig::new(plan)
    };
    let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(5);
    // warmup: pay XLA compilation outside the measured window
    let warm: Vec<GenRequest> = (0..c.batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(8), 2))
        .collect();
    serving.run(warm).unwrap();
    serving.metrics = Default::default();
    let reqs: Vec<GenRequest> = (0..c.batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(16), rounds))
        .collect();
    let t0 = std::time::Instant::now();
    let out = serving.run(reqs).unwrap();
    let wall = t0.elapsed();
    let tokens: usize = out.iter().map(|r| r.generated_tokens).sum();
    let per_round = serving.metrics.decode_step_latency.mean_ms();
    let p99 = serving.metrics.decode_step_latency.percentile_ms(99.0);
    let tok_s = tokens as f64 / wall.as_secs_f64();
    let staged = serving.metrics.staged_kv_bytes as f64
        / serving.metrics.decode_rounds.max(1) as f64;
    println!(
        "bench decode_hotpath/{label:<36} round mean={:>10} p99={:>10}  {:>8.1} tok/s (b={})  staged {:.1} KiB/round",
        fmt_ns(per_round * 1e6),
        fmt_ns(p99 * 1e6),
        tok_s,
        c.batch,
        staged / 1024.0,
    );
    CaseResult {
        label: label.to_string(),
        batch: c.batch,
        faithful: c.faithful,
        resident: c.resident,
        raw_format: match c.raw {
            Format::F32 => "f32",
            Format::F16 => "f16",
            Format::Int8 => "int8",
        },
        mean_ms: per_round,
        p99_ms: p99,
        tok_s,
        staged_bytes_per_round: staged,
        slot_rebuild_bytes: serving.metrics.slot_rebuild_bytes,
        peak_cache_bytes: serving.cache.pool_stats().peak_live_bytes,
        outputs: out.into_iter().map(|r| r.output).collect(),
    }
}

/// Position-wise token agreement between two runs of the same workload.
fn token_agreement(a: &[Vec<u8>], b: &[Vec<u8>]) -> f64 {
    let (mut same, mut total) = (0usize, 0usize);
    for (x, y) in a.iter().zip(b) {
        total += x.len().max(y.len());
        same += x.iter().zip(y).filter(|(p, q)| p == q).count();
    }
    if total == 0 {
        return 1.0;
    }
    same as f64 / total as f64
}

fn json_path() -> String {
    std::env::var("KVCAR_BENCH_JSON").unwrap_or_else(|_| "BENCH_decode_hotpath.json".into())
}

/// Compare against the previous run's file (the cross-PR trajectory).
fn report_deltas(prev: &Json, cases: &[CaseResult]) {
    let Some(prev_cases) = prev.get("cases").and_then(Json::as_arr) else {
        return;
    };
    for c in cases {
        let old = prev_cases.iter().find_map(|p| {
            (p.get("label").and_then(Json::as_str) == Some(c.label.as_str()))
                .then(|| p.get("round_mean_ms").and_then(Json::as_f64))
                .flatten()
        });
        if let Some(old_mean) = old {
            if old_mean > 0.0 {
                println!(
                    "bench decode_hotpath/{:<36} vs previous: {:+.1}% round mean ({:.3} -> {:.3} ms)",
                    c.label,
                    100.0 * (c.mean_ms - old_mean) / old_mean,
                    old_mean,
                    c.mean_ms,
                );
            }
        }
    }
}

/// Delta the shared-prefix section against the previous run's file —
/// launches saved collapsing toward 0 is the sharing regression canary.
fn report_shared_prefix_delta(prev: &Json, cur: &Json) {
    let saved = |j: &Json| {
        j.get("shared_prefix")
            .or(Some(j))
            .and_then(|s| s.get("launches_saved"))
            .and_then(Json::as_f64)
    };
    let (Some(old), Some(new)) = (saved(prev), cur.get("launches_saved").and_then(Json::as_f64))
    else {
        println!("bench decode_hotpath/shared_prefix: no previous section; deltas start next run");
        return;
    };
    println!(
        "bench decode_hotpath/shared_prefix vs previous: launches saved {old:.0} -> {new:.0} ({:+.0})",
        new - old,
    );
}

/// Burst admission: a backlog of requests admitted in max_batch-sized
/// waves with max_new = 1, so the run is pure admission cost.  Run
/// twice — batched wave prefill vs the forced per-request ladder — and
/// report launches, amortized prefill ms/request, and the wave-size
/// distribution (the one-launch-per-wave law made measurable).
fn run_burst(engine: &mut Engine, plan: &CompressionPlan) -> Json {
    let n_requests = 24usize;
    let mut results = Vec::new();
    for batched in [true, false] {
        let cfg = ServeConfig {
            max_batch: 8,
            seed: 17,
            batched_prefill: batched,
            // isolate the wave-vs-per-request launch law from prompt
            // dedup (shared_prefix measures that axis separately)
            prefix_sharing: false,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
        let mut prompts = corpus::wiki(9);
        // warmup compiles the prefill entries outside the measurement
        serving
            .run((0..8).map(|i| GenRequest::greedy(i, &prompts.tokens(16), 1)).collect())
            .unwrap();
        serving.metrics = Default::default();
        let reqs: Vec<GenRequest> = (0..n_requests as u64)
            .map(|i| GenRequest::greedy(i, &prompts.tokens(16), 1))
            .collect();
        let t0 = std::time::Instant::now();
        serving.run(reqs).unwrap();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let m = &serving.metrics;
        let amortized = wall_ms / n_requests as f64;
        println!(
            "bench decode_hotpath/burst_admission({}): {} waves / {} launches, {:.2} ms/request amortized (waves: {:?})",
            if batched { "wave" } else { "per-request" },
            m.prefill_waves,
            m.prefill_launches,
            amortized,
            m.wave_admitted.samples(),
        );
        results.push(json::obj(vec![
            ("batched", Json::Bool(batched)),
            ("prefill_waves", json::num(m.prefill_waves as f64)),
            ("prefill_launches", json::num(m.prefill_launches as f64)),
            ("amortized_prefill_ms_per_request", json::num(amortized)),
            (
                "wave_sizes",
                json::arr(m.wave_admitted.samples().iter().map(|&s| json::num(s as f64))),
            ),
            ("mean_wave_size", json::num(m.wave_admitted.mean())),
        ]));
    }
    json::obj(vec![
        ("requests", json::num(n_requests as f64)),
        ("runs", Json::Arr(results)),
    ])
}

/// Shared-prefix burst: 24 requests over 4 distinct prompts that share
/// a 32-token prefix, max_new = 1 (pure admission cost), run with
/// prefix sharing on and off.  The section reports the distinct-prompts
/// law end to end: prefill launches, zero-launch admissions, the chunk
/// hit rate, and shared-once vs private (per-sequence) cache bytes.
fn run_shared_prefix(engine: &mut Engine, plan: &CompressionPlan) -> Json {
    let (n_requests, n_distinct) = (24usize, 4usize);
    // one synthetic template family: shared 32-token system prefix +
    // 8-token distinct suffix per "user"
    let prefix: Vec<u8> = (0..32u32).map(|i| ((i * 37 + 11) % 251) as u8).collect();
    let prompts: Vec<Vec<u8>> = (0..n_distinct as u8)
        .map(|d| {
            let mut p = prefix.clone();
            p.extend((0..8u8).map(|t| d.wrapping_mul(31).wrapping_add(t * 7 + 3)));
            p
        })
        .collect();
    // warmup on a throwaway engine: XLA compilation lives in `engine`
    // and carries over, while the measured engines below start with
    // clean prefix/template state — their cumulative prefix_stats and
    // peak bytes describe only the burst
    {
        let cfg = ServeConfig {
            max_batch: 8,
            seed: 29,
            ..ServeConfig::new(plan.clone())
        };
        let mut warmup = ServingEngine::new(engine, MODEL, cfg).unwrap();
        let mut warm = corpus::wiki(13);
        warmup
            .run((0..8).map(|i| GenRequest::greedy(i, &warm.tokens(16), 1)).collect())
            .unwrap();
    }
    let mut results = Vec::new();
    for sharing in [true, false] {
        let cfg = ServeConfig {
            max_batch: 8,
            seed: 29,
            prefix_sharing: sharing,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
        let reqs: Vec<GenRequest> = (0..n_requests as u64)
            .map(|i| GenRequest::greedy(i, &prompts[i as usize % n_distinct], 1))
            .collect();
        let t0 = std::time::Instant::now();
        serving.run(reqs).unwrap();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let m = &serving.metrics;
        let p = serving.cache.prefix_stats();
        let lookups = p.chunk_hits + p.chunk_misses;
        let hit_rate = if lookups > 0 {
            p.chunk_hits as f64 / lookups as f64
        } else {
            0.0
        };
        println!(
            "bench decode_hotpath/shared_prefix({}): {} launches / {} zero-launch admissions, chunk hit rate {:.0}%, shared {:.1} KiB held once, {:.2} ms/request",
            if sharing { "on" } else { "off" },
            m.prefill_launches,
            m.shared_admissions,
            hit_rate * 100.0,
            p.shared_bytes as f64 / 1024.0,
            wall_ms / n_requests as f64,
        );
        results.push(json::obj(vec![
            ("sharing", Json::Bool(sharing)),
            ("prefill_launches", json::num(m.prefill_launches as f64)),
            ("shared_admissions", json::num(m.shared_admissions as f64)),
            ("shared_prefix_rows", json::num(m.shared_prefix_rows as f64)),
            ("chunk_hit_rate", json::num(hit_rate)),
            ("shared_cache_bytes", json::num(p.shared_bytes as f64)),
            (
                "peak_cache_bytes",
                json::num(serving.cache.pool_stats().peak_live_bytes as f64),
            ),
            ("amortized_prefill_ms_per_request", json::num(wall_ms / n_requests as f64)),
        ]));
    }
    let launches = |r: &Json| {
        r.get("prefill_launches").and_then(Json::as_f64).unwrap_or(0.0)
    };
    let saved = launches(&results[1]) - launches(&results[0]);
    println!(
        "bench decode_hotpath/shared_prefix: {saved:.0} prefill launches saved by sharing ({} requests, {} distinct prompts)",
        n_requests, n_distinct,
    );
    json::obj(vec![
        ("requests", json::num(n_requests as f64)),
        ("distinct_prompts", json::num(n_distinct as f64)),
        ("launches_saved", json::num(saved)),
        ("runs", Json::Arr(results)),
    ])
}

/// Device residency: the same decode workload with delta uploads on vs
/// off, reporting the run's host→device traffic from the engine's byte
/// meters, plus a store-level simulation against a patch-capable mirror
/// device that pins the steady-round O(B·L·kvd) upload law (the real
/// PJRT binding cannot patch buffers in place, so its on/off figures
/// converge until the binding grows a sub-buffer or
/// dynamic-update-slice upload).
fn run_device_residency(engine: &mut Engine, plan: &CompressionPlan) -> Json {
    let (batch, rounds) = (4usize, 16usize);
    let mut results = Vec::new();
    for residency in [true, false] {
        let cfg = ServeConfig {
            max_batch: batch,
            seed: 23,
            device_residency: residency,
            prefix_sharing: false,
            ..ServeConfig::new(plan.clone())
        };
        let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
        let mut prompts = corpus::wiki(15);
        serving
            .run((0..4u64).map(|i| GenRequest::greedy(i, &prompts.tokens(8), 2)).collect())
            .unwrap();
        serving.metrics = Default::default();
        let reqs: Vec<GenRequest> = (0..batch as u64)
            .map(|i| GenRequest::greedy(i, &prompts.tokens(16), rounds))
            .collect();
        serving.run(reqs).unwrap();
        let m = &serving.metrics;
        let uploaded = m.resident_bytes_uploaded as f64 / m.decode_rounds.max(1) as f64;
        let total = (m.resident_bytes_uploaded + m.resident_bytes_skipped) as f64;
        let skip = if total > 0.0 {
            m.resident_bytes_skipped as f64 / total
        } else {
            0.0
        };
        println!(
            "bench decode_hotpath/device_residency({}): {:.1} KiB/round uploaded, {:.0}% skipped, {} full uploads, in {:.1} KiB out {:.1} KiB",
            if residency { "on" } else { "off" },
            uploaded / 1024.0,
            skip * 100.0,
            m.full_uploads,
            m.input_bytes as f64 / 1024.0,
            m.output_bytes as f64 / 1024.0,
        );
        results.push(json::obj(vec![
            ("device_residency", Json::Bool(residency)),
            ("uploaded_bytes_per_round", json::num(uploaded)),
            ("skip_ratio", json::num(skip)),
            ("full_uploads", json::num(m.full_uploads as f64)),
            ("input_bytes", json::num(m.input_bytes as f64)),
            ("output_bytes", json::num(m.output_bytes as f64)),
            ("buffers_evicted", json::num(m.buffers_evicted as f64)),
        ]));
    }
    json::obj(vec![
        ("runs", Json::Arr(results)),
        ("simulated_patch_capable", simulate_patch_capable(batch, rounds)),
    ])
}

/// Store-level simulation of a patch-capable device: resident
/// `[B, L, S, kvd]` regions, one new row per slot per round declared via
/// the dirty-span log, synced through [`BufferCache`] into a patching
/// [`MirrorBackend`].  Steady rounds must upload exactly 2·B·L·kvd·4
/// bytes — the figure the `device_residency` config would realize with
/// an in-place binding.
fn simulate_patch_capable(b: usize, rounds: usize) -> Json {
    let (l, s, kvd) = (4usize, 128usize, 64usize);
    let rounds = rounds.min(s);
    let seq = l * s * kvd;
    let mut store = Store::new();
    let mut cache = BufferCache::new();
    cache.ensure_entry("decode", 2);
    let mut dev = MirrorBackend::patching();
    let mut stats = EngineStats::default();
    let mut first_round = 0u64;
    for round in 0..rounds {
        for (i, name) in ["k_sim", "v_sim"].into_iter().enumerate() {
            let (region, _) = store.resident_region(name, vec![b, l, s, kvd]);
            let mut spans = Vec::new();
            for slot in 0..b {
                for layer in 0..l {
                    let at = slot * seq + layer * s * kvd + round * kvd;
                    region[at..at + kvd].fill((round + 1) as f32);
                    spans.push((at, at + kvd));
                }
            }
            store.note_region_writes(name, &spans);
            let io = IoSpec {
                name: name.to_string(),
                shape: vec![b, l, s, kvd],
                dtype: DType::F32,
            };
            let t = store.get(name).unwrap().clone();
            cache
                .sync_input(&mut dev, "decode", i, &io, &t, &store, true, 1, &mut stats)
                .unwrap();
        }
        if round == 0 {
            first_round = stats.resident_bytes_uploaded;
        }
    }
    let steady = (stats.resident_bytes_uploaded - first_round) as f64 / (rounds - 1) as f64;
    let full = (2 * b * seq * 4) as f64;
    let total = (stats.resident_bytes_uploaded + stats.resident_bytes_skipped) as f64;
    println!(
        "bench decode_hotpath/device_residency(simulated): steady {:.1} KiB/round vs {:.1} KiB full upload ({:.0}x fewer uploaded bytes)",
        steady / 1024.0,
        full / 1024.0,
        full / steady,
    );
    json::obj(vec![
        ("steady_uploaded_bytes_per_round", json::num(steady)),
        ("full_upload_bytes", json::num(full)),
        ("full_over_steady_ratio", json::num(full / steady)),
        ("skip_ratio", json::num(stats.resident_bytes_skipped as f64 / total)),
        ("patches", json::num(dev.patches as f64)),
    ])
}

/// Delta the device-residency section against the previous run's file —
/// the residency-on uploaded bytes/round creeping toward the full-upload
/// figure is the delta-path regression canary.
fn report_device_residency_delta(prev: &Json, cur: &Json) {
    let on_uploaded = |j: &Json| {
        j.get("device_residency")
            .or(Some(j))
            .and_then(|s| s.get("runs"))
            .and_then(Json::as_arr)
            .and_then(|runs| {
                runs.iter()
                    .find(|r| matches!(r.get("device_residency"), Some(Json::Bool(true))))
                    .and_then(|r| r.get("uploaded_bytes_per_round"))
                    .and_then(Json::as_f64)
            })
    };
    let (Some(old), Some(new)) = (on_uploaded(prev), on_uploaded(cur)) else {
        println!(
            "bench decode_hotpath/device_residency: no previous section; deltas start next run"
        );
        return;
    };
    println!(
        "bench decode_hotpath/device_residency vs previous: uploaded {:.1} -> {:.1} KiB/round ({:+.1}%)",
        old / 1024.0,
        new / 1024.0,
        if old > 0.0 { 100.0 * (new - old) / old } else { 0.0 },
    );
}

#[allow(clippy::too_many_arguments)]
fn write_json(
    cases: &[CaseResult],
    staging: Json,
    f16_raw: Json,
    burst: Json,
    shared_prefix: Json,
    device_residency: Json,
    prefill_mean_ms: f64,
    prefill_p99_ms: f64,
    rounds: usize,
) {
    let path = json_path();
    match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(prev) => {
                report_deltas(&prev, cases);
                report_shared_prefix_delta(&prev, &shared_prefix);
                report_device_residency_delta(&prev, &device_residency);
            }
            Err(e) => println!(
                "bench decode_hotpath: previous {path} unreadable ({e}); skipping deltas"
            ),
        },
        // absent baseline is the normal first-run case, not an error:
        // say so instead of silently comparing against nothing
        Err(_) => println!(
            "bench decode_hotpath: no previous run ({path} absent); deltas start next run"
        ),
    }
    let j = json::obj(vec![
        ("version", json::num(2.0)),
        ("bench", json::s("decode_hotpath")),
        ("model", json::s(MODEL)),
        ("rounds", json::num(rounds as f64)),
        (
            "cases",
            json::arr(cases.iter().map(|c| {
                json::obj(vec![
                    ("label", json::s(&c.label)),
                    ("batch", json::num(c.batch as f64)),
                    ("faithful", Json::Bool(c.faithful)),
                    ("resident", Json::Bool(c.resident)),
                    ("raw_format", json::s(c.raw_format)),
                    ("round_mean_ms", json::num(c.mean_ms)),
                    ("round_p99_ms", json::num(c.p99_ms)),
                    ("tok_per_s", json::num(c.tok_s)),
                    ("staged_bytes_per_round", json::num(c.staged_bytes_per_round)),
                    ("slot_rebuild_bytes", json::num(c.slot_rebuild_bytes as f64)),
                ])
            })),
        ),
        ("staging", staging),
        ("f16_raw", f16_raw),
        ("burst_admission", burst),
        ("shared_prefix", shared_prefix),
        ("device_residency", device_residency),
        (
            "prefill_64tok",
            json::obj(vec![
                ("mean_ms", json::num(prefill_mean_ms)),
                ("p99_ms", json::num(prefill_p99_ms)),
            ]),
        ),
    ]);
    match std::fs::write(&path, j.to_string()) {
        Ok(()) => println!("bench decode_hotpath: wrote {path}"),
        Err(e) => eprintln!("bench decode_hotpath: could not write {path}: {e}"),
    }
}

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_hotpath: skipped (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, MODEL).unwrap();
    let rounds = std::env::var("KVCAR_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);

    let none = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let ae = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let aeq = CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant();
    // serving defaults (store-resident staging, f16 raw rows)
    let def = |batch, faithful| CaseCfg {
        batch,
        faithful,
        resident: true,
        raw: Format::F16,
    };

    let mut cases = Vec::new();
    for b in [1usize, 8] {
        cases.push(run_case(&mut engine, &format!("baseline/b{b}"), none.clone(), def(b, false), rounds));
        cases.push(run_case(&mut engine, &format!("ae_all/b{b}"), ae.clone(), def(b, false), rounds));
        cases.push(run_case(&mut engine, &format!("ae_int8/b{b}"), aeq.clone(), def(b, false), rounds));
    }
    // faithful per-step reconstruction — the decode-on-retrieval dataflow
    // the incremental effective-cache path optimizes; tracked across PRs.
    // b8 exercises the batch-first path: one {m}_decode_kv_bt launch per
    // round instead of one decode_kv_t launch per live sequence
    cases.push(run_case(&mut engine, "ae_all_faithful/b1", ae.clone(), def(1, true), rounds));
    cases.push(run_case(&mut engine, "ae_int8_faithful/b1", aeq.clone(), def(1, true), rounds));
    cases.push(run_case(&mut engine, "ae_all_faithful/b8", ae.clone(), def(8, true), rounds));
    cases.push(run_case(&mut engine, "ae_int8_faithful/b8", aeq.clone(), def(8, true), rounds));

    // resident vs legacy copy staging, same workload: the staged-bytes
    // ratio is the win of the store-resident effective cache (≈ S×)
    cases.push(run_case(
        &mut engine,
        "ae_all_faithful_copy/b8",
        ae.clone(),
        CaseCfg { batch: 8, faithful: true, resident: false, raw: Format::F16 },
        rounds,
    ));
    let staging = {
        let res = cases.iter().find(|c| c.label == "ae_all_faithful/b8").unwrap();
        let copy = cases.iter().find(|c| c.label == "ae_all_faithful_copy/b8").unwrap();
        let ratio = if res.staged_bytes_per_round > 0.0 {
            copy.staged_bytes_per_round / res.staged_bytes_per_round
        } else {
            0.0
        };
        println!(
            "bench decode_hotpath/staging: resident {:.1} KiB/round vs copy {:.1} KiB/round ({ratio:.0}x fewer staged bytes)",
            res.staged_bytes_per_round / 1024.0,
            copy.staged_bytes_per_round / 1024.0,
        );
        json::obj(vec![
            ("resident_bytes_per_round", json::num(res.staged_bytes_per_round)),
            ("copy_bytes_per_round", json::num(copy.staged_bytes_per_round)),
            ("copy_over_resident_ratio", json::num(ratio)),
        ])
    };

    // f16 vs f32 raw rows under faithful reconstruction of an
    // uncompressed plan (every stream stores raw rows, so the format
    // delta is maximal): bytes halve, accuracy is the agreement rate
    cases.push(run_case(
        &mut engine,
        "baseline_faithful_f16/b4",
        none.clone(),
        CaseCfg { batch: 4, faithful: true, resident: true, raw: Format::F16 },
        rounds,
    ));
    cases.push(run_case(
        &mut engine,
        "baseline_faithful_f32/b4",
        none.clone(),
        CaseCfg { batch: 4, faithful: true, resident: true, raw: Format::F32 },
        rounds,
    ));
    let f16_raw = {
        let h = cases.iter().find(|c| c.label == "baseline_faithful_f16/b4").unwrap();
        let f = cases.iter().find(|c| c.label == "baseline_faithful_f32/b4").unwrap();
        let bytes_ratio = if f.peak_cache_bytes > 0 {
            h.peak_cache_bytes as f64 / f.peak_cache_bytes as f64
        } else {
            0.0
        };
        let agreement = token_agreement(&h.outputs, &f.outputs);
        println!(
            "bench decode_hotpath/f16_raw: {:.2}x stored bytes vs f32, token agreement {:.1}%",
            bytes_ratio,
            agreement * 100.0,
        );
        json::obj(vec![
            ("peak_cache_bytes_f16", json::num(h.peak_cache_bytes as f64)),
            ("peak_cache_bytes_f32", json::num(f.peak_cache_bytes as f64)),
            ("bytes_ratio", json::num(bytes_ratio)),
            ("token_agreement", json::num(agreement)),
        ])
    };

    // burst admission: the one-launch-per-admission-wave law end to end
    let burst = run_burst(&mut engine, &ae);

    // shared-prefix burst: launches/bytes ∝ distinct prompts, not N
    let shared_prefix = run_shared_prefix(&mut engine, &ae);

    // device residency: uploaded bytes/round with delta uploads on vs
    // off + the simulated patch-capable steady-round law
    let device_residency = run_device_residency(&mut engine, &ae);

    // prefill latency (sharing off: every run must really prefill)
    let cfg = ServeConfig {
        max_batch: 1,
        seed: 1,
        prefix_sharing: false,
        ..ServeConfig::new(ae)
    };
    let mut serving = ServingEngine::new(&mut engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(6);
    for _ in 0..8 {
        let reqs = vec![GenRequest::greedy(0, &prompts.tokens(64), 1)];
        serving.run(reqs).unwrap();
    }
    let prefill_mean = serving.metrics.prefill_latency.mean_ms();
    let prefill_p99 = serving.metrics.prefill_latency.percentile_ms(99.0);
    println!(
        "bench decode_hotpath/prefill_64tok                 mean={:>10} p99={:>10}",
        fmt_ns(prefill_mean * 1e6),
        fmt_ns(prefill_p99 * 1e6),
    );
    write_json(
        &cases,
        staging,
        f16_raw,
        burst,
        shared_prefix,
        device_residency,
        prefill_mean,
        prefill_p99,
        rounds,
    );
}
