//! End-to-end decode hot path over real artifacts: per-round latency for
//! batch 1 and 8 under baseline / AE / AE+int8 / faithful-reconstruct
//! plans, plus prefill latency.  The headline L3 numbers for
//! EXPERIMENTS.md §Perf.
//!
//! Skips (exit 0) when artifacts are missing.

use kvcar::coordinator::{GenRequest, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::model::memory::CompressionPlan;
use kvcar::model::ModelSpec;
use kvcar::runtime::{artifacts_dir, Engine};
use kvcar::util::bench::fmt_ns;

const MODEL: &str = "gpt2t";

fn run_case(
    engine: &mut Engine,
    label: &str,
    plan: CompressionPlan,
    batch: usize,
    faithful: bool,
    rounds: usize,
) {
    let cfg = ServeConfig {
        plan,
        max_batch: batch,
        seed: 3,
        per_step_reconstruct: faithful,
    };
    let mut serving = ServingEngine::new(engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(5);
    // warmup: pay XLA compilation outside the measured window
    let warm: Vec<GenRequest> = (0..batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(8), 2))
        .collect();
    serving.run(warm).unwrap();
    serving.metrics = Default::default();
    let reqs: Vec<GenRequest> = (0..batch)
        .map(|i| GenRequest::greedy(i as u64, &prompts.tokens(16), rounds))
        .collect();
    let t0 = std::time::Instant::now();
    let out = serving.run(reqs).unwrap();
    let wall = t0.elapsed();
    let tokens: usize = out.iter().map(|r| r.generated_tokens).sum();
    let per_round = serving.metrics.decode_step_latency.mean_ms();
    let p99 = serving.metrics.decode_step_latency.percentile_ms(99.0);
    println!(
        "bench decode_hotpath/{label:<36} round mean={:>10} p99={:>10}  {:>8.1} tok/s (b={batch})",
        fmt_ns(per_round * 1e6),
        fmt_ns(p99 * 1e6),
        tokens as f64 / wall.as_secs_f64(),
    );
}

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_hotpath: skipped (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let spec = ModelSpec::from_manifest(&engine.manifest.raw, MODEL).unwrap();
    let rounds = std::env::var("KVCAR_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);

    let none = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let ae = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
    let aeq = CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant();

    for b in [1usize, 8] {
        run_case(&mut engine, &format!("baseline/b{b}"), none.clone(), b, false, rounds);
        run_case(&mut engine, &format!("ae_all/b{b}"), ae.clone(), b, false, rounds);
        run_case(&mut engine, &format!("ae_int8/b{b}"), aeq.clone(), b, false, rounds);
    }
    // faithful per-step reconstruction (the unoptimized paper dataflow)
    run_case(&mut engine, "ae_all_faithful/b1", ae.clone(), 1, true, rounds);

    // prefill latency
    let cfg = ServeConfig {
        plan: ae,
        max_batch: 1,
        seed: 1,
        per_step_reconstruct: false,
    };
    let mut serving = ServingEngine::new(&mut engine, MODEL, cfg).unwrap();
    let mut prompts = corpus::wiki(6);
    for _ in 0..8 {
        let reqs = vec![GenRequest::greedy(0, &prompts.tokens(64), 1)];
        serving.run(reqs).unwrap();
    }
    println!(
        "bench decode_hotpath/prefill_64tok                 mean={:>10} p99={:>10}",
        fmt_ns(serving.metrics.prefill_latency.mean_ms() * 1e6),
        fmt_ns(serving.metrics.prefill_latency.percentile_ms(99.0) * 1e6),
    );
}
