//! Coordinator logic benches (no runtime needed): admission/batch
//! planning, corpus + task generation, similarity selection.

use kvcar::compress::similarity::HeadDistances;
use kvcar::coordinator::batcher::{plan_round, BatcherConfig};
use kvcar::data::{corpus, tasks};
use kvcar::model::gpt2_774m;
use kvcar::model::memory::CompressionPlan;
use kvcar::util::bench::{black_box, Bench};

fn main() {
    let spec = gpt2_774m();
    let plan = CompressionPlan::ae_first_layers(&spec, 18);
    let cfg = BatcherConfig {
        max_batch: 8,
        decode_batches: vec![1, 8],
        cache_budget: Some(1 << 30),
    };
    let waiting: Vec<(usize, usize)> = (0..64).map(|i| (32 + i % 100, 64)).collect();
    let r = Bench::new("coordinator/plan_round/64_waiting")
        .run(|| black_box(plan_round(&cfg, &spec, &plan, 3, 123 << 20, &waiting)));
    r.print();

    let mut c = corpus::wiki(0);
    let r = Bench::new("data/corpus_tokens/4KiB").run(|| black_box(c.tokens(4096)));
    r.print_throughput(4096.0, "B");

    let mut c4 = corpus::c4(0);
    let r = Bench::new("data/corpus_tokens_noisy/4KiB").run(|| black_box(c4.tokens(4096)));
    r.print_throughput(4096.0, "B");

    let r = Bench::new("data/piqa_items/100")
        .run(|| black_box(tasks::generate(tasks::Task::Piqa, 100, 1)));
    r.print_throughput(100.0, "item");

    // similarity selection over paper-scale head counts
    let mut hd = HeadDistances::new(36, 20);
    let flat: Vec<f32> = (0..36 * 20).map(|i| (i % 97) as f32 / 97.0).collect();
    hd.accumulate(&flat, &flat);
    let hd = hd.finalize();
    let r = Bench::new("similarity/select_top/36x20")
        .run(|| black_box(hd.select_top(19, 25)));
    r.print();
}
