//! Eq. 4 quantization bandwidth (the int8 packing cost the cache pays
//! per stored vector).

use kvcar::compress::quant::{dequantize_into, quantize};
use kvcar::util::bench::{black_box, Bench};
use kvcar::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    for n in [64usize, 640, 4096] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let r = Bench::new(&format!("quant/quantize/{n}")).run(|| black_box(quantize(&x)));
        r.print_throughput(n as f64 * 4.0, "B");

        let q = quantize(&x);
        let mut out = vec![0.0f32; n];
        let r = Bench::new(&format!("quant/dequantize/{n}"))
            .run(|| dequantize_into(black_box(&q), black_box(&mut out)));
        r.print_throughput(n as f64 * 4.0, "B");
    }

    // round-trip at the cache's actual latent width
    let x: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    let mut out = vec![0.0f32; 64];
    let r = Bench::new("quant/roundtrip/latent64").run(|| {
        let q = quantize(black_box(&x));
        dequantize_into(&q, &mut out);
        black_box(out[0])
    });
    r.print();
}
