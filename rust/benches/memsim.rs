//! Fig. 2/3 frontier-sweep benches (cheap; exists so every figure has a
//! regenerating bench target) plus Eq. 3 accounting cost.

use kvcar::memsim::{frontier, FigureCompression, GpuModel, FIGURE_BATCHES};
use kvcar::model::memory::{kv_bytes_per_token, CompressionPlan};
use kvcar::model::{gpt2_774m, tinyllama_1_1b};
use kvcar::util::bench::{black_box, Bench};

fn main() {
    for spec in [gpt2_774m(), tinyllama_1_1b()] {
        let gpu = GpuModel::a40_for(&spec);
        let name = spec.name.clone();
        let r = Bench::new(&format!("memsim/frontier_sweep/{name}")).run(|| {
            for c in FigureCompression::all() {
                black_box(frontier(&gpu, &spec, c.ratio(), &FIGURE_BATCHES));
            }
        });
        r.print();
    }

    let spec = gpt2_774m();
    let plan = CompressionPlan::ae_first_layers(&spec, 18).with_quant();
    let r = Bench::new("memory/kv_bytes_per_token/gpt2-774m")
        .run(|| black_box(kv_bytes_per_token(&spec, &plan)));
    r.print();
}
