//! Live sequence migration between sharded workers (DESIGN.md §10).
//!
//! A migration moves one in-flight sequence — its request, sampled
//! output, and compressed KV bytes — from a source [`ServingEngine`]
//! to a destination, without changing a single future token.  The
//! transfer substrate is the tier wire format ([`ParkedBytes`]): the
//! source extracts its encoded suffix exactly as a park would, the
//! destination restores it exactly as a resume would, and the bytes in
//! between travel under two volume optimizations:
//!
//! * **rsync-style delta** (`kvcache::delta`): the suffix payload is
//!   cut into `block_size`-row groups with CRC32 checksums; only groups
//!   the destination's retained *replica basis* lacks actually ship.
//!   KV grows append-only in immutable encoded blocks, so a
//!   re-migration ships O(rows appended since the last transfer), not
//!   O(sequence).
//! * **content-addressed prefix chunks**: shared prefix chain chunks
//!   are identified by [`chunk_chain_id`](crate::kvcache::chunk_chain_id)
//!   — a pure function of the
//!   chain's token keys — so the router can prove a worker already
//!   holds a chunk and skip it.  Delivered chains are pinned on the
//!   destination ([`ServingEngine::migration_pins`]), making "each
//!   chunk ships to a worker at most once, *ever*" sound even after
//!   every local sharer retires.
//!
//! Every step is transactional: a failure at any point (including an
//! injected transfer corruption caught by the group CRCs) rolls the
//! sequence back onto the source worker, bit-identically live, and the
//! whole-stack invariant checker passes in between.  The
//! [`Router`](super::router::Router) drives these pieces and owns the
//! per-worker delivered-chunk and replica-basis ledgers.

use super::scheduler::{ActiveSeq, RunState, ServingEngine};
use crate::kvcache::delta::{self, BlockManifest, DeltaPayload};
use crate::kvcache::ParkedBytes;
use anyhow::{anyhow, Result};
use std::collections::HashSet;

/// A sequence lifted off its source worker, ready to ship: the
/// scheduler state that travels with it, the full suffix payload (the
/// source's replica basis if the migration commits), its block-checksum
/// manifest, and the content-addressed descriptors of its shared
/// prefix chain.
pub(crate) struct Outbound {
    /// in-flight scheduler state (request, sampled output, position)
    pub(crate) seq: ActiveSeq,
    /// full suffix payload in tier wire format
    pub(crate) parked: ParkedBytes,
    /// per-group checksums of `parked` — the delta protocol's first
    /// exchange
    pub(crate) manifest: BlockManifest,
    /// `(chain id, token key)` per shared prefix chunk, root first
    /// (empty for unshared sequences)
    pub(crate) chain: Vec<(u64, Vec<u8>)>,
    /// source-side trie node per chain element (chunk payload export)
    pub(crate) src_nodes: Vec<u32>,
}

/// What a completed destination install reports back to the router.
pub(crate) struct Installed {
    /// the sequence's cache id on the destination worker
    pub(crate) cache_id: u64,
    /// suffix payload bytes that actually shipped (changed/new groups)
    pub(crate) delta_bytes: u64,
    /// suffix payload bytes the destination's replica basis supplied
    pub(crate) bytes_saved: u64,
}

/// Lift `cache_id` off the source worker: remove it from the live set,
/// drop its working-set scratch, extract its encoded suffix bytes
/// (device pool really shrinks, exactly like a park), and compute the
/// delta manifest and prefix-chain descriptors.  On any failure the
/// sequence is put back fully live and an error returned — nothing to
/// roll back for the caller.
pub(crate) fn extract(
    src: &mut ServingEngine<'_>,
    state: &mut RunState,
    cache_id: u64,
) -> Result<Outbound> {
    let seq = state
        .take_seq(cache_id)
        .ok_or_else(|| anyhow!("sequence {cache_id} is not in the source worker's live set"))?;
    if seq.parked || seq.done {
        let msg = if seq.parked { "parked" } else { "finished" };
        let err = anyhow!("sequence {cache_id} is {msg}; only live sequences migrate");
        state.push_seq(seq);
        return Err(err);
    }
    let leaf = src.cache.seq_prefix_leaf(cache_id);
    let (chain, src_nodes) = match leaf {
        Some(leaf) => (src.cache.prefix_chain(leaf)?, src.cache.prefix_path(leaf)?),
        None => (Vec::new(), Vec::new()),
    };
    src.eff.remove(&cache_id);
    src.arena.release(cache_id);
    let parked = match src.cache.extract_sequence_bytes(cache_id) {
        Ok(p) => p,
        Err(e) => {
            // the blocks never moved: re-derive the scratch and restore
            // the sequence to the live set untouched
            src.rebuild_effective(cache_id)?;
            state.push_seq(seq);
            return Err(e);
        }
    };
    let manifest = match delta::manifest(&src.cache.cfg, &parked) {
        Ok(m) => m,
        Err(e) => {
            src.cache.restore_sequence_bytes(cache_id, &parked)?;
            src.rebuild_effective(cache_id)?;
            state.push_seq(seq);
            return Err(e);
        }
    };
    Ok(Outbound {
        seq,
        parked,
        manifest,
        chain,
        src_nodes,
    })
}

/// Ship the outbound sequence's shared prefix chain to the destination,
/// content-addressed: a chunk travels only if the destination neither
/// holds it (its own admissions may have built it) nor has it in the
/// router's `delivered` ledger.  On first delivery of a chain, its leaf
/// is pinned on the destination and recorded in
/// [`ServingEngine::migration_pins`], and every chain id enters
/// `delivered` — the "at most once per worker, ever" law.  Returns the
/// destination-side leaf node and the chunk bytes that actually
/// traveled.  All-or-nothing: a failure partway down the chain removes
/// every node this call imported.
pub(crate) fn ship_chunks(
    src: &ServingEngine<'_>,
    dst: &mut ServingEngine<'_>,
    out: &Outbound,
    delivered: &mut HashSet<u64>,
) -> Result<(Option<u32>, u64)> {
    if out.chain.is_empty() {
        return Ok((None, 0));
    }
    let mut parent: Option<u32> = None;
    let mut created: Vec<u32> = Vec::new();
    let mut shipped_bytes = 0u64;
    let mut failure: Option<anyhow::Error> = None;
    for ((chain_id, key), &src_node) in out.chain.iter().zip(&out.src_nodes) {
        let step = if delivered.contains(chain_id) || dst.cache.prefix_child(parent, key).is_some()
        {
            // dedup hit: the payload never travels (an empty-stream
            // import resolves the existing child without touching it)
            dst.metrics.migration_chunks_deduped += 1;
            dst.cache.import_chunk(parent, key, &[])
        } else {
            src.cache.export_chunk(src_node).and_then(|streams| {
                let bytes: usize = streams.iter().map(Vec::len).sum();
                let node = dst.cache.import_chunk(parent, key, &streams)?;
                shipped_bytes += bytes as u64;
                dst.metrics.migration_chunks_in += 1;
                dst.metrics.migration_chunk_bytes += bytes as u64;
                created.push(node);
                Ok(node)
            })
        };
        match step {
            Ok(node) => parent = Some(node),
            Err(e) => {
                failure = Some(e);
                break;
            }
        }
    }
    if let Some(e) = failure {
        // unwind deepest-first so every removed node is childless
        for node in created.into_iter().rev() {
            dst.cache.remove_unreferenced_chunk(node);
        }
        return Err(e);
    }
    let leaf = parent.expect("non-empty chain yields a leaf");
    let leaf_chain_id = out.chain.last().expect("non-empty chain").0;
    if !delivered.contains(&leaf_chain_id) {
        // first delivery of this chain: pin it resident forever on this
        // worker so the delivered ledger can never go stale
        dst.cache.prefix_ref(leaf)?;
        dst.migration_pins.push(leaf);
        for (chain_id, _) in &out.chain {
            delivered.insert(*chain_id);
        }
    }
    Ok((Some(leaf), shipped_bytes))
}

/// Install the outbound sequence on the destination: diff its manifest
/// against the retained replica `basis`, ship only the missing groups,
/// verify every group CRC plus the end-to-end payload CRC while
/// assembling (the tier corruption contract — mismatches surface as
/// typed `checksum mismatch` errors), then restore the bytes into
/// fresh destination blocks and rebuild the effective cache exactly as
/// a resume would.  `corrupt` arms the chaos path: one bit of the
/// shipped delta flips in transit, which the group CRC must catch.
/// On error the destination is left clean (no sequence, no scratch);
/// delivered chunks stay — they transferred intact and remain pinned.
pub(crate) fn install(
    dst: &mut ServingEngine<'_>,
    out: &Outbound,
    dst_leaf: Option<u32>,
    basis: Option<&ParkedBytes>,
    corrupt: bool,
) -> Result<Installed> {
    let basis_manifest = match basis {
        Some(b) => Some(delta::manifest(&dst.cache.cfg, b)?),
        None => None,
    };
    let wanted = delta::diff(&out.manifest, basis_manifest.as_ref());
    let mut payload: DeltaPayload = delta::extract(&dst.cache.cfg, &out.parked, &wanted)?;
    if corrupt {
        if let Some((_, bytes)) = payload.groups.first_mut() {
            if let Some(b) = bytes.first_mut() {
                *b ^= 0x40;
            }
        }
    }
    let delta_bytes = payload.shipped_bytes() as u64;
    let bytes_saved = out.manifest.full_bytes() as u64 - delta_bytes;
    let assembled = delta::assemble(&dst.cache.cfg, &out.manifest, basis, &payload)?;
    let cache_id = dst.cache.import_sequence(
        out.parked.len,
        dst_leaf,
        out.parked.demoted,
        &out.parked.demoted_spans,
    )?;
    if let Err(e) = dst.cache.restore_sequence_bytes(cache_id, &assembled) {
        dst.cache.free_sequence(cache_id);
        return Err(e);
    }
    if let Err(e) = dst.rebuild_effective(cache_id) {
        dst.eff.remove(&cache_id);
        dst.cache.free_sequence(cache_id);
        return Err(e);
    }
    Ok(Installed {
        cache_id,
        delta_bytes,
        bytes_saved,
    })
}

/// Roll a failed migration back onto the source worker: restore the
/// extracted bytes into fresh source blocks, rebuild the working-set
/// scratch, and put the sequence back in the live set — bitwise exactly
/// where it was.
pub(crate) fn rollback(
    src: &mut ServingEngine<'_>,
    state: &mut RunState,
    out: Outbound,
) -> Result<()> {
    let cache_id = out.seq.cache_id;
    src.cache.restore_sequence_bytes(cache_id, &out.parked)?;
    src.rebuild_effective(cache_id)?;
    state.push_seq(out.seq);
    src.metrics.migration_failures += 1;
    Ok(())
}
