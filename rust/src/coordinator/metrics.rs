//! Serving metrics: latency distributions, throughput, cache savings.
//!
//! All stamps are [`Stamp`]s on the scheduler's clock (wall or virtual),
//! so under a virtual clock every latency figure here — TTFT included —
//! is bit-reproducible from the workload seed.

use super::clock::Stamp;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
/// Latency samples with mean/percentile reporting.
pub struct Histogram {
    samples_ns: Vec<u64>,
}

impl Histogram {
    /// Add one sample.
    pub fn record(&mut self, d: Duration) {
        self.samples_ns.push(d.as_nanos() as u64);
    }

    /// Samples recorded.
    pub fn len(&self) -> usize {
        self.samples_ns.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples_ns.is_empty()
    }

    /// Mean in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64 / 1e6
    }

    /// Nearest-rank percentile in milliseconds (0 when empty).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ns.clone();
        s.sort_unstable();
        let idx = ((s.len() - 1) as f64 * p / 100.0).round() as usize;
        s[idx] as f64 / 1e6
    }
}

#[derive(Debug, Default, Clone)]
/// Integer-valued samples (wave sizes, counts) with mean/max reporting —
/// the count-domain sibling of [`Histogram`].
pub struct CountHistogram {
    samples: Vec<u64>,
}

impl CountHistogram {
    /// Add one sample.
    pub fn record(&mut self, v: u64) {
        self.samples.push(v);
    }

    /// Samples recorded.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Sum of all samples.
    pub fn total(&self) -> u64 {
        self.samples.iter().sum()
    }

    /// The raw samples, in record order (bench distributions).
    pub fn samples(&self) -> &[u64] {
        &self.samples
    }
}

/// Aggregate serving counters for one `ServingEngine::run` workload.
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    /// requests fully generated and retired
    pub requests_completed: u64,
    /// tokens sampled (prefill first-tokens included)
    pub tokens_generated: u64,
    /// per-request prefill latency
    pub prefill_latency: Histogram,
    /// per decode round, per token
    pub decode_step_latency: Histogram,
    /// enqueue-to-prefill wait
    pub queue_latency: Histogram,
    /// true time-to-first-token: request arrival → first emitted token
    /// (the first token is sampled from the prefill logits, so this is
    /// queue wait + the request's share of its admission wave)
    pub ttft: Histogram,
    /// admission waves processed (each admits >= 1 request; the
    /// one-launch-per-wave law is `prefill_launches == prefill_waves`
    /// when the artifact set has `{m}_prefill_b` and no wave exceeds
    /// its compiled capacity)
    pub prefill_waves: u64,
    /// prefill artifact launches issued (batched waves count 1 each,
    /// per-request fallbacks 1 per request)
    pub prefill_launches: u64,
    /// requests admitted per wave (batching quality of admission)
    pub wave_admitted: CountHistogram,
    /// requests admitted with **zero** prefill launches because their
    /// clamped prompt was already computed (within-wave dedup or the
    /// admission planner's prompt-template cache) — under
    /// `ServeConfig::prefix_sharing`, prefill launches are ∝ distinct
    /// prompts, and this counter is the difference
    pub shared_admissions: u64,
    /// prompt rows served from the shared prefix store instead of a
    /// fresh prefill's output (whole prompts of zero-launch admissions
    /// plus block-aligned chunks launched lanes reused)
    pub shared_prefix_rows: u64,
    /// decode rounds executed and total rows (batch slots) used
    pub decode_rounds: u64,
    /// batch slots that carried a live sequence
    pub decode_slots_used: u64,
    /// batch slots paid for (live + padding)
    pub decode_slots_total: u64,
    /// sequences parked by admission control under memory pressure
    pub auto_parks: u64,
    /// parked sequences brought back once memory freed
    pub auto_resumes: u64,
    /// k/v cache bytes staged into the decode-step inputs on the steady
    /// path: per-row syncs under the store-resident effective cache
    /// (O(B·L·kvd) per round), or the full per-round buffer copies under
    /// the legacy copy path (O(B·L·S·kvd) per round) — the ratio between
    /// the two is the win the resident refactor is measured by.  This
    /// counts the **host staging memcpy** only; the host→device side of
    /// the same rows is tracked by `resident_bytes_uploaded` /
    /// `resident_bytes_skipped` below (delta uploads under device
    /// residency, full re-uploads on the reference path)
    pub staged_kv_bytes: u64,
    /// bytes written by slot transitions only: full slot fills after
    /// (re)assignment / capacity-rung switches plus one-time zeroing of
    /// vacated slots — amortized cost, not per-round cost
    pub slot_rebuild_bytes: u64,
    /// slots (re)built from scratch (admission, park/resume, rung switch)
    pub slot_rebuilds: u64,
    /// capacity-rung switches: the resident `[B, L, S, kvd]` regions were
    /// reallocated for a different compiled batch size, invalidating
    /// every slot
    pub capacity_switches: u64,
    /// host→device bytes moved for artifact inputs over the run
    /// (delta patches count only the rows they patch)
    pub input_bytes: u64,
    /// device→host bytes fetched for artifact outputs over the run
    pub output_bytes: u64,
    /// host→device bytes spent keeping resident k/v regions current
    /// (delta patches + full re-uploads of region inputs)
    pub resident_bytes_uploaded: u64,
    /// resident-region bytes the device already held and did **not**
    /// travel again — the savings of the dirty-span delta path; the
    /// steady-state law is uploaded ≈ O(B·L·kvd) per round while
    /// skipped ≈ O(B·L·S·kvd)
    pub resident_bytes_skipped: u64,
    /// resident-region syncs that fell back to a whole-tensor upload
    /// (no span log, undeclared writes, or the device binding cannot
    /// patch buffers in place)
    pub full_uploads: u64,
    /// stale device buffers dropped when their region was released or
    /// reallocated (capacity-rung switches)
    pub buffers_evicted: u64,
    /// supervised retries of failed rounds (Transient / ResourceExhausted
    /// faults re-attempted under the deterministic RetryPolicy)
    pub retries: u64,
    /// total retry backoff charged on the serving clock (virtual-clock
    /// runs reproduce this bit-identically from the seed)
    pub backoff: Duration,
    /// live sequences evicted with a typed error after recovery failed
    pub quarantines: u64,
    /// not-yet-admitted requests rejected with a typed error + retry hint
    pub rejects: u64,
    /// sequences re-encoded to a cheaper storage rung by the pressure
    /// ladder (demotion frees bytes without evicting anyone)
    pub demotions: u64,
    /// demotions that were **per-row-region** (adaptive plans only:
    /// the ladder re-encoded the victim's coldest block run instead of
    /// its whole sequence; every one is also counted in `demotions`)
    pub region_demotions: u64,
    /// tier payloads that failed CRC verification on unpark (each one
    /// quarantines its sequence instead of propagating garbage rows)
    pub checksum_failures: u64,
    /// cached prompt templates shed by the pressure ladder
    pub template_sheds: u64,
    /// wall-clock time of the whole run
    pub wall: Duration,
    /// latent-decoder reconstructions served by the cross-sequence
    /// batched `{m}_decode_kv_bt` rung (the intended steady-state path)
    pub decode_rung_bt: u64,
    /// reconstructions that fell to the token-granular `{m}_decode_kv_t`
    /// rung (single-sequence bulk ranges, or no batched entry compiled)
    pub decode_rung_t: u64,
    /// reconstructions that fell all the way to the zero-padded
    /// full-sequence `{m}_decode_kv` rung — the silent-degradation case
    /// the ROADMAP's "regenerate artifacts" item exists for, made
    /// observable here
    pub decode_rung_padded: u64,
    /// admission waves served by the batched `{m}_prefill_b` rung
    pub prefill_rung_b: u64,
    /// admissions that fell to the per-request `{m}_prefill` rung
    pub prefill_rung_single: u64,
    /// live sequences this worker handed to a peer (router rebalance
    /// or drain; DESIGN.md §10)
    pub migrations_out: u64,
    /// live sequences this worker received from a peer
    pub migrations_in: u64,
    /// already-sampled output tokens that left with migrating sequences
    /// — the invariant checker's token-conservation law nets these out:
    /// `tokens_generated == emitted + tokens_migrated_out -
    /// tokens_migrated_in`
    pub tokens_migrated_out: u64,
    /// already-sampled output tokens that arrived with migrations in
    pub tokens_migrated_in: u64,
    /// suffix payload bytes actually shipped by the delta protocol
    /// (changed/new block groups only)
    pub migration_delta_bytes: u64,
    /// suffix payload bytes the delta protocol did **not** ship because
    /// the destination already held a bitwise-equal replica basis —
    /// the re-migration savings the delta law pins
    pub migration_bytes_saved: u64,
    /// content-addressed prefix chunks shipped to this worker (each
    /// chain ships at most once per worker, ever)
    pub migration_chunks_in: u64,
    /// encoded bytes those chunks carried
    pub migration_chunk_bytes: u64,
    /// prefix chunks a migration referenced that this worker already
    /// held (dedup hits of the content-addressed transfer)
    pub migration_chunks_deduped: u64,
    /// migrations that failed verification or install and rolled back
    /// to the source worker (the sequence keeps running there)
    pub migration_failures: u64,
}

impl ServeMetrics {
    /// Tokens per wall-clock second over the run.
    pub fn throughput_tok_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / secs
    }

    /// Record one admission wave: its size, the prefill launches it
    /// cost, and — from each admitted request's own `arrival` stamp —
    /// the real per-request queue wait to `start` (the moment the
    /// wave's prefill began) plus the true TTFT to `first_token` (the
    /// moment the wave's prefill finished and its first tokens were
    /// sampled).  Staggered arrivals therefore record distinct waits;
    /// `saturating_since` guards the degenerate case of an arrival
    /// stamped after the wave started.
    pub fn record_wave(
        &mut self,
        start: Stamp,
        first_token: Stamp,
        arrivals: &[Stamp],
        launches: u64,
    ) {
        if arrivals.is_empty() {
            return;
        }
        self.prefill_waves += 1;
        self.prefill_launches += launches;
        self.wave_admitted.record(arrivals.len() as u64);
        for &at in arrivals {
            self.queue_latency.record(start.saturating_since(at));
            self.ttft.record(first_token.saturating_since(at));
        }
    }

    /// Fraction of decode batch slots doing useful work (batching quality).
    pub fn batch_efficiency(&self) -> f64 {
        if self.decode_slots_total == 0 {
            return 0.0;
        }
        self.decode_slots_used as f64 / self.decode_slots_total as f64
    }

    /// Human-readable dump of every counter.
    pub fn print_summary(&self, label: &str) {
        println!("--- serve metrics: {label} ---");
        println!(
            "  requests {}  tokens {}  wall {:.2}s  throughput {:.1} tok/s",
            self.requests_completed,
            self.tokens_generated,
            self.wall.as_secs_f64(),
            self.throughput_tok_per_sec()
        );
        println!(
            "  prefill ms: mean {:.1} p50 {:.1} p99 {:.1}   decode-step ms: mean {:.2} p50 {:.2} p99 {:.2}",
            self.prefill_latency.mean_ms(),
            self.prefill_latency.percentile_ms(50.0),
            self.prefill_latency.percentile_ms(99.0),
            self.decode_step_latency.mean_ms(),
            self.decode_step_latency.percentile_ms(50.0),
            self.decode_step_latency.percentile_ms(99.0),
        );
        println!(
            "  queue ms: mean {:.1}   batch efficiency {:.0}%  ({} rounds)",
            self.queue_latency.mean_ms(),
            self.batch_efficiency() * 100.0,
            self.decode_rounds,
        );
        if !self.ttft.is_empty() {
            println!(
                "  ttft ms: mean {:.1} p50 {:.1} p99 {:.1}",
                self.ttft.mean_ms(),
                self.ttft.percentile_ms(50.0),
                self.ttft.percentile_ms(99.0),
            );
        }
        if self.prefill_waves > 0 {
            println!(
                "  admission: {} waves / {} prefill launches  (mean {:.1} max {} admitted per wave)",
                self.prefill_waves,
                self.prefill_launches,
                self.wave_admitted.mean(),
                self.wave_admitted.max(),
            );
        }
        if self.shared_admissions + self.shared_prefix_rows > 0 {
            println!(
                "  prefix sharing: {} zero-launch admissions, {} prompt rows reused",
                self.shared_admissions, self.shared_prefix_rows,
            );
        }
        if self.auto_parks + self.auto_resumes > 0 {
            println!(
                "  memory pressure: {} parks / {} resumes through the host tier",
                self.auto_parks, self.auto_resumes,
            );
        }
        if self.retries + self.quarantines + self.rejects + self.demotions + self.template_sheds > 0
        {
            println!(
                "  recovery: {} retries ({:.1} ms backoff), {} quarantined / {} rejected, \
                 {} demotions ({} regional), {} template sheds, {} checksum failures",
                self.retries,
                self.backoff.as_secs_f64() * 1e3,
                self.quarantines,
                self.rejects,
                self.demotions,
                self.region_demotions,
                self.template_sheds,
                self.checksum_failures,
            );
        }
        if self.staged_kv_bytes + self.slot_rebuild_bytes > 0 {
            println!(
                "  kv staging: {:.1} KiB/round steady + {:.1} KiB in {} slot rebuilds ({} rung switches)",
                self.staged_kv_bytes as f64 / self.decode_rounds.max(1) as f64 / 1024.0,
                self.slot_rebuild_bytes as f64 / 1024.0,
                self.slot_rebuilds,
                self.capacity_switches,
            );
        }
        if self.input_bytes + self.output_bytes > 0 {
            println!(
                "  device traffic: {:.1} KiB in / {:.1} KiB out  ({} stale buffers evicted)",
                self.input_bytes as f64 / 1024.0,
                self.output_bytes as f64 / 1024.0,
                self.buffers_evicted,
            );
        }
        if self.resident_bytes_uploaded + self.resident_bytes_skipped > 0 {
            let total = (self.resident_bytes_uploaded + self.resident_bytes_skipped) as f64;
            println!(
                "  device residency: {:.1} KiB/round uploaded, {:.0}% skipped ({} full uploads)",
                self.resident_bytes_uploaded as f64 / self.decode_rounds.max(1) as f64 / 1024.0,
                self.resident_bytes_skipped as f64 / total * 100.0,
                self.full_uploads,
            );
        }
        if self.decode_rung_bt + self.decode_rung_t + self.decode_rung_padded > 0 {
            println!(
                "  decoder rungs: {} batched (kv_bt) / {} token (kv_t) / {} padded (kv)",
                self.decode_rung_bt, self.decode_rung_t, self.decode_rung_padded,
            );
        }
        if self.prefill_rung_b + self.prefill_rung_single > 0 {
            println!(
                "  prefill rungs: {} batched (prefill_b) / {} per-request (prefill)",
                self.prefill_rung_b, self.prefill_rung_single,
            );
        }
        if self.migrations_in + self.migrations_out + self.migration_failures > 0 {
            println!(
                "  migration: {} in / {} out ({} failed+rolled back), \
                 {:.1} KiB delta shipped / {:.1} KiB basis-saved, \
                 {} chunks in ({:.1} KiB) / {} deduped",
                self.migrations_in,
                self.migrations_out,
                self.migration_failures,
                self.migration_delta_bytes as f64 / 1024.0,
                self.migration_bytes_saved as f64 / 1024.0,
                self.migration_chunks_in,
                self.migration_chunk_bytes as f64 / 1024.0,
                self.migration_chunks_deduped,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100u64 {
            h.record(Duration::from_millis(i));
        }
        assert!((h.percentile_ms(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile_ms(99.0) - 99.0).abs() <= 1.0);
        assert!((h.mean_ms() - 50.5).abs() < 0.01);
    }

    #[test]
    fn staggered_arrivals_record_individual_queue_waits() {
        // three requests arriving 30/20/10 ms before the wave starts:
        // the old shared-enqueue stamp would have recorded one wait for
        // all of them; per-request arrivals must record the real spread
        let mut m = ServeMetrics::default();
        let start = Stamp::from_ms(30);
        let first_token = start + Duration::from_millis(4);
        let arrivals = [Stamp::from_ms(0), Stamp::from_ms(10), Stamp::from_ms(20)];
        m.record_wave(start, first_token, &arrivals, 1);
        assert_eq!(m.prefill_waves, 1);
        assert_eq!(m.prefill_launches, 1);
        assert_eq!(m.wave_admitted.total(), 3);
        assert_eq!(m.queue_latency.len(), 3);
        assert!((m.queue_latency.mean_ms() - 20.0).abs() < 1e-9);
        assert!((m.queue_latency.percentile_ms(99.0) - 30.0).abs() < 1e-9);
        // a second wave for the straggler arriving mid-run
        let later = start + Duration::from_millis(5);
        m.record_wave(later, later, &[start], 1);
        assert_eq!(m.prefill_waves, 2);
        assert!((m.wave_admitted.mean() - 2.0).abs() < 1e-9);
        // arrivals stamped after the wave start clamp to zero wait
        m.record_wave(start, start, &[start + Duration::from_millis(1)], 1);
        assert_eq!(m.queue_latency.len(), 5);
        // empty waves record nothing
        m.record_wave(start, start, &[], 1);
        assert_eq!(m.prefill_waves, 3);
    }

    #[test]
    fn ttft_measures_arrival_to_first_token() {
        // staggered trace: arrivals at 0/10/20 ms, wave prefill starts
        // at 30 ms and its first tokens emerge at 34 ms — TTFT must be
        // queue wait *plus* the wave's prefill time (34/24/14 ms), not
        // the queue_latency figures (30/20/10 ms)
        let mut m = ServeMetrics::default();
        let start = Stamp::from_ms(30);
        let first_token = Stamp::from_ms(34);
        let arrivals = [Stamp::from_ms(0), Stamp::from_ms(10), Stamp::from_ms(20)];
        m.record_wave(start, first_token, &arrivals, 1);
        assert_eq!(m.ttft.len(), 3);
        assert!((m.ttft.mean_ms() - 24.0).abs() < 1e-9);
        assert!((m.ttft.percentile_ms(99.0) - 34.0).abs() < 1e-9);
        assert!((m.ttft.percentile_ms(0.0) - 14.0).abs() < 1e-9);
        // every TTFT sample strictly exceeds its queue wait by prefill
        assert!(
            (m.ttft.mean_ms() - m.queue_latency.mean_ms() - 4.0).abs() < 1e-9,
            "ttft must exceed queue wait by exactly the wave prefill time"
        );
    }

    #[test]
    fn batch_efficiency() {
        let m = ServeMetrics {
            decode_slots_used: 30,
            decode_slots_total: 40,
            ..Default::default()
        };
        assert!((m.batch_efficiency() - 0.75).abs() < 1e-9);
    }
}
