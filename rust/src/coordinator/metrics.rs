//! Serving metrics: latency distributions, throughput, cache savings.

use std::time::Duration;

#[derive(Debug, Default, Clone)]
/// Latency samples with mean/percentile reporting.
pub struct Histogram {
    samples_ns: Vec<u64>,
}

impl Histogram {
    /// Add one sample.
    pub fn record(&mut self, d: Duration) {
        self.samples_ns.push(d.as_nanos() as u64);
    }

    /// Samples recorded.
    pub fn len(&self) -> usize {
        self.samples_ns.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples_ns.is_empty()
    }

    /// Mean in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64 / 1e6
    }

    /// Nearest-rank percentile in milliseconds (0 when empty).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ns.clone();
        s.sort_unstable();
        let idx = ((s.len() - 1) as f64 * p / 100.0).round() as usize;
        s[idx] as f64 / 1e6
    }
}

/// Aggregate serving counters for one `ServingEngine::run` workload.
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    /// requests fully generated and retired
    pub requests_completed: u64,
    /// tokens sampled (prefill first-tokens included)
    pub tokens_generated: u64,
    /// per-request prefill latency
    pub prefill_latency: Histogram,
    /// per decode round, per token
    pub decode_step_latency: Histogram,
    /// enqueue-to-prefill wait
    pub queue_latency: Histogram,
    /// decode rounds executed and total rows (batch slots) used
    pub decode_rounds: u64,
    /// batch slots that carried a live sequence
    pub decode_slots_used: u64,
    /// batch slots paid for (live + padding)
    pub decode_slots_total: u64,
    /// sequences parked by admission control under memory pressure
    pub auto_parks: u64,
    /// parked sequences brought back once memory freed
    pub auto_resumes: u64,
    /// k/v cache bytes staged into the decode-step inputs on the steady
    /// path: per-row syncs under the store-resident effective cache
    /// (O(B·L·kvd) per round), or the full per-round buffer copies under
    /// the legacy copy path (O(B·L·S·kvd) per round) — the ratio between
    /// the two is the win the resident refactor is measured by.  This
    /// counts the **host staging memcpy** only: the engine's
    /// version-keyed device cache still re-uploads the whole tensor when
    /// its version bumps, so the host→device transfer is unchanged until
    /// the artifact side grows device residency / delta uploads (the
    /// ROADMAP's donated-buffers item)
    pub staged_kv_bytes: u64,
    /// bytes written by slot transitions only: full slot fills after
    /// (re)assignment / capacity-rung switches plus one-time zeroing of
    /// vacated slots — amortized cost, not per-round cost
    pub slot_rebuild_bytes: u64,
    /// slots (re)built from scratch (admission, park/resume, rung switch)
    pub slot_rebuilds: u64,
    /// capacity-rung switches: the resident `[B, L, S, kvd]` regions were
    /// reallocated for a different compiled batch size, invalidating
    /// every slot
    pub capacity_switches: u64,
    /// wall-clock time of the whole run
    pub wall: Duration,
}

impl ServeMetrics {
    /// Tokens per wall-clock second over the run.
    pub fn throughput_tok_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / secs
    }

    /// Fraction of decode batch slots doing useful work (batching quality).
    pub fn batch_efficiency(&self) -> f64 {
        if self.decode_slots_total == 0 {
            return 0.0;
        }
        self.decode_slots_used as f64 / self.decode_slots_total as f64
    }

    /// Human-readable dump of every counter.
    pub fn print_summary(&self, label: &str) {
        println!("--- serve metrics: {label} ---");
        println!(
            "  requests {}  tokens {}  wall {:.2}s  throughput {:.1} tok/s",
            self.requests_completed,
            self.tokens_generated,
            self.wall.as_secs_f64(),
            self.throughput_tok_per_sec()
        );
        println!(
            "  prefill ms: mean {:.1} p50 {:.1} p99 {:.1}   decode-step ms: mean {:.2} p50 {:.2} p99 {:.2}",
            self.prefill_latency.mean_ms(),
            self.prefill_latency.percentile_ms(50.0),
            self.prefill_latency.percentile_ms(99.0),
            self.decode_step_latency.mean_ms(),
            self.decode_step_latency.percentile_ms(50.0),
            self.decode_step_latency.percentile_ms(99.0),
        );
        println!(
            "  queue ms: mean {:.1}   batch efficiency {:.0}%  ({} rounds)",
            self.queue_latency.mean_ms(),
            self.batch_efficiency() * 100.0,
            self.decode_rounds,
        );
        if self.auto_parks + self.auto_resumes > 0 {
            println!(
                "  memory pressure: {} parks / {} resumes through the host tier",
                self.auto_parks, self.auto_resumes,
            );
        }
        if self.staged_kv_bytes + self.slot_rebuild_bytes > 0 {
            println!(
                "  kv staging: {:.1} KiB/round steady + {:.1} KiB in {} slot rebuilds ({} rung switches)",
                self.staged_kv_bytes as f64 / self.decode_rounds.max(1) as f64 / 1024.0,
                self.slot_rebuild_bytes as f64 / 1024.0,
                self.slot_rebuilds,
                self.capacity_switches,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100u64 {
            h.record(Duration::from_millis(i));
        }
        assert!((h.percentile_ms(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile_ms(99.0) - 99.0).abs() <= 1.0);
        assert!((h.mean_ms() - 50.5).abs() < 0.01);
    }

    #[test]
    fn batch_efficiency() {
        let m = ServeMetrics {
            decode_slots_used: 30,
            decode_slots_total: 40,
            ..Default::default()
        };
        assert!((m.batch_efficiency() - 0.75).abs() < 1e-9);
    }
}
