//! Sharded multi-worker serving front end (DESIGN.md §10).
//!
//! A [`Router`] owns N independent [`ServingEngine`] workers — each
//! with its own execution backend, KV pool, host tier, and supervisor
//! — and drives them in lock-step rounds on a shared virtual clock so
//! sharded runs stay bit-reproducible.  It places arriving requests by
//! request-id hash affinity (with a load-aware override when the
//! affinity worker is clearly busier than its least-loaded peer), and
//! rebalances or drains workers by *live sequence migration*: a
//! mid-generation sequence lifts off its source worker in tier wire
//! format, ships under the rsync-style delta protocol plus
//! content-addressed prefix chunks (`coordinator::migrate`), and
//! resumes on the destination without perturbing a single future
//! token.
//!
//! Determinism contract: under greedy sampling a migrated sequence's
//! remaining tokens are bitwise identical to the never-migrated run,
//! because the decode path is a pure function of the restored KV bytes
//! and the sampled prefix — both of which the transfer preserves
//! exactly (every group CRC plus an end-to-end payload CRC is verified
//! on install, and any mismatch rolls the sequence back onto its
//! source, still live).

use super::clock::Clock;
use super::invariants::{self, Fnv};
use super::migrate;
use super::request::{GenRequest, GenResponse};
use super::scheduler::{RunState, ServeConfig, ServingEngine};
use super::supervisor::{RecoveryAction, ServeError};
use crate::kvcache::{tier, ParkedBytes};
use crate::runtime::backend::ExecBackend;
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// Tuning knobs for placement and automatic rebalance.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Migrate from the busiest to the least-loaded worker whenever
    /// their live-sequence counts differ by at least this much.
    pub rebalance_threshold: usize,
    /// Upper bound on automatic rebalance migrations per round.
    pub max_migrations_per_round: usize,
    /// Override hash affinity at admission when the affinity worker's
    /// queue depth exceeds the least-loaded worker's by more than this.
    pub load_spread: usize,
    /// Whether [`Router::step`] rebalances automatically; scenarios
    /// that force their own migrations turn this off.
    pub auto_rebalance: bool,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            rebalance_threshold: 2,
            max_migrations_per_round: 1,
            load_spread: 2,
            auto_rebalance: true,
        }
    }
}

/// Cumulative router-level counters (per-worker detail lives in each
/// worker's [`super::metrics::ServeMetrics`]).
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    /// Lock-step rounds driven.
    pub rounds: u64,
    /// Migrations committed (drain + rebalance + forced).
    pub migrations: u64,
    /// Migrations that failed in transfer and rolled back cleanly.
    pub failed_migrations: u64,
    /// Committed migrations initiated by [`Router::drain`].
    pub drain_migrations: u64,
    /// Committed migrations initiated by automatic rebalance.
    pub rebalance_migrations: u64,
    /// Suffix payload bytes that actually shipped (delta groups only).
    pub delta_bytes: u64,
    /// Suffix payload bytes replica bases supplied instead of the wire.
    pub bytes_saved: u64,
    /// Shared prefix chunk bytes shipped (first delivery per worker).
    pub chunk_bytes: u64,
    /// Admissions where load override beat hash affinity.
    pub placements_overridden: u64,
}

/// How one requested migration ended.
#[derive(Debug)]
pub enum MigrationOutcome {
    /// The sequence now lives on the destination worker.
    Committed {
        /// suffix payload bytes that actually shipped
        delta_bytes: u64,
        /// suffix payload bytes the destination's replica basis supplied
        bytes_saved: u64,
        /// shared prefix chunk bytes shipped
        chunk_bytes: u64,
    },
    /// The transfer failed (e.g. a checksum mismatch caught by the
    /// delta protocol's group CRCs); the sequence is back on its
    /// source worker, bitwise exactly where it was.
    RolledBack {
        /// the classified transfer fault
        fault: ServeError,
    },
}

/// One shard: a serving engine, its in-flight run state, and the
/// router-side migration ledgers.
struct Worker<'e> {
    serving: ServingEngine<'e>,
    state: RunState,
    /// chunk chain ids ever delivered to this worker by a migration —
    /// paired with the pins in `ServingEngine::migration_pins`, this
    /// makes "each chunk ships at most once per worker" sound forever
    delivered: HashSet<u64>,
    /// replica bases retained when a sequence migrated away, keyed by
    /// request id (cache ids differ per worker); a returning sequence
    /// diffs against this and ships only groups appended since
    replicas: HashMap<u64, ParkedBytes>,
    draining: bool,
    stalls: u32,
}

impl Worker<'_> {
    fn load(&self) -> usize {
        self.state.n_waiting() + self.state.n_active()
    }

    fn live(&self) -> usize {
        self.state
            .active_seqs()
            .iter()
            .filter(|s| !s.done && !s.parked)
            .count()
    }
}

/// Sharded serving front end: N workers, hash-affinity placement, and
/// delta-sync live migration for rebalance and drain.
pub struct Router<'e> {
    workers: Vec<Worker<'e>>,
    cfg: RouterConfig,
    stats: RouterStats,
    /// requests placed and not yet returned by [`Router::finish`] —
    /// the conservation target for [`Router::check`]
    expected: usize,
}

impl<'e> Router<'e> {
    /// Build one worker per backend, all serving `model` under the
    /// same (cloned) [`ServeConfig`] so compiled rungs and budgets
    /// agree across the cluster.
    pub fn new(
        backends: Vec<&'e mut dyn ExecBackend>,
        model: &str,
        cfg: ServeConfig,
        rcfg: RouterConfig,
    ) -> Result<Router<'e>> {
        anyhow::ensure!(!backends.is_empty(), "a router needs at least one worker backend");
        let mut workers = Vec::with_capacity(backends.len());
        for backend in backends {
            let mut serving = ServingEngine::new(backend, model, cfg.clone())?;
            let state = serving.begin(Vec::new());
            workers.push(Worker {
                serving,
                state,
                delivered: HashSet::new(),
                replicas: HashMap::new(),
                draining: false,
                stalls: 0,
            });
        }
        Ok(Router {
            workers,
            cfg: rcfg,
            stats: RouterStats::default(),
            expected: 0,
        })
    }

    /// Number of workers in the cluster.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Router-level counters.
    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    /// Worker `w`'s serving engine (metrics, cache stats).
    pub fn engine(&self, w: usize) -> &ServingEngine<'e> {
        &self.workers[w].serving
    }

    /// Worker `w`'s serving engine, mutably (clock overrides, fault
    /// injection, manual park/resume in tests).
    pub fn engine_mut(&mut self, w: usize) -> &mut ServingEngine<'e> {
        &mut self.workers[w].serving
    }

    /// Worker `w`'s in-flight run state.
    pub fn worker_state(&self, w: usize) -> &RunState {
        &self.workers[w].state
    }

    /// Cache ids of worker `w`'s migratable sequences (live, unparked,
    /// unfinished), ascending for deterministic victim choice.
    pub fn live_sequences(&self, w: usize) -> Vec<u64> {
        let mut ids: Vec<u64> = self.workers[w]
            .state
            .active_seqs()
            .iter()
            .filter(|s| !s.done && !s.parked)
            .map(|s| s.cache_id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// `(request id, cache id)` of worker `w`'s migratable sequences,
    /// sorted by request id — the scenario harness's deterministic
    /// victim choice.
    pub fn live_requests(&self, w: usize) -> Vec<(u64, u64)> {
        let mut ids: Vec<(u64, u64)> = self.workers[w]
            .state
            .active_seqs()
            .iter()
            .filter(|s| !s.done && !s.parked)
            .map(|s| (s.req.id, s.cache_id))
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Whether worker `w` is out of the admission rotation.
    pub fn is_draining(&self, w: usize) -> bool {
        self.workers[w].draining
    }

    /// Give every worker its own copy of `clock` (virtual clocks keep
    /// the cluster bit-reproducible; the lock-step sync after each
    /// round holds them together).
    pub fn set_clock(&mut self, clock: &Clock) {
        for wk in self.workers.iter_mut() {
            wk.serving.set_clock(clock.clone());
        }
    }

    /// Whether every worker has drained its queue and live set.
    pub fn is_finished(&self) -> bool {
        self.workers.iter().all(|w| w.state.is_finished())
    }

    /// Hash-affinity placement with load-aware override.  `extra` adds
    /// per-worker pending load the run states don't know about yet
    /// (the buckets [`Router::begin`] is still filling).
    fn place(&mut self, req_id: u64, extra: &[usize]) -> usize {
        let n = self.workers.len();
        let mut h = Fnv::new();
        h.push(req_id);
        let mut affinity = (h.finish() % n as u64) as usize;
        // linear probe past draining workers (at least one worker is
        // always accepting — drain refuses to mark the last one)
        for _ in 0..n {
            if !self.workers[affinity].draining {
                break;
            }
            affinity = (affinity + 1) % n;
        }
        let least = (0..n)
            .filter(|&i| !self.workers[i].draining)
            .min_by_key(|&i| (self.workers[i].load() + extra[i], i))
            .unwrap_or(affinity);
        let (la, ll) = (
            self.workers[affinity].load() + extra[affinity],
            self.workers[least].load() + extra[least],
        );
        if la > ll + self.cfg.load_spread {
            self.stats.placements_overridden += 1;
            least
        } else {
            affinity
        }
    }

    /// The least-loaded worker other than `skip` (and not draining).
    fn least_loaded_excluding(&self, skip: usize) -> Result<usize> {
        (0..self.workers.len())
            .filter(|&i| i != skip && !self.workers[i].draining)
            .min_by_key(|&i| (self.workers[i].load(), i))
            .ok_or_else(|| anyhow::anyhow!("no worker available to receive migrations"))
    }

    /// Place `requests` across the workers and start a run on each.
    /// Each worker's [`ServingEngine::begin`] stamps its bucket with
    /// that worker's current clock.
    pub fn begin(&mut self, requests: Vec<GenRequest>) {
        self.expected += requests.len();
        let n = self.workers.len();
        let mut buckets: Vec<Vec<GenRequest>> = (0..n).map(|_| Vec::new()).collect();
        let mut extra = vec![0usize; n];
        for r in requests {
            let w = self.place(r.id, &extra);
            extra[w] += 1;
            buckets[w].push(r);
        }
        for (wk, reqs) in self.workers.iter_mut().zip(buckets) {
            wk.state = wk.serving.begin(reqs);
        }
    }

    /// One lock-step cluster round: every unfinished worker takes a
    /// supervised scheduler step, clocks re-synchronize to the
    /// slowest worker, then automatic rebalance migrates at most
    /// [`RouterConfig::max_migrations_per_round`] sequences from the
    /// busiest to the least-loaded worker.  Returns whether work
    /// remains anywhere; errors only when a worker stalls past its
    /// retry budget on a fault its supervisor cannot act on.
    pub fn step(&mut self) -> Result<bool> {
        self.stats.rounds += 1;
        let mut more = false;
        for wk in self.workers.iter_mut() {
            if wk.state.is_finished() {
                continue;
            }
            let rep = wk.serving.step_supervised(&mut wk.state);
            match (&rep.fault, rep.action) {
                (Some(_), RecoveryAction::None) => wk.stalls += 1,
                _ => wk.stalls = 0,
            }
            if wk.stalls > wk.serving.cfg.retry.max_retries {
                let fault = rep.fault.expect("stall counter only advances on faults");
                return Err(fault.into_anyhow());
            }
            more |= rep.more;
        }
        self.sync_clocks();
        if self.cfg.auto_rebalance {
            self.rebalance()?;
        }
        Ok(more)
    }

    /// Advance every worker's clock to the slowest worker's stamp —
    /// the lock-step barrier that keeps virtual-clock runs
    /// reproducible regardless of worker iteration order (a no-op on
    /// wall clocks).
    fn sync_clocks(&mut self) {
        let Some(t) = self.workers.iter().map(|w| w.serving.clock.now()).max() else {
            return;
        };
        for wk in self.workers.iter_mut() {
            wk.serving.clock.advance_to(t);
        }
    }

    /// Automatic load balancing: while the live-count gap between the
    /// busiest and least-loaded workers reaches the threshold, migrate
    /// the busiest worker's lowest-numbered live sequence over.
    fn rebalance(&mut self) -> Result<()> {
        for _ in 0..self.cfg.max_migrations_per_round {
            let counts: Vec<(usize, usize)> = (0..self.workers.len())
                .filter(|&i| !self.workers[i].draining)
                .map(|i| (i, self.workers[i].live()))
                .collect();
            let Some(&(busiest, hi)) = counts.iter().max_by_key(|&&(i, c)| (c, usize::MAX - i))
            else {
                return Ok(());
            };
            let Some(&(least, lo)) = counts.iter().min_by_key(|&&(i, c)| (c, i)) else {
                return Ok(());
            };
            if busiest == least || hi < lo + self.cfg.rebalance_threshold {
                return Ok(());
            }
            let Some(victim) = self.live_sequences(busiest).first().copied() else {
                return Ok(());
            };
            match self.migrate(busiest, least, victim, false)? {
                MigrationOutcome::Committed { .. } => self.stats.rebalance_migrations += 1,
                // the rollback left the source live; stop trying this
                // round rather than re-failing the same transfer
                MigrationOutcome::RolledBack { .. } => return Ok(()),
            }
        }
        Ok(())
    }

    /// Migrate live sequence `cache_id` from worker `src` to worker
    /// `dst`: extract in tier wire format, ship the shared prefix
    /// chain content-addressed (dedup against the delivered ledger),
    /// install the suffix as a checksummed delta against the
    /// destination's retained replica basis, and commit — or roll the
    /// sequence back onto `src`, still live, if any transfer step
    /// fails.  `corrupt` arms the chaos path: one bit of the shipped
    /// delta flips in transit and the group CRC must catch it.
    ///
    /// Errors only for caller mistakes (bad worker index, sequence not
    /// live on `src`) or an unrecoverable rollback; transfer faults
    /// come back as [`MigrationOutcome::RolledBack`].
    pub fn migrate(
        &mut self,
        src: usize,
        dst: usize,
        cache_id: u64,
        corrupt: bool,
    ) -> Result<MigrationOutcome> {
        let n = self.workers.len();
        anyhow::ensure!(src < n && dst < n, "worker index out of range");
        anyhow::ensure!(src != dst, "source and destination workers must differ");
        let (s, d) = self.pair_mut(src, dst);
        let out = migrate::extract(&mut s.serving, &mut s.state, cache_id)?;
        let req_id = out.seq.req.id;
        let tokens = out.seq.output.len() as u64;
        let (dst_leaf, chunk_bytes) =
            match migrate::ship_chunks(&s.serving, &mut d.serving, &out, &mut d.delivered) {
                Ok(v) => v,
                Err(e) => {
                    let fault = ServeError::classify(&e).with_seq(cache_id).with_req(req_id);
                    migrate::rollback(&mut s.serving, &mut s.state, out)?;
                    self.stats.failed_migrations += 1;
                    return Ok(MigrationOutcome::RolledBack { fault });
                }
            };
        let installed = match migrate::install(
            &mut d.serving,
            &out,
            dst_leaf,
            d.replicas.get(&req_id),
            corrupt,
        ) {
            Ok(i) => i,
            Err(e) => {
                let fault = ServeError::classify(&e).with_seq(cache_id).with_req(req_id);
                migrate::rollback(&mut s.serving, &mut s.state, out)?;
                self.stats.failed_migrations += 1;
                return Ok(MigrationOutcome::RolledBack { fault });
            }
        };
        // commit: the sequence changes identity on the destination and
        // disappears from the source, which retains the full payload
        // as the replica basis for any future return trip
        let migrate::Outbound {
            mut seq,
            parked,
            manifest,
            ..
        } = out;
        let old_id = seq.cache_id;
        seq.cache_id = installed.cache_id;
        seq.admit_seq = d.serving.next_admit_seq();
        d.state.push_seq(seq);
        s.serving.cache.free_sequence(old_id);
        s.serving.clear_supervision(old_id, req_id);
        s.replicas.insert(req_id, parked);
        s.serving.metrics.migrations_out += 1;
        s.serving.metrics.tokens_migrated_out += tokens;
        d.serving.metrics.migrations_in += 1;
        d.serving.metrics.tokens_migrated_in += tokens;
        d.serving.metrics.migration_delta_bytes += installed.delta_bytes;
        d.serving.metrics.migration_bytes_saved += installed.bytes_saved;
        // both endpoints pay for the wire: manifest exchange plus the
        // chunk and delta payloads, at host-tier transfer bandwidth
        let wire =
            32 + 16 * manifest.groups.len() + chunk_bytes as usize + installed.delta_bytes as usize;
        let cost = tier::transfer_cost(wire);
        s.serving.clock.charge(cost);
        d.serving.clock.charge(cost);
        let (delta_bytes, bytes_saved) = (installed.delta_bytes, installed.bytes_saved);
        self.stats.migrations += 1;
        self.stats.delta_bytes += delta_bytes;
        self.stats.bytes_saved += bytes_saved;
        self.stats.chunk_bytes += chunk_bytes;
        self.sync_clocks();
        Ok(MigrationOutcome::Committed {
            delta_bytes,
            bytes_saved,
            chunk_bytes,
        })
    }

    /// Take worker `w` out of rotation: stop placing new work on it,
    /// re-route its queued requests to its peers, resume anything it
    /// parked, and migrate every live sequence to the least-loaded
    /// peer.  Returns how many requests and sequences moved.  The
    /// worker keeps stepping (it may still be mid-drain when called
    /// between rounds) but ends the round empty.
    pub fn drain(&mut self, w: usize) -> Result<usize> {
        anyhow::ensure!(w < self.workers.len(), "worker index out of range");
        anyhow::ensure!(
            self.workers
                .iter()
                .enumerate()
                .any(|(i, wk)| i != w && !wk.draining),
            "cannot drain the last accepting worker"
        );
        self.workers[w].draining = true;
        let mut moved = 0usize;
        let reqs = self.workers[w].state.drain_waiting();
        let zeros = vec![0usize; self.workers.len()];
        for r in reqs {
            let target = self.place(r.id, &zeros);
            self.workers[target].state.push_waiting(r);
            moved += 1;
        }
        let parked: Vec<u64> = self.workers[w]
            .state
            .active_seqs()
            .iter()
            .filter(|s| s.parked && !s.done)
            .map(|s| s.cache_id)
            .collect();
        for id in parked {
            self.workers[w].serving.resume_sequence(id)?;
            // the engine resumed the bytes; mirror it in scheduler state
            // (the pressure-path resume does both sides itself)
            if let Some(mut seq) = self.workers[w].state.take_seq(id) {
                seq.parked = false;
                self.workers[w].state.push_seq(seq);
            }
        }
        for id in self.live_sequences(w) {
            let dst = self.least_loaded_excluding(w)?;
            match self.migrate(w, dst, id, false)? {
                MigrationOutcome::Committed { .. } => {
                    self.stats.drain_migrations += 1;
                    moved += 1;
                }
                MigrationOutcome::RolledBack { fault } => return Err(fault.into_anyhow()),
            }
        }
        Ok(moved)
    }

    /// Put a drained worker back in the admission rotation.
    pub fn undrain(&mut self, w: usize) {
        self.workers[w].draining = false;
    }

    /// Close out the run on every worker and merge the responses,
    /// sorted by request id.
    pub fn finish(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        for wk in self.workers.iter_mut() {
            let state = std::mem::replace(&mut wk.state, wk.serving.begin(Vec::new()));
            out.extend(wk.serving.finish(state));
        }
        out.sort_by_key(|r| r.id);
        self.expected = 0;
        out
    }

    /// Serve `requests` across the cluster to completion:
    /// [`Router::begin`] → [`Router::step`] until drained →
    /// [`Router::finish`].
    pub fn run(&mut self, requests: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        self.begin(requests);
        while self.step()? {}
        Ok(self.finish())
    }

    /// Audit the whole cluster ([`invariants::check_cluster`]):
    /// per-worker round invariants plus the cross-worker laws —
    /// placement uniqueness, request conservation against everything
    /// placed, and migration symmetry.  Returns the cluster state
    /// fingerprint.
    pub fn check(&self, strict_budget: bool) -> Result<u64, String> {
        let pairs: Vec<(&ServingEngine<'_>, &RunState)> = self
            .workers
            .iter()
            .map(|wk| (&wk.serving, &wk.state))
            .collect();
        invariants::check_cluster(&pairs, self.expected, strict_budget)
    }

    /// Split-borrow two distinct workers mutably.
    fn pair_mut(&mut self, a: usize, b: usize) -> (&mut Worker<'e>, &mut Worker<'e>) {
        debug_assert_ne!(a, b);
        if a < b {
            let (lo, hi) = self.workers.split_at_mut(b);
            (&mut lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.workers.split_at_mut(a);
            (&mut hi[0], &mut lo[b])
        }
    }
}
