//! Request/response types for the serving path.

use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    Greedy,
    /// temperature > 0 softmax sampling (seeded, deterministic)
    Temperature(f32),
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// stop generation at this byte (e.g. b'.'), in addition to the
    /// max_new_tokens budget
    pub stop_byte: Option<u8>,
}

impl GenRequest {
    pub fn greedy(id: u64, prompt: &[u8], max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.to_vec(),
            max_new_tokens,
            sampling: Sampling::Greedy,
            stop_byte: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// generated continuation (prompt excluded)
    pub output: Vec<u8>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill_latency: Duration,
    pub decode_latency: Duration,
    /// queueing delay before prefill started
    pub queue_latency: Duration,
}

impl GenResponse {
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.decode_latency.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let r = GenResponse {
            id: 1,
            output: vec![b'a'; 10],
            prompt_tokens: 5,
            generated_tokens: 10,
            prefill_latency: Duration::from_millis(100),
            decode_latency: Duration::from_millis(500),
            queue_latency: Duration::ZERO,
        };
        assert!((r.tokens_per_sec() - 20.0).abs() < 1e-9);
    }
}
