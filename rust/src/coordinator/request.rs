//! Request/response types for the serving path.

use super::clock::Stamp;
use super::supervisor::ServeError;
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq)]
/// Token selection policy.
pub enum Sampling {
    /// argmax decoding (deterministic)
    Greedy,
    /// temperature > 0 softmax sampling (seeded, deterministic)
    Temperature(f32),
}

#[derive(Debug, Clone)]
/// One generation request entering the scheduler.
pub struct GenRequest {
    /// caller-chosen id, echoed in the response
    pub id: u64,
    /// byte-token prompt (clamped to max_seq - 1)
    pub prompt: Vec<u8>,
    /// generation budget including the prefill token
    pub max_new_tokens: usize,
    /// token selection policy
    pub sampling: Sampling,
    /// stop generation at this byte (e.g. b'.'), in addition to the
    /// max_new_tokens budget
    pub stop_byte: Option<u8>,
    /// When the request entered the system, as a [`Stamp`] on the
    /// serving clock.  `None` means "stamp me on receipt": the
    /// scheduler/server fills it in with `clock.now()` the moment the
    /// request is first seen.  Trace replay sets an explicit stamp so
    /// `queue_latency`/TTFT reproduce bit-identically under a virtual
    /// clock; under a virtual clock a future stamp also *gates*
    /// admission — the request is not schedulable before its arrival.
    pub arrival: Option<Stamp>,
}

impl GenRequest {
    /// Greedy request with no stop byte, stamped on receipt.
    pub fn greedy(id: u64, prompt: &[u8], max_new_tokens: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.to_vec(),
            max_new_tokens,
            sampling: Sampling::Greedy,
            stop_byte: None,
            arrival: None,
        }
    }

    /// Same request with an explicit arrival stamp (trace replay, tests).
    pub fn at(mut self, arrival: Stamp) -> GenRequest {
        self.arrival = Some(arrival);
        self
    }
}

#[derive(Debug, Clone)]
/// A completed request with its latency breakdown.
pub struct GenResponse {
    /// id from the originating request
    pub id: u64,
    /// generated continuation (prompt excluded)
    pub output: Vec<u8>,
    /// prompt tokens actually consumed
    pub prompt_tokens: usize,
    /// tokens produced (== output.len())
    pub generated_tokens: usize,
    /// time in the prefill artifact
    pub prefill_latency: Duration,
    /// summed decode-round time attributed to this request
    pub decode_latency: Duration,
    /// queueing delay before prefill started
    pub queue_latency: Duration,
    /// why the request did not complete normally: `None` for a clean
    /// completion; `Some` when the supervisor quarantined the sequence
    /// (partial `output` retained) or rejected the request before
    /// admission (empty `output`, message carries a retry hint)
    pub error: Option<ServeError>,
}

impl GenResponse {
    /// Decode throughput of this request alone.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.decode_latency.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let r = GenResponse {
            id: 1,
            output: vec![b'a'; 10],
            prompt_tokens: 5,
            generated_tokens: 10,
            prefill_latency: Duration::from_millis(100),
            decode_latency: Duration::from_millis(500),
            queue_latency: Duration::ZERO,
            error: None,
        };
        assert!((r.tokens_per_sec() - 20.0).abs() < 1e-9);
    }
}
