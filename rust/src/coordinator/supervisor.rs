//! Fault-tolerant serving supervisor (DESIGN.md §9).
//!
//! The scheduler raises every serving-path failure as a typed
//! [`ServeError`] carrying its blast radius (an attributed live
//! sequence, a not-yet-admitted request, or a whole admission wave).
//! [`ServingEngine::step_supervised`] classifies the error and picks a
//! [`RecoveryAction`]:
//!
//! * `Transient` faults are retried under the deterministic
//!   [`RetryPolicy`] — exponential backoff with seeded jitter *charged
//!   on the serving clock*, so retry timing is bit-reproducible under a
//!   virtual clock — and quarantine the attributed target once retries
//!   are exhausted.
//! * `ResourceExhausted` faults walk a pressure-degradation ladder with
//!   hysteresis: shed prompt templates → demote the fattest sequence to
//!   a cheaper storage rung → force-park a victim → reject the
//!   attributed request with a retry hint.  The rung ratchets up under
//!   sustained pressure and decays one step per
//!   [`RetryPolicy::calm_rounds`] consecutive clean rounds.
//! * `Corruption` / `Permanent` faults skip retries and quarantine the
//!   attributed target immediately — a corrupted tier payload or a
//!   broken artifact can only get worse by retrying.
//!
//! Quarantine evicts exactly the attributed sequence: its state is
//! rolled back across every layer (scheduler, slot arena, cache
//! manager, host tier) and its caller receives a [`GenResponse`] with
//! [`GenResponse::error`] set, while every other sequence finishes with
//! a token stream bitwise identical to the fault-free run.
//!
//! [`ServingEngine::step_supervised`]: super::scheduler::ServingEngine::step_supervised
//! [`GenResponse`]: super::request::GenResponse
//! [`GenResponse::error`]: super::request::GenResponse::error

use super::invariants::Fnv;
use std::collections::HashMap;
use std::fmt;
use std::time::Duration;

/// Failure taxonomy of the serving path.  The class decides the
/// recovery strategy, not the message text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// retry is expected to succeed (flaky launch, injected fault)
    Transient,
    /// memory/budget pressure: retry after shedding load
    ResourceExhausted,
    /// data integrity violation (checksum mismatch): never retry on
    /// the same bytes — quarantine or rebuild
    Corruption,
    /// structural failure (missing entry, shape mismatch): retrying
    /// cannot help
    Permanent,
}

/// A typed serving-path error with blast-radius attribution.
///
/// At most one of `seq` / `req` is meaningful for recovery: `seq` names
/// a live sequence (cache id) to quarantine, `req` a not-yet-admitted
/// request (caller id) to reject.  `wave` records which admission wave
/// the failure interrupted, for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeError {
    /// recovery class
    pub class: ErrorClass,
    /// attributed live sequence (cache id), if any
    pub seq: Option<u64>,
    /// attributed not-yet-admitted request (caller id), if any
    pub req: Option<u64>,
    /// admission wave ordinal the failure interrupted, if wave-scoped
    pub wave: Option<u64>,
    /// human-readable cause (the full anyhow context chain)
    pub msg: String,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.class)?;
        if let Some(s) = self.seq {
            write!(f, "[seq {s}]")?;
        }
        if let Some(r) = self.req {
            write!(f, "[req {r}]")?;
        }
        if let Some(w) = self.wave {
            write!(f, "[wave {w}]")?;
        }
        write!(f, ": {}", self.msg)
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// Unattributed error of the given class.
    pub fn new(class: ErrorClass, msg: impl Into<String>) -> ServeError {
        ServeError {
            class,
            seq: None,
            req: None,
            wave: None,
            msg: msg.into(),
        }
    }

    /// Attribute a live sequence (kept if already attributed).
    pub fn with_seq(mut self, seq: u64) -> ServeError {
        self.seq.get_or_insert(seq);
        self
    }

    /// Attribute a not-yet-admitted request (kept if already attributed).
    pub fn with_req(mut self, req: u64) -> ServeError {
        self.req.get_or_insert(req);
        self
    }

    /// Attribute an admission wave (kept if already attributed).
    pub fn with_wave(mut self, wave: u64) -> ServeError {
        self.wave.get_or_insert(wave);
        self
    }

    /// Classify an `anyhow` error from the serving path.  A
    /// [`ServeError`] anywhere in the context chain passes through
    /// unchanged (raise sites attribute close to the failure); bare
    /// errors fall back to message heuristics so pre-taxonomy raise
    /// sites still land in the right class.
    pub fn classify(err: &anyhow::Error) -> ServeError {
        if let Some(se) = err.downcast_ref::<ServeError>() {
            return se.clone();
        }
        let msg = format!("{err:#}");
        let lower = msg.to_lowercase();
        let class = if lower.contains("checksum") || lower.contains("corrupt") {
            ErrorClass::Corruption
        } else if lower.contains("budget") || lower.contains("pool") {
            ErrorClass::ResourceExhausted
        } else if lower.contains("injected") && lower.contains("fault") {
            ErrorClass::Transient
        } else {
            ErrorClass::Permanent
        };
        ServeError::new(class, msg)
    }

    /// Wrap into an `anyhow::Error` (the serving path's transport).
    pub fn into_anyhow(self) -> anyhow::Error {
        anyhow::Error::new(self)
    }
}

/// Classify + attribute a sequence-scoped failure in one step (raise
/// sites on the decode/park/resume paths).
pub(crate) fn seq_err(e: anyhow::Error, seq: u64) -> anyhow::Error {
    ServeError::classify(&e).with_seq(seq).into_anyhow()
}

/// Classify + attribute a wave-scoped failure: the wave ordinal plus
/// its lead request (the quarantine/reject target when retries run out).
pub(crate) fn wave_err(e: anyhow::Error, wave: u64, req: u64) -> anyhow::Error {
    ServeError::classify(&e)
        .with_wave(wave)
        .with_req(req)
        .into_anyhow()
}

/// Deterministic retry/backoff policy.  All waits are charged on the
/// serving [`Clock`](super::clock::Clock), so under a virtual clock
/// every retry timing — jitter included — is a pure function of the
/// config seed and the failure's attribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// failed attempts per target before the supervisor gives up and
    /// quarantines/escalates
    pub max_retries: u32,
    /// backoff before the first retry
    pub base: Duration,
    /// multiplier per further attempt (exponential)
    pub factor: u32,
    /// backoff ceiling (pre-jitter)
    pub max_backoff: Duration,
    /// consecutive clean rounds before the pressure ladder decays one
    /// rung (the hysteresis half of the degradation ladder)
    pub calm_rounds: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(2),
            factor: 2,
            max_backoff: Duration::from_millis(40),
            calm_rounds: 4,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based) of `target`, with
    /// seeded jitter: `min(base * factor^(attempt-1), max_backoff)`
    /// plus an FNV-derived jitter in `[0, base)`.  Deterministic in
    /// `(seed, target, attempt)` — two runs of the same scenario charge
    /// bit-identical waits.
    pub fn backoff(&self, seed: u64, target: u64, attempt: u32) -> Duration {
        let base_ns = self.base.as_nanos() as u64;
        let exp = attempt.saturating_sub(1).min(16);
        let raw = base_ns.saturating_mul((self.factor as u64).saturating_pow(exp));
        let capped = raw.min(self.max_backoff.as_nanos() as u64);
        let mut h = Fnv::new();
        h.push(seed);
        h.push(target);
        h.push(attempt as u64);
        let jitter = if base_ns == 0 { 0 } else { h.finish() % base_ns };
        Duration::from_nanos(capped + jitter)
    }
}

/// What the supervisor did about one failed round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// nothing to do (clean round, or an unattributed fault the caller
    /// must decide on)
    None,
    /// the round will be re-attempted after the charged backoff
    Retry {
        /// 1-based attempt counter for the attributed target
        attempt: u32,
        /// wait charged on the serving clock before the retry
        backoff: Duration,
    },
    /// degradation rung 1: a cached prompt template was shed
    Shed,
    /// degradation rung 2: this sequence (cache id) was re-encoded to a
    /// cheaper storage rung
    Demote(u64),
    /// degradation rung 3: this sequence (cache id) was force-parked
    Park(u64),
    /// this request (caller id) was evicted with a typed error response
    Quarantine(u64),
    /// this not-yet-admitted request (caller id) was rejected with a
    /// typed error response carrying a retry hint
    Reject(u64),
}

/// One supervised scheduler round: whether work remains, the classified
/// fault (if the round failed), and the recovery taken.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// more rounds remain (mirrors `step`'s `Ok(bool)`)
    pub more: bool,
    /// the round's classified failure, `None` for a clean round
    pub fault: Option<ServeError>,
    /// what the supervisor did about it
    pub action: RecoveryAction,
}

/// Supervisor bookkeeping: per-target consecutive failed attempts, the
/// current pressure-ladder rung, and the clean-round streak that decays
/// it (hysteresis).
#[derive(Debug, Default)]
pub struct SupervisorState {
    /// (is_request, id) -> consecutive failed attempts
    attempts: HashMap<(bool, u64), u32>,
    /// current degradation rung: 0 = none, 1 = shed, 2 = demote,
    /// 3 = park, 4 = reject
    pressure: u32,
    /// consecutive clean rounds since the last escalation
    calm: u32,
}

impl SupervisorState {
    /// Record one failed attempt for a target; returns the new count.
    pub(crate) fn bump(&mut self, key: (bool, u64)) -> u32 {
        let n = self.attempts.entry(key).or_insert(0);
        *n += 1;
        *n
    }

    /// Forget a target (it recovered, or it was evicted).
    pub(crate) fn clear(&mut self, key: (bool, u64)) {
        self.attempts.remove(&key);
    }

    /// Forget both attributions of an id (sequence and request scoped).
    pub(crate) fn clear_id(&mut self, id: u64) {
        self.attempts.remove(&(false, id));
        self.attempts.remove(&(true, id));
    }

    /// Current degradation rung (0 = no pressure).
    pub fn pressure(&self) -> u32 {
        self.pressure
    }

    /// Ratchet the pressure rung up to at least `rung` (escalation
    /// resets the calm streak — decay starts over).
    pub(crate) fn ratchet(&mut self, rung: u32) {
        self.pressure = self.pressure.max(rung);
        self.calm = 0;
    }

    /// Record a clean round; after `calm_rounds` in a row the pressure
    /// rung decays one step (hysteresis: recovery is gradual, so a
    /// single quiet round cannot flap the ladder).
    pub(crate) fn note_clean(&mut self, policy: &RetryPolicy) {
        if self.pressure == 0 {
            return;
        }
        self.calm += 1;
        if self.calm >= policy.calm_rounds.max(1) {
            self.pressure -= 1;
            self.calm = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn backoff_is_deterministic_exponential_and_capped() {
        let p = RetryPolicy::default();
        let a1 = p.backoff(7, 42, 1);
        let a1b = p.backoff(7, 42, 1);
        assert_eq!(a1, a1b, "same (seed, target, attempt) must reproduce");
        assert_ne!(
            p.backoff(7, 42, 1),
            p.backoff(7, 43, 1),
            "jitter must separate targets"
        );
        // pre-jitter schedule doubles: attempt n+1 >= attempt n floor
        let floor = |n: u32| p.base.as_nanos() as u64 * 2u64.pow(n - 1);
        for n in 1..=4 {
            let b = p.backoff(7, 42, n).as_nanos() as u64;
            assert!(b >= floor(n), "attempt {n} under its exponential floor");
            assert!(
                b < floor(n) + p.base.as_nanos() as u64,
                "attempt {n} jitter exceeds base"
            );
        }
        // deep attempts cap at max_backoff + jitter
        let deep = p.backoff(7, 42, 30);
        assert!(deep <= p.max_backoff + p.base);
    }

    #[test]
    fn classify_heuristics_cover_untyped_errors() {
        let cases = [
            ("injected decode launch fault", ErrorClass::Transient),
            ("cache budget exceeded", ErrorClass::ResourceExhausted),
            ("checksum mismatch on unpark", ErrorClass::Corruption),
            ("mock has no entry 'x'", ErrorClass::Permanent),
        ];
        for (msg, class) in cases {
            assert_eq!(
                ServeError::classify(&anyhow!("{msg}")).class,
                class,
                "{msg}"
            );
        }
    }

    #[test]
    fn typed_errors_survive_the_anyhow_round_trip() {
        let e = ServeError::new(ErrorClass::Corruption, "bad bytes")
            .with_seq(9)
            .with_wave(2);
        let any = e.clone().into_anyhow().context("resuming sequence 9");
        let back = ServeError::classify(&any);
        assert_eq!(back, e, "context wrapping must not strip the taxonomy");
        // attribution is first-writer-wins
        assert_eq!(back.with_seq(4).seq, Some(9));
    }

    #[test]
    fn pressure_ladder_ratchets_and_decays_with_hysteresis() {
        let p = RetryPolicy {
            calm_rounds: 2,
            ..RetryPolicy::default()
        };
        let mut s = SupervisorState::default();
        s.ratchet(2);
        s.ratchet(1); // never down
        assert_eq!(s.pressure(), 2);
        s.note_clean(&p);
        assert_eq!(s.pressure(), 2, "one quiet round must not decay");
        s.note_clean(&p);
        assert_eq!(s.pressure(), 1, "calm_rounds quiet rounds decay one rung");
        s.note_clean(&p);
        s.note_clean(&p);
        assert_eq!(s.pressure(), 0);
        s.note_clean(&p);
        assert_eq!(s.pressure(), 0);
    }

    #[test]
    fn attempts_track_targets_independently() {
        let mut s = SupervisorState::default();
        assert_eq!(s.bump((false, 1)), 1);
        assert_eq!(s.bump((false, 1)), 2);
        assert_eq!(s.bump((true, 1)), 1, "request scope is separate");
        s.clear_id(1);
        assert_eq!(s.bump((false, 1)), 1);
    }
}
