//! The serving engine: prefill/decode scheduling with continuous batching
//! over the compressed KV cache.
//!
//! Dataflow per the paper's Fig. 1:
//!
//! * **prefill** — one request at a time through the `{m}_prefill`
//!   artifact (store-transform semantics), then the prompt's compressed
//!   rows enter the cache manager (latents for AE layers, raw or
//!   head-subset rows otherwise; int8-packed when the plan stacks Eq. 4).
//! * **decode** — active sequences are batched each round through
//!   `{m}_decode_step_b{B}`; the artifact receives the *effective*
//!   (decoded + reuse-resolved) cache, appends the new token's raw row
//!   in-graph, and returns latent/raw/effective rows for storage.
//!
//! The effective cache is transient scratch (the decode-on-retrieval
//! working set).  Two modes:
//!
//! * `incremental` (default) — effective rows are appended as decode
//!   produces them; the persistent store is still only compressed rows.
//! * `per_step_reconstruct` — the faithful-paper mode: every round
//!   rebuilds the effective cache from the compressed store through the
//!   `{m}_decode_kv` decoder artifact (reconstruction on every
//!   retrieval).  Slower; used to validate the incremental path and to
//!   quantify the optimization in EXPERIMENTS.md §Perf.

use super::metrics::ServeMetrics;
use super::request::{GenRequest, GenResponse, Sampling};
use crate::compress::planner::{to_masks, RuntimeMasks};
use crate::kvcache::{CacheConfig, CacheManager, Side, StoredRows};
use crate::model::memory::CompressionPlan;
use crate::model::ModelSpec;
use crate::runtime::{Engine, Store, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub plan: CompressionPlan,
    /// concurrent decode sequences targeted by the batcher
    pub max_batch: usize,
    pub seed: u64,
    /// faithful-paper mode: rebuild the effective cache from the
    /// compressed store every decode round
    pub per_step_reconstruct: bool,
}

impl ServeConfig {
    pub fn baseline(spec: &ModelSpec) -> ServeConfig {
        ServeConfig {
            plan: CompressionPlan::none(spec.n_layer, spec.n_kv_head),
            max_batch: 8,
            seed: 0,
            per_step_reconstruct: false,
        }
    }
}

struct EffBuf {
    /// [L, S, kvd] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

struct ActiveSeq {
    req: GenRequest,
    cache_id: u64,
    /// position the next decode step writes (prompt_len + generated - 1
    /// is the last written; see step accounting in decode_round)
    pos: usize,
    next_token: u8,
    output: Vec<u8>,
    enqueued: Instant,
    prefill_start: Instant,
    prefill_end: Instant,
    decode_time: std::time::Duration,
    done: bool,
}

pub struct ServingEngine<'e> {
    pub engine: &'e mut Engine,
    pub store: Store,
    pub spec: ModelSpec,
    pub model: String,
    pub masks: RuntimeMasks,
    pub cache: CacheManager,
    pub cfg: ServeConfig,
    pub metrics: ServeMetrics,
    eff: HashMap<u64, EffBuf>,
    decode_batches: Vec<usize>,
    rng: Rng,
    /// reusable decode-round staging buffers (avoid 4 MB/round allocs)
    kc_buf: Vec<f32>,
    vc_buf: Vec<f32>,
}

impl<'e> ServingEngine<'e> {
    pub fn new(engine: &'e mut Engine, model: &str, cfg: ServeConfig) -> Result<Self> {
        let mut store = Store::new();
        engine.load_params(model, &mut store)?;
        let spec = ModelSpec::from_manifest(&engine.manifest.raw, model)?;
        cfg.plan
            .validate()
            .map_err(|e| anyhow!("invalid plan: {e}"))?;
        let masks = to_masks(&cfg.plan);
        let decode_batches: Vec<usize> = engine
            .manifest
            .raw
            .get("models")
            .and_then(|m| m.get(model))
            .and_then(|m| m.get("decode_batches"))
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_else(|| vec![1, 8]);
        let cache = CacheManager::new(CacheConfig::new(spec.clone(), cfg.plan.clone()));
        let seed = cfg.seed;
        let mut s = ServingEngine {
            engine,
            store,
            spec,
            model: model.to_string(),
            masks,
            cache,
            cfg,
            metrics: ServeMetrics::default(),
            eff: HashMap::new(),
            decode_batches,
            rng: Rng::new(seed ^ 0x5E47E),
            kc_buf: Vec::new(),
            vc_buf: Vec::new(),
        };
        s.apply_masks();
        Ok(s)
    }

    fn apply_masks(&mut self) {
        let (l, h) = (self.spec.n_layer, self.spec.n_kv_head);
        self.store
            .insert("compress", Tensor::f32(vec![l], self.masks.compress.clone()));
        self.store
            .insert("reuse_k", Tensor::f32(vec![l, h], self.masks.reuse_k.clone()));
        self.store
            .insert("reuse_v", Tensor::f32(vec![l, h], self.masks.reuse_v.clone()));
        self.store
            .insert("quant", Tensor::scalar_f32(self.masks.quant));
    }

    fn sample(&mut self, logits: &[f32], sampling: Sampling) -> u8 {
        match sampling {
            Sampling::Greedy => {
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &v) in logits.iter().enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                best as u8
            }
            Sampling::Temperature(t) => {
                let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = logits
                    .iter()
                    .map(|&v| (((v - m) / t.max(1e-4)) as f64).exp())
                    .collect();
                self.rng.weighted(&weights) as u8
            }
        }
    }

    /// Run prefill for one request; returns the active sequence handle.
    fn prefill(&mut self, req: GenRequest, enqueued: Instant) -> Result<ActiveSeq> {
        let t0 = Instant::now();
        let (l, s, kvd, dl, v) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
            self.spec.vocab,
        );
        let plen = req.prompt.len().clamp(1, s - 1);
        let mut tokens = vec![0i32; s];
        let mut mask = vec![0.0f32; s];
        for t in 0..plen {
            tokens[t] = req.prompt[t] as i32;
            mask[t] = 1.0;
        }
        self.store.insert("tokens", Tensor::i32(vec![1, s], tokens));
        self.store.insert("len_mask", Tensor::f32(vec![1, s], mask));
        self.store
            .insert("last", Tensor::scalar_i32((plen - 1) as i32));
        let entry = format!("{}_prefill", self.model);
        let out = self.engine.execute(&entry, &self.store)?;
        let logits = out[0].1.as_f32()?;
        debug_assert_eq!(logits.len(), v);
        let k_raw = out[1].1.as_f32()?;
        let v_raw = out[2].1.as_f32()?;
        let k_lat = out[3].1.as_f32()?;
        let v_lat = out[4].1.as_f32()?;
        let k_eff = out[5].1.as_f32()?;
        let v_eff = out[6].1.as_f32()?;

        // store the prompt's compressed rows
        let cache_id = self.cache.create_sequence();
        let mut kl = vec![0.0f32; l * dl];
        let mut vl = vec![0.0f32; l * dl];
        let mut kr = vec![0.0f32; l * kvd];
        let mut vr = vec![0.0f32; l * kvd];
        for t in 0..plen {
            for layer in 0..l {
                kl[layer * dl..(layer + 1) * dl]
                    .copy_from_slice(&k_lat[layer * s * dl + t * dl..][..dl]);
                vl[layer * dl..(layer + 1) * dl]
                    .copy_from_slice(&v_lat[layer * s * dl + t * dl..][..dl]);
                kr[layer * kvd..(layer + 1) * kvd]
                    .copy_from_slice(&k_raw[layer * s * kvd + t * kvd..][..kvd]);
                vr[layer * kvd..(layer + 1) * kvd]
                    .copy_from_slice(&v_raw[layer * s * kvd + t * kvd..][..kvd]);
            }
            self.cache.append_token(cache_id, &kl, &vl, &kr, &vr)?;
        }

        // effective-cache scratch, seeded from the prefill's k_eff/v_eff
        let mut eff = EffBuf {
            k: vec![0.0; l * s * kvd],
            v: vec![0.0; l * s * kvd],
        };
        for layer in 0..l {
            let base = layer * s * kvd;
            eff.k[base..base + plen * kvd].copy_from_slice(&k_eff[base..base + plen * kvd]);
            eff.v[base..base + plen * kvd].copy_from_slice(&v_eff[base..base + plen * kvd]);
        }
        self.eff.insert(cache_id, eff);

        let first = self.sample(logits, req.sampling);
        let now = Instant::now();
        self.metrics.prefill_latency.record(now - t0);
        self.metrics.queue_latency.record(t0 - enqueued);
        self.metrics.tokens_generated += 1; // prefill samples the first token
        let mut seq = ActiveSeq {
            cache_id,
            pos: plen,
            next_token: first,
            output: vec![first],
            enqueued,
            prefill_start: t0,
            prefill_end: now,
            decode_time: std::time::Duration::ZERO,
            done: false,
            req,
        };
        self.check_done(&mut seq);
        Ok(seq)
    }

    fn check_done(&self, seq: &mut ActiveSeq) {
        let last = *seq.output.last().unwrap();
        if seq.output.len() >= seq.req.max_new_tokens
            || seq.pos >= self.spec.max_seq
            || seq.req.stop_byte == Some(last)
        {
            seq.done = true;
        }
    }

    /// Faithful-paper reconstruction: rebuild one sequence's effective
    /// cache from the compressed store (latents through the decoder
    /// artifact, aliases resolved layer-by-layer).
    pub fn rebuild_effective(&mut self, cache_id: u64) -> Result<()> {
        let (l, s, kvd, dl) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
        );
        let len = self
            .cache
            .seq_len(cache_id)
            .ok_or_else(|| anyhow!("unknown sequence"))?;
        // pass 1: gather latents for AE layers, decode them in one call
        let mut k_lat = vec![0.0f32; l * s * dl];
        let mut v_lat = vec![0.0f32; l * s * dl];
        let mut has_latent = false;
        for layer in 0..l {
            for (side, buf) in [(Side::K, &mut k_lat), (Side::V, &mut v_lat)] {
                if let StoredRows::Latent(rows) = self.cache.stored_rows(cache_id, layer, side)? {
                    has_latent = true;
                    for t in 0..len {
                        buf[layer * s * dl + t * dl..][..dl]
                            .copy_from_slice(&rows[t * dl..(t + 1) * dl]);
                    }
                }
            }
        }
        let (k_rec, v_rec) = if has_latent {
            self.store.insert("k_lat", Tensor::f32(vec![l, s, dl], k_lat));
            self.store.insert("v_lat", Tensor::f32(vec![l, s, dl], v_lat));
            let entry = format!("{}_decode_kv", self.model);
            let out = self.engine.execute(&entry, &self.store)?;
            (
                out[0].1.as_f32()?.to_vec(),
                out[1].1.as_f32()?.to_vec(),
            )
        } else {
            (vec![0.0; l * s * kvd], vec![0.0; l * s * kvd])
        };

        // pass 2: assemble effective rows layer-by-layer (aliases read the
        // already-assembled previous layer)
        let dh = self.spec.d_head;
        let (reuse_k, reuse_v) = {
            let (rk, rv) = self.cache.reuse_masks();
            (rk.clone(), rv.clone())
        };
        let mut eff = EffBuf {
            k: vec![0.0; l * s * kvd],
            v: vec![0.0; l * s * kvd],
        };
        for layer in 0..l {
            for (side, out_buf, rec, reuse) in [
                (Side::K, 0usize, &k_rec, &reuse_k),
                (Side::V, 1, &v_rec, &reuse_v),
            ] {
                let stored = self.cache.stored_rows(cache_id, layer, side)?;
                let (dst_all, src_prev): (&mut Vec<f32>, Vec<f32>) = if out_buf == 0 {
                    let prev = if layer > 0 {
                        eff.k[(layer - 1) * s * kvd..layer * s * kvd].to_vec()
                    } else {
                        vec![0.0; s * kvd]
                    };
                    (&mut eff.k, prev)
                } else {
                    let prev = if layer > 0 {
                        eff.v[(layer - 1) * s * kvd..layer * s * kvd].to_vec()
                    } else {
                        vec![0.0; s * kvd]
                    };
                    (&mut eff.v, prev)
                };
                let dst = &mut dst_all[layer * s * kvd..(layer + 1) * s * kvd];
                match stored {
                    StoredRows::Alias => {
                        dst[..len * kvd].copy_from_slice(&src_prev[..len * kvd]);
                    }
                    StoredRows::Latent(_) => {
                        for t in 0..len {
                            dst[t * kvd..(t + 1) * kvd]
                                .copy_from_slice(&rec[layer * s * kvd + t * kvd..][..kvd]);
                        }
                        // reused heads override the reconstruction
                        for (h, &r) in reuse[layer].iter().enumerate() {
                            if r {
                                for t in 0..len {
                                    dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                                        .copy_from_slice(
                                            &src_prev[t * kvd + h * dh..t * kvd + (h + 1) * dh],
                                        );
                                }
                            }
                        }
                    }
                    StoredRows::Heads(rows, heads) => {
                        let epr = heads.len() * dh;
                        for t in 0..len {
                            for (slot, &h) in heads.iter().enumerate() {
                                dst[t * kvd + h * dh..t * kvd + (h + 1) * dh].copy_from_slice(
                                    &rows[t * epr + slot * dh..t * epr + (slot + 1) * dh],
                                );
                            }
                        }
                        for (h, &r) in reuse[layer].iter().enumerate() {
                            if r {
                                for t in 0..len {
                                    dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                                        .copy_from_slice(
                                            &src_prev[t * kvd + h * dh..t * kvd + (h + 1) * dh],
                                        );
                                }
                            }
                        }
                    }
                }
            }
        }
        self.eff.insert(cache_id, eff);
        Ok(())
    }

    /// One batched decode round over the given active sequences.
    fn decode_round(&mut self, active: &mut [ActiveSeq]) -> Result<()> {
        let live: Vec<usize> = (0..active.len()).filter(|&i| !active[i].done).collect();
        if live.is_empty() {
            return Ok(());
        }
        if self.cfg.per_step_reconstruct {
            for &i in &live {
                self.rebuild_effective(active[i].cache_id)?;
            }
        }
        let t0 = Instant::now();
        let b = *self
            .decode_batches
            .iter()
            .find(|&&b| b >= live.len())
            .unwrap_or(self.decode_batches.last().unwrap());
        let rows = live.len().min(b);
        let (l, s, kvd, dl, v) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
            self.spec.vocab,
        );
        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        // recycle staging buffers across rounds: steal the previous
        // round's tensors back out of the store instead of allocating
        // fresh multi-MB vectors every round
        let need = b * l * s * kvd;
        let mut steal = |name: &str, fallback: &mut Vec<f32>| -> Vec<f32> {
            let mut data = std::mem::take(fallback);
            if let Ok(t) = self.store.get_mut(name) {
                let old = std::mem::replace(
                    t,
                    Tensor::F32 {
                        shape: vec![0],
                        data: Vec::new(),
                    },
                );
                if let Tensor::F32 { data: d, .. } = old {
                    data = d;
                }
            }
            data.resize(need, 0.0);
            data
        };
        let mut k_cache = steal("k_cache", &mut self.kc_buf);
        let mut v_cache = steal("v_cache", &mut self.vc_buf);
        for (slot, &i) in live.iter().take(rows).enumerate() {
            let seq = &active[i];
            token[slot] = seq.next_token as i32;
            pos[slot] = seq.pos as i32;
            let eff = &self.eff[&seq.cache_id];
            k_cache[slot * l * s * kvd..(slot + 1) * l * s * kvd].copy_from_slice(&eff.k);
            v_cache[slot * l * s * kvd..(slot + 1) * l * s * kvd].copy_from_slice(&eff.v);
        }
        for slot in rows..b {
            k_cache[slot * l * s * kvd..(slot + 1) * l * s * kvd].fill(0.0);
            v_cache[slot * l * s * kvd..(slot + 1) * l * s * kvd].fill(0.0);
        }
        self.store.insert("token", Tensor::i32(vec![b], token));
        self.store.insert("pos", Tensor::i32(vec![b], pos));
        self.store
            .insert("k_cache", Tensor::f32(vec![b, l, s, kvd], k_cache));
        self.store
            .insert("v_cache", Tensor::f32(vec![b, l, s, kvd], v_cache));
        let entry = format!("{}_decode_step_b{}", self.model, b);
        let out = self.engine.execute(&entry, &self.store)?;
        let round = t0.elapsed();
        self.metrics.decode_rounds += 1;
        self.metrics.decode_slots_used += rows as u64;
        self.metrics.decode_slots_total += b as u64;
        self.metrics.decode_step_latency.record(round);

        let logits = out[0].1.as_f32()?;
        let k_lat = out[1].1.as_f32()?;
        let v_lat = out[2].1.as_f32()?;
        let k_raw = out[3].1.as_f32()?;
        let v_raw = out[4].1.as_f32()?;
        let k_eff = out[5].1.as_f32()?;
        let v_eff = out[6].1.as_f32()?;

        for (slot, &i) in live.iter().take(rows).enumerate() {
            let sampling = active[i].req.sampling;
            let next = self.sample(&logits[slot * v..(slot + 1) * v], sampling);
            let seq = &mut active[i];
            self.cache.append_token(
                seq.cache_id,
                &k_lat[slot * l * dl..(slot + 1) * l * dl],
                &v_lat[slot * l * dl..(slot + 1) * l * dl],
                &k_raw[slot * l * kvd..(slot + 1) * l * kvd],
                &v_raw[slot * l * kvd..(slot + 1) * l * kvd],
            )?;
            let eff = self.eff.get_mut(&seq.cache_id).unwrap();
            for layer in 0..l {
                let dst = layer * s * kvd + seq.pos * kvd;
                eff.k[dst..dst + kvd]
                    .copy_from_slice(&k_eff[slot * l * kvd + layer * kvd..][..kvd]);
                eff.v[dst..dst + kvd]
                    .copy_from_slice(&v_eff[slot * l * kvd + layer * kvd..][..kvd]);
            }
            seq.pos += 1;
            seq.output.push(next);
            seq.next_token = next;
            seq.decode_time += round;
            seq.generated_check(self.spec.max_seq);
            self.metrics.tokens_generated += 1;
        }
        Ok(())
    }

    fn retire(&mut self, seq: ActiveSeq) -> GenResponse {
        self.cache.free_sequence(seq.cache_id);
        self.eff.remove(&seq.cache_id);
        self.metrics.requests_completed += 1;
        GenResponse {
            id: seq.req.id,
            prompt_tokens: seq.req.prompt.len().min(self.spec.max_seq - 1),
            generated_tokens: seq.output.len(),
            output: seq.output,
            prefill_latency: seq.prefill_end - seq.prefill_start,
            decode_latency: seq.decode_time,
            queue_latency: seq.prefill_start - seq.enqueued,
        }
    }

    /// Serve a workload to completion with continuous batching: admit new
    /// prefills whenever a decode slot frees up.
    pub fn run(&mut self, requests: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let t0 = Instant::now();
        let enqueued = Instant::now();
        let mut waiting: VecDeque<GenRequest> = requests.into();
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut done: Vec<GenResponse> = Vec::new();
        loop {
            while active.len() < self.cfg.max_batch {
                match waiting.pop_front() {
                    Some(req) => active.push(self.prefill(req, enqueued)?),
                    None => break,
                }
            }
            if active.is_empty() {
                break;
            }
            self.decode_round(&mut active)?;
            let mut i = 0;
            while i < active.len() {
                if active[i].done {
                    let seq = active.swap_remove(i);
                    done.push(self.retire(seq));
                } else {
                    i += 1;
                }
            }
            if active.is_empty() && waiting.is_empty() {
                break;
            }
        }
        self.metrics.wall += t0.elapsed();
        done.sort_by_key(|r| r.id);
        Ok(done)
    }
}

impl ActiveSeq {
    fn generated_check(&mut self, max_seq: usize) {
        let last = *self.output.last().unwrap();
        if self.output.len() >= self.req.max_new_tokens
            || self.pos >= max_seq
            || self.req.stop_byte == Some(last)
        {
            self.done = true;
        }
    }
}
