//! The serving engine: prefill/decode scheduling with continuous batching
//! over the compressed KV cache.
//!
//! Dataflow per the paper's Fig. 1:
//!
//! * **prefill** — admission is *wave-based*: each round's admitted
//!   requests prefill together through the `[B, S]` `{m}_prefill_b`
//!   artifact — **one launch per admission wave** instead of one per
//!   request (`coordinator::prefill::PrefillWave`; ladder down to the
//!   per-request `{m}_prefill` for lone admissions and artifact sets
//!   that predate the batched entry).  Every lane is bit-identical to a
//!   per-request prefill, so wave admission changes launch counts, not
//!   outputs.  Each lane's compressed rows then enter the cache manager
//!   (latents for AE layers, raw or head-subset rows otherwise;
//!   int8-packed when the plan stacks Eq. 4), and — on the resident
//!   path — the lane seeds its decode slot up front
//!   (`SlotArena::seed_slot`).  Under `ServeConfig::prefix_sharing`
//!   (default), admission additionally dedups across requests: a lane
//!   whose clamped prompt was already computed admits with **zero**
//!   launches (template replay + refcounted prefix chain, DESIGN.md
//!   §6), and launched lanes store each block-aligned leading chunk at
//!   most once — launches and prefix cache bytes ∝ distinct prompts.
//! * **decode** — active sequences are batched each round through
//!   `{m}_decode_step_b{B}`; the artifact receives the *effective*
//!   (decoded + reuse-resolved) cache, appends the new token's raw row
//!   in-graph, and returns latent/raw/effective rows for storage.
//!
//! The effective cache is per-sequence scratch owned by an
//! `EffectiveCache` (coordinator::effective) — the decode-on-retrieval
//! working set.  Two modes:
//!
//! * in-graph (default) — decode_step returns each new token's effective
//!   row and `push_step_row` appends it; the persistent store is still
//!   only compressed rows.
//! * `per_step_reconstruct` — the faithful-paper mode: effective rows
//!   come from the compressed store through the decoder artifacts
//!   (reconstruction on retrieval).  Maintained *incrementally and
//!   batch-first*: each round `BatchedAdvance` packs every live
//!   sequence's pending watermark row into one `[B, L, 1, dl]` staging
//!   tensor and reconstructs all of them with a single
//!   `{m}_decode_kv_bt` call — O(1) decoder launches per round instead
//!   of O(B) (fallback ladder: `decode_kv_t`, then padded `decode_kv`).
//!   `rebuild_full` remains for eviction-resume (tier.rs).
//!
//! Decode-step staging is **store-resident** by default
//! (`ServeConfig::resident_cache`, DESIGN.md §3.2): the slotted
//! `k_cache`/`v_cache` regions persist in the `Store` between rounds
//! and only the rows each sequence materialized since the previous
//! round are written into its (stable) slot — O(B·L·kvd) staged bytes
//! per round instead of the legacy full O(B·L·S·kvd) copy, with full
//! slot rebuilds only on slot reassignment, park/resume, and
//! capacity-rung switches (`coordinator::resident::SlotArena`).
//!
//! Under a `cache_budget` the run loop additionally executes the
//! batcher's park/resume plans: over-budget rounds spill the encoded
//! bytes of the sequences with the worst stored-bytes-per-remaining-
//! token ratio to the host tier and bring them back (with a
//! `rebuild_full`) once memory frees (DESIGN.md §4).
//!
//! Failures on any of these paths surface as typed
//! [`ServeError`](super::supervisor::ServeError)s with blast-radius
//! attribution; [`ServingEngine::step_supervised`] retries, degrades,
//! quarantines, or rejects per the taxonomy (DESIGN.md §9).

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::batcher::{plan_parking, plan_resume, plan_round, BatcherConfig};
use super::clock::{Clock, Stamp};
use super::effective::{BatchLatentDecoder, BatchedAdvance, EffectiveCache, LatentDecoder};
use super::metrics::ServeMetrics;
use super::prefill::{PrefillWave, WaveOutput, WavePrefiller, TEMPLATE_BYTE_BUDGET};
use super::request::{GenRequest, GenResponse, Sampling};
use super::resident::{stage_copy_round, SlotArena};
use super::supervisor::{
    seq_err, wave_err, ErrorClass, RecoveryAction, RetryPolicy, ServeError, StepReport,
    SupervisorState,
};
use crate::compress::planner::{to_masks, RuntimeMasks};
use crate::compress::strategy::PlanManifest;
use crate::kvcache::tier::HostTier;
use crate::kvcache::{CacheConfig, CacheManager, Format};
use crate::model::memory::CompressionPlan;
use crate::model::ModelSpec;
use crate::runtime::backend::ExecBackend;
use crate::runtime::{Store, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::time::Duration;

/// Blocks one regional ladder demotion re-encodes at a time: small
/// enough that a single rung-2 action stays O(blocks) work, large
/// enough that sustained pressure frees bytes in few actions.
const DEMOTE_REGION_BLOCKS: usize = 4;

/// Serving engine configuration: the compression plan plus batching,
/// reconstruction, and memory-pressure policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// compression plan every sequence's cache is stored under
    pub plan: CompressionPlan,
    /// concurrent decode sequences targeted by the batcher
    pub max_batch: usize,
    /// sampling seed
    pub seed: u64,
    /// faithful-paper mode: rebuild the effective cache from the
    /// compressed store every decode round
    pub per_step_reconstruct: bool,
    /// device-cache byte budget for admission control and automatic
    /// park/resume: when the projected working set exceeds it, the
    /// batcher parks the lowest-priority live sequences in the host
    /// tier (their actual encoded bytes move; `CacheManager::
    /// extract_sequence_bytes`) and resumes them when memory frees.
    /// None = unlimited (no parking, admission by slots alone).
    pub cache_budget: Option<usize>,
    /// keep the effective k/v cache **store-resident** between decode
    /// rounds (`coordinator::resident::SlotArena`): per round only each
    /// live sequence's new rows are staged — O(B·L·kvd) bytes — instead
    /// of the full O(B·L·S·kvd) per-round copy.  `false` selects the
    /// legacy copy staging, kept as the bitwise reference
    /// (`ServeMetrics::staged_kv_bytes` measures both).
    pub resident_cache: bool,
    /// keep the resident k/v regions **device-resident** between decode
    /// rounds (`runtime::residency`): the engine holds persistent device
    /// buffers for them and each round re-uploads only the dirty row
    /// spans the arena declared — O(B·L·kvd) host→device bytes — instead
    /// of the whole O(B·L·S·kvd) tensor.  `false` forces a full upload
    /// whenever a region's version bumps, kept as the bitwise reference
    /// (`KVCAR_NO_DEVICE_RESIDENCY` forces it process-wide).  Moot when
    /// `resident_cache` is off — copy staging re-inserts whole tensors,
    /// which invalidates the span log every round anyway.
    pub device_residency: bool,
    /// admit each round's wave of requests through one batched
    /// `{m}_prefill_b` launch (when the artifact set has the entry)
    /// instead of one `{m}_prefill` launch per request.  `false` forces
    /// the per-request ladder rung — kept as the launch-count baseline
    /// and bitwise reference (every lane of the batched entry is
    /// bit-identical to a per-request call, so outputs never differ).
    pub batched_prefill: bool,
    /// share prefill work and prefix cache bytes **across requests**
    /// (DESIGN.md §6): requests whose clamped prompt was already
    /// computed admit with zero prefill launches (within-wave dedup +
    /// the planner's prompt-template cache), and launched prompts store
    /// each block-aligned leading chunk at most once in the cache
    /// manager's refcounted prefix trie.  Outputs never differ —
    /// prefill is a pure function of the clamped prompt — so `false`
    /// only serves as the O(requests) launch/byte baseline.
    pub prefix_sharing: bool,
    /// hard byte ceiling on the cache manager's block pool
    /// ([`CacheManager::with_budget`]): allocations past it **fail**,
    /// surfacing at admission as a failed — and transactionally rolled
    /// back — wave.  Distinct from `cache_budget`, the *soft* watermark
    /// that parks sequences through the host tier; the scenario
    /// harness uses this to prove admission-time budget exhaustion
    /// leaks nothing.  `None` = unbounded pool.
    pub pool_budget: Option<usize>,
    /// block encoding for raw (non-latent) stored rows.  `F16` is the
    /// default for new serving configs (the paper's fp16 serving
    /// assumption — half the raw-row bytes).  **Interaction with
    /// `per_step_reconstruct`:** faithful mode re-reads stored raw rows
    /// every round, so f16 makes its outputs diverge from the in-graph
    /// path by rounding; use [`ServeConfig::faithful`] (or set `F32`
    /// here explicitly) when bit-exact faithful reconstruction is
    /// required.  Enabling `per_step_reconstruct` by struct update on
    /// [`ServeConfig::new`] keeps f16 — an intentional opt-in for
    /// measuring the fp16 accuracy cost (the bench's `f16_raw` cases).
    pub raw_format: Format,
    /// deterministic retry/backoff + pressure-ladder hysteresis policy
    /// the supervisor ([`ServingEngine::step_supervised`]) recovers
    /// under.  Backoffs are charged on the serving clock, so under a
    /// virtual clock retry timing is bit-reproducible.
    pub retry: RetryPolicy,
    /// host-byte ceiling on the admission planner's prompt-template
    /// cache (`coordinator::prefill::TemplateCache`): cached prefill
    /// templates evict oldest-first once their summed bytes exceed it.
    /// Defaults to [`TEMPLATE_BYTE_BUDGET`] (64 MiB); the serve CLI
    /// exposes it as `--template-budget`.
    pub template_byte_budget: usize,
    /// adaptive per-layer/per-head/per-row-region compression manifest
    /// (DESIGN.md §11).  When set, the manifest's embedded plan
    /// *replaces* `plan`, its row regions install into the cache
    /// manager's [`CacheConfig::regions`], and the pressure ladder's
    /// demote rung becomes per-region
    /// ([`CacheManager::demote_region`](crate::kvcache::CacheManager::demote_region))
    /// instead of whole-sequence.  `None` — the default, and what
    /// `KVCAR_NO_ADAPTIVE_PLAN=1` forces process-wide — keeps the
    /// legacy single-rung policy, which a uniform manifest is pinned
    /// bitwise-identical to (`tests/adaptive_plan.rs`).
    pub adaptive_plan: Option<PlanManifest>,
}

impl ServeConfig {
    /// Serving defaults for a plan: batch 8, in-graph reconstruction,
    /// no budget, store-resident staging with device-resident delta
    /// uploads, batched admission prefill, cross-request prefix
    /// sharing, f16 raw rows.
    ///
    /// # Examples
    ///
    /// ```
    /// use kvcar::coordinator::ServeConfig;
    /// use kvcar::model::gpt2_774m;
    /// use kvcar::model::memory::CompressionPlan;
    ///
    /// let spec = gpt2_774m();
    /// let cfg = ServeConfig::new(CompressionPlan::ae_first_layers(&spec, 4));
    /// assert!(cfg.resident_cache && cfg.device_residency);
    /// assert!(cfg.batched_prefill && cfg.prefix_sharing);
    /// // the faithful constructor flips reconstruction on *and* pins
    /// // lossless f32 raw rows, so store reads stay bit-exact
    /// let faithful = ServeConfig::faithful(
    ///     CompressionPlan::none(spec.n_layer, spec.n_kv_head),
    /// );
    /// assert!(faithful.per_step_reconstruct);
    /// assert_eq!(faithful.raw_format, kvcar::kvcache::Format::F32);
    /// ```
    pub fn new(plan: CompressionPlan) -> ServeConfig {
        ServeConfig {
            plan,
            max_batch: 8,
            seed: 0,
            per_step_reconstruct: false,
            cache_budget: None,
            resident_cache: true,
            device_residency: true,
            batched_prefill: true,
            prefix_sharing: true,
            pool_budget: None,
            raw_format: Format::F16,
            retry: RetryPolicy::default(),
            template_byte_budget: TEMPLATE_BYTE_BUDGET,
            adaptive_plan: None,
        }
    }

    /// Faithful-paper serving defaults: like [`ServeConfig::new`] but
    /// with `per_step_reconstruct` on **and lossless f32 raw rows**, so
    /// reconstruction from the store is bit-exact against the in-graph
    /// path.  This is the constructor library callers should reach for
    /// when enabling faithful mode — flipping `per_step_reconstruct` on
    /// an f16 config silently trades exactness for bytes.
    pub fn faithful(plan: CompressionPlan) -> ServeConfig {
        ServeConfig {
            per_step_reconstruct: true,
            raw_format: Format::F32,
            ..ServeConfig::new(plan)
        }
    }

    /// Uncompressed plan, slot-only admission, in-graph reconstruction.
    pub fn baseline(spec: &ModelSpec) -> ServeConfig {
        ServeConfig::new(CompressionPlan::none(spec.n_layer, spec.n_kv_head))
    }
}

/// One in-flight sequence in the scheduler's active set (crate-visible
/// so the invariant checker can audit the live set against the cache
/// manager, slot arena, and host tier).
pub(crate) struct ActiveSeq {
    pub(crate) req: GenRequest,
    pub(crate) cache_id: u64,
    /// position the next decode step writes (prompt_len + generated - 1
    /// is the last written; see step accounting in decode_round)
    pub(crate) pos: usize,
    pub(crate) next_token: u8,
    pub(crate) output: Vec<u8>,
    pub(crate) prefill_start: Stamp,
    pub(crate) prefill_end: Stamp,
    pub(crate) decode_time: Duration,
    pub(crate) done: bool,
    /// admission order (monotone): parking victims are chosen
    /// latest-admitted-first, resumes oldest-first
    pub(crate) admit_seq: u64,
    /// spilled to the host tier by admission control; skipped by decode
    /// rounds until resumed
    pub(crate) parked: bool,
}

/// The prefill/decode scheduler: continuous batching over the
/// compressed KV cache, batch-first faithful reconstruction, and
/// automatic park/resume through the host tier under memory pressure.
pub struct ServingEngine<'e> {
    /// execution backend: the PJRT artifact runtime in production, the
    /// deterministic [`crate::runtime::MockEngine`] in the scenario
    /// harness and server tests
    pub engine: &'e mut dyn ExecBackend,
    /// store threading parameters and staging tensors through calls
    pub store: Store,
    /// runtime model dimensions (from the manifest)
    pub spec: ModelSpec,
    /// model name prefix for artifact entry points
    pub model: String,
    /// runtime mask tensors derived from the plan
    pub masks: RuntimeMasks,
    /// compressed per-sequence block store
    pub cache: CacheManager,
    /// serving configuration
    pub cfg: ServeConfig,
    /// latency/throughput/parking counters for the current run
    pub metrics: ServeMetrics,
    /// host tier holding parked sequences' encoded bytes
    pub tier: HostTier,
    /// batch-first faithful-advance planner (shared packing staging
    /// + launch counters)
    pub batched: BatchedAdvance,
    /// wave-based admission planner (prefill ladder + launch counters)
    pub waves: PrefillWave,
    /// owner of the store-resident `k_cache`/`v_cache` staging regions:
    /// stable slot assignment, sync watermarks, dirty-padding bits
    pub arena: SlotArena,
    /// serving clock: wall time by default, virtual (charge-driven,
    /// bit-reproducible) under [`ServingEngine::set_clock`]
    pub(crate) clock: Clock,
    pub(crate) eff: HashMap<u64, EffectiveCache>,
    /// prefix-chain leaves pinned because a router delivered their
    /// content-addressed chunks to this worker (DESIGN.md §10): the pin
    /// keeps the chain resident so "each shared chunk ships to a worker
    /// at most once, ever" stays sound even after every local sharer
    /// retires.  The invariant checker folds these into the derived
    /// refcount audit alongside the admission-template pins.
    pub(crate) migration_pins: Vec<u32>,
    /// supervisor bookkeeping: per-target retry attempts, pressure
    /// rung, calm streak (DESIGN.md §9)
    sup: SupervisorState,
    decode_batches: Vec<usize>,
    admit_counter: u64,
    rng: Rng,
    /// one-shot injected tier faults (scenario harness)
    park_faults: u32,
    resume_faults: u32,
}

impl<'e> ServingEngine<'e> {
    /// Build a serving engine for `model` over an initialized runtime
    /// engine: loads parameters, validates the plan, and derives the
    /// compiled decode batch sizes from the manifest.
    pub fn new(engine: &'e mut dyn ExecBackend, model: &str, mut cfg: ServeConfig) -> Result<Self> {
        let mut store = Store::new();
        engine.load_params(model, &mut store)?;
        let spec = engine.model_spec(model)?;
        // the env kill-switch pins the legacy single-rung policy even
        // when a manifest is configured (CI's legacy-pinning leg),
        // mirroring KVCAR_NO_DEVICE_RESIDENCY below
        let adaptive = cfg
            .adaptive_plan
            .clone()
            .filter(|_| std::env::var("KVCAR_NO_ADAPTIVE_PLAN").is_err());
        if let Some(m) = &adaptive {
            cfg.plan = m.plan.clone();
        }
        cfg.plan
            .validate()
            .map_err(|e| anyhow!("invalid plan: {e}"))?;
        let masks = to_masks(&cfg.plan);
        let decode_batches = engine.decode_batches(model);
        let mut ccfg = CacheConfig::new(spec.clone(), cfg.plan.clone());
        ccfg.raw_format = cfg.raw_format;
        if let Some(m) = &adaptive {
            m.validate(ccfg.block_size)
                .map_err(|e| anyhow!("invalid adaptive plan manifest: {e}"))?;
            ccfg.regions = m.regions.clone();
        }
        let cache = match cfg.pool_budget {
            Some(b) => CacheManager::with_budget(ccfg, b),
            None => CacheManager::new(ccfg),
        };
        let seed = cfg.seed;
        // re-derived per construction (not &&= — engines are reused
        // across serving configs); the env kill-switch stays authoritative
        engine.set_device_residency(
            cfg.device_residency && std::env::var("KVCAR_NO_DEVICE_RESIDENCY").is_err(),
        );
        let mut s = ServingEngine {
            engine,
            store,
            spec,
            model: model.to_string(),
            masks,
            cache,
            cfg,
            metrics: ServeMetrics::default(),
            tier: HostTier::new(),
            batched: BatchedAdvance::new(),
            waves: PrefillWave::new(),
            arena: SlotArena::new(),
            clock: Clock::wall(),
            eff: HashMap::new(),
            migration_pins: Vec::new(),
            sup: SupervisorState::default(),
            decode_batches,
            admit_counter: 0,
            rng: Rng::new(seed ^ 0x5E47E),
            park_faults: 0,
            resume_faults: 0,
        };
        s.waves.set_template_byte_budget(s.cfg.template_byte_budget);
        s.apply_masks();
        Ok(s)
    }

    /// Replace the serving clock.  With a virtual clock every latency,
    /// TTFT, and throughput figure becomes a pure function of the
    /// workload and the clock's [`super::clock::CostModel`] —
    /// bit-reproducible run over run (the scenario harness's
    /// determinism contract).  Arrival stamps on waiting requests then
    /// also *gate* admission: a request is not schedulable before its
    /// trace arrival.
    pub fn set_clock(&mut self, clock: Clock) {
        self.clock = clock;
    }

    /// Arm one-shot tier faults: the next `park` park attempts and the
    /// next `resume` resume attempts fail with an injected error (then
    /// the counters drain).  A park fault fires *before* any state
    /// moves; a resume fault fires after the tier handed its bytes back
    /// and exercises the repark rollback — either way the scheduler's
    /// accounting must stay coherent, which the invariant checker
    /// asserts after the error surfaces.
    pub fn inject_tier_faults(&mut self, park: u32, resume: u32) {
        self.park_faults = park;
        self.resume_faults = resume;
    }

    fn apply_masks(&mut self) {
        let (l, h) = (self.spec.n_layer, self.spec.n_kv_head);
        self.store
            .insert("compress", Tensor::f32(vec![l], self.masks.compress.clone()));
        self.store
            .insert("reuse_k", Tensor::f32(vec![l, h], self.masks.reuse_k.clone()));
        self.store
            .insert("reuse_v", Tensor::f32(vec![l, h], self.masks.reuse_v.clone()));
        self.store
            .insert("quant", Tensor::scalar_f32(self.masks.quant));
    }

    fn sample(&mut self, logits: &[f32], sampling: Sampling) -> u8 {
        match sampling {
            Sampling::Greedy => {
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &v) in logits.iter().enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                best as u8
            }
            Sampling::Temperature(t) => {
                let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = logits
                    .iter()
                    .map(|&v| (((v - m) / t.max(1e-4)) as f64).exp())
                    .collect();
                self.rng.weighted(&weights) as u8
            }
        }
    }

    /// Smallest compiled decode batch covering `live` concurrent
    /// sequences (the rung `decode_round` runs at and `seed_slot`
    /// seeds at — both must agree or seeded slots rebuild).
    fn decode_rung(&self, live: usize) -> usize {
        *self
            .decode_batches
            .iter()
            .find(|&&b| b >= live)
            .unwrap_or_else(|| {
                self.decode_batches
                    .last()
                    .expect("manifest provides at least one decode batch")
            })
    }

    /// Admit one wave of requests: prefill them together (one
    /// `{m}_prefill_b` launch per capacity chunk when available —
    /// `coordinator::prefill`), sample each lane's first token, and on
    /// the resident path seed each new sequence's decode slot from its
    /// lane.  `live_before` is the pre-wave live-set size, from which
    /// the next decode round's capacity rung is projected so slot
    /// seeding lands on the rung the round will actually run at.
    fn admit_wave(
        &mut self,
        reqs: Vec<GenRequest>,
        live_before: usize,
    ) -> Result<Vec<ActiveSeq>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now();
        let launches_before = self.waves.stats.launches;
        let shared_before = (
            self.waves.stats.shared_admissions,
            self.waves.stats.shared_rows,
        );
        let rows_total: usize = reqs
            .iter()
            .map(|r| r.prompt.len().clamp(1, self.spec.max_seq - 1))
            .sum();
        let prompts: Vec<&[u8]> = reqs.iter().map(|r| r.prompt.as_slice()).collect();
        let mut runner = ArtifactPrefiller {
            engine: &mut *self.engine,
            store: &mut self.store,
            model: &self.model,
            spec: &self.spec,
            batched: self.cfg.batched_prefill,
            metrics: &mut self.metrics,
        };
        let admitted = self.waves.admit_wave(
            &mut self.cache,
            &mut self.eff,
            &self.spec,
            !self.cfg.per_step_reconstruct,
            self.cfg.prefix_sharing,
            &prompts,
            &mut runner,
        )?;
        self.metrics.shared_admissions +=
            self.waves.stats.shared_admissions - shared_before.0;
        let shared_rows = self.waves.stats.shared_rows - shared_before.1;
        self.metrics.shared_prefix_rows += shared_rows;
        let launches = self.waves.stats.launches - launches_before;
        // virtual clock: price the wave by what actually launched —
        // shared-prefix rows cost no prefill work (that IS the sharing
        // win, and it must show up in virtual TTFT too)
        let costs = self.clock.costs();
        self.clock.charge(costs.prefill_cost(
            launches,
            rows_total.saturating_sub(shared_rows as usize),
        ));
        let now = self.clock.now();
        let arrivals: Vec<Stamp> = reqs.iter().map(|r| r.arrival.unwrap_or(t0)).collect();
        self.metrics.record_wave(t0, now, &arrivals, launches);
        let mut out = Vec::with_capacity(reqs.len());
        for (req, lane) in reqs.into_iter().zip(admitted) {
            let plen = req.prompt.len().clamp(1, self.spec.max_seq - 1);
            let first = self.sample(&lane.logits, req.sampling);
            self.metrics.prefill_latency.record(now.saturating_since(t0));
            self.metrics.tokens_generated += 1; // prefill samples the first token
            self.admit_counter += 1;
            let mut seq = ActiveSeq {
                cache_id: lane.cache_id,
                pos: plen,
                next_token: first,
                output: vec![first],
                prefill_start: t0,
                prefill_end: now,
                decode_time: Duration::ZERO,
                done: false,
                admit_seq: self.admit_counter,
                parked: false,
                req,
            };
            seq.generated_check(self.spec.max_seq);
            out.push(seq);
        }
        // resident path, in-graph mode: seed each surviving lane's
        // decode slot now, while its effective rows are hot — the next
        // round then syncs zero bytes for it instead of a full rebuild.
        // (Faithful mode has nothing to seed: the watermark is 0 and
        // the first round reconstructs the prompt from the store.)
        if self.cfg.resident_cache && !self.cfg.per_step_reconstruct {
            let live_after = live_before + out.iter().filter(|s| !s.done).count();
            if live_after > 0 {
                let b = self.decode_rung(live_after);
                let dims = (self.spec.n_layer, self.spec.max_seq, self.spec.kv_dim());
                for seq in out.iter().filter(|s| !s.done) {
                    let eff = self
                        .eff
                        .get(&seq.cache_id)
                        .expect("admitted sequence must have an effective cache");
                    let upto = self.cache.decoded_upto(seq.cache_id).unwrap_or(0);
                    self.arena.seed_slot(
                        &mut self.store,
                        (seq.cache_id, upto),
                        eff,
                        b,
                        dims,
                        &mut self.metrics,
                    )?;
                }
            }
        }
        Ok(out)
    }

    /// Faithful full reconstruction of one sequence's effective cache
    /// from the compressed store — the eviction-resume path.  Per-step
    /// maintenance goes through `EffectiveCache::advance` instead
    /// (incremental, O(new rows)).
    pub fn rebuild_effective(&mut self, cache_id: u64) -> Result<()> {
        let spec = &self.spec;
        let eff = self
            .eff
            .entry(cache_id)
            .or_insert_with(|| EffectiveCache::new(spec));
        let mut dec = ArtifactDecoder {
            engine: &mut *self.engine,
            store: &mut self.store,
            model: &self.model,
            spec: &self.spec,
            metrics: &mut self.metrics,
        };
        eff.rebuild_full(&mut self.cache, cache_id, &mut dec)?;
        Ok(())
    }

    /// Evict a sequence's working set: drop the effective-cache scratch
    /// and spill its **actual encoded block bytes** to the host tier
    /// (`CacheManager::extract_sequence_bytes` — the device pool really
    /// shrinks, and the transfer cost is paid on the real compressed
    /// volume, which is the paper's composition-with-offloading claim).
    pub fn park_sequence(&mut self, cache_id: u64) -> Result<Duration> {
        if self.park_faults > 0 {
            // injected before any state moves: a failed park must leave
            // the sequence fully live (scenario-harness fault lane)
            self.park_faults -= 1;
            return Err(anyhow!("injected park fault for sequence {cache_id}"));
        }
        anyhow::ensure!(
            !self.tier.is_parked(cache_id),
            "sequence {cache_id} already parked (double-evict would corrupt tier accounting)"
        );
        self.eff.remove(&cache_id);
        self.arena.release(cache_id); // slot frees; padding zeroed once
        let bytes = self.cache.extract_sequence_bytes(cache_id)?;
        Ok(self.tier.park(cache_id, bytes))
    }

    /// Resume a parked sequence: pay the transfer on the real encoded
    /// bytes, **verify their park-time checksum** (a mismatch is a typed
    /// [`ErrorClass::Corruption`] error — the entry is dropped, the
    /// supervisor quarantines the sequence; corrupted bytes never reach
    /// the device cache), restore them bit-identically into fresh device
    /// blocks, and rebuild the effective cache in full (`rebuild_full`)
    /// from the compressed store.
    pub fn resume_sequence(&mut self, cache_id: u64) -> Result<Duration> {
        let (bytes, cost) = match self.tier.unpark_verified(cache_id) {
            Ok(Some(x)) => x,
            Ok(None) => return Err(anyhow!("sequence {cache_id} not parked")),
            // checksum mismatch: classified Corruption by message, and
            // sequence-attributed so recovery evicts exactly this one
            Err(e) => {
                self.metrics.checksum_failures = self.tier.stats.checksum_failures;
                return Err(seq_err(e, cache_id));
            }
        };
        if self.resume_faults > 0 {
            // injected between unpark and restore: exercises the repark
            // rollback, after which the tier must account the sequence
            // exactly as before the attempt
            self.resume_faults -= 1;
            self.tier.repark(cache_id, bytes);
            return Err(seq_err(
                anyhow!("injected resume fault for sequence {cache_id}"),
                cache_id,
            ));
        }
        if let Err(e) = self.cache.restore_sequence_bytes(cache_id, &bytes) {
            // undo: payload survives and the tier stats are reversed, so
            // the failed attempt leaves no phantom transfer accounting
            self.tier.repark(cache_id, bytes);
            return Err(seq_err(e, cache_id));
        }
        self.rebuild_effective(cache_id)
            .map_err(|e| seq_err(e, cache_id))?;
        Ok(cost)
    }

    // ------------------------------------------------------------------
    // cross-worker migration support (coordinator::migrate drives these;
    // DESIGN.md §10)
    // ------------------------------------------------------------------

    /// Next admission ordinal — migrated-in sequences re-enter this
    /// worker's park/resume priority order as its newest admission.
    pub(crate) fn next_admit_seq(&mut self) -> u64 {
        self.admit_counter += 1;
        self.admit_counter
    }

    /// Drop supervisor retry bookkeeping for a sequence that left this
    /// worker (its retry budget must not leak onto an unrelated target
    /// that later reuses the id).
    pub(crate) fn clear_supervision(&mut self, cache_id: u64, req_id: u64) {
        self.sup.clear_id(cache_id);
        self.sup.clear_id(req_id);
    }

    /// One batched decode round over the given active sequences (parked
    /// sequences sit out until admission control resumes them).
    fn decode_round(&mut self, active: &mut [ActiveSeq]) -> Result<()> {
        let live: Vec<usize> = (0..active.len())
            .filter(|&i| !active[i].done && !active[i].parked)
            .collect();
        if live.is_empty() {
            return Ok(());
        }
        // the round timer starts before reconstruction so the measured
        // decode_step_latency includes the retrieval work the incremental
        // path optimizes (BENCH_decode_hotpath.json tracks this number)
        let t0 = self.clock.now();
        if self.cfg.per_step_reconstruct {
            // batch-first incremental faithful reconstruction: every live
            // sequence's pending watermark row is packed into one
            // [B, L, 1, dl] staging tensor and decoded with a single
            // decoder call per round (O(1) launches instead of O(B));
            // bulk pending ranges (prompt after prefill, resume) fall
            // back to the per-sequence ladder inside BatchedAdvance
            let ids: Vec<u64> = live.iter().map(|&i| active[i].cache_id).collect();
            let mut dec = ArtifactDecoder {
                engine: &mut *self.engine,
                store: &mut self.store,
                model: &self.model,
                spec: &self.spec,
                metrics: &mut self.metrics,
            };
            self.batched
                .advance_round(&mut self.cache, &mut self.eff, &ids, &mut dec)?;
        }
        let b = self.decode_rung(live.len());
        let rows = live.len().min(b);
        let (l, s, kvd, dl, v) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
            self.spec.vocab,
        );
        // stage the effective k/v cache.  Resident path (default): the
        // slotted [b, L, S, kvd] regions persist in the store between
        // rounds, slot assignment is stable, and only each sequence's
        // rows past its sync watermark move — O(new rows) staged bytes
        // per round instead of the full O(B·L·S·kvd) copy.  The copy
        // path remains as the bitwise reference.
        let participants: Vec<u64> = live
            .iter()
            .take(rows)
            .map(|&i| active[i].cache_id)
            .collect();
        if self.cfg.resident_cache {
            let marks: Vec<(u64, usize)> = participants
                .iter()
                .map(|&id| (id, self.cache.decoded_upto(id).unwrap_or(0)))
                .collect();
            self.arena.stage_round(
                &mut self.store,
                &marks,
                &self.eff,
                b,
                (l, s, kvd),
                &mut self.metrics,
            )?;
        } else {
            stage_copy_round(
                &mut self.store,
                &self.eff,
                &participants,
                b,
                (l, s, kvd),
                &mut self.metrics,
            )?;
        }
        // each participant's batch slot: arena-assigned (stable across
        // rounds) on the resident path, enumeration order on the copy
        // path.  token/pos and the output unpack below index by slot.
        let slots: Vec<usize> = if self.cfg.resident_cache {
            participants
                .iter()
                .map(|&id| {
                    self.arena
                        .slot_of(id)
                        .expect("staged sequence must hold a slot")
                })
                .collect()
        } else {
            (0..rows).collect()
        };
        {
            let token = self.store.insert_view_i32("token", vec![b]);
            token.fill(0);
            for (&slot, &i) in slots.iter().zip(&live[..rows]) {
                token[slot] = active[i].next_token as i32;
            }
        }
        {
            let pos = self.store.insert_view_i32("pos", vec![b]);
            pos.fill(0);
            for (&slot, &i) in slots.iter().zip(&live[..rows]) {
                pos[slot] = active[i].pos as i32;
            }
        }
        let entry = format!("{}_decode_step_b{}", self.model, b);
        // attribute a failed batch launch to its lead participant: the
        // supervisor retries the round, and once retries run out evicts
        // one deterministic victim instead of the whole batch
        let lead = participants.first().copied().unwrap_or(0);
        let out = self
            .engine
            .execute(&entry, &self.store)
            .map_err(|e| seq_err(e, lead))?;
        let costs = self.clock.costs();
        self.clock.charge(costs.decode_cost(rows));
        let round = self.clock.now().saturating_since(t0);
        self.metrics.decode_rounds += 1;
        self.metrics.decode_slots_used += rows as u64;
        self.metrics.decode_slots_total += b as u64;
        self.metrics.decode_step_latency.record(round);

        let logits = out[0].1.as_f32()?;
        let k_lat = out[1].1.as_f32()?;
        let v_lat = out[2].1.as_f32()?;
        let k_raw = out[3].1.as_f32()?;
        let v_raw = out[4].1.as_f32()?;
        let k_eff = out[5].1.as_f32()?;
        let v_eff = out[6].1.as_f32()?;

        for (idx, &i) in live.iter().take(rows).enumerate() {
            let slot = slots[idx];
            let sampling = active[i].req.sampling;
            let next = self.sample(&logits[slot * v..(slot + 1) * v], sampling);
            let seq = &mut active[i];
            let cid = seq.cache_id;
            self.cache
                .append_token(
                    cid,
                    &k_lat[slot * l * dl..(slot + 1) * l * dl],
                    &v_lat[slot * l * dl..(slot + 1) * l * dl],
                    &k_raw[slot * l * kvd..(slot + 1) * l * kvd],
                    &v_raw[slot * l * kvd..(slot + 1) * l * kvd],
                )
                .map_err(|e| seq_err(e, cid))?;
            if !self.cfg.per_step_reconstruct {
                // in-graph mode: the artifact returned the new token's
                // exact effective rows; append them and move the
                // watermark.  Faithful mode leaves the watermark behind
                // so the next round's advance() reconstructs this row
                // from the compressed store instead.
                let eff = self.eff.get_mut(&cid).ok_or_else(|| {
                    seq_err(anyhow!("effective cache missing for sequence {cid}"), cid)
                })?;
                eff.push_step_row(
                    &mut self.cache,
                    seq.cache_id,
                    seq.pos,
                    &k_eff[slot * l * kvd..(slot + 1) * l * kvd],
                    &v_eff[slot * l * kvd..(slot + 1) * l * kvd],
                );
            }
            seq.pos += 1;
            seq.output.push(next);
            seq.next_token = next;
            seq.decode_time += round;
            seq.generated_check(self.spec.max_seq);
            self.metrics.tokens_generated += 1;
        }
        Ok(())
    }

    fn retire(&mut self, seq: ActiveSeq) -> GenResponse {
        self.cache.free_sequence(seq.cache_id);
        self.eff.remove(&seq.cache_id);
        self.arena.release(seq.cache_id); // slot frees; padding zeroed once
        self.metrics.requests_completed += 1;
        GenResponse {
            id: seq.req.id,
            prompt_tokens: seq.req.prompt.len().min(self.spec.max_seq - 1),
            generated_tokens: seq.output.len(),
            output: seq.output,
            prefill_latency: seq.prefill_end - seq.prefill_start,
            decode_latency: seq.decode_time,
            // the request's own arrival stamp: staggered arrivals get
            // their real waits, not a shared run-start timestamp
            queue_latency: seq
                .prefill_start
                .saturating_since(seq.req.arrival.unwrap_or(seq.prefill_start)),
            error: None,
        }
    }

    /// Device bytes held by live (unparked) sequences, plus the shared
    /// prefix store counted **once** (its chunks are refcounted across
    /// sequences, so summing them per sequence would overstate the
    /// budget; per-sequence park victims still free only their own
    /// suffix bytes, which is what `seq_stored_bytes` measures).
    pub(crate) fn live_cache_bytes(&self, active: &[ActiveSeq]) -> usize {
        active
            .iter()
            .filter(|s| !s.parked)
            .map(|s| self.cache.seq_stored_bytes(s.cache_id))
            .sum::<usize>()
            + self.cache.prefix_stats().shared_bytes
    }

    /// Worst-case device-cache growth of one sequence across one round,
    /// priced at the cache's **actual block formats** — with f16 raw
    /// rows the modeled `round_headroom_bytes` (Eq. 3, f32) would be 2×
    /// the measured `seq_stored_bytes` it is compared against in the
    /// park/resume plans, parking far earlier than the budget requires.
    fn headroom(&self) -> usize {
        self.cache.cfg.bytes_per_token() * self.cache.cfg.block_size
    }

    /// Resume parked sequences that fit under the budget again, oldest
    /// first.  When nothing is running at all, the oldest parked
    /// sequence resumes regardless — something must decode so memory
    /// eventually frees.
    fn resume_under_budget(&mut self, active: &mut [ActiveSeq]) -> Result<()> {
        let Some(budget) = self.cfg.cache_budget else {
            // no cache budget: a parked sequence can only have been
            // force-parked by the pressure ladder.  Resume the oldest
            // once the rung has decayed back to calm (hysteresis keeps
            // this from flapping against the very pressure that parked
            // it), or nothing would ever finish it.
            if self.sup.pressure() == 0 {
                let oldest = active
                    .iter()
                    .filter(|s| s.parked)
                    .min_by_key(|s| s.admit_seq)
                    .map(|s| s.cache_id);
                if let Some(id) = oldest {
                    let cost = self.resume_sequence(id)?;
                    self.clock.charge(cost);
                    active
                        .iter_mut()
                        .find(|s| s.cache_id == id)
                        .expect("resume id comes from the active set")
                        .parked = false;
                    self.metrics.auto_resumes += 1;
                }
            }
            return Ok(());
        };
        let mut parked: Vec<(u64, u64, usize)> = active
            .iter()
            .filter(|s| s.parked)
            .map(|s| {
                (
                    s.admit_seq,
                    s.cache_id,
                    self.tier.parked_bytes(s.cache_id).unwrap_or(0),
                )
            })
            .collect();
        if parked.is_empty() {
            return Ok(());
        }
        parked.sort_by_key(|p| p.0);
        let list: Vec<(u64, usize)> = parked.iter().map(|p| (p.1, p.2)).collect();
        let running = active.iter().filter(|s| !s.parked && !s.done).count();
        let mut resume = plan_resume(
            budget,
            self.headroom(),
            self.live_cache_bytes(active),
            running,
            &list,
        );
        if resume.is_empty() && running == 0 {
            resume.push(list[0].0); // forced: guarantee progress
        }
        for id in resume {
            let cost = self.resume_sequence(id)?;
            self.clock.charge(cost);
            active
                .iter_mut()
                .find(|s| s.cache_id == id)
                .expect("planned resume id comes from the active set")
                .parked = false;
            self.metrics.auto_resumes += 1;
        }
        Ok(())
    }

    /// Park live sequences while the projected next round exceeds the
    /// budget — cost-aware victims (largest stored bytes per remaining
    /// token first, never all of them; `batcher::plan_parking`).  The
    /// victims' encoded bytes move to the host tier.  The shared prefix
    /// store lives in the same budgeted pool but parking cannot shrink
    /// it (chunks stay resident for their other sharers and pinned
    /// templates), so the plan runs against the budget *minus* the
    /// shared bytes — otherwise private rows would be allowed to grow
    /// until shared + private overshoots the operator's budget.
    fn park_under_pressure(&mut self, active: &mut [ActiveSeq]) -> Result<()> {
        let Some(budget) = self.cfg.cache_budget else {
            return Ok(());
        };
        // pressure valve: chains pinned only by cached admission
        // templates (no live sharers) hold device bytes parking cannot
        // reclaim — without this, a template-heavy history could leave
        // the shared store owning the whole budget and park private
        // sequences forever.  Shed oldest templates until the shared
        // store leaves at least half the budget for private rows, and
        // stop as soon as a shed frees nothing: chains kept alive by
        // live sharers survive the unpin (their bytes are genuinely in
        // use), so draining the rest of the cache would only disable
        // zero-launch admission without recovering a byte.
        loop {
            let before = self.cache.prefix_stats().shared_bytes;
            if before <= budget / 2 || !self.waves.shed_oldest_template(&mut self.cache) {
                break;
            }
            if self.cache.prefix_stats().shared_bytes >= before {
                break;
            }
        }
        let budget = budget.saturating_sub(self.cache.prefix_stats().shared_bytes);
        let mut live: Vec<(u64, u64, usize, usize)> = active
            .iter()
            .filter(|s| !s.parked && !s.done)
            .map(|s| {
                (
                    s.admit_seq,
                    s.cache_id,
                    self.cache.seq_stored_bytes(s.cache_id),
                    s.req.max_new_tokens.saturating_sub(s.output.len()).max(1),
                )
            })
            .collect();
        live.sort_by_key(|l| l.0);
        let list: Vec<(u64, usize, usize)> = live.iter().map(|l| (l.1, l.2, l.3)).collect();
        for id in plan_parking(budget, self.headroom(), &list) {
            let cost = self.park_sequence(id).map_err(|e| seq_err(e, id))?;
            self.clock.charge(cost);
            active
                .iter_mut()
                .find(|s| s.cache_id == id)
                .expect("planned park id comes from the active set")
                .parked = true;
            self.metrics.auto_parks += 1;
        }
        Ok(())
    }

    /// Serve a workload to completion with continuous batching: admit
    /// each round's wave of new requests through one batched prefill
    /// launch whenever decode slots free up, and under a cache budget
    /// automatically park/resume sequences through the host tier.
    ///
    /// Convenience wrapper over the resumable loop:
    /// [`ServingEngine::begin`] → [`ServingEngine::step_supervised`]
    /// until drained → [`ServingEngine::finish`] — faults are classified
    /// and recovered (retry/ladder/quarantine) instead of aborting the
    /// run.  The scenario harness drives the pieces itself so it can run
    /// invariant checks between rounds.
    pub fn run(&mut self, requests: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let mut state = self.begin(requests);
        let mut stalled = 0u32;
        loop {
            let rep = self.step_supervised(&mut state);
            // forward-progress valve: a fault the supervisor could not
            // act on (no attribution, nothing to evict) repeated
            // past the retry budget surfaces as a hard error instead of
            // spinning forever
            match (&rep.fault, rep.action) {
                (Some(_), RecoveryAction::None) => stalled += 1,
                _ => stalled = 0,
            }
            if stalled > self.cfg.retry.max_retries {
                let fault = rep.fault.expect("stall counter only advances on faults");
                return Err(fault.into_anyhow());
            }
            if !rep.more {
                break;
            }
        }
        Ok(self.finish(state))
    }

    /// Start a serving run: stamp unstamped requests with the current
    /// clock (trace-replayed requests keep their explicit arrivals) and
    /// snapshot the clock/device-traffic baselines the run's metrics
    /// are deltas against.
    pub fn begin(&mut self, requests: Vec<GenRequest>) -> RunState {
        let t0 = self.clock.now();
        let mut waiting: VecDeque<GenRequest> = requests.into();
        for r in waiting.iter_mut() {
            r.arrival.get_or_insert(t0);
        }
        let bcfg = BatcherConfig {
            max_batch: self.cfg.max_batch,
            decode_batches: self.decode_batches.clone(),
            cache_budget: self.cfg.cache_budget,
        };
        RunState {
            waiting,
            active: Vec::new(),
            done: Vec::new(),
            bcfg,
            t0,
            dev0: self.device_traffic(),
        }
    }

    /// Execute one scheduler round: resume parked work under the
    /// budget, admit the due wave through one batched prefill launch,
    /// decode every live sequence once, park under pressure, and retire
    /// finished sequences.  Returns whether work remains.
    ///
    /// **Transactional on error:** a failed admission wave pushes its
    /// requests back to the front of the queue (the wave itself already
    /// rolled back its cache state — `PrefillWave::admit_wave` frees
    /// every sequence it created), and failed decode/park/resume rounds
    /// mutate nothing a later round cannot retry — which is exactly
    /// what the scenario harness's invariant checks assert after every
    /// injected fault.
    pub fn step(&mut self, state: &mut RunState) -> Result<bool> {
        self.resume_under_budget(&mut state.active)?;
        // under a virtual clock, trace arrivals gate admission: only
        // the FIFO prefix that has actually arrived is schedulable, and
        // an idle scheduler jumps straight to the next arrival (wall
        // clocks keep the old behavior — everything handed in is due)
        let due = if self.clock.is_virtual() {
            let now = self.clock.now();
            let due_prefix = |q: &VecDeque<GenRequest>, at: Stamp| {
                q.iter()
                    .take_while(|r| r.arrival.unwrap_or(at) <= at)
                    .count()
            };
            let mut due = due_prefix(&state.waiting, now);
            if due == 0 && state.active.is_empty() && !state.waiting.is_empty() {
                let next = state.waiting[0].arrival.unwrap_or(now);
                self.clock.advance_to(next);
                due = due_prefix(&state.waiting, self.clock.now());
            }
            due
        } else {
            state.waiting.len()
        };
        // admit through the batcher's tested admission planner
        // (slots + worst-case budget projection); when nothing holds
        // a slot the head request is admitted regardless so an
        // over-budget request still runs
        // plan_round only ever admits a prefix within max_batch, so
        // metadata for the queue head suffices
        let waiting_meta: Vec<(usize, usize)> = state
            .waiting
            .iter()
            .take(due.min(self.cfg.max_batch))
            .map(|r| (r.prompt.len(), r.max_new_tokens))
            .collect();
        let plan = plan_round(
            &state.bcfg,
            &self.spec,
            &self.cfg.plan,
            state.active.len(),
            self.live_cache_bytes(&state.active),
            &waiting_meta,
        );
        let admit = if state.active.is_empty() && due > 0 {
            plan.admit.max(1)
        } else {
            plan.admit
        };
        // the whole wave prefills through one launch (prefill_b)
        let wave: Vec<GenRequest> = state.waiting.drain(..admit).collect();
        let live_before = state
            .active
            .iter()
            .filter(|s| !s.done && !s.parked)
            .count();
        let backup = wave.clone();
        match self.admit_wave(wave, live_before) {
            Ok(admitted) => state.active.extend(admitted),
            Err(e) => {
                // requeue in original order so the failed wave is
                // invisible to scheduling except for the error itself;
                // the error carries the wave ordinal and the lead
                // request id so recovery can reject exactly that one
                // if the fault proves persistent
                let lead = backup.first().map(|r| r.id).unwrap_or(0);
                for r in backup.into_iter().rev() {
                    state.waiting.push_front(r);
                }
                return Err(wave_err(e, self.metrics.prefill_waves + 1, lead));
            }
        }
        if state.active.is_empty() {
            return Ok(!state.waiting.is_empty());
        }
        self.decode_round(&mut state.active)?;
        self.park_under_pressure(&mut state.active)?;
        let mut i = 0;
        while i < state.active.len() {
            if state.active[i].done {
                let seq = state.active.swap_remove(i);
                let resp = self.retire(seq);
                state.done.push(resp);
            } else {
                i += 1;
            }
        }
        Ok(!(state.active.is_empty() && state.waiting.is_empty()))
    }

    /// Close out a run: fold the run's clock and device-traffic deltas
    /// into [`ServeMetrics`] and return the completed responses sorted
    /// by request id.
    pub fn finish(&mut self, state: RunState) -> Vec<GenResponse> {
        self.metrics.wall += self.clock.now().saturating_since(state.t0);
        let dev1 = self.device_traffic();
        let m = &mut self.metrics;
        for (total, at0, at1) in [
            (&mut m.input_bytes, state.dev0.0, dev1.0),
            (&mut m.output_bytes, state.dev0.1, dev1.1),
            (&mut m.resident_bytes_uploaded, state.dev0.2, dev1.2),
            (&mut m.resident_bytes_skipped, state.dev0.3, dev1.3),
            (&mut m.full_uploads, state.dev0.4, dev1.4),
            (&mut m.buffers_evicted, state.dev0.5, dev1.5),
        ] {
            *total += at1 - at0;
        }
        let mut done = state.done;
        done.sort_by_key(|r| r.id);
        done
    }

    /// The engine's cumulative device-traffic counters, snapshotted at
    /// the ends of [`ServingEngine::run`] so the run's delta lands in
    /// [`ServeMetrics`] (the engine may be shared across runs).
    fn device_traffic(&self) -> (u64, u64, u64, u64, u64, u64) {
        let s = self.engine.stats();
        (
            s.input_bytes,
            s.output_bytes,
            s.resident_bytes_uploaded,
            s.resident_bytes_skipped,
            s.full_uploads,
            s.buffers_evicted,
        )
    }

    // ------------------------------------------------------------------
    // fault-tolerant supervisor (DESIGN.md §9)
    // ------------------------------------------------------------------

    /// Current pressure-ladder rung (0 = calm), for the invariant
    /// checker's fingerprints and operator dashboards.
    pub fn pressure(&self) -> u32 {
        self.sup.pressure()
    }

    /// One supervised scheduler round: [`ServingEngine::step`], and on
    /// failure classify the error ([`ServeError::classify`]) and apply
    /// the matching recovery — deterministic retry/backoff for transient
    /// faults, the pressure-degradation ladder for exhaustion, immediate
    /// quarantine for corruption and permanent faults.  Never returns an
    /// error: every failure is absorbed into a [`StepReport`] so the
    /// caller (and the scenario harness) can keep stepping and audit
    /// invariants between rounds.
    pub fn step_supervised(&mut self, state: &mut RunState) -> StepReport {
        match self.step(state) {
            Ok(more) => {
                self.sup.note_clean(&self.cfg.retry);
                StepReport {
                    more,
                    fault: None,
                    action: RecoveryAction::None,
                }
            }
            Err(e) => {
                let fault = ServeError::classify(&e);
                let action = self.recover(state, &fault);
                StepReport {
                    more: !state.is_finished(),
                    fault: Some(fault),
                    action,
                }
            }
        }
    }

    /// Pick and apply the recovery for one classified fault.
    fn recover(&mut self, state: &mut RunState, fault: &ServeError) -> RecoveryAction {
        match fault.class {
            ErrorClass::Transient => self.retry_or_quarantine(state, fault),
            ErrorClass::ResourceExhausted => self.escalate(state, fault),
            // retrying corrupted bytes or a structural failure cannot
            // help: evict the attributed target immediately
            ErrorClass::Corruption | ErrorClass::Permanent => {
                self.quarantine_target(state, fault)
            }
        }
    }

    /// The retry-budget key of a fault: sequence attribution wins over
    /// request attribution (a live sequence is the more specific blast
    /// radius); `None` for a fully unattributed fault.
    fn fault_key(fault: &ServeError) -> Option<(bool, u64)> {
        fault
            .seq
            .map(|s| (false, s))
            .or(fault.req.map(|r| (true, r)))
    }

    /// Transient recovery: charge a deterministic backoff and let the
    /// next round retry, until the target's budget runs out — then
    /// quarantine exactly the attributed target.
    fn retry_or_quarantine(
        &mut self,
        state: &mut RunState,
        fault: &ServeError,
    ) -> RecoveryAction {
        let Some(key) = Self::fault_key(fault) else {
            return RecoveryAction::None;
        };
        let attempt = self.sup.bump(key);
        if attempt <= self.cfg.retry.max_retries {
            let wait = self.cfg.retry.backoff(self.cfg.seed, key.1, attempt);
            self.clock.charge(wait);
            self.metrics.retries += 1;
            self.metrics.backoff += wait;
            return RecoveryAction::Retry {
                attempt,
                backoff: wait,
            };
        }
        self.sup.clear(key);
        self.quarantine_target(state, fault)
    }

    /// Exhaustion recovery: retry under backoff first (pressure is often
    /// transient — a resume burst, one oversized wave), then walk the
    /// degradation ladder one rung at a time: shed a cached prompt
    /// template → demote the fattest sequence to a cheaper storage rung
    /// → force-park a victim → reject/quarantine the attributed target.
    /// Each escalation ratchets [`SupervisorState::pressure`]; the rung
    /// decays only after [`RetryPolicy::calm_rounds`] clean rounds
    /// (hysteresis), so repeated pressure skips straight to the deeper
    /// remedies instead of flapping on the cheap ones.
    fn escalate(&mut self, state: &mut RunState, fault: &ServeError) -> RecoveryAction {
        let key = Self::fault_key(fault).unwrap_or((true, u64::MAX));
        let attempt = self.sup.bump(key);
        if attempt <= self.cfg.retry.max_retries {
            let wait = self.cfg.retry.backoff(self.cfg.seed, key.1, attempt);
            self.clock.charge(wait);
            self.metrics.retries += 1;
            self.metrics.backoff += wait;
            return RecoveryAction::Retry {
                attempt,
                backoff: wait,
            };
        }
        self.sup.clear(key);
        let mut rung = self.sup.pressure().max(1);
        while rung <= 3 {
            self.sup.ratchet(rung);
            match rung {
                1 => {
                    if self.waves.shed_oldest_template(&mut self.cache) {
                        self.metrics.template_sheds += 1;
                        return RecoveryAction::Shed;
                    }
                }
                2 => {
                    if let Some(id) = self.demote_victim(state) {
                        return RecoveryAction::Demote(id);
                    }
                }
                _ => {
                    if let Some(id) = self.park_victim(state) {
                        return RecoveryAction::Park(id);
                    }
                }
            }
            rung += 1;
        }
        self.quarantine_target(state, fault)
    }

    /// Evict the fault's attributed target: quarantine its live
    /// sequence, or reject its not-yet-admitted request; unattributed
    /// faults fall back to the queue head, then the oldest live
    /// sequence, so eviction always relieves *something*.
    fn quarantine_target(&mut self, state: &mut RunState, fault: &ServeError) -> RecoveryAction {
        if let Some(cid) = fault.seq {
            if let Some(i) = state.active.iter().position(|s| s.cache_id == cid) {
                let seq = state.active.swap_remove(i);
                return self.quarantine(state, seq, fault);
            }
        }
        if let Some(rid) = fault.req {
            if let Some(pos) = state.waiting.iter().position(|r| r.id == rid) {
                return self.reject(state, pos, fault);
            }
        }
        if !state.waiting.is_empty() {
            return self.reject(state, 0, fault);
        }
        if !state.active.is_empty() {
            let seq = state.active.swap_remove(0);
            return self.quarantine(state, seq, fault);
        }
        RecoveryAction::None
    }

    /// Quarantine one live sequence: roll its state back across every
    /// layer (host tier, effective cache, slot arena, cache manager,
    /// supervisor bookkeeping) and complete its request with a typed
    /// error response retaining whatever output it produced.  Every
    /// other sequence is untouched — their token streams stay bitwise
    /// identical to the fault-free run.
    fn quarantine(
        &mut self,
        state: &mut RunState,
        seq: ActiveSeq,
        fault: &ServeError,
    ) -> RecoveryAction {
        let cache_id = seq.cache_id;
        self.tier.discard(cache_id);
        self.eff.remove(&cache_id);
        self.arena.release(cache_id);
        self.cache.free_sequence(cache_id);
        self.sup.clear_id(cache_id);
        self.sup.clear_id(seq.req.id);
        self.metrics.quarantines += 1;
        let resp = GenResponse {
            id: seq.req.id,
            prompt_tokens: seq.req.prompt.len().min(self.spec.max_seq - 1),
            generated_tokens: seq.output.len(),
            output: seq.output,
            prefill_latency: seq.prefill_end - seq.prefill_start,
            decode_latency: seq.decode_time,
            queue_latency: seq
                .prefill_start
                .saturating_since(seq.req.arrival.unwrap_or(seq.prefill_start)),
            error: Some(fault.clone().with_seq(cache_id)),
        };
        let req_id = resp.id;
        state.done.push(resp);
        RecoveryAction::Quarantine(req_id)
    }

    /// Reject a queued (not-yet-admitted) request with a typed error
    /// response carrying a retry hint — no sequence state exists yet, so
    /// nothing to roll back.
    fn reject(&mut self, state: &mut RunState, pos: usize, fault: &ServeError) -> RecoveryAction {
        let Some(req) = state.waiting.remove(pos) else {
            return RecoveryAction::None;
        };
        self.sup.clear_id(req.id);
        self.metrics.rejects += 1;
        let now = self.clock.now();
        let mut err = fault.clone().with_req(req.id);
        err.msg
            .push_str(" (rejected pre-admission; safe to retry after backoff)");
        state.done.push(GenResponse {
            id: req.id,
            output: Vec::new(),
            prompt_tokens: 0,
            generated_tokens: 0,
            prefill_latency: Duration::ZERO,
            decode_latency: Duration::ZERO,
            queue_latency: now.saturating_since(req.arrival.unwrap_or(now)),
            error: Some(err),
        });
        RecoveryAction::Reject(req.id)
    }

    /// Degradation rung 2: re-encode the fattest live sequence's stored
    /// blocks to the Int8 rung (`CacheManager::demote_sequence`).  In
    /// in-graph mode the exact effective rows stay resident in the
    /// scratch/arena, so the watermark the demotion reset is restored
    /// and decode keeps consuming the identical rows — stored bytes get
    /// cheaper, outputs stay bitwise unchanged.  Faithful mode leaves
    /// the watermark at 0 by contract: the next round reconstructs from
    /// the demoted store.
    ///
    /// Under an adaptive plan that genuinely partitions the row axis
    /// (`CacheConfig::regions` has more than one region) the rung is
    /// **per-region** instead: the coldest not-yet-int8 block run of
    /// the fattest victim is demoted (`CacheManager::demote_region`),
    /// so one ladder action re-encodes O(block) rows rather than the
    /// whole sequence and repeated pressure walks a sequence
    /// cold-to-hot.  Victims with nothing left to demote are skipped,
    /// exactly like the legacy `seq_demoted` filter.  Uniform
    /// manifests (one open region) keep the whole-sequence rung — they
    /// are pinned bitwise-identical to the legacy path, ladder
    /// trajectory included (`tests/adaptive_plan.rs`).
    fn demote_victim(&mut self, state: &mut RunState) -> Option<u64> {
        if self.cache.cfg.regions.len() <= 1 {
            let victim = state
                .active
                .iter()
                .filter(|s| !s.parked && !s.done && !self.cache.seq_demoted(s.cache_id))
                .max_by_key(|s| (self.cache.seq_stored_bytes(s.cache_id), s.cache_id))
                .map(|s| s.cache_id)?;
            match self.cache.demote_sequence(victim) {
                Ok(freed) if freed > 0 => {
                    self.metrics.demotions += 1;
                    if !self.cfg.per_step_reconstruct {
                        let len = self.cache.seq_len(victim).unwrap_or(0);
                        self.cache.mark_decoded(victim, len);
                    }
                    Some(victim)
                }
                _ => None,
            }
        } else {
            let (victim, (start, end)) = state
                .active
                .iter()
                .filter(|s| !s.parked && !s.done)
                .filter_map(|s| {
                    self.cache
                        .coldest_promotable_region(s.cache_id, DEMOTE_REGION_BLOCKS)
                        .map(|r| (s.cache_id, r))
                })
                .max_by_key(|&(id, _)| (self.cache.seq_stored_bytes(id), id))?;
            match self.cache.demote_region(victim, start, end) {
                Ok(freed) if freed > 0 => {
                    self.metrics.demotions += 1;
                    self.metrics.region_demotions += 1;
                    if !self.cfg.per_step_reconstruct {
                        let len = self.cache.seq_len(victim).unwrap_or(0);
                        self.cache.mark_decoded(victim, len);
                    }
                    Some(victim)
                }
                _ => None,
            }
        }
    }

    /// Degradation rung 3: force-park the fattest live sequence through
    /// the host tier.  Requires at least two live sequences — something
    /// must keep decoding or parked memory never frees.
    fn park_victim(&mut self, state: &mut RunState) -> Option<u64> {
        let live = state
            .active
            .iter()
            .filter(|s| !s.parked && !s.done)
            .count();
        if live < 2 {
            return None;
        }
        let victim = state
            .active
            .iter()
            .filter(|s| !s.parked && !s.done)
            .max_by_key(|s| (self.cache.seq_stored_bytes(s.cache_id), s.cache_id))
            .map(|s| s.cache_id)?;
        match self.park_sequence(victim) {
            Ok(cost) => {
                self.clock.charge(cost);
                state
                    .active
                    .iter_mut()
                    .find(|s| s.cache_id == victim)
                    .expect("victim chosen from the active set")
                    .parked = true;
                self.metrics.auto_parks += 1;
                Some(victim)
            }
            Err(_) => None,
        }
    }
}

/// In-flight state of one serving run, produced by
/// [`ServingEngine::begin`] and advanced one scheduler round at a time
/// by [`ServingEngine::step`].  Owning this separately from the engine
/// is what lets the scenario harness interleave whole-stack invariant
/// checks (and keep stepping past injected faults) between rounds.
pub struct RunState {
    waiting: VecDeque<GenRequest>,
    active: Vec<ActiveSeq>,
    done: Vec<GenResponse>,
    bcfg: BatcherConfig,
    t0: Stamp,
    dev0: (u64, u64, u64, u64, u64, u64),
}

impl RunState {
    /// Requests still queued for admission.
    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently admitted (parked ones included).
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Responses completed so far.
    pub fn n_done(&self) -> usize {
        self.done.len()
    }

    /// Whether the run has fully drained.
    pub fn is_finished(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    /// Drop the queue-head request (the one a persistent admission
    /// fault keeps failing on) and return its id; `None` when the
    /// queue is empty.  The scenario harness's forward-progress valve:
    /// after repeated wave failures the head is rejected rather than
    /// retried forever.
    pub fn reject_head(&mut self) -> Option<u64> {
        self.waiting.pop_front().map(|r| r.id)
    }

    /// The live set, for the invariant checker.
    pub(crate) fn active_seqs(&self) -> &[ActiveSeq] {
        &self.active
    }

    /// Remove one in-flight sequence by cache id — the source half of a
    /// live migration (`coordinator::migrate`).  The caller owns putting
    /// it back (rollback) or committing it to another worker.
    pub(crate) fn take_seq(&mut self, cache_id: u64) -> Option<ActiveSeq> {
        let i = self.active.iter().position(|s| s.cache_id == cache_id)?;
        Some(self.active.swap_remove(i))
    }

    /// Insert an in-flight sequence — the destination half of a live
    /// migration, and the source-side rollback of a failed one.
    pub(crate) fn push_seq(&mut self, seq: ActiveSeq) {
        self.active.push(seq);
    }

    /// Hand back every not-yet-admitted request (FIFO order) — the
    /// drain hook: a draining worker's queue re-routes to its peers.
    pub(crate) fn drain_waiting(&mut self) -> Vec<GenRequest> {
        self.waiting.drain(..).collect()
    }

    /// Append a re-routed request — a drained worker's queued requests
    /// land here on its peers, keeping their original arrival stamps.
    pub(crate) fn push_waiting(&mut self, req: GenRequest) {
        self.waiting.push_back(req);
    }

    /// The admission queue, for placement/conservation audits.
    pub(crate) fn waiting_requests(&self) -> &VecDeque<GenRequest> {
        &self.waiting
    }

    /// Completed responses so far, for the invariant checker's
    /// conservation laws.
    pub(crate) fn done_responses(&self) -> &[GenResponse] {
        &self.done
    }
}

/// `LatentDecoder`/`BatchLatentDecoder` over the AOT decoder artifacts.
///
/// Fallback ladder (most to least specific):
///
/// 1. `{m}_decode_kv_bt` — [B, L, 1, dl] cross-sequence batched decode:
///    one launch reconstructs every live sequence's pending row
///    (unused slots zero-padded up to the compiled B).
/// 2. `{m}_decode_kv_t` — [L, 1, dl] token-granular single-sequence
///    decode (constant work per step).
/// 3. `{m}_decode_kv` — [L, S, dl] full-sequence signature, zero-padded:
///    bulk ranges (prompt reconstruction, eviction-resume) and artifact
///    sets built before the granular entries existed.
///
/// Every rung is staged through `Store::insert_view`, so per-round
/// packing reuses the same resident buffers (no allocations on the hot
/// path) and the engine's version-keyed device cache re-uploads only
/// what changed.
struct ArtifactDecoder<'a> {
    engine: &'a mut dyn ExecBackend,
    store: &'a mut Store,
    model: &'a str,
    spec: &'a ModelSpec,
    /// rung-visibility counters: every reconstruction call records
    /// which ladder rung actually served it (`ServeMetrics::
    /// decode_rung_bt`/`_t`/`_padded`), so a missing granular artifact
    /// shows up in the run summary instead of silently degrading
    metrics: &'a mut ServeMetrics,
}

impl LatentDecoder for ArtifactDecoder<'_> {
    fn decode_latents_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        n: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()> {
        let (l, s, dl, kvd) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.ae_latent,
            self.spec.kv_dim(),
        );
        debug_assert_eq!(k_lat.len(), l * n * dl);
        debug_assert_eq!(k_rec.len(), l * n * kvd);
        let entry_t = format!("{}_decode_kv_t", self.model);
        if n == 1 && self.engine.has_entry(&entry_t) {
            self.metrics.decode_rung_t += 1;
            self.store
                .insert_view("k_lat", vec![l, 1, dl])
                .copy_from_slice(k_lat);
            self.store
                .insert_view("v_lat", vec![l, 1, dl])
                .copy_from_slice(v_lat);
            let out = self.engine.execute(&entry_t, self.store)?;
            k_rec.copy_from_slice(out[0].1.as_f32()?);
            v_rec.copy_from_slice(out[1].1.as_f32()?);
            return Ok(());
        }
        anyhow::ensure!(n <= s, "latent range exceeds max_seq");
        self.metrics.decode_rung_padded += 1;
        {
            let kd = self.store.insert_view("k_lat", vec![l, s, dl]);
            kd.fill(0.0);
            for layer in 0..l {
                kd[layer * s * dl..layer * s * dl + n * dl]
                    .copy_from_slice(&k_lat[layer * n * dl..(layer + 1) * n * dl]);
            }
        }
        {
            let vd = self.store.insert_view("v_lat", vec![l, s, dl]);
            vd.fill(0.0);
            for layer in 0..l {
                vd[layer * s * dl..layer * s * dl + n * dl]
                    .copy_from_slice(&v_lat[layer * n * dl..(layer + 1) * n * dl]);
            }
        }
        let entry = format!("{}_decode_kv", self.model);
        let out = self.engine.execute(&entry, self.store)?;
        let (kr, vr) = (out[0].1.as_f32()?, out[1].1.as_f32()?);
        for layer in 0..l {
            k_rec[layer * n * kvd..(layer + 1) * n * kvd]
                .copy_from_slice(&kr[layer * s * kvd..layer * s * kvd + n * kvd]);
            v_rec[layer * n * kvd..(layer + 1) * n * kvd]
                .copy_from_slice(&vr[layer * s * kvd..layer * s * kvd + n * kvd]);
        }
        Ok(())
    }
}

impl BatchLatentDecoder for ArtifactDecoder<'_> {
    fn batch_capacity(&self) -> Option<usize> {
        let entry = format!("{}_decode_kv_bt", self.model);
        self.engine.entry_lanes(&entry, "k_lat")
    }

    fn decode_latents_batch_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        b: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()> {
        let (l, dl, kvd) = (self.spec.n_layer, self.spec.ae_latent, self.spec.kv_dim());
        let cap = self
            .batch_capacity()
            .ok_or_else(|| anyhow!("artifact set has no {}_decode_kv_bt entry", self.model))?;
        anyhow::ensure!(b <= cap, "batch {b} exceeds compiled decoder capacity {cap}");
        debug_assert_eq!(k_lat.len(), b * l * dl);
        debug_assert_eq!(k_rec.len(), b * l * kvd);
        self.metrics.decode_rung_bt += 1;
        // pack the live slots; zero-pad the unused tail up to the
        // compiled B (same padding policy as decode_step_b{B})
        {
            let kd = self.store.insert_view("k_lat", vec![cap, l, 1, dl]);
            kd[..b * l * dl].copy_from_slice(k_lat);
            kd[b * l * dl..].fill(0.0);
        }
        {
            let vd = self.store.insert_view("v_lat", vec![cap, l, 1, dl]);
            vd[..b * l * dl].copy_from_slice(v_lat);
            vd[b * l * dl..].fill(0.0);
        }
        let entry = format!("{}_decode_kv_bt", self.model);
        let out = self.engine.execute(&entry, self.store)?;
        k_rec.copy_from_slice(&out[0].1.as_f32()?[..b * l * kvd]);
        v_rec.copy_from_slice(&out[1].1.as_f32()?[..b * l * kvd]);
        Ok(())
    }
}

/// [`WavePrefiller`] over the AOT prefill artifacts.
///
/// Fallback ladder (most to least specific):
///
/// 1. `{m}_prefill_b` — `[B, S]` cross-request batched prefill: one
///    launch admits a whole wave (unused lanes zero-padded up to the
///    compiled B; an all-zero `len_mask` lane is inert by
///    construction, see `python/compile/model.py::make_prefill_b`).
/// 2. `{m}_prefill` — `[1, S]` per-request prefill: lone admissions
///    and artifact sets built before the batched entry existed (or
///    `ServeConfig::batched_prefill = false`).
///
/// Both rungs stage through `Store::insert_view*`, so wave packing
/// reuses the same resident buffers across admissions, and the
/// executed output tensors are handed to the planner as-is
/// (`WaveOutput` borrows lanes out of them — no per-lane copies).
struct ArtifactPrefiller<'a> {
    engine: &'a mut dyn ExecBackend,
    store: &'a mut Store,
    model: &'a str,
    spec: &'a ModelSpec,
    /// `ServeConfig::batched_prefill`: `false` reports no capacity,
    /// forcing the per-request rung (the launch-count baseline)
    batched: bool,
    /// rung-visibility counters (`ServeMetrics::prefill_rung_b` /
    /// `prefill_rung_single`): which prefill ladder rung each launch
    /// actually ran on
    metrics: &'a mut ServeMetrics,
}

impl WavePrefiller for ArtifactPrefiller<'_> {
    fn wave_capacity(&self) -> Option<usize> {
        if !self.batched {
            return None;
        }
        let entry = format!("{}_prefill_b", self.model);
        self.engine.entry_lanes(&entry, "tokens")
    }

    fn prefill_wave(&mut self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput> {
        let s = self.spec.max_seq;
        let cap = self
            .wave_capacity()
            .ok_or_else(|| anyhow!("artifact set has no {}_prefill_b entry", self.model))?;
        anyhow::ensure!(
            prompts.len() <= cap,
            "wave of {} exceeds compiled prefill capacity {cap}",
            prompts.len()
        );
        // pack the wave's lanes; dead lanes keep zero tokens and an
        // all-zero mask (inert — the compiled graph's diagonal guard
        // keeps them NaN-free and they touch no live lane)
        {
            let tokens = self.store.insert_view_i32_zeroed("tokens", vec![cap, s]);
            for (lane, &(p, plen)) in prompts.iter().enumerate() {
                for t in 0..plen.min(p.len()) {
                    tokens[lane * s + t] = p[t] as i32;
                }
            }
        }
        {
            let mask = self.store.insert_view_zeroed("len_mask", vec![cap, s]);
            for (lane, &(_, plen)) in prompts.iter().enumerate() {
                mask[lane * s..lane * s + plen].fill(1.0);
            }
        }
        {
            let last = self.store.insert_view_i32_zeroed("last", vec![cap]);
            for (lane, &(_, plen)) in prompts.iter().enumerate() {
                last[lane] = (plen - 1) as i32;
            }
        }
        let entry = format!("{}_prefill_b", self.model);
        let out = self.engine.execute(&entry, self.store)?;
        self.metrics.prefill_rung_b += 1;
        WaveOutput::new(out, cap, prompts.len())
    }

    fn prefill_one(&mut self, prompt: &[u8], plen: usize) -> Result<WaveOutput> {
        let s = self.spec.max_seq;
        {
            let tokens = self.store.insert_view_i32_zeroed("tokens", vec![1, s]);
            for t in 0..plen.min(prompt.len()) {
                tokens[t] = prompt[t] as i32;
            }
        }
        {
            let mask = self.store.insert_view_zeroed("len_mask", vec![1, s]);
            mask[..plen].fill(1.0);
        }
        self.store
            .insert("last", Tensor::scalar_i32((plen - 1) as i32));
        let entry = format!("{}_prefill", self.model);
        let out = self.engine.execute(&entry, self.store)?;
        self.metrics.prefill_rung_single += 1;
        WaveOutput::new(out, 1, 1)
    }
}

impl ActiveSeq {
    fn generated_check(&mut self, max_seq: usize) {
        let last = self.output.last().copied();
        if self.output.len() >= self.req.max_new_tokens
            || self.pos >= max_seq
            || (last.is_some() && self.req.stop_byte == last)
        {
            self.done = true;
        }
    }
}
