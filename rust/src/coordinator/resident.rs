//! Store-resident effective KV cache — O(new rows) decode staging.
//!
//! Before this module, every decode round memcpy'd each live sequence's
//! **entire** `[L, S, kvd]` effective cache into the `k_cache`/`v_cache`
//! staging tensors (plus zero-fills of the dead slots): O(B·L·S·kvd)
//! staged bytes per round, dominated by rows that had not changed since
//! the previous round.  [`SlotArena`] instead keeps the slotted staging
//! regions **resident in the [`Store`]** between rounds
//! (`Store::resident_region` — allocation persists, contents persist,
//! plain `insert_view` on the name panics instead of silently aliasing
//! it) and maintains them as an incrementally synced mirror of each
//! sequence's [`EffectiveCache`]:
//!
//! * **steady state** — per round only the rows past each sequence's
//!   *sync watermark* are copied into its slot
//!   (`EffectiveCache::sync_rows_into`): O(B·L·kvd) bytes, one row per
//!   live sequence, independent of context length;
//! * **slot transitions** — a slot is fully rebuilt (zero + copy rows
//!   `[0, upto)`) only when its assignment changes: admission into a
//!   previously-used slot, park/resume, retirement-then-reuse, or a
//!   capacity-rung switch (the compiled decode batch `b` changed, which
//!   reallocates the `[b, L, S, kvd]` regions and invalidates every
//!   slot).  These are counted separately
//!   (`ServeMetrics::slot_rebuild_bytes` / `slot_rebuilds` /
//!   `capacity_switches`) because they are amortized costs, not
//!   per-round costs;
//! * **dead slots** — padding slots are zeroed **once per transition**
//!   (a per-slot clean/dirty bit), not once per round.
//!
//! Slot assignment is stable (`batcher::plan_slots`): admissions and
//! retirements never move an unrelated live sequence, since every move
//! would cost a full O(L·S·kvd) rebuild.
//!
//! The legacy full-copy staging survives as [`stage_copy_round`]
//! (selected by `ServeConfig::resident_cache = false`): it is the
//! reference the resident path is asserted **bitwise identical** against
//! (`tests/incremental_equivalence.rs` at the staged-tensor level,
//! `tests/pipeline_integration.rs` at the logits level over real
//! artifacts), and the baseline the staged-bytes ratio in
//! `BENCH_decode_hotpath.json` is measured from.
//!
//! Invalidation rules (who calls what):
//!
//! | event                         | action                                   |
//! |-------------------------------|------------------------------------------|
//! | sequence retired              | `release` → slot freed, marked dirty     |
//! | sequence parked (host tier)   | `release` → same                         |
//! | sequence resumed              | nothing — next round assigns + rebuilds  |
//! | compiled batch rung changed   | regions realloc'd, every slot rebuilt    |
//! | region epoch changed          | same (allocation was replaced)           |

// serving hot path: faults travel as typed errors to the supervisor
// (DESIGN.md §9), never as panics
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::batcher::plan_slots;
use super::effective::EffectiveCache;
use super::metrics::ServeMetrics;
use crate::kvcache::Side;
use crate::runtime::Store;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Store name of the resident effective-K staging region.
pub const K_CACHE: &str = "k_cache";
/// Store name of the resident effective-V staging region.
pub const V_CACHE: &str = "v_cache";

/// What one slot needs this round (planned once, applied to both the K
/// and the V region so the dirty/synced bookkeeping commits exactly
/// once).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotAction {
    /// clean dead slot (already zero) or nothing pending
    Keep,
    /// vacated slot still holding a retired/parked sequence's rows:
    /// write the zero padding once, then it is clean until reused
    ZeroDead,
    /// (re)assigned slot: zero (stale rows past `upto` must not leak)
    /// and copy rows `[0, upto)` from the owning sequence's scratch
    Rebuild {
        /// owning sequence
        id: u64,
        /// rows materialized in the sequence's effective cache
        upto: usize,
    },
    /// steady state: copy only rows `[from, upto)` — O(new rows)
    Sync {
        /// owning sequence
        id: u64,
        /// slot's sync watermark (rows `[0, from)` already mirrored)
        from: usize,
        /// rows materialized in the sequence's effective cache
        upto: usize,
    },
}

/// Owner of the slotted, store-resident `k_cache`/`v_cache` staging
/// regions: slot assignment (stable), per-slot sync watermarks, and the
/// clean/dirty padding bits.  One arena per serving engine; all byte
/// movement is counted into [`ServeMetrics`].
#[derive(Debug, Default)]
pub struct SlotArena {
    /// current capacity rung (compiled decode batch); 0 = uninitialized
    b: usize,
    /// elements of one slot: `L * S * kvd`
    seq_elems: usize,
    /// slot → owning sequence
    assign: Vec<Option<u64>>,
    /// slot holds stale rows (vacated or reassigned since last write)
    dirty: Vec<bool>,
    /// sequence → rows `[0, n)` of its slot that mirror its scratch
    synced: HashMap<u64, usize>,
    /// last-seen `(k, v)` region epochs: any change means the backing
    /// allocations were replaced or re-registered after a lapse, so
    /// every slot and watermark is invalid
    epochs: (u64, u64),
}

impl SlotArena {
    /// Empty arena; regions are registered on the first `stage_round`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Slot currently assigned to a sequence, if any.
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        self.assign.iter().position(|x| *x == Some(id))
    }

    /// Current capacity rung (0 before the first round).
    pub fn capacity(&self) -> usize {
        self.b
    }

    /// Slot → owning sequence, verbatim.  Inspection hook for the
    /// scenario harness's coherence checks (owners must be live and
    /// unparked, no sequence may own two slots).
    pub fn assignments(&self) -> &[Option<u64>] {
        &self.assign
    }

    /// Rows `[0, n)` of its slot that mirror a sequence's scratch, or
    /// `None` when the sequence holds no watermark.  Inspection hook:
    /// a watermark past the sequence's decoded rows means the mirror
    /// claims data that was never produced.
    pub fn synced_upto(&self, id: u64) -> Option<usize> {
        self.synced.get(&id).copied()
    }

    /// Last-seen `(k, v)` region epochs.  Inspection hook: while the
    /// regions are resident these must match the store's epochs, or the
    /// arena is mirroring allocations that no longer exist.
    pub fn region_epochs(&self) -> (u64, u64) {
        self.epochs
    }

    /// Release a sequence's slot (retirement or park): the slot frees
    /// up for reuse and is marked dirty, so the padding zero-fill is
    /// paid once on the next round that includes it — not every round.
    pub fn release(&mut self, id: u64) {
        if let Some(slot) = self.slot_of(id) {
            self.assign[slot] = None;
            self.dirty[slot] = true;
        }
        self.synced.remove(&id);
    }

    /// Open both regions at capacity rung `b` and invalidate all slot
    /// state if anything about the backing allocations changed (rung
    /// switch, first registration, epoch bump from an external
    /// release/re-register).  Returns the per-side `fresh` flags —
    /// a fresh region is already zeroed, so zero-fills are skipped.
    fn ensure_rung(
        &mut self,
        store: &mut Store,
        b: usize,
        dims: (usize, usize, usize),
        metrics: &mut ServeMetrics,
    ) -> [bool; 2] {
        let (l, s, kvd) = dims;
        let seq_elems = l * s * kvd;
        // open (or create) both regions up front so any reallocation —
        // rung switch, first round, or an external release/re-register —
        // surfaces as an epoch change *before* slot actions are planned
        let mut fresh = [false; 2];
        for (i, name) in [K_CACHE, V_CACHE].into_iter().enumerate() {
            fresh[i] = store.resident_region(name, vec![b, l, s, kvd]).1;
        }
        let all_fresh = fresh[0] && fresh[1];
        let epochs = (store.region_epoch(K_CACHE), store.region_epoch(V_CACHE));
        // `fresh` is part of the condition because epochs are only
        // unique within one Store: if the engine's store is swapped
        // wholesale between rounds, the new store's epochs can collide
        // with the recorded ones while the regions are brand new
        if fresh[0] || fresh[1] || epochs != self.epochs || b != self.b
            || seq_elems != self.seq_elems
        {
            // every slot and watermark is invalid: the regions were
            // reallocated (rung switch — fresh, zeroed) or their
            // protection lapsed (contents untrusted — mark dirty so
            // stale rows are zeroed before reuse)
            if self.b != 0 && (b != self.b || seq_elems != self.seq_elems) {
                metrics.capacity_switches += 1;
            }
            self.b = b;
            self.seq_elems = seq_elems;
            self.assign = vec![None; b];
            self.dirty = vec![!all_fresh; b];
            self.synced.clear();
            self.epochs = epochs;
        }
        fresh
    }

    /// Seed one freshly-admitted sequence's slot straight from its
    /// prefill lane: assign the lowest free slot at rung `b` and fill
    /// rows `[0, upto)` from the sequence's [`EffectiveCache`] scratch
    /// (which the admission wave just seeded).  The next decode round
    /// then finds the slot synced and stages **zero** bytes for this
    /// sequence instead of paying the full `Rebuild` there — the slot
    /// fill moves to admission, where the wave's data is hot.
    ///
    /// Counted as a slot rebuild (`ServeMetrics::slot_rebuild_bytes` /
    /// `slot_rebuilds`), exactly like the fill `stage_round` would
    /// otherwise have performed — the one-fill-per-admission law is
    /// unchanged, only its timing moves.  Returns `false` (no state
    /// touched) when every slot at rung `b` is taken; `stage_round`
    /// rebuilds as before in that case.
    ///
    /// `seq` is `(cache_id, rows_materialized)`, the same pair shape
    /// `stage_round`'s `live` entries use.
    pub fn seed_slot(
        &mut self,
        store: &mut Store,
        seq: (u64, usize),
        eff: &EffectiveCache,
        b: usize,
        dims: (usize, usize, usize),
        metrics: &mut ServeMetrics,
    ) -> Result<bool> {
        let (id, upto) = seq;
        let (l, s, kvd) = dims;
        let seq_elems = l * s * kvd;
        let fresh = self.ensure_rung(store, b, dims, metrics);
        anyhow::ensure!(
            self.slot_of(id).is_none(),
            "sequence {id} already holds a slot (seed is for fresh admissions)"
        );
        let Some(slot) = (0..self.b).find(|&sl| self.assign[sl].is_none()) else {
            return Ok(false);
        };
        self.assign[slot] = Some(id);
        for (i, (name, side)) in [(K_CACHE, Side::K), (V_CACHE, Side::V)]
            .into_iter()
            .enumerate()
        {
            // re-opened, not re-created: ensure_rung already sized both
            let (region, _) = store.resident_region(name, vec![b, l, s, kvd]);
            let dst = &mut region[slot * seq_elems..(slot + 1) * seq_elems];
            let zeroed = self.dirty[slot] && !fresh[i];
            if zeroed {
                dst.fill(0.0);
                metrics.slot_rebuild_bytes += (seq_elems * 4) as u64;
            }
            metrics.slot_rebuild_bytes += eff.sync_rows_into(side, dst, 0, upto) as u64;
            // declare the dirty spans so the engine can delta-upload:
            // a zeroed slot is dirty end to end, a plain fill only in
            // the rows actually written
            let spans = if zeroed {
                vec![(slot * seq_elems, (slot + 1) * seq_elems)]
            } else {
                eff.row_spans(slot * seq_elems, 0, upto)
            };
            store.note_region_writes(name, &spans);
        }
        self.dirty[slot] = false;
        self.synced.insert(id, upto);
        metrics.slot_rebuilds += 1;
        Ok(true)
    }

    /// Bring the resident regions up to date for one decode round.
    ///
    /// `live` is `(cache_id, rows_materialized)` for every sequence
    /// taking a slot this round (`rows_materialized` = the cache
    /// manager's `decoded_upto` watermark: rows `[0, n)` of the
    /// sequence's [`EffectiveCache`] scratch are valid); `b` is the
    /// compiled decode batch; `dims` is `(n_layer, max_seq, kv_dim)`.
    ///
    /// After this returns, the store's `k_cache`/`v_cache` tensors are
    /// bitwise identical to what [`stage_copy_round`] would have
    /// produced for the same per-slot contents, having moved only
    /// O(new rows) bytes in steady state.
    pub fn stage_round(
        &mut self,
        store: &mut Store,
        live: &[(u64, usize)],
        effs: &HashMap<u64, EffectiveCache>,
        b: usize,
        dims: (usize, usize, usize),
        metrics: &mut ServeMetrics,
    ) -> Result<()> {
        let (l, s, kvd) = dims;
        let seq_elems = l * s * kvd;
        anyhow::ensure!(
            live.len() <= b,
            "{} live sequences exceed {b} decode slots",
            live.len()
        );
        let fresh = self.ensure_rung(store, b, dims, metrics);

        // stable assignment: nobody moves unless they must
        let ids: Vec<u64> = live.iter().map(|p| p.0).collect();
        let next = plan_slots(&self.assign, &ids, b);
        for slot in 0..b {
            if self.assign[slot] != next[slot] {
                if let Some(old) = self.assign[slot] {
                    self.synced.remove(&old);
                }
                self.dirty[slot] = true;
            }
        }
        self.assign = next;

        // plan each slot once; apply identically to the K and V regions
        let actions: Vec<SlotAction> = (0..b)
            .map(|slot| match self.assign[slot] {
                None if self.dirty[slot] => SlotAction::ZeroDead,
                None => SlotAction::Keep,
                Some(id) => {
                    let upto = ids
                        .iter()
                        .position(|&x| x == id)
                        .map(|i| live[i].1)
                        .unwrap_or(0);
                    match self.synced.get(&id) {
                        // a watermark that ran backwards (external
                        // reset_decoded) means rows past `upto` are
                        // stale in the mirror: rebuild, never sync
                        Some(&from) if !self.dirty[slot] && from <= upto => {
                            SlotAction::Sync { id, from, upto }
                        }
                        _ => SlotAction::Rebuild { id, upto },
                    }
                }
            })
            .collect();
        metrics.slot_rebuilds += actions
            .iter()
            .filter(|a| matches!(a, SlotAction::Rebuild { .. }))
            .count() as u64;

        for (i, (name, side)) in [(K_CACHE, Side::K), (V_CACHE, Side::V)]
            .into_iter()
            .enumerate()
        {
            // re-opened, not re-created: the probe above already sized
            // both regions, so this cannot reallocate mid-round
            let (region, _) = store.resident_region(name, vec![b, l, s, kvd]);
            let region_fresh = fresh[i];
            debug_assert_eq!(region.len(), b * seq_elems);
            // dirty spans this side writes, declared to the store after
            // the pass so the engine re-uploads only these (the region
            // borrow must end before `note_region_writes`)
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for (slot, act) in actions.iter().enumerate() {
                let base = slot * seq_elems;
                let dst = &mut region[base..base + seq_elems];
                match *act {
                    SlotAction::Keep => {}
                    SlotAction::ZeroDead => {
                        // a fresh region is already zeroed
                        if !region_fresh {
                            dst.fill(0.0);
                            metrics.slot_rebuild_bytes += (seq_elems * 4) as u64;
                            spans.push((base, base + seq_elems));
                        }
                    }
                    SlotAction::Rebuild { id, upto } => {
                        if !region_fresh {
                            dst.fill(0.0);
                            metrics.slot_rebuild_bytes += (seq_elems * 4) as u64;
                        }
                        let eff = effs
                            .get(&id)
                            .ok_or_else(|| anyhow!("no effective cache for sequence {id}"))?;
                        metrics.slot_rebuild_bytes +=
                            eff.sync_rows_into(side, dst, 0, upto) as u64;
                        // zero + row fill: the whole slot changed
                        spans.push((base, base + seq_elems));
                    }
                    SlotAction::Sync { id, from, upto } => {
                        let eff = effs
                            .get(&id)
                            .ok_or_else(|| anyhow!("no effective cache for sequence {id}"))?;
                        metrics.staged_kv_bytes +=
                            eff.sync_rows_into(side, dst, from, upto) as u64;
                        spans.extend(eff.row_spans(base, from, upto));
                    }
                }
            }
            store.note_region_writes(name, &spans);
        }

        // commit bookkeeping once, after both regions were written
        for (slot, act) in actions.iter().enumerate() {
            match *act {
                SlotAction::Keep => {}
                SlotAction::ZeroDead => self.dirty[slot] = false,
                SlotAction::Rebuild { id, upto } | SlotAction::Sync { id, upto, .. } => {
                    self.dirty[slot] = false;
                    self.synced.insert(id, upto);
                }
            }
        }
        Ok(())
    }
}

/// The legacy per-round copy staging — every live sequence's whole
/// `[L, S, kvd]` effective cache memcpy'd into `Store::insert_view`
/// staging plus zero-fills of the dead slots, O(B·L·S·kvd) bytes per
/// round.  Kept as the reference implementation the resident path is
/// asserted bitwise-identical against, and as the measured baseline for
/// the staged-bytes ratio (`ServeConfig::resident_cache = false`).
/// Sequence `i` of `ids` occupies slot `i`.
pub fn stage_copy_round(
    store: &mut Store,
    effs: &HashMap<u64, EffectiveCache>,
    ids: &[u64],
    b: usize,
    dims: (usize, usize, usize),
    metrics: &mut ServeMetrics,
) -> Result<()> {
    let (l, s, kvd) = dims;
    let seq_elems = l * s * kvd;
    let rows = ids.len().min(b);
    for (name, side) in [(K_CACHE, Side::K), (V_CACHE, Side::V)] {
        let cache = store.insert_view(name, vec![b, l, s, kvd]);
        for (slot, id) in ids.iter().take(rows).enumerate() {
            let eff = effs
                .get(id)
                .ok_or_else(|| anyhow!("no effective cache for sequence {id}"))?;
            // full-range sync (rows [0, S) of every layer) == the old
            // whole-buffer memcpy, and it sources template-seeded rows
            // from their shared `EffTemplate` (copy-on-write admission)
            // instead of the owned zeros behind them
            eff.sync_rows_into(side, &mut cache[slot * seq_elems..(slot + 1) * seq_elems], 0, s);
        }
        for slot in rows..b {
            cache[slot * seq_elems..(slot + 1) * seq_elems].fill(0.0);
        }
    }
    // live copies + dead-slot zero fills: the full tensor pair moves
    metrics.staged_kv_bytes += 2 * (b * seq_elems * 4) as u64;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, ModelSpec};

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "arena".into(),
            arch: Arch::Gpt2,
            vocab: 256,
            n_layer: 2,
            d_model: 16,
            n_head: 2,
            n_kv_head: 2,
            d_head: 4,
            ffn_dim: 32,
            max_seq: 8,
            ae_hidden: 8,
            ae_latent: 4,
            bytes_per_el: 4,
        }
    }

    fn dims(spec: &ModelSpec) -> (usize, usize, usize) {
        (spec.n_layer, spec.max_seq, spec.kv_dim())
    }

    #[test]
    fn release_frees_slot_and_marks_dirty_once() {
        let spec = tiny_spec();
        let (l, s, kvd) = dims(&spec);
        let mut store = Store::new();
        let mut m = ServeMetrics::default();
        let mut arena = SlotArena::new();
        let mut effs = HashMap::new();
        let mut eff = EffectiveCache::new(&spec);
        eff.k.fill(1.0);
        eff.v.fill(2.0);
        effs.insert(7u64, eff);
        // round 1: assign + rebuild into a fresh region (no zero cost)
        arena
            .stage_round(&mut store, &[(7, 3)], &effs, 2, (l, s, kvd), &mut m)
            .unwrap();
        assert_eq!(arena.slot_of(7), Some(0));
        assert_eq!(m.slot_rebuilds, 1);
        let fill = 2 * l * 3 * kvd * 4; // K+V rows [0,3)
        assert_eq!(m.slot_rebuild_bytes as usize, fill);
        // round 2: one new row syncs, nothing rebuilds
        arena
            .stage_round(&mut store, &[(7, 4)], &effs, 2, (l, s, kvd), &mut m)
            .unwrap();
        assert_eq!(m.slot_rebuilds, 1);
        assert_eq!(m.staged_kv_bytes as usize, 2 * l * kvd * 4);
        // release: the vacated slot is zeroed exactly once, then clean
        arena.release(7);
        assert_eq!(arena.slot_of(7), None);
        let before = m.slot_rebuild_bytes;
        arena
            .stage_round(&mut store, &[], &effs, 2, (l, s, kvd), &mut m)
            .unwrap();
        let zeroed = m.slot_rebuild_bytes - before;
        assert_eq!(zeroed as usize, 2 * l * s * kvd * 4, "one-time zero of the slot");
        let k = store.get(K_CACHE).unwrap().as_f32().unwrap();
        assert!(k.iter().all(|&x| x == 0.0), "vacated slot must read as padding");
        arena
            .stage_round(&mut store, &[], &effs, 2, (l, s, kvd), &mut m)
            .unwrap();
        assert_eq!(m.slot_rebuild_bytes, before + zeroed, "no per-round re-zeroing");
    }

    #[test]
    fn seeded_slot_syncs_zero_bytes_on_first_round() {
        let spec = tiny_spec();
        let (l, s, kvd) = dims(&spec);
        let mut store = Store::new();
        let mut m = ServeMetrics::default();
        let mut arena = SlotArena::new();
        let mut effs = HashMap::new();
        let mut eff = EffectiveCache::new(&spec);
        eff.k.fill(3.0);
        eff.v.fill(4.0);
        effs.insert(9u64, eff);
        // admission-time seed: slot assigned + filled, one rebuild
        assert!(arena
            .seed_slot(&mut store, (9, 5), &effs[&9], 2, (l, s, kvd), &mut m)
            .unwrap());
        assert_eq!(arena.slot_of(9), Some(0));
        assert_eq!(m.slot_rebuilds, 1);
        assert_eq!(m.slot_rebuild_bytes as usize, 2 * l * 5 * kvd * 4);
        // the first decode round finds the slot synced: zero staged bytes
        arena
            .stage_round(&mut store, &[(9, 5)], &effs, 2, (l, s, kvd), &mut m)
            .unwrap();
        assert_eq!(m.slot_rebuilds, 1, "seeded slot must not rebuild again");
        assert_eq!(m.staged_kv_bytes, 0);
        let k = store.get(K_CACHE).unwrap().as_f32().unwrap();
        assert_eq!(k[0], 3.0, "seeded rows must be resident");
        // a second admission takes the next free slot
        effs.insert(11u64, EffectiveCache::new(&spec));
        assert!(arena
            .seed_slot(&mut store, (11, 2), &effs[&11], 2, (l, s, kvd), &mut m)
            .unwrap());
        assert_eq!(arena.slot_of(11), Some(1));
        // a third admission finds no free slot: nothing changes
        effs.insert(12u64, EffectiveCache::new(&spec));
        assert!(!arena
            .seed_slot(&mut store, (12, 1), &effs[&12], 2, (l, s, kvd), &mut m)
            .unwrap());
        assert_eq!(arena.slot_of(12), None);
        // double-seeding an already-slotted sequence is a logic error
        assert!(arena
            .seed_slot(&mut store, (9, 5), &effs[&9], 2, (l, s, kvd), &mut m)
            .is_err());
    }

    #[test]
    fn rung_switch_invalidates_every_slot() {
        let spec = tiny_spec();
        let d = dims(&spec);
        let mut store = Store::new();
        let mut m = ServeMetrics::default();
        let mut arena = SlotArena::new();
        let mut effs = HashMap::new();
        effs.insert(1u64, EffectiveCache::new(&spec));
        effs.insert(2u64, EffectiveCache::new(&spec));
        arena
            .stage_round(&mut store, &[(1, 2), (2, 2)], &effs, 4, d, &mut m)
            .unwrap();
        assert_eq!(m.capacity_switches, 0, "first registration is not a switch");
        assert_eq!(m.slot_rebuilds, 2);
        let epoch = store.region_epoch(K_CACHE);
        // b 4 -> 1: region realloc, survivor rebuilt from row 0
        arena
            .stage_round(&mut store, &[(1, 2)], &effs, 1, d, &mut m)
            .unwrap();
        assert_eq!(m.capacity_switches, 1);
        assert_eq!(m.slot_rebuilds, 3);
        assert_eq!(arena.slot_of(1), Some(0));
        assert!(store.region_epoch(K_CACHE) > epoch, "realloc must bump the epoch");
    }
}
