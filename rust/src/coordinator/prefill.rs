//! Wave-based admission prefill — one launch per admission wave.
//!
//! Before this module, admission prefilled one request at a time
//! through `{m}_prefill`: O(admitted) launches per wave, which
//! dominates time-to-first-token under bursty load (exactly the
//! serving regime the paper's batch/sequence-scaling measurements
//! target).  [`PrefillWave`] is the admission-side twin of the decode
//! path's `BatchedAdvance`: the batcher's admission wave is packed
//! into the `[B, S]` lanes of the `{m}_prefill_b` artifact and
//! prefilled with a **single** launch, then each lane seeds its own
//! sequence — compressed rows into the [`CacheManager`], the in-graph
//! effective rows into the sequence's [`EffectiveCache`], and (through
//! the scheduler) its resident `SlotArena` slot.
//!
//! Contract of the batched entry: lane `b` of `{m}_prefill_b` is
//! **bit-identical** to a `{m}_prefill` call on that request alone
//! (per-lane length masking keeps padded rows and dead lanes inert;
//! proven in `python/tests/test_decode_parity.py`).  That is what
//! makes a batched wave bitwise-equivalent to sequential prefills —
//! watermarks, stored streams, effective-cache contents, and sampled
//! first tokens included — asserted without artifacts in
//! `rust/tests/wave_prefill.rs` via [`LaneWiseMockPrefiller`], and
//! over real artifacts in `tests/pipeline_integration.rs`.
//!
//! Fallback ladder, mirroring the decoder's (`DESIGN.md` §3.1):
//!
//! 1. `{m}_prefill_b` — `[B, S]` cross-request batched prefill; waves
//!    larger than the compiled capacity chunk, unused lanes zero-pad.
//! 2. `{m}_prefill` — per-request: lone admissions (padding the
//!    batched entry would cost more than it saves) and artifact sets
//!    that predate the batched entry (`wave_capacity() == None`).

use super::batcher::wave_bucket;
use super::effective::EffectiveCache;
use crate::kvcache::CacheManager;
use crate::model::ModelSpec;
use crate::runtime::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Positional indices of the seven prefill outputs inside a
/// [`WaveOutput`] — the order `{m}_prefill[_b]` emits them.
pub mod lane_out {
    /// `[cap, V]` last-position logits
    pub const LOGITS: usize = 0;
    /// `[cap, L, S, kvd]` raw K rows
    pub const K_RAW: usize = 1;
    /// `[cap, L, S, kvd]` raw V rows
    pub const V_RAW: usize = 2;
    /// `[cap, L, S, dl]` K latents
    pub const K_LAT: usize = 3;
    /// `[cap, L, S, dl]` V latents
    pub const V_LAT: usize = 4;
    /// `[cap, L, S, kvd]` store-transformed (effective) K rows
    pub const K_EFF: usize = 5;
    /// `[cap, L, S, kvd]` store-transformed (effective) V rows
    pub const V_EFF: usize = 6;
}

/// Outputs of one prefill launch: the seven output tensors
/// ([`lane_out`] order), each packed `[cap, ...]` lane-major, of which
/// the first `lanes` lanes carry live requests.  Holds the executed
/// tensors themselves — lane reads are borrows, so admission is
/// zero-copy up to the cache-manager ingest.  A per-request launch is
/// the `cap == lanes == 1` case; the ingestion path is identical on
/// every ladder rung.
pub struct WaveOutput {
    tensors: Vec<(String, Tensor)>,
    /// lane pitch of the packed tensors (the compiled B)
    cap: usize,
    /// leading lanes that carry live requests
    lanes: usize,
}

impl WaveOutput {
    /// Wrap one launch's outputs (exactly the seven prefill outputs,
    /// in [`lane_out`] order); `cap` is the compiled lane count,
    /// `lanes` how many leading lanes are live.
    pub fn new(tensors: Vec<(String, Tensor)>, cap: usize, lanes: usize) -> Result<WaveOutput> {
        anyhow::ensure!(
            tensors.len() == 7,
            "prefill must produce 7 outputs, got {}",
            tensors.len()
        );
        anyhow::ensure!(
            lanes >= 1 && lanes <= cap,
            "{lanes} live lanes out of range for capacity {cap}"
        );
        Ok(WaveOutput { tensors, cap, lanes })
    }

    /// Live lanes carried.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Borrow one lane of output `out` (a [`lane_out`] index).
    pub fn lane(&self, out: usize, lane: usize) -> Result<&[f32]> {
        debug_assert!(lane < self.lanes);
        let (name, t) = &self.tensors[out];
        let d = t.as_f32()?;
        anyhow::ensure!(
            d.len() % self.cap == 0,
            "prefill output {name} is not divisible into {} lanes",
            self.cap
        );
        let n = d.len() / self.cap;
        Ok(&d[lane * n..(lane + 1) * n])
    }
}

/// Runs the prefill artifacts.  The serving engine implements this
/// over `{m}_prefill_b` / `{m}_prefill`; tests use
/// [`LaneWiseMockPrefiller`] so the wave dataflow is checkable without
/// artifacts.
///
/// Implementations must be pure per-lane maps: lane `i` of
/// `prefill_wave` must equal a `prefill_one` call on that prompt
/// alone, **bitwise** — the property that makes wave admission
/// equivalent to sequential prefill (the L2 `prefill_b` entry
/// satisfies it by construction).
pub trait WavePrefiller {
    /// Lanes of the batched prefill entry, or `None` when only the
    /// per-request entry exists (artifact sets that predate
    /// `prefill_b`, or batched prefill disabled by config).
    fn wave_capacity(&self) -> Option<usize>;

    /// One launch covering every `(prompt, plen)` lane; called with
    /// `2..=wave_capacity()` lanes.  `plen` is already clamped to
    /// `[1, max_seq - 1]`.
    fn prefill_wave(&mut self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput>;

    /// Per-request rung: one launch for one prompt.
    fn prefill_one(&mut self, prompt: &[u8], plen: usize) -> Result<WaveOutput>;
}

/// Launch/padding accounting for the admission path: tests assert one
/// launch per wave, and the bench reports amortized prefill cost.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WaveStats {
    /// admission waves processed (>= 1 request each)
    pub waves: u64,
    /// prefill launches issued (batched chunks and per-request calls)
    pub launches: u64,
    /// requests admitted through a batched launch
    pub batched_lanes: u64,
    /// requests admitted through the per-request rung (lone
    /// admissions, capacity chunk remainders, or no batched entry)
    pub fallback_prefills: u64,
    /// lane rows staged beyond each prompt's length, summed up to the
    /// wave's padded bucket (`batcher::wave_bucket`) — the padding
    /// cost of batching admission
    pub padded_rows: u64,
}

/// One admitted request's handles out of a wave: the sequence created
/// for it and the logits its first token is sampled from.
pub struct AdmittedLane {
    /// cache-manager sequence holding the prompt's compressed rows
    pub cache_id: u64,
    /// `[V]` last-position logits (the scheduler samples from these)
    pub logits: Vec<f32>,
}

/// The admission-wave planner: packs a wave of prompts through the
/// prefill ladder, ingests each lane's compressed rows, and seeds each
/// sequence's effective cache.  Owns the launch accounting
/// ([`WaveStats`]); one planner per serving engine.
#[derive(Debug, Default)]
pub struct PrefillWave {
    /// launch/padding accounting for the admission path
    pub stats: WaveStats,
}

impl PrefillWave {
    /// Empty planner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit one wave of prompts: prefill them (one launch per
    /// capacity chunk when the runner has a batched entry), ingest
    /// every lane's compressed rows into `cache`, and register each
    /// sequence's [`EffectiveCache`] in `effs` — seeded from the
    /// lane's in-graph effective rows when `seed_effective` (the
    /// faithful mode instead leaves the watermark at 0 so the first
    /// decode round reconstructs the prompt from the store).
    ///
    /// The wave is transactional: launches run first (they touch no
    /// persistent state), and an ingestion failure frees every
    /// sequence the wave already created — a half-admitted wave would
    /// otherwise leak rows the scheduler can neither see nor retire.
    ///
    /// Returns one [`AdmittedLane`] per prompt, in order.
    pub fn admit_wave<P: WavePrefiller>(
        &mut self,
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        spec: &ModelSpec,
        seed_effective: bool,
        prompts: &[&[u8]],
        runner: &mut P,
    ) -> Result<Vec<AdmittedLane>> {
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        self.stats.waves += 1;
        let s = spec.max_seq;
        let lanes: Vec<(&[u8], usize)> = prompts
            .iter()
            .map(|p| (*p, p.len().clamp(1, s - 1)))
            .collect();

        // phase 1: launches.  Chunk by capacity; a lone chunk prefills
        // cheaper through the unpadded per-request entry (same policy
        // as the decoder ladder's lone-row rule), as does everything
        // when no batched entry exists (capacity 1).
        let cap = runner.wave_capacity().filter(|&c| c > 1).unwrap_or(1);
        let mut outputs: Vec<(WaveOutput, &[(&[u8], usize)])> = Vec::new();
        for group in lanes.chunks(cap) {
            let w = if group.len() == 1 {
                self.stats.fallback_prefills += 1;
                runner.prefill_one(group[0].0, group[0].1)?
            } else {
                let w = runner.prefill_wave(group)?;
                anyhow::ensure!(
                    w.lanes() == group.len(),
                    "prefill wave returned {} lanes for {} prompts",
                    w.lanes(),
                    group.len()
                );
                self.stats.batched_lanes += group.len() as u64;
                let bucket = wave_bucket(group.iter().map(|g| g.1), s);
                for &(_, plen) in group {
                    self.stats.padded_rows += (bucket - plen.min(bucket)) as u64;
                }
                w
            };
            self.stats.launches += 1;
            outputs.push((w, group));
        }

        // phase 2: ingestion, with rollback on failure
        let mut admitted = Vec::with_capacity(lanes.len());
        for (w, group) in &outputs {
            for (lane, &(_, plen)) in group.iter().enumerate() {
                match Self::ingest(cache, effs, spec, seed_effective, w, (lane, plen)) {
                    Ok(a) => admitted.push(a),
                    Err(e) => {
                        for a in &admitted {
                            cache.free_sequence(a.cache_id);
                            effs.remove(&a.cache_id);
                        }
                        return Err(e);
                    }
                }
            }
        }
        Ok(admitted)
    }

    /// Seed one lane: create the sequence, bulk-ingest its compressed
    /// prompt rows, and register its effective-cache scratch.  `lane`
    /// is `(lane_index, plen)`.  Frees the sequence it created if the
    /// ingest fails partway, so errors leave no orphaned state.
    fn ingest(
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        spec: &ModelSpec,
        seed_effective: bool,
        w: &WaveOutput,
        lane: (usize, usize),
    ) -> Result<AdmittedLane> {
        let (lane, plen) = lane;
        let (l, s, kvd, dl) = (spec.n_layer, spec.max_seq, spec.kv_dim(), spec.ae_latent);
        // borrow every lane slice before touching persistent state
        let logits = w.lane(lane_out::LOGITS, lane)?;
        let k_raw = w.lane(lane_out::K_RAW, lane)?;
        let v_raw = w.lane(lane_out::V_RAW, lane)?;
        let k_lat = w.lane(lane_out::K_LAT, lane)?;
        let v_lat = w.lane(lane_out::V_LAT, lane)?;
        let k_eff = w.lane(lane_out::K_EFF, lane)?;
        let v_eff = w.lane(lane_out::V_EFF, lane)?;
        anyhow::ensure!(
            k_raw.len() == l * s * kvd && k_lat.len() == l * s * dl,
            "prefill lane shapes do not match the model spec"
        );
        let id = cache.create_sequence();
        if let Err(e) = cache.append_rows(id, plen, s, k_lat, v_lat, k_raw, v_raw) {
            cache.free_sequence(id); // e.g. pool budget exceeded
            return Err(e);
        }
        let mut eff = EffectiveCache::new(spec);
        if seed_effective {
            eff.seed(cache, id, k_eff, v_eff, plen);
        }
        effs.insert(id, eff);
        Ok(AdmittedLane {
            cache_id: id,
            logits: logits.to_vec(),
        })
    }
}

/// Deterministic lane-wise mock prefiller for tests and benches: every
/// output element is a pure function of the lane's prompt bytes and
/// position (like the real per-lane transformer), so a batched wave is
/// bitwise-equal to per-request calls by construction — the one
/// [`WavePrefiller`] contract the wave-equivalence tests rely on.
/// Counts calls on both rungs so tests can assert launch laws.
pub struct LaneWiseMockPrefiller {
    n_layer: usize,
    max_seq: usize,
    kv_dim: usize,
    ae_latent: usize,
    vocab: usize,
    /// capacity reported through [`WavePrefiller::wave_capacity`];
    /// `None` simulates an artifact set without `prefill_b`
    pub capacity: Option<usize>,
    /// batched (`prefill_wave`) launches observed
    pub wave_calls: u64,
    /// per-request (`prefill_one`) launches observed
    pub single_calls: u64,
}

impl LaneWiseMockPrefiller {
    /// Mock sized for `spec`, batch-capable with a default capacity of 8.
    pub fn for_spec(spec: &ModelSpec) -> Self {
        LaneWiseMockPrefiller {
            n_layer: spec.n_layer,
            max_seq: spec.max_seq,
            kv_dim: spec.kv_dim(),
            ae_latent: spec.ae_latent,
            vocab: spec.vocab,
            capacity: Some(8),
            wave_calls: 0,
            single_calls: 0,
        }
    }

    /// Override the reported capacity (None = no batched entry).
    pub fn with_capacity(mut self, capacity: Option<usize>) -> Self {
        self.capacity = capacity;
        self
    }

    /// Pure per-element value: mixes prompt byte, stream tag, layer,
    /// token, and element index so distinct prompts produce distinct
    /// (but reproducible) tensors.
    fn val(tag: u32, byte: u8, layer: usize, t: usize, j: usize) -> f32 {
        let h = tag
            .wrapping_mul(0x9E37)
            .wrapping_add(byte as u32 * 131)
            .wrapping_add(layer as u32 * 31)
            .wrapping_add(t as u32 * 7)
            .wrapping_add(j as u32);
        ((h % 2003) as f32 - 1001.0) / 257.0
    }

    /// Fill one lane of the seven positional buffers ([`lane_out`]
    /// order) with the pure per-lane map.
    fn fill_lane(&self, prompt: &[u8], plen: usize, lane: usize, bufs: &mut [Vec<f32>; 7]) {
        let (l, s, kvd, dl, v) = (
            self.n_layer,
            self.max_seq,
            self.kv_dim,
            self.ae_latent,
            self.vocab,
        );
        // empty prompts still prefill one (zero) token row, matching
        // the artifact path's zero-padded lane
        let byte = |t: usize| {
            if prompt.is_empty() {
                0
            } else {
                prompt[t % prompt.len()]
            }
        };
        for layer in 0..l {
            for t in 0..plen {
                for j in 0..kvd {
                    let base = lane * l * s * kvd + layer * s * kvd + t * kvd + j;
                    bufs[lane_out::K_RAW][base] = Self::val(1, byte(t), layer, t, j);
                    bufs[lane_out::V_RAW][base] = Self::val(2, byte(t), layer, t, j);
                    bufs[lane_out::K_EFF][base] = Self::val(5, byte(t), layer, t, j);
                    bufs[lane_out::V_EFF][base] = Self::val(6, byte(t), layer, t, j);
                }
                for j in 0..dl {
                    let base = lane * l * s * dl + layer * s * dl + t * dl + j;
                    bufs[lane_out::K_LAT][base] = Self::val(3, byte(t), layer, t, j);
                    bufs[lane_out::V_LAT][base] = Self::val(4, byte(t), layer, t, j);
                }
            }
        }
        for j in 0..v {
            bufs[lane_out::LOGITS][lane * v + j] = Self::val(7, byte(plen - 1), plen, j, j);
        }
    }

    /// Build one launch's output for the given lanes (pure per lane).
    fn build(&self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput> {
        let (l, s, kvd, dl, v) = (
            self.n_layer,
            self.max_seq,
            self.kv_dim,
            self.ae_latent,
            self.vocab,
        );
        let n = prompts.len();
        let mut bufs: [Vec<f32>; 7] = [
            vec![0.0; n * v],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * dl],
            vec![0.0; n * l * s * dl],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * kvd],
        ];
        for (lane, &(p, plen)) in prompts.iter().enumerate() {
            self.fill_lane(p, plen, lane, &mut bufs);
        }
        let names = ["logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff"];
        let shapes: [Vec<usize>; 7] = [
            vec![n, v],
            vec![n, l, s, kvd],
            vec![n, l, s, kvd],
            vec![n, l, s, dl],
            vec![n, l, s, dl],
            vec![n, l, s, kvd],
            vec![n, l, s, kvd],
        ];
        let tensors = names
            .iter()
            .zip(shapes)
            .zip(bufs)
            .map(|((name, shape), data)| (name.to_string(), Tensor::f32(shape, data)))
            .collect();
        WaveOutput::new(tensors, n, n)
    }
}

impl WavePrefiller for LaneWiseMockPrefiller {
    fn wave_capacity(&self) -> Option<usize> {
        self.capacity
    }

    fn prefill_wave(&mut self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput> {
        if let Some(cap) = self.capacity {
            anyhow::ensure!(prompts.len() <= cap, "wave exceeds mock capacity");
        } else {
            return Err(anyhow!("mock has no batched prefill entry"));
        }
        self.wave_calls += 1;
        self.build(prompts)
    }

    fn prefill_one(&mut self, prompt: &[u8], plen: usize) -> Result<WaveOutput> {
        self.single_calls += 1;
        self.build(&[(prompt, plen)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::model::memory::CompressionPlan;
    use crate::model::Arch;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "wave".into(),
            arch: Arch::Gpt2,
            vocab: 64,
            n_layer: 3,
            d_model: 24,
            n_head: 3,
            n_kv_head: 3,
            d_head: 8,
            ffn_dim: 48,
            max_seq: 32,
            ae_hidden: 16,
            ae_latent: 12,
            bytes_per_el: 4,
        }
    }

    #[test]
    fn mock_wave_lane_equals_single_call_bitwise() {
        let spec = tiny_spec();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let prompts: [&[u8]; 3] = [b"abc", b"defgh", b"z"];
        let lanes: Vec<(&[u8], usize)> = prompts.iter().map(|p| (*p, p.len())).collect();
        let wave = mock.prefill_wave(&lanes).unwrap();
        for (i, &(p, plen)) in lanes.iter().enumerate() {
            let one = mock.prefill_one(p, plen).unwrap();
            for out in 0..7 {
                let a = wave.lane(out, i).unwrap();
                let b = one.lane(out, 0).unwrap();
                assert!(
                    a.len() == b.len()
                        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "mock lane {i} output {out} must be a pure per-lane map"
                );
            }
        }
        assert_eq!((mock.wave_calls, mock.single_calls), (1, 3));
    }

    #[test]
    fn wave_chunks_by_capacity_and_lone_remainder_falls_back() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec).with_capacity(Some(2));
        let mut wave = PrefillWave::new();
        let prompts: Vec<&[u8]> = vec![b"aa", b"bb", b"cc", b"dd", b"ee"];
        let admitted = wave
            .admit_wave(&mut cache, &mut effs, &spec, true, &prompts, &mut mock)
            .unwrap();
        assert_eq!(admitted.len(), 5);
        // 5 prompts at capacity 2: two batched chunks + a lone single
        assert_eq!(mock.wave_calls, 2);
        assert_eq!(mock.single_calls, 1);
        assert_eq!(wave.stats.launches, 3);
        assert_eq!(wave.stats.batched_lanes, 4);
        assert_eq!(wave.stats.fallback_prefills, 1);
        // every admission carries its prompt rows and a seeded watermark
        for (lane, p) in admitted.iter().zip(&prompts) {
            assert_eq!(cache.seq_len(lane.cache_id), Some(p.len()));
            assert_eq!(cache.decoded_upto(lane.cache_id), Some(p.len()));
            assert_eq!(lane.logits.len(), spec.vocab);
        }
    }

    #[test]
    fn faithful_mode_leaves_watermark_at_zero() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::new();
        let prompts: Vec<&[u8]> = vec![b"abcd", b"efg"];
        let admitted = wave
            .admit_wave(&mut cache, &mut effs, &spec, false, &prompts, &mut mock)
            .unwrap();
        for lane in &admitted {
            assert_eq!(cache.decoded_upto(lane.cache_id), Some(0));
            let eff = &effs[&lane.cache_id];
            assert!(eff.k.iter().all(|&x| x == 0.0), "faithful mode must not seed");
        }
    }

    #[test]
    fn padding_accounting_uses_wave_bucket() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::new();
        // plens 3 and 7 -> bucket 8 -> padding (8-3) + (8-7) = 6
        let prompts: Vec<&[u8]> = vec![b"abc", b"abcdefg"];
        wave.admit_wave(&mut cache, &mut effs, &spec, true, &prompts, &mut mock)
            .unwrap();
        assert_eq!(wave.stats.padded_rows, 6);
    }
}
