//! Wave-based admission prefill — one launch per admission wave.
//!
//! Before this module, admission prefilled one request at a time
//! through `{m}_prefill`: O(admitted) launches per wave, which
//! dominates time-to-first-token under bursty load (exactly the
//! serving regime the paper's batch/sequence-scaling measurements
//! target).  [`PrefillWave`] is the admission-side twin of the decode
//! path's `BatchedAdvance`: the batcher's admission wave is packed
//! into the `[B, S]` lanes of the `{m}_prefill_b` artifact and
//! prefilled with a **single** launch, then each lane seeds its own
//! sequence — compressed rows into the [`CacheManager`], the in-graph
//! effective rows into the sequence's [`EffectiveCache`], and (through
//! the scheduler) its resident `SlotArena` slot.
//!
//! Contract of the batched entry: lane `b` of `{m}_prefill_b` is
//! **bit-identical** to a `{m}_prefill` call on that request alone
//! (per-lane length masking keeps padded rows and dead lanes inert;
//! proven in `python/tests/test_decode_parity.py`).  That is what
//! makes a batched wave bitwise-equivalent to sequential prefills —
//! watermarks, stored streams, effective-cache contents, and sampled
//! first tokens included — asserted without artifacts in
//! `rust/tests/wave_prefill.rs` via [`LaneWiseMockPrefiller`], and
//! over real artifacts in `tests/pipeline_integration.rs`.
//!
//! Fallback ladder, mirroring the decoder's (`DESIGN.md` §3.1):
//!
//! 1. `{m}_prefill_b` — `[B, S]` cross-request batched prefill; waves
//!    larger than the compiled capacity chunk, unused lanes zero-pad.
//! 2. `{m}_prefill` — per-request: lone admissions (padding the
//!    batched entry would cost more than it saves) and artifact sets
//!    that predate the batched entry (`wave_capacity() == None`).
//!
//! **Cross-request prefix sharing** (DESIGN.md §6) sits in front of the
//! ladder: prefill is a pure function of the clamped prompt tokens, so
//! a lane whose clamped prompt was already computed — by an earlier
//! lane in the same wave (`batcher::plan_dedup`) or by a previous
//! admission whose [`PromptTemplate`] is still cached — admits with
//! **zero launches**: its block-aligned prefix rows attach to the
//! refcounted shared chain inside the [`CacheManager`]
//! (`attach_prefix`), its tail rows and first-token logits replay from
//! the template, and its effective rows seed by reference
//! (`EffectiveCache::seed_shared`, copy-on-write).  Launched lanes
//! still share storage: `CacheManager::ingest_prompt_shared` references
//! any leading chunk another admission already stored instead of
//! re-storing it.  Prefill launches and prefix cache bytes are
//! therefore ∝ distinct prompts, not requests.

use super::batcher::{plan_dedup, wave_bucket};
use super::effective::{EffTemplate, EffectiveCache};
use crate::kvcache::{CacheManager, SharedIngest};
use crate::model::ModelSpec;
use crate::runtime::Tensor;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// Positional indices of the seven prefill outputs inside a
/// [`WaveOutput`] — the order `{m}_prefill[_b]` emits them.
pub mod lane_out {
    /// `[cap, V]` last-position logits
    pub const LOGITS: usize = 0;
    /// `[cap, L, S, kvd]` raw K rows
    pub const K_RAW: usize = 1;
    /// `[cap, L, S, kvd]` raw V rows
    pub const V_RAW: usize = 2;
    /// `[cap, L, S, dl]` K latents
    pub const K_LAT: usize = 3;
    /// `[cap, L, S, dl]` V latents
    pub const V_LAT: usize = 4;
    /// `[cap, L, S, kvd]` store-transformed (effective) K rows
    pub const K_EFF: usize = 5;
    /// `[cap, L, S, kvd]` store-transformed (effective) V rows
    pub const V_EFF: usize = 6;
}

/// Outputs of one prefill launch: the seven output tensors
/// ([`lane_out`] order), each packed `[cap, ...]` lane-major, of which
/// the first `lanes` lanes carry live requests.  Holds the executed
/// tensors themselves — lane reads are borrows, so admission is
/// zero-copy up to the cache-manager ingest.  A per-request launch is
/// the `cap == lanes == 1` case; the ingestion path is identical on
/// every ladder rung.
pub struct WaveOutput {
    tensors: Vec<(String, Tensor)>,
    /// lane pitch of the packed tensors (the compiled B)
    cap: usize,
    /// leading lanes that carry live requests
    lanes: usize,
}

impl WaveOutput {
    /// Wrap one launch's outputs (exactly the seven prefill outputs,
    /// in [`lane_out`] order); `cap` is the compiled lane count,
    /// `lanes` how many leading lanes are live.
    pub fn new(tensors: Vec<(String, Tensor)>, cap: usize, lanes: usize) -> Result<WaveOutput> {
        anyhow::ensure!(
            tensors.len() == 7,
            "prefill must produce 7 outputs, got {}",
            tensors.len()
        );
        anyhow::ensure!(
            lanes >= 1 && lanes <= cap,
            "{lanes} live lanes out of range for capacity {cap}"
        );
        Ok(WaveOutput { tensors, cap, lanes })
    }

    /// Live lanes carried.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Borrow one lane of output `out` (a [`lane_out`] index).
    pub fn lane(&self, out: usize, lane: usize) -> Result<&[f32]> {
        debug_assert!(lane < self.lanes);
        let (name, t) = &self.tensors[out];
        let d = t.as_f32()?;
        anyhow::ensure!(
            d.len() % self.cap == 0,
            "prefill output {name} is not divisible into {} lanes",
            self.cap
        );
        let n = d.len() / self.cap;
        Ok(&d[lane * n..(lane + 1) * n])
    }
}

/// Runs the prefill artifacts.  The serving engine implements this
/// over `{m}_prefill_b` / `{m}_prefill`; tests use
/// [`LaneWiseMockPrefiller`] so the wave dataflow is checkable without
/// artifacts.
///
/// Implementations must be pure per-lane maps: lane `i` of
/// `prefill_wave` must equal a `prefill_one` call on that prompt
/// alone, **bitwise** — the property that makes wave admission
/// equivalent to sequential prefill (the L2 `prefill_b` entry
/// satisfies it by construction).
pub trait WavePrefiller {
    /// Lanes of the batched prefill entry, or `None` when only the
    /// per-request entry exists (artifact sets that predate
    /// `prefill_b`, or batched prefill disabled by config).
    fn wave_capacity(&self) -> Option<usize>;

    /// One launch covering every `(prompt, plen)` lane; called with
    /// `2..=wave_capacity()` lanes.  `plen` is already clamped to
    /// `[1, max_seq - 1]`.
    fn prefill_wave(&mut self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput>;

    /// Per-request rung: one launch for one prompt.
    fn prefill_one(&mut self, prompt: &[u8], plen: usize) -> Result<WaveOutput>;
}

/// Launch/padding accounting for the admission path: tests assert one
/// launch per wave, and the bench reports amortized prefill cost.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WaveStats {
    /// admission waves processed (>= 1 request each)
    pub waves: u64,
    /// prefill launches issued (batched chunks and per-request calls)
    pub launches: u64,
    /// requests admitted through a batched launch
    pub batched_lanes: u64,
    /// requests admitted through the per-request rung (lone
    /// admissions, capacity chunk remainders, or no batched entry)
    pub fallback_prefills: u64,
    /// lane rows staged beyond each prompt's length, summed up to the
    /// wave's padded bucket (`batcher::wave_bucket`) — the padding
    /// cost of batching admission
    pub padded_rows: u64,
    /// requests admitted with **zero** prefill launches: their clamped
    /// prompt was already computed by an earlier lane of the same wave
    /// or a cached [`PromptTemplate`] (launches ∝ distinct prompts)
    pub shared_admissions: u64,
    /// prompt rows served from the shared prefix store instead of a
    /// fresh prefill's output: whole prompts of zero-launch admissions
    /// plus reused leading chunks of launched lanes
    pub shared_rows: u64,
}

/// Everything needed to admit one more request with an identical
/// clamped prompt at **zero prefill launches**: the prompt's
/// block-aligned prefix lives refcounted in the cache manager's shared
/// chain (`leaf`, pinned while this template is cached), the unshared
/// tail rows and last-position logits are replayed from here, and the
/// effective rows seed by reference through the shared [`EffTemplate`].
#[derive(Debug)]
pub struct PromptTemplate {
    /// clamped prompt rows the template covers
    pub plen: usize,
    /// leaf of the shared prefix chain covering the block-aligned
    /// leading rows (`None` when the prompt is shorter than one block)
    pub leaf: Option<u32>,
    /// rows covered by the shared chain
    pub prefix_rows: usize,
    /// `[V]` last-position logits the first token is sampled from
    pub logits: Vec<f32>,
    /// `[L, tail, dl]` K latents of the unshared tail rows
    pub k_lat_tail: Vec<f32>,
    /// `[L, tail, dl]` V latents of the unshared tail rows
    pub v_lat_tail: Vec<f32>,
    /// `[L, tail, kvd]` raw K rows of the unshared tail
    pub k_raw_tail: Vec<f32>,
    /// `[L, tail, kvd]` raw V rows of the unshared tail
    pub v_raw_tail: Vec<f32>,
    /// shared effective-row seed (`None` when registered under faithful
    /// mode, which reconstructs from the store instead of seeding)
    pub eff: Option<Arc<EffTemplate>>,
}

impl PromptTemplate {
    /// Host bytes this template holds (tail rows, logits, and the
    /// shared effective seed — the dominant term at real model sizes).
    pub fn host_bytes(&self) -> usize {
        let eff = self.eff.as_ref().map_or(0, |e| (e.k.len() + e.v.len()) * 4);
        (self.logits.len()
            + self.k_lat_tail.len()
            + self.v_lat_tail.len()
            + self.k_raw_tail.len()
            + self.v_raw_tail.len())
            * 4
            + eff
    }
}

/// Default host-byte budget for cached templates (64 MiB): effective
/// seeds are `2·L·plen·kvd` f32 each, so an entry-count cap alone would
/// let long prompts at real model sizes pin gigabytes of host RAM.
pub const TEMPLATE_BYTE_BUDGET: usize = 64 << 20;

/// Bounded FIFO cache of [`PromptTemplate`]s keyed by clamped prompt —
/// the cross-wave half of zero-launch admission.  Bounded twice: by
/// distinct-prompt count and by **host bytes**
/// ([`TEMPLATE_BYTE_BUDGET`]; templates carry the prompt's effective
/// rows, which dominate at real model sizes and are invisible to the
/// device-side `cache_budget`).  Each cached template pins its prefix
/// chain in the [`CacheManager`] (`prefix_ref`), and eviction or
/// [`TemplateCache::clear`] releases the pin, so template lifetime and
/// chain lifetime can never drift apart.
#[derive(Debug)]
pub struct TemplateCache {
    map: HashMap<Vec<u8>, Arc<PromptTemplate>>,
    order: VecDeque<Vec<u8>>,
    cap: usize,
    byte_budget: usize,
    bytes: usize,
}

impl Default for TemplateCache {
    fn default() -> Self {
        TemplateCache::new(32)
    }
}

impl TemplateCache {
    /// Cache holding at most `cap` distinct prompts and at most
    /// [`TEMPLATE_BYTE_BUDGET`] host bytes (FIFO eviction on both).
    pub fn new(cap: usize) -> Self {
        TemplateCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            byte_budget: TEMPLATE_BYTE_BUDGET,
            bytes: 0,
        }
    }

    /// Distinct prompts currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether no template is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Host bytes currently held by cached templates.
    pub fn host_bytes(&self) -> usize {
        self.bytes
    }

    /// The current host-byte budget.
    pub fn byte_budget(&self) -> usize {
        self.byte_budget
    }

    /// Override the host-byte budget (clamped to at least one byte).
    /// Evictions need the [`CacheManager`] to release chain pins, so a
    /// tightened bound takes effect at the next insertion rather than
    /// immediately.
    pub fn set_byte_budget(&mut self, bytes: usize) {
        self.byte_budget = bytes.max(1);
    }

    fn get(&self, key: &[u8]) -> Option<Arc<PromptTemplate>> {
        self.map.get(key).cloned()
    }

    fn drop_entry(&mut self, cache: &mut CacheManager, key: &[u8]) {
        if let Some(old) = self.map.remove(key) {
            self.bytes -= old.host_bytes();
            if let Some(leaf) = old.leaf {
                cache.prefix_unref(leaf);
            }
        }
    }

    fn insert(&mut self, cache: &mut CacheManager, key: Vec<u8>, t: Arc<PromptTemplate>) {
        self.bytes += t.host_bytes();
        if let Some(old) = self.map.insert(key.clone(), t) {
            // re-registration (e.g. the serving mode flipped): swap the
            // chain pin and accounting, the FIFO slot stays
            self.bytes -= old.host_bytes();
            if let Some(leaf) = old.leaf {
                cache.prefix_unref(leaf);
            }
        } else {
            self.order.push_back(key);
        }
        // count bound, then byte bound — enforced on re-registrations
        // too (a swapped-in template can be bigger than the one it
        // replaced), always keeping at least one entry so an oversized
        // prompt degrades to a cache-of-one instead of thrashing to zero
        while self.order.len() > self.cap
            || (self.bytes > self.byte_budget && self.order.len() > 1)
        {
            let evict = self.order.pop_front().expect("non-empty order");
            self.drop_entry(cache, &evict);
        }
    }

    /// Evict the oldest template (releasing its chain pin); `false`
    /// when nothing is cached.  The scheduler's memory-pressure valve:
    /// a pinned chain with no live sharers holds device bytes only a
    /// template eviction can free.
    pub fn shed_oldest(&mut self, cache: &mut CacheManager) -> bool {
        let Some(evict) = self.order.pop_front() else {
            return false;
        };
        self.drop_entry(cache, &evict);
        true
    }

    /// Leaves currently pinned by cached templates (refcount audits).
    pub fn pinned_leaves(&self) -> Vec<u32> {
        self.map.values().filter_map(|t| t.leaf).collect()
    }

    /// Drop every template and release its chain pin.
    pub fn clear(&mut self, cache: &mut CacheManager) {
        for (_, t) in self.map.drain() {
            if let Some(leaf) = t.leaf {
                cache.prefix_unref(leaf);
            }
        }
        self.order.clear();
        self.bytes = 0;
    }
}

/// One admitted request's handles out of a wave: the sequence created
/// for it and the logits its first token is sampled from.
pub struct AdmittedLane {
    /// cache-manager sequence holding the prompt's compressed rows
    pub cache_id: u64,
    /// `[V]` last-position logits (the scheduler samples from these)
    pub logits: Vec<f32>,
}

/// Bounded FIFO memory of clamped prompts seen at least once: a
/// [`PromptTemplate`] (which copies the lane's full effective rows) is
/// only worth building for prompts that actually repeat, so the first
/// occurrence just records the key here and the *second* occurrence
/// builds and caches the template — unique-prompt traffic then pays no
/// template memcpys and never churns the template cache.
#[derive(Debug)]
struct SeenKeys {
    set: std::collections::HashSet<Vec<u8>>,
    order: VecDeque<Vec<u8>>,
    cap: usize,
}

impl SeenKeys {
    fn new(cap: usize) -> Self {
        SeenKeys {
            set: std::collections::HashSet::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
        }
    }

    /// Whether `key` was seen before; records it either way.
    fn check_and_record(&mut self, key: &[u8]) -> bool {
        if self.set.contains(key) {
            return true;
        }
        self.set.insert(key.to_vec());
        self.order.push_back(key.to_vec());
        while self.order.len() > self.cap {
            let evict = self.order.pop_front().expect("non-empty order");
            self.set.remove(&evict);
        }
        false
    }
}

impl Default for SeenKeys {
    fn default() -> Self {
        SeenKeys::new(128)
    }
}

/// The admission-wave planner: dedups the wave against itself and the
/// cached [`PromptTemplate`]s (zero-launch admissions), packs the
/// remaining distinct prompts through the prefill ladder, ingests each
/// lane's compressed rows — sharing block-aligned prefixes through the
/// cache manager's refcounted trie — and seeds each sequence's
/// effective cache.  Owns the launch accounting ([`WaveStats`]) and the
/// template cache; one planner per serving engine.
#[derive(Debug, Default)]
pub struct PrefillWave {
    /// launch/padding/sharing accounting for the admission path
    pub stats: WaveStats,
    templates: TemplateCache,
    seen: SeenKeys,
}

/// How one wave lane is admitted (planned before any launch).
enum LanePlan {
    /// zero-launch: replay a cached template from a previous wave
    Cached(Arc<PromptTemplate>),
    /// zero-launch: duplicate of an earlier lane in this wave (always a
    /// `Launch` lane — template hits dedup through `Cached` instead)
    Dup(usize),
    /// real prefill; index into the wave's deduplicated launch list
    Launch(usize),
}

impl PrefillWave {
    /// Empty planner with the default template capacity.
    pub fn new() -> Self {
        Self::default()
    }

    /// Planner whose template cache holds at most `cap` distinct
    /// prompts (FIFO eviction; evicted templates unpin their chains).
    pub fn with_template_capacity(cap: usize) -> Self {
        PrefillWave {
            stats: WaveStats::default(),
            templates: TemplateCache::new(cap),
            seen: SeenKeys::default(),
        }
    }

    /// Distinct prompts whose templates are cached for zero-launch
    /// re-admission.
    pub fn cached_prompts(&self) -> usize {
        self.templates.len()
    }

    /// Host bytes the cached templates hold (bounded by
    /// [`TEMPLATE_BYTE_BUDGET`] unless overridden through
    /// [`PrefillWave::set_template_byte_budget`]).
    pub fn template_bytes(&self) -> usize {
        self.templates.host_bytes()
    }

    /// Override the template cache's host-byte budget — plumbed from
    /// `ServeConfig::template_byte_budget` (serve CLI
    /// `--template-budget`) so deployments can size the host-RAM
    /// ceiling per machine instead of living with the 64 MiB default.
    pub fn set_template_byte_budget(&mut self, bytes: usize) {
        self.templates.set_byte_budget(bytes);
    }

    /// Prefix-chain leaves pinned by cached templates (refcount audits:
    /// pass to `CacheManager::prefix_integrity`).
    pub fn pinned_leaves(&self) -> Vec<u32> {
        self.templates.pinned_leaves()
    }

    /// Drop every cached template and release its chain pin (the
    /// template cache's contribution to `prefix_stats` goes to zero
    /// once no sequence references the chains either).
    pub fn clear_templates(&mut self, cache: &mut CacheManager) {
        self.templates.clear(cache);
    }

    /// Evict the oldest cached template (see
    /// [`TemplateCache::shed_oldest`]); `false` when none is cached.
    pub fn shed_oldest_template(&mut self, cache: &mut CacheManager) -> bool {
        self.templates.shed_oldest(cache)
    }

    /// Admit one wave of prompts: dedup identical clamped prompts
    /// (within the wave via `batcher::plan_dedup`, across waves via the
    /// template cache) into zero-launch admissions, prefill the
    /// remaining distinct prompts (one launch per capacity chunk when
    /// the runner has a batched entry), ingest every lane's compressed
    /// rows into `cache` — block-aligned prefixes shared through the
    /// refcounted trie when `share_prefixes` — and register each
    /// sequence's [`EffectiveCache`] in `effs`: seeded from the lane's
    /// in-graph effective rows when `seed_effective` (zero-launch lanes
    /// seed by reference, copy-on-write), while the faithful mode
    /// leaves the watermark at 0 so the first decode round reconstructs
    /// the prompt from the (possibly shared) store.
    ///
    /// The wave is transactional: launches run first (they touch no
    /// persistent state), an ingestion failure frees every sequence the
    /// wave already created *and* unpins the templates it built — a
    /// half-admitted wave would otherwise leak rows the scheduler can
    /// neither see nor retire — and the wave's new templates enter the
    /// bounded cache only after every lane ingested (a mid-wave
    /// eviction could otherwise free a chain a planned `Cached` lane
    /// still needs).
    ///
    /// Returns one [`AdmittedLane`] per prompt, in order.
    #[allow(clippy::too_many_arguments)]
    pub fn admit_wave<P: WavePrefiller>(
        &mut self,
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        spec: &ModelSpec,
        seed_effective: bool,
        share_prefixes: bool,
        prompts: &[&[u8]],
        runner: &mut P,
    ) -> Result<Vec<AdmittedLane>> {
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        self.stats.waves += 1;
        let s = spec.max_seq;
        let plens: Vec<usize> = prompts.iter().map(|p| p.len().clamp(1, s - 1)).collect();
        // clamped token keys: prefill only ever sees rows [0, plen), so
        // equal keys are the same computation (short prompts pad with
        // zero tokens, matching the artifact's zero-padded lanes).
        // Built only when sharing needs them — the sharing-off baseline
        // keeps borrowing the prompt slices as before.
        let toks: Vec<Vec<u8>> = if share_prefixes {
            prompts
                .iter()
                .zip(&plens)
                .map(|(p, &plen)| (0..plen).map(|t| p.get(t).copied().unwrap_or(0)).collect())
                .collect()
        } else {
            Vec::new()
        };
        let keys: Vec<&[u8]> = toks.iter().map(|t| t.as_slice()).collect();

        // plan each lane; only distinct, uncached prompts launch
        let dup = if share_prefixes {
            plan_dedup(&keys)
        } else {
            vec![None; prompts.len()]
        };
        let mut plans: Vec<LanePlan> = Vec::with_capacity(prompts.len());
        let mut launches: Vec<(&[u8], usize)> = Vec::new();
        // launch lanes worth a template: the key repeats within this
        // wave (a Dup lane will replay it) or was seen in an earlier
        // wave — templates copy the lane's full effective rows, so
        // unique-prompt traffic should not pay for them
        let mut wants_template: Vec<bool> = Vec::new();
        for i in 0..prompts.len() {
            if share_prefixes {
                let key = keys[i];
                if let Some(t) = self.templates.get(key) {
                    // faithful and in-graph templates don't interchange
                    if t.eff.is_some() == seed_effective {
                        plans.push(LanePlan::Cached(t));
                        continue;
                    }
                }
                if let Some(j) = dup[i] {
                    match &plans[j] {
                        LanePlan::Launch(li) => {
                            wants_template[*li] = true;
                            plans.push(LanePlan::Dup(j));
                            continue;
                        }
                        LanePlan::Cached(t) => {
                            plans.push(LanePlan::Cached(t.clone()));
                            continue;
                        }
                        // j is the earliest occurrence of the key, so it
                        // cannot itself be a duplicate
                        LanePlan::Dup(_) => unreachable!("dedup target is a duplicate"),
                    }
                }
            }
            plans.push(LanePlan::Launch(launches.len()));
            wants_template.push(share_prefixes && self.seen.check_and_record(keys[i]));
            // the runner sees the clamped tokens when sharing (the key
            // IS the computation) and the raw prompt otherwise —
            // bitwise the same lane either way, since prefill reads
            // only tokens [0, plen)
            launches.push(if share_prefixes {
                (keys[i], plens[i])
            } else {
                (prompts[i], plens[i])
            });
        }

        // phase 1: launches over the deduplicated lanes.  Chunk by
        // capacity; a lone chunk prefills cheaper through the unpadded
        // per-request entry (same policy as the decoder ladder's
        // lone-row rule), as does everything when no batched entry
        // exists (capacity 1).
        let cap = runner.wave_capacity().filter(|&c| c > 1).unwrap_or(1);
        let mut outputs: Vec<WaveOutput> = Vec::new();
        let mut launch_loc: Vec<(usize, usize)> = Vec::with_capacity(launches.len());
        let mut start = 0usize;
        while start < launches.len() {
            let group = &launches[start..(start + cap).min(launches.len())];
            let w = if group.len() == 1 {
                self.stats.fallback_prefills += 1;
                runner.prefill_one(group[0].0, group[0].1)?
            } else {
                let w = runner.prefill_wave(group)?;
                anyhow::ensure!(
                    w.lanes() == group.len(),
                    "prefill wave returned {} lanes for {} prompts",
                    w.lanes(),
                    group.len()
                );
                self.stats.batched_lanes += group.len() as u64;
                let bucket = wave_bucket(group.iter().map(|g| g.1), s);
                for &(_, plen) in group {
                    self.stats.padded_rows += (bucket - plen.min(bucket)) as u64;
                }
                w
            };
            self.stats.launches += 1;
            for lane in 0..group.len() {
                launch_loc.push((outputs.len(), lane));
            }
            outputs.push(w);
            start += group.len();
        }

        // phase 2: ingestion in request order, with rollback on failure.
        // Launched lanes flagged `wants_template` build one for their
        // duplicates (this wave via `wave_templates`, future waves via
        // the cache) — but registration into the bounded cache is
        // DEFERRED to the end of the wave: an insert can evict an older
        // template, and evicting mid-wave could free (and let the trie
        // recycle the node ids of) a chain that a later `Cached` lane
        // of this same wave was planned against.  Until the wave
        // completes, planned chains stay alive through the cache's
        // existing pins.
        let mut admitted: Vec<AdmittedLane> = Vec::with_capacity(prompts.len());
        let mut wave_templates: HashMap<usize, Arc<PromptTemplate>> = HashMap::new();
        let mut to_register: Vec<(Vec<u8>, Arc<PromptTemplate>)> = Vec::new();
        for (i, plan) in plans.iter().enumerate() {
            let res = match plan {
                LanePlan::Launch(li) => {
                    let (oi, lane) = launch_loc[*li];
                    let w = &outputs[oi];
                    let toks_i: &[u8] = if share_prefixes { keys[i] } else { &[] };
                    match Self::ingest(
                        cache,
                        effs,
                        spec,
                        seed_effective,
                        share_prefixes,
                        w,
                        (lane, toks_i, plens[i]),
                    ) {
                        Ok((a, info)) => {
                            self.stats.shared_rows += info.reused_rows as u64;
                            let mut reg_err = None;
                            if share_prefixes && wants_template[*li] {
                                match Self::build_template(
                                    cache,
                                    spec,
                                    seed_effective,
                                    w,
                                    lane,
                                    keys[i],
                                    &a.logits,
                                    &info,
                                ) {
                                    Ok(t) => {
                                        wave_templates.insert(*li, t.clone());
                                        to_register.push((keys[i].to_vec(), t));
                                    }
                                    Err(e) => reg_err = Some(e),
                                }
                            }
                            match reg_err {
                                None => Ok(a),
                                Some(e) => {
                                    cache.free_sequence(a.cache_id);
                                    effs.remove(&a.cache_id);
                                    Err(e)
                                }
                            }
                        }
                        Err(e) => Err(e),
                    }
                }
                LanePlan::Dup(j) => {
                    let li = match &plans[*j] {
                        LanePlan::Launch(li) => *li,
                        _ => unreachable!("duplicates target launch lanes"),
                    };
                    let t = wave_templates
                        .get(&li)
                        .expect("launched lane registered its template")
                        .clone();
                    Self::ingest_template(cache, effs, spec, seed_effective, &t).map(|a| {
                        self.stats.shared_admissions += 1;
                        self.stats.shared_rows += t.plen as u64;
                        a
                    })
                }
                LanePlan::Cached(t) => {
                    Self::ingest_template(cache, effs, spec, seed_effective, t).map(|a| {
                        self.stats.shared_admissions += 1;
                        self.stats.shared_rows += t.plen as u64;
                        a
                    })
                }
            };
            match res {
                Ok(a) => admitted.push(a),
                Err(e) => {
                    // free every admitted sequence and release the pins
                    // build_template took for not-yet-registered
                    // templates, so a failed wave leaves no state behind
                    for a in &admitted {
                        cache.free_sequence(a.cache_id);
                        effs.remove(&a.cache_id);
                    }
                    for (_, t) in &to_register {
                        if let Some(leaf) = t.leaf {
                            cache.prefix_unref(leaf);
                        }
                    }
                    return Err(e);
                }
            }
        }
        // the wave is committed: register its templates (evictions are
        // now safe — every planned lane has attached its chain, so
        // freed templates can no longer strand an admission in flight)
        for (key, t) in to_register {
            self.templates.insert(cache, key, t);
        }
        Ok(admitted)
    }

    /// Seed one launched lane: create the sequence, ingest its
    /// compressed prompt rows (prefix-shared when `share` — leading
    /// chunks another admission stored are referenced, not re-stored),
    /// and register its effective-cache scratch.  `lane` is
    /// `(lane_index, clamped_tokens, plen)`; the tokens are only
    /// consulted on the shared path (empty otherwise).  Frees the
    /// sequence it created if the ingest fails partway, so errors leave
    /// no orphaned state.
    fn ingest(
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        spec: &ModelSpec,
        seed_effective: bool,
        share: bool,
        w: &WaveOutput,
        lane: (usize, &[u8], usize),
    ) -> Result<(AdmittedLane, SharedIngest)> {
        let (lane, toks, plen) = lane;
        debug_assert!(!share || toks.len() == plen);
        let (l, s, kvd, dl) = (spec.n_layer, spec.max_seq, spec.kv_dim(), spec.ae_latent);
        // borrow every lane slice before touching persistent state
        let logits = w.lane(lane_out::LOGITS, lane)?;
        let k_raw = w.lane(lane_out::K_RAW, lane)?;
        let v_raw = w.lane(lane_out::V_RAW, lane)?;
        let k_lat = w.lane(lane_out::K_LAT, lane)?;
        let v_lat = w.lane(lane_out::V_LAT, lane)?;
        let k_eff = w.lane(lane_out::K_EFF, lane)?;
        let v_eff = w.lane(lane_out::V_EFF, lane)?;
        anyhow::ensure!(
            k_raw.len() == l * s * kvd && k_lat.len() == l * s * dl,
            "prefill lane shapes do not match the model spec"
        );
        let id = cache.create_sequence();
        let info = if share {
            match cache.ingest_prompt_shared(id, toks, s, k_lat, v_lat, k_raw, v_raw) {
                Ok(info) => info,
                Err(e) => {
                    cache.free_sequence(id); // e.g. pool budget exceeded
                    return Err(e);
                }
            }
        } else {
            if let Err(e) = cache.append_rows(id, plen, s, k_lat, v_lat, k_raw, v_raw) {
                cache.free_sequence(id); // e.g. pool budget exceeded
                return Err(e);
            }
            SharedIngest {
                prefix_rows: 0,
                reused_rows: 0,
                leaf: None,
            }
        };
        let mut eff = EffectiveCache::new(spec);
        if seed_effective {
            eff.seed(cache, id, k_eff, v_eff, plen);
        }
        effs.insert(id, eff);
        Ok((
            AdmittedLane {
                cache_id: id,
                logits: logits.to_vec(),
            },
            info,
        ))
    }

    /// Admit one request entirely from a [`PromptTemplate`] — **zero
    /// launches**: attach the shared chain, replay the unshared tail
    /// rows, seed the effective cache by reference (copy-on-write), and
    /// hand back the template's logits.  Bitwise-identical to what a
    /// fresh prefill of the same clamped prompt would have produced,
    /// because prefill is a pure function of those tokens.
    fn ingest_template(
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        spec: &ModelSpec,
        seed_effective: bool,
        t: &PromptTemplate,
    ) -> Result<AdmittedLane> {
        let id = cache.create_sequence();
        let tail = t.plen - t.prefix_rows;
        let staged = (|| -> Result<()> {
            if let Some(leaf) = t.leaf {
                cache.attach_prefix(id, leaf)?;
            }
            cache.append_rows(
                id,
                tail,
                tail,
                &t.k_lat_tail,
                &t.v_lat_tail,
                &t.k_raw_tail,
                &t.v_raw_tail,
            )
        })();
        if let Err(e) = staged {
            cache.free_sequence(id);
            return Err(e);
        }
        let mut eff = EffectiveCache::new(spec);
        if seed_effective {
            let tmpl = t
                .eff
                .as_ref()
                .expect("in-graph admission needs a seeded template")
                .clone();
            eff.seed_shared(cache, id, tmpl);
        }
        effs.insert(id, eff);
        Ok(AdmittedLane {
            cache_id: id,
            logits: t.logits.clone(),
        })
    }

    /// Build the zero-launch admission template for one launched lane:
    /// pin its prefix chain, copy its unshared tail rows and logits,
    /// and (in-graph mode) pack its effective rows into a shared
    /// [`EffTemplate`] every future sharer seeds by reference.
    #[allow(clippy::too_many_arguments)]
    fn build_template(
        cache: &mut CacheManager,
        spec: &ModelSpec,
        seed_effective: bool,
        w: &WaveOutput,
        lane: usize,
        toks: &[u8],
        logits: &[f32],
        info: &SharedIngest,
    ) -> Result<Arc<PromptTemplate>> {
        let (l, s, kvd, dl) = (spec.n_layer, spec.max_seq, spec.kv_dim(), spec.ae_latent);
        let plen = toks.len();
        let tail = plen - info.prefix_rows;
        let slice_tail = |buf: &[f32], width: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; l * tail * width];
            for layer in 0..l {
                let src = layer * s * width + info.prefix_rows * width;
                out[layer * tail * width..(layer + 1) * tail * width]
                    .copy_from_slice(&buf[src..src + tail * width]);
            }
            out
        };
        let pack_rows = |buf: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; l * plen * kvd];
            for layer in 0..l {
                let src = layer * s * kvd;
                out[layer * plen * kvd..(layer + 1) * plen * kvd]
                    .copy_from_slice(&buf[src..src + plen * kvd]);
            }
            out
        };
        let k_lat = w.lane(lane_out::K_LAT, lane)?;
        let v_lat = w.lane(lane_out::V_LAT, lane)?;
        let k_raw = w.lane(lane_out::K_RAW, lane)?;
        let v_raw = w.lane(lane_out::V_RAW, lane)?;
        let eff = if seed_effective {
            let k_eff = w.lane(lane_out::K_EFF, lane)?;
            let v_eff = w.lane(lane_out::V_EFF, lane)?;
            Some(Arc::new(EffTemplate {
                rows: plen,
                k: pack_rows(k_eff),
                v: pack_rows(v_eff),
            }))
        } else {
            None
        };
        if let Some(leaf) = info.leaf {
            cache.prefix_ref(leaf)?;
        }
        Ok(Arc::new(PromptTemplate {
            plen,
            leaf: info.leaf,
            prefix_rows: info.prefix_rows,
            logits: logits.to_vec(),
            k_lat_tail: slice_tail(k_lat, dl),
            v_lat_tail: slice_tail(v_lat, dl),
            k_raw_tail: slice_tail(k_raw, kvd),
            v_raw_tail: slice_tail(v_raw, kvd),
            eff,
        }))
    }
}

/// Deterministic lane-wise mock prefiller for tests and benches: every
/// output element is a pure function of the lane's prompt bytes and
/// position (like the real per-lane transformer), so a batched wave is
/// bitwise-equal to per-request calls by construction — the one
/// [`WavePrefiller`] contract the wave-equivalence tests rely on.
/// Counts calls on both rungs so tests can assert launch laws.
pub struct LaneWiseMockPrefiller {
    n_layer: usize,
    max_seq: usize,
    kv_dim: usize,
    ae_latent: usize,
    vocab: usize,
    /// capacity reported through [`WavePrefiller::wave_capacity`];
    /// `None` simulates an artifact set without `prefill_b`
    pub capacity: Option<usize>,
    /// batched (`prefill_wave`) launches observed
    pub wave_calls: u64,
    /// per-request (`prefill_one`) launches observed
    pub single_calls: u64,
}

impl LaneWiseMockPrefiller {
    /// Mock sized for `spec`, batch-capable with a default capacity of 8.
    pub fn for_spec(spec: &ModelSpec) -> Self {
        LaneWiseMockPrefiller {
            n_layer: spec.n_layer,
            max_seq: spec.max_seq,
            kv_dim: spec.kv_dim(),
            ae_latent: spec.ae_latent,
            vocab: spec.vocab,
            capacity: Some(8),
            wave_calls: 0,
            single_calls: 0,
        }
    }

    /// Override the reported capacity (None = no batched entry).
    pub fn with_capacity(mut self, capacity: Option<usize>) -> Self {
        self.capacity = capacity;
        self
    }

    /// Pure per-element value: mixes prompt byte, stream tag, layer,
    /// token, and element index so distinct prompts produce distinct
    /// (but reproducible) tensors.
    fn val(tag: u32, byte: u8, layer: usize, t: usize, j: usize) -> f32 {
        let h = tag
            .wrapping_mul(0x9E37)
            .wrapping_add(byte as u32 * 131)
            .wrapping_add(layer as u32 * 31)
            .wrapping_add(t as u32 * 7)
            .wrapping_add(j as u32);
        ((h % 2003) as f32 - 1001.0) / 257.0
    }

    /// Fill one lane of the seven positional buffers ([`lane_out`]
    /// order) with the pure per-lane map.
    fn fill_lane(&self, prompt: &[u8], plen: usize, lane: usize, bufs: &mut [Vec<f32>; 7]) {
        let (l, s, kvd, dl, v) = (
            self.n_layer,
            self.max_seq,
            self.kv_dim,
            self.ae_latent,
            self.vocab,
        );
        // empty prompts still prefill one (zero) token row, matching
        // the artifact path's zero-padded lane
        let byte = |t: usize| {
            if prompt.is_empty() {
                0
            } else {
                prompt[t % prompt.len()]
            }
        };
        for layer in 0..l {
            for t in 0..plen {
                for j in 0..kvd {
                    let base = lane * l * s * kvd + layer * s * kvd + t * kvd + j;
                    bufs[lane_out::K_RAW][base] = Self::val(1, byte(t), layer, t, j);
                    bufs[lane_out::V_RAW][base] = Self::val(2, byte(t), layer, t, j);
                    bufs[lane_out::K_EFF][base] = Self::val(5, byte(t), layer, t, j);
                    bufs[lane_out::V_EFF][base] = Self::val(6, byte(t), layer, t, j);
                }
                for j in 0..dl {
                    let base = lane * l * s * dl + layer * s * dl + t * dl + j;
                    bufs[lane_out::K_LAT][base] = Self::val(3, byte(t), layer, t, j);
                    bufs[lane_out::V_LAT][base] = Self::val(4, byte(t), layer, t, j);
                }
            }
        }
        for j in 0..v {
            bufs[lane_out::LOGITS][lane * v + j] = Self::val(7, byte(plen - 1), plen, j, j);
        }
    }

    /// Build one launch's output for the given lanes (pure per lane).
    fn build(&self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput> {
        let (l, s, kvd, dl, v) = (
            self.n_layer,
            self.max_seq,
            self.kv_dim,
            self.ae_latent,
            self.vocab,
        );
        let n = prompts.len();
        let mut bufs: [Vec<f32>; 7] = [
            vec![0.0; n * v],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * dl],
            vec![0.0; n * l * s * dl],
            vec![0.0; n * l * s * kvd],
            vec![0.0; n * l * s * kvd],
        ];
        for (lane, &(p, plen)) in prompts.iter().enumerate() {
            self.fill_lane(p, plen, lane, &mut bufs);
        }
        let names = ["logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff"];
        let shapes: [Vec<usize>; 7] = [
            vec![n, v],
            vec![n, l, s, kvd],
            vec![n, l, s, kvd],
            vec![n, l, s, dl],
            vec![n, l, s, dl],
            vec![n, l, s, kvd],
            vec![n, l, s, kvd],
        ];
        let tensors = names
            .iter()
            .zip(shapes)
            .zip(bufs)
            .map(|((name, shape), data)| (name.to_string(), Tensor::f32(shape, data)))
            .collect();
        WaveOutput::new(tensors, n, n)
    }
}

impl WavePrefiller for LaneWiseMockPrefiller {
    fn wave_capacity(&self) -> Option<usize> {
        self.capacity
    }

    fn prefill_wave(&mut self, prompts: &[(&[u8], usize)]) -> Result<WaveOutput> {
        if let Some(cap) = self.capacity {
            anyhow::ensure!(prompts.len() <= cap, "wave exceeds mock capacity");
        } else {
            return Err(anyhow!("mock has no batched prefill entry"));
        }
        self.wave_calls += 1;
        self.build(prompts)
    }

    fn prefill_one(&mut self, prompt: &[u8], plen: usize) -> Result<WaveOutput> {
        self.single_calls += 1;
        self.build(&[(prompt, plen)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::model::memory::CompressionPlan;
    use crate::model::Arch;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "wave".into(),
            arch: Arch::Gpt2,
            vocab: 64,
            n_layer: 3,
            d_model: 24,
            n_head: 3,
            n_kv_head: 3,
            d_head: 8,
            ffn_dim: 48,
            max_seq: 32,
            ae_hidden: 16,
            ae_latent: 12,
            bytes_per_el: 4,
        }
    }

    #[test]
    fn mock_wave_lane_equals_single_call_bitwise() {
        let spec = tiny_spec();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let prompts: [&[u8]; 3] = [b"abc", b"defgh", b"z"];
        let lanes: Vec<(&[u8], usize)> = prompts.iter().map(|p| (*p, p.len())).collect();
        let wave = mock.prefill_wave(&lanes).unwrap();
        for (i, &(p, plen)) in lanes.iter().enumerate() {
            let one = mock.prefill_one(p, plen).unwrap();
            for out in 0..7 {
                let a = wave.lane(out, i).unwrap();
                let b = one.lane(out, 0).unwrap();
                assert!(
                    a.len() == b.len()
                        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "mock lane {i} output {out} must be a pure per-lane map"
                );
            }
        }
        assert_eq!((mock.wave_calls, mock.single_calls), (1, 3));
    }

    #[test]
    fn wave_chunks_by_capacity_and_lone_remainder_falls_back() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec).with_capacity(Some(2));
        let mut wave = PrefillWave::new();
        let prompts: Vec<&[u8]> = vec![b"aa", b"bb", b"cc", b"dd", b"ee"];
        let admitted = wave
            .admit_wave(&mut cache, &mut effs, &spec, true, true, &prompts, &mut mock)
            .unwrap();
        assert_eq!(admitted.len(), 5);
        // 5 prompts at capacity 2: two batched chunks + a lone single
        assert_eq!(mock.wave_calls, 2);
        assert_eq!(mock.single_calls, 1);
        assert_eq!(wave.stats.launches, 3);
        assert_eq!(wave.stats.batched_lanes, 4);
        assert_eq!(wave.stats.fallback_prefills, 1);
        // every admission carries its prompt rows and a seeded watermark
        for (lane, p) in admitted.iter().zip(&prompts) {
            assert_eq!(cache.seq_len(lane.cache_id), Some(p.len()));
            assert_eq!(cache.decoded_upto(lane.cache_id), Some(p.len()));
            assert_eq!(lane.logits.len(), spec.vocab);
        }
    }

    #[test]
    fn faithful_mode_leaves_watermark_at_zero() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::new();
        let prompts: Vec<&[u8]> = vec![b"abcd", b"efg"];
        let admitted = wave
            .admit_wave(&mut cache, &mut effs, &spec, false, true, &prompts, &mut mock)
            .unwrap();
        for lane in &admitted {
            assert_eq!(cache.decoded_upto(lane.cache_id), Some(0));
            let eff = &effs[&lane.cache_id];
            assert!(eff.k.iter().all(|&x| x == 0.0), "faithful mode must not seed");
        }
    }

    #[test]
    fn padding_accounting_uses_wave_bucket() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::new();
        // plens 3 and 7 -> bucket 8 -> padding (8-3) + (8-7) = 6
        let prompts: Vec<&[u8]> = vec![b"abc", b"abcdefg"];
        wave.admit_wave(&mut cache, &mut effs, &spec, true, false, &prompts, &mut mock)
            .unwrap();
        assert_eq!(wave.stats.padded_rows, 6);
    }

    #[test]
    fn identical_prompts_admit_with_zero_launches() {
        // launches ∝ distinct prompts: a wave of 4 requests over 2
        // distinct prompts costs one batched launch; the duplicates and
        // every later wave of the same prompts cost zero
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::new();
        // >= one block (16 tokens) so the prefix chain is exercised too
        let p: &[u8] = b"system prompt + few-shot body";
        let q: &[u8] = b"another distinct long prompt!";
        let prompts: Vec<&[u8]> = vec![p, p, q, p];
        let admitted = wave
            .admit_wave(&mut cache, &mut effs, &spec, true, true, &prompts, &mut mock)
            .unwrap();
        assert_eq!(admitted.len(), 4);
        assert_eq!(mock.wave_calls, 1, "only the 2 distinct prompts launch");
        assert_eq!(mock.single_calls, 0);
        assert_eq!(wave.stats.launches, 1);
        assert_eq!(wave.stats.shared_admissions, 2);
        // zero-launch lanes are byte-replays of the launched lane
        for (i, j) in [(1usize, 0usize), (3, 0)] {
            assert_eq!(
                admitted[i].logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                admitted[j].logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "duplicate lane logits must replay the launched lane"
            );
            assert_eq!(cache.seq_len(admitted[i].cache_id), Some(p.len()));
            assert_eq!(cache.decoded_upto(admitted[i].cache_id), Some(p.len()));
        }
        // sharers reference one stored prefix: bytes held once
        assert!(cache.seq_prefix_rows(admitted[1].cache_id) >= 16);
        assert_eq!(
            cache.seq_shared_bytes(admitted[0].cache_id),
            cache.seq_shared_bytes(admitted[1].cache_id)
        );
        // a later wave of an already-cached prompt costs zero launches
        let again = wave
            .admit_wave(&mut cache, &mut effs, &spec, true, true, &[p], &mut mock)
            .unwrap();
        assert_eq!(wave.stats.launches, 1, "cached prompt must not launch");
        assert_eq!(wave.stats.shared_admissions, 3);
        assert_eq!(cache.seq_len(again[0].cache_id), Some(p.len()));
        cache.prefix_integrity(&wave.pinned_leaves()).unwrap();
        // retiring everything + clearing templates releases every byte
        for a in admitted.iter().chain(again.iter()) {
            cache.free_sequence(a.cache_id);
        }
        wave.clear_templates(&mut cache);
        cache.prefix_integrity(&[]).unwrap();
        assert_eq!(cache.prefix_stats().nodes_live, 0);
        assert_eq!(cache.pool_stats().live_bytes, 0);
    }

    #[test]
    fn templates_are_lazy_evictable_and_mode_aware() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 1);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::with_template_capacity(1);
        let p: &[u8] = b"sixteen-plus token prompt p";
        let q: &[u8] = b"sixteen-plus token prompt q";
        // lazy templates: a never-repeated prompt builds none...
        wave.admit_wave(&mut cache, &mut effs, &spec, true, true, &[q], &mut mock)
            .unwrap();
        assert_eq!(wave.cached_prompts(), 0, "unique prompts pay no template");
        // ...a within-wave duplicate does
        wave.admit_wave(&mut cache, &mut effs, &spec, true, true, &[p, p], &mut mock)
            .unwrap();
        assert_eq!(wave.cached_prompts(), 1);
        assert_eq!(wave.stats.launches, 2);
        assert_eq!(wave.stats.shared_admissions, 1);
        // a faithful admission never replays an in-graph template: it
        // relaunches and (p repeated before) re-registers faithful
        wave.admit_wave(&mut cache, &mut effs, &spec, false, true, &[p], &mut mock)
            .unwrap();
        assert_eq!(wave.stats.launches, 3, "mode mismatch must relaunch");
        // q repeats: no template (capacity 1 holds p's), but it was
        // seen, so this launch registers one and evicts p's — whose
        // chain now survives only through its live sequences
        let hits_before = cache.prefix_stats().chunk_hits;
        wave.admit_wave(&mut cache, &mut effs, &spec, false, true, &[q], &mut mock)
            .unwrap();
        assert_eq!(wave.stats.launches, 4, "evicted template must relaunch");
        assert!(
            cache.prefix_stats().chunk_hits > hits_before,
            "the relaunch still reuses the stored chunks byte-free"
        );
        assert_eq!(wave.cached_prompts(), 1);
        assert_eq!(wave.pinned_leaves().len(), 1);
        cache.prefix_integrity(&wave.pinned_leaves()).unwrap();
        // the memory-pressure valve: shedding the oldest template
        // unpins its chain; with the sequences retired too, the chain's
        // bytes are actually freed
        let ids: Vec<u64> = effs.keys().copied().collect();
        for id in ids {
            cache.free_sequence(id);
        }
        assert!(wave.shed_oldest_template(&mut cache));
        assert_eq!(wave.cached_prompts(), 0);
        assert!(!wave.shed_oldest_template(&mut cache), "nothing left to shed");
        cache.prefix_integrity(&[]).unwrap();
        assert_eq!(cache.prefix_stats().nodes_live, 0);
        assert_eq!(cache.pool_stats().live_bytes, 0);
    }

    #[test]
    fn template_byte_budget_is_bounded_and_configurable() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 1);
        // the serving default and the cache default agree on 64 MiB
        assert_eq!(TEMPLATE_BYTE_BUDGET, 64 << 20);
        let cfg = crate::coordinator::scheduler::ServeConfig::new(plan.clone());
        assert_eq!(cfg.template_byte_budget, TEMPLATE_BYTE_BUDGET);
        let mut cache = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut effs = HashMap::new();
        let mut mock = LaneWiseMockPrefiller::for_spec(&spec);
        let mut wave = PrefillWave::with_template_capacity(8);
        let p: &[u8] = b"sixteen-plus token prompt p";
        let q: &[u8] = b"sixteen-plus token prompt q";
        // two repeated prompts cache two templates under the default
        wave.admit_wave(&mut cache, &mut effs, &spec, true, true, &[p, p, q, q], &mut mock)
            .unwrap();
        assert_eq!(wave.cached_prompts(), 2);
        assert!(wave.template_bytes() > 0);
        assert!(wave.template_bytes() <= TEMPLATE_BYTE_BUDGET);
        // tighten the budget below one template: the bound bites at the
        // next insertion and degrades to a cache-of-one, never to zero
        wave.set_template_byte_budget(1);
        let r: &[u8] = b"sixteen-plus token prompt r";
        wave.admit_wave(&mut cache, &mut effs, &spec, true, true, &[r, r], &mut mock)
            .unwrap();
        assert_eq!(wave.cached_prompts(), 1, "byte bound degrades to cache-of-one");
        cache.prefix_integrity(&wave.pinned_leaves()).unwrap();
        let ids: Vec<u64> = effs.keys().copied().collect();
        for id in ids {
            cache.free_sequence(id);
        }
        wave.clear_templates(&mut cache);
        assert_eq!(wave.template_bytes(), 0);
        cache.prefix_integrity(&[]).unwrap();
        assert_eq!(cache.pool_stats().live_bytes, 0);
    }
}
