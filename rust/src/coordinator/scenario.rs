//! Deterministic scenario harness: named workloads, fault injection,
//! and whole-stack invariant checking under a virtual clock
//! (DESIGN.md §8).
//!
//! A [`Scenario`] names a workload shape (trace config + serving
//! policy + [`FaultPlan`]); [`run_scenario`] serves it round by round
//! through [`ServingEngine::begin`] and
//! [`step_supervised`](ServingEngine::step_supervised) — faults are
//! classified and recovered by the serving supervisor (retry/backoff,
//! degradation ladder, quarantine; DESIGN.md §9) — running
//! [`check_round`] after **every** round, the ones that failed with an
//! injected fault included, and folds the per-round state fingerprints
//! into an invariant digest.  Everything runs on a
//! [`Clock::virtual_with`] clock, so the resulting [`ScenarioReport`]
//! (TTFT percentiles, throughput, digests — timing included) is a pure
//! function of the scenario: the determinism contract is simply
//! `run_scenario(a) == run_scenario(b)` for equal inputs, which the
//! scenario test suite asserts via `PartialEq`.
//!
//! The harness is backend-agnostic: CI drives it with the deterministic
//! [`crate::runtime::MockEngine`]; the same entry point accepts the
//! real artifact [`crate::runtime::Engine`] when artifacts are present
//! (`benches/scenarios.rs`).
//!
//! [`run_sharded`] extends the same contract to the multi-worker
//! [`Router`]: one backend per worker, a deterministic migration plan
//! (forced nomad hops, drains, armed transfer corruption), the
//! cluster-wide invariant audit after every round, and a
//! [`ShardedReport`] whose token digests must equal the single-worker
//! run's bit for bit.

use super::clock::Clock;
use super::invariants::{check_round, Fnv};
use super::prefill::PrefillWave;
use super::request::GenResponse;
use super::router::{MigrationOutcome, Router, RouterConfig};
use super::scheduler::{ServeConfig, ServingEngine};
use super::supervisor::{ErrorClass, RecoveryAction};
use super::trace::{generate, Arrival, TraceConfig};
use crate::compress::strategy::PlanManifest;
use crate::data::corpus::wiki;
use crate::kvcache::CacheConfig;
use crate::model::memory::CompressionPlan;
use crate::model::{Arch, ModelSpec};
use crate::runtime::backend::ExecBackend;
use anyhow::{bail, Result};

/// Faults to inject while a scenario runs.  Launch faults default to
/// one-shot — each fires once at its scheduled occurrence, then clears
/// — and a non-zero burst re-arms them for consecutive launches
/// (flapping backend), which is what drives a target past its retry
/// budget into quarantine.  The supervisor must absorb every error and
/// complete (or typed-error-complete) the workload anyway.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// fail the nth (1-based) prefill launch mid-wave
    pub prefill_launch: Option<u64>,
    /// fail the nth (1-based) decode-step launch mid-round
    pub decode_launch: Option<u64>,
    /// after the prefill fault fires, re-arm it for the next prefill
    /// launch this many more times (flapping backend)
    pub prefill_burst: u64,
    /// after the decode fault fires, re-arm it for the next decode
    /// launch this many more times (flapping backend)
    pub decode_burst: u64,
    /// fail this many park attempts (before any state moves)
    pub park: u32,
    /// fail this many resume attempts (after unpark, exercising the
    /// repark rollback)
    pub resume: u32,
    /// flip one bit in this many parked payloads in the host tier —
    /// the unpark checksum must catch each one and the supervisor must
    /// quarantine exactly the corrupted sequence
    pub corrupt_park: u32,
    /// hard block-pool ceiling in **tokens** (priced at the plan's
    /// `bytes_per_token` when the scenario runs): admission waves that
    /// would allocate past it fail and must roll back — the
    /// budget-exhaustion-at-admission lane
    pub admission_budget_tokens: Option<usize>,
}

impl FaultPlan {
    /// No faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }
}

/// One named scenario: a workload shape plus the serving policy and
/// fault plan it runs under.  Budgets are in tokens so scenarios stay
/// independent of the plan's byte sizes.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// scenario name (report key; bench JSON case label)
    pub name: &'static str,
    /// synthetic workload the scenario serves
    pub trace: TraceConfig,
    /// scheduler's target concurrent batch
    pub max_batch: usize,
    /// soft cache budget in tokens (× `bytes_per_token` at run time):
    /// the park/resume watermark; `None` = unlimited
    pub cache_budget_tokens: Option<usize>,
    /// admission template-cache capacity override (template-pressure
    /// scenarios); `None` keeps the default
    pub template_capacity: Option<usize>,
    /// serve in faithful per-step-reconstruct mode
    pub faithful: bool,
    /// cross-request prefix sharing (feature-off legs set `false`)
    pub prefix_sharing: bool,
    /// store-resident decode staging (feature-off legs set `false`)
    pub resident_cache: bool,
    /// batched admission prefill (feature-off legs set `false`)
    pub batched_prefill: bool,
    /// adaptive compression manifest to serve under
    /// ([`ServeConfig::adaptive_plan`]); `None` — the default — keeps
    /// the matrix's standard single-rung plan.  When set, the
    /// manifest's embedded plan replaces the standard one, so the
    /// adaptive test legs build manifests around the same
    /// `ae_first_layers` plan to keep budgets and digests comparable
    pub adaptive_plan: Option<PlanManifest>,
    /// faults to inject
    pub faults: FaultPlan,
}

impl Scenario {
    /// A scenario over `trace` with default policy (batch 8, no
    /// budgets, all features on, no faults).
    pub fn new(name: &'static str, trace: TraceConfig) -> Scenario {
        Scenario {
            name,
            trace,
            max_batch: 8,
            cache_budget_tokens: None,
            template_capacity: None,
            faithful: false,
            prefix_sharing: true,
            resident_cache: true,
            batched_prefill: true,
            adaptive_plan: None,
            faults: FaultPlan::none(),
        }
    }
}

/// Everything a scenario run reports.  Derives `PartialEq` because the
/// determinism contract is literal equality: same scenario, same seed,
/// same backend ⇒ the same report **bit for bit**, timing fields
/// included (virtual clock).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    /// scenario name, echoed
    pub name: String,
    /// requests that completed cleanly (no error on their response)
    pub completed: usize,
    /// request ids the supervisor rejected pre-admission with a typed
    /// error response (persistent admission failure, budget exhaustion)
    pub rejected: Vec<u64>,
    /// request ids the supervisor quarantined mid-flight with a typed
    /// error response (retries exhausted, corruption, permanent fault)
    pub quarantined: Vec<u64>,
    /// scheduler rounds executed (failed rounds included)
    pub rounds: u64,
    /// invariant audits that ran (one per round)
    pub invariant_checks: u64,
    /// injected faults that actually surfaced as round errors
    pub faults_injected: u64,
    /// true time-to-first-token, median (virtual ms)
    pub ttft_p50_ms: f64,
    /// true time-to-first-token, p99 (virtual ms)
    pub ttft_p99_ms: f64,
    /// per-request decode throughput, median (tok/s, virtual time)
    pub tok_s_p50: f64,
    /// per-request decode throughput, p99 (tok/s, virtual time)
    pub tok_s_p99: f64,
    /// whole-run throughput (tok/s, virtual time)
    pub throughput_tok_s: f64,
    /// sequences parked under memory pressure
    pub parks: u64,
    /// parked sequences resumed
    pub resumes: u64,
    /// zero-launch admissions served from shared prefixes
    pub shared_admissions: u64,
    /// deterministic retries the supervisor charged
    pub retries: u64,
    /// total retry backoff charged on the virtual clock, in ms
    pub backoff_ms: f64,
    /// sequences demoted to the cheaper storage rung under pressure
    pub demotions: u64,
    /// demotions that were per-row-region (adaptive-plan ladder;
    /// counted inside `demotions` too)
    pub region_demotions: u64,
    /// tier transfers that failed checksum verification on unpark
    pub checksum_failures: u64,
    /// admission templates shed by the degradation ladder
    pub template_sheds: u64,
    /// virtual wall-clock of the run in ms
    pub virtual_ms: f64,
    /// FNV digest over every response's id and token stream
    pub tokens_digest: u64,
    /// FNV digest folding every round's invariant-state fingerprint
    pub invariant_digest: u64,
    /// per-response (request id, FNV digest of its token stream),
    /// sorted by id — the per-sequence half of the blast-radius
    /// contract: a quarantined sequence must not perturb any survivor's
    /// digest relative to the fault-free run
    pub output_digests: Vec<(u64, u64)>,
}

/// Model dimensions the mock-backed scenario matrix runs at: small
/// enough that 24-request storms finish in milliseconds, large enough
/// (3 layers, 48 positions, AE latents) that every subsystem — prefix
/// trie, slot arena, host tier, batched prefill — does real work.
pub fn scenario_spec() -> ModelSpec {
    ModelSpec {
        name: "mock".into(),
        arch: Arch::Gpt2,
        vocab: 64,
        n_layer: 3,
        d_model: 24,
        n_head: 3,
        n_kv_head: 3,
        d_head: 8,
        ffn_dim: 48,
        max_seq: 48,
        ae_hidden: 16,
        ae_latent: 12,
        bytes_per_el: 4,
    }
}

/// The named scenario workloads of the standard matrix (admission
/// storm, template stress, budget-bound long tail, duplicate storm,
/// mixed steady state, plus the chaos trio: flapping backend, corrupted
/// unpark, sustained pressure), each with its fault plan.
pub fn standard_matrix() -> Vec<Scenario> {
    let mut bursty = Scenario::new(
        "bursty_admission_storm",
        TraceConfig {
            n_requests: 24,
            arrival: Arrival::Bursty {
                size: 8,
                period_ms: 50,
            },
            prompt_len_range: (8, 16),
            max_new_range: (6, 12),
            temperature: None,
            distinct_prompts: None,
            seed: 11,
        },
    );
    bursty.faults = FaultPlan {
        prefill_launch: Some(2),
        admission_budget_tokens: Some(320),
        ..FaultPlan::none()
    };

    let mut template = Scenario::new(
        "template_storm",
        TraceConfig {
            n_requests: 24,
            arrival: Arrival::Poisson { rate: 200.0 },
            prompt_len_range: (10, 20),
            max_new_range: (4, 10),
            temperature: None,
            distinct_prompts: Some(3),
            seed: 23,
        },
    );
    template.template_capacity = Some(2);
    template.faults = FaultPlan {
        prefill_launch: Some(1),
        decode_launch: Some(4),
        ..FaultPlan::none()
    };

    let mut tail = Scenario::new(
        "long_context_tail",
        TraceConfig {
            n_requests: 8,
            arrival: Arrival::Batch,
            prompt_len_range: (18, 24),
            max_new_range: (12, 16),
            temperature: None,
            distinct_prompts: None,
            seed: 37,
        },
    );
    tail.max_batch = 4;
    tail.cache_budget_tokens = Some(120);
    tail.faithful = true;
    tail.faults = FaultPlan {
        park: 1,
        resume: 1,
        ..FaultPlan::none()
    };

    let mut dup = Scenario::new(
        "adversarial_duplicate_storm",
        TraceConfig {
            n_requests: 24,
            arrival: Arrival::Bursty {
                size: 6,
                period_ms: 20,
            },
            prompt_len_range: (12, 18),
            max_new_range: (4, 8),
            temperature: None,
            distinct_prompts: Some(1),
            seed: 41,
        },
    );
    dup.faults = FaultPlan {
        prefill_launch: Some(1),
        decode_launch: Some(2),
        ..FaultPlan::none()
    };

    let mut steady = Scenario::new(
        "mixed_steady_state",
        TraceConfig {
            n_requests: 20,
            arrival: Arrival::Poisson { rate: 30.0 },
            prompt_len_range: (8, 24),
            max_new_range: (4, 14),
            temperature: Some(0.8),
            distinct_prompts: None,
            seed: 53,
        },
    );
    steady.faults = FaultPlan {
        decode_launch: Some(6),
        ..FaultPlan::none()
    };

    // chaos trio (DESIGN.md §9): a decode launch that keeps failing
    // until the attributed sequence exhausts its retry budget and is
    // quarantined — every survivor must finish bitwise identical
    let mut flap = Scenario::new(
        "flapping_backend",
        TraceConfig {
            n_requests: 12,
            arrival: Arrival::Bursty {
                size: 4,
                period_ms: 30,
            },
            prompt_len_range: (8, 16),
            max_new_range: (6, 10),
            temperature: None,
            distinct_prompts: None,
            seed: 61,
        },
    );
    flap.faults = FaultPlan {
        decode_launch: Some(2),
        decode_burst: 5,
        ..FaultPlan::none()
    };

    // a parked payload corrupted in the host tier: the unpark checksum
    // must catch it and quarantine exactly the corrupted sequence
    let mut corrupt = Scenario::new(
        "corrupted_unpark",
        TraceConfig {
            n_requests: 8,
            arrival: Arrival::Batch,
            prompt_len_range: (18, 24),
            max_new_range: (12, 16),
            temperature: None,
            distinct_prompts: None,
            seed: 67,
        },
    );
    corrupt.max_batch = 4;
    corrupt.cache_budget_tokens = Some(120);
    corrupt.faults = FaultPlan {
        corrupt_park: 1,
        ..FaultPlan::none()
    };

    // a pool budget the storm keeps slamming into: the degradation
    // ladder (shed → demote → park → reject) must keep the run moving
    let mut pressure = Scenario::new(
        "sustained_pressure",
        TraceConfig {
            n_requests: 16,
            arrival: Arrival::Bursty {
                size: 8,
                period_ms: 10,
            },
            prompt_len_range: (12, 20),
            max_new_range: (8, 14),
            temperature: None,
            distinct_prompts: Some(2),
            seed: 71,
        },
    );
    pressure.template_capacity = Some(2);
    pressure.faults = FaultPlan {
        admission_budget_tokens: Some(240),
        ..FaultPlan::none()
    };

    vec![bursty, template, tail, dup, steady, flap, corrupt, pressure]
}

/// Hard cap on scheduler rounds per scenario — a convergence guard,
/// not a tuning knob (the standard matrix finishes in well under 200).
const MAX_ROUNDS: u64 = 10_000;

/// Serve one scenario to completion on `engine` and return its report.
///
/// The run is fully deterministic: a virtual clock is installed (so
/// every latency figure — retry backoff included — is charged, not
/// measured), faults are armed up front, and [`check_round`] audits the
/// whole stack after every round — a fault that corrupts state fails
/// the scenario with the full violation list rather than a skewed
/// number.  Recovery is the supervisor's: transient faults retry under
/// the deterministic backoff policy, exhaustion walks the degradation
/// ladder, corruption quarantines — and every quarantine/rejection is
/// reported with its typed error response.
pub fn run_scenario(
    engine: &mut dyn ExecBackend,
    model: &str,
    sc: &Scenario,
) -> Result<ScenarioReport> {
    let spec = engine.model_spec(model)?;
    let plan = CompressionPlan::ae_first_layers(&spec, (spec.n_layer / 2).max(1));
    let bytes_per_token = {
        let ccfg = CacheConfig::new(spec.clone(), plan.clone());
        ccfg.bytes_per_token()
    };
    if let Some(n) = sc.faults.prefill_launch {
        engine.inject_launch_fault_burst("prefill", n, sc.faults.prefill_burst);
    }
    if let Some(n) = sc.faults.decode_launch {
        engine.inject_launch_fault_burst("decode", n, sc.faults.decode_burst);
    }
    let mut cfg = if sc.faithful {
        ServeConfig::faithful(plan)
    } else {
        ServeConfig::new(plan)
    };
    cfg.max_batch = sc.max_batch;
    cfg.seed = sc.trace.seed;
    cfg.cache_budget = sc.cache_budget_tokens.map(|t| t * bytes_per_token);
    cfg.pool_budget = sc
        .faults
        .admission_budget_tokens
        .map(|t| t * bytes_per_token);
    cfg.prefix_sharing = sc.prefix_sharing;
    cfg.resident_cache = sc.resident_cache;
    cfg.batched_prefill = sc.batched_prefill;
    cfg.adaptive_plan = sc.adaptive_plan.clone();
    let mut serving = ServingEngine::new(engine, model, cfg)?;
    if let Some(cap) = sc.template_capacity {
        serving.waves = PrefillWave::with_template_capacity(cap);
        serving
            .waves
            .set_template_byte_budget(serving.cfg.template_byte_budget);
    }
    serving.set_clock(Clock::virtual_default());
    serving.inject_tier_faults(sc.faults.park, sc.faults.resume);
    serving.tier.inject_corruption(sc.faults.corrupt_park);

    let trace = generate(&sc.trace, &mut wiki(sc.trace.seed));
    let requests: Vec<_> = trace.items.into_iter().map(|i| i.request).collect();
    let mut state = serving.begin(requests);

    let mut inv = Fnv::new();
    let mut rounds = 0u64;
    let mut invariant_checks = 0u64;
    let mut faults_injected = 0u64;
    let mut rejected: Vec<u64> = Vec::new();
    let mut quarantined: Vec<u64> = Vec::new();
    let mut stalled = 0u32;
    loop {
        rounds += 1;
        if rounds > MAX_ROUNDS {
            bail!("scenario '{}' did not converge in {MAX_ROUNDS} rounds", sc.name);
        }
        let rep = serving.step_supervised(&mut state);
        // the audit runs after EVERY round — the recovery claim is
        // precisely that a failed round *plus its recovery action*
        // leaves the stack coherent
        let strict = rep.fault.is_none();
        let fp = check_round(&serving, &state, strict).map_err(|v| {
            anyhow::anyhow!("scenario '{}' round {rounds} violated invariants:\n{v}", sc.name)
        })?;
        invariant_checks += 1;
        inv.push(fp);
        if rep.fault.is_some() {
            faults_injected += 1;
        }
        match rep.action {
            RecoveryAction::Quarantine(id) => quarantined.push(id),
            RecoveryAction::Reject(id) => rejected.push(id),
            _ => {}
        }
        // forward-progress valve: a fault the supervisor could take no
        // action on, repeated round after round, fails the scenario
        // loudly instead of spinning to the round cap
        match (&rep.fault, rep.action) {
            (Some(_), RecoveryAction::None) => stalled += 1,
            _ => stalled = 0,
        }
        if stalled > 8 {
            bail!(
                "scenario '{}' stalled on an unrecoverable fault: {}",
                sc.name,
                rep.fault.map(|f| f.to_string()).unwrap_or_default()
            );
        }
        if !rep.more {
            break;
        }
    }
    let responses = serving.finish(state);

    let (tokens_digest, output_digests) = digest_responses(&responses);
    let mut tok_s: Vec<f64> = responses.iter().map(|r| r.tokens_per_sec()).collect();
    tok_s.sort_by(|a, b| a.partial_cmp(b).expect("throughputs are finite"));
    let pct = |v: &[f64], p: f64| -> f64 {
        if v.is_empty() {
            return 0.0;
        }
        v[((v.len() - 1) as f64 * p / 100.0).round() as usize]
    };
    let m = &serving.metrics;
    Ok(ScenarioReport {
        name: sc.name.to_string(),
        completed: responses.iter().filter(|r| r.error.is_none()).count(),
        rejected,
        quarantined,
        rounds,
        invariant_checks,
        faults_injected,
        ttft_p50_ms: m.ttft.percentile_ms(50.0),
        ttft_p99_ms: m.ttft.percentile_ms(99.0),
        tok_s_p50: pct(&tok_s, 50.0),
        tok_s_p99: pct(&tok_s, 99.0),
        throughput_tok_s: m.throughput_tok_per_sec(),
        parks: m.auto_parks,
        resumes: m.auto_resumes,
        shared_admissions: m.shared_admissions,
        retries: m.retries,
        backoff_ms: m.backoff.as_secs_f64() * 1e3,
        demotions: m.demotions,
        region_demotions: m.region_demotions,
        checksum_failures: serving.tier.stats.checksum_failures,
        template_sheds: m.template_sheds,
        virtual_ms: m.wall.as_secs_f64() * 1e3,
        tokens_digest,
        invariant_digest: inv.finish(),
        output_digests,
    })
}

/// The whole-run and per-response FNV token digests: the currency of
/// every bitwise-equivalence contract in this module (fault-free vs
/// chaos, single-worker vs sharded).
fn digest_responses(responses: &[GenResponse]) -> (u64, Vec<(u64, u64)>) {
    let mut tokens = Fnv::new();
    tokens.push(responses.len() as u64);
    for r in responses {
        tokens.push(r.id);
        tokens.push(r.output.len() as u64);
        for &b in &r.output {
            tokens.push(b as u64);
        }
    }
    let output_digests: Vec<(u64, u64)> = responses
        .iter()
        .map(|r| {
            let mut d = Fnv::new();
            for &b in &r.output {
                d.push(b as u64);
            }
            (r.id, d.finish())
        })
        .collect();
    (tokens.finish(), output_digests)
}

/// A sharded serving scenario: the workload and serving policy in
/// `base`, served by `n_workers` router workers instead of one, plus a
/// deterministic migration plan — forced mid-generation moves of a
/// "nomad" sequence, an optional worker drain, optional transfer
/// corruption.  The determinism contract extends the single-worker
/// one: under greedy sampling (`temperature: None`) the cluster's
/// token streams are **bitwise identical** to `run_scenario(base)` on
/// one worker, no matter how many times sequences migrate — which the
/// sharded test suite asserts digest-for-digest.
#[derive(Debug, Clone)]
pub struct ShardedScenario {
    /// workload + serving policy; its [`FaultPlan`] stays empty —
    /// sharded chaos is transfer corruption, not launch faults
    pub base: Scenario,
    /// router workers (one backend each)
    pub n_workers: usize,
    /// every this many rounds, force-migrate the live sequence with
    /// the lowest request id to the next worker in cyclic order
    /// (`0` disables).  Repeated moves cycle the nomad back onto
    /// workers that retain its replica basis, exercising the delta
    /// law: a return trip ships only groups appended since it left.
    pub migrate_every: u64,
    /// arm transfer corruption on this many forced migrations; each
    /// must be caught by a delta group CRC and rolled back with the
    /// sequence still live on its source
    pub corrupt_migrations: u32,
    /// at this round, drain this worker: re-route its queue and
    /// migrate its live sequences to peers
    pub drain_at_round: Option<(u64, usize)>,
    /// let the router migrate on live-count imbalance by itself
    pub auto_rebalance: bool,
}

/// Everything a sharded scenario run reports.  `PartialEq` for the
/// same reason as [`ScenarioReport`]: same scenario, same seeds ⇒ the
/// same report bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedReport {
    /// scenario name, echoed
    pub name: String,
    /// workers the cluster ran
    pub n_workers: usize,
    /// requests that completed cleanly
    pub completed: usize,
    /// lock-step cluster rounds executed
    pub rounds: u64,
    /// cluster-wide invariant audits that ran
    pub invariant_checks: u64,
    /// migrations committed (forced + drain + rebalance)
    pub migrations: u64,
    /// committed forced (plan-driven) migrations
    pub forced_migrations: u64,
    /// committed migrations the router initiated to rebalance load
    pub rebalance_migrations: u64,
    /// committed migrations initiated by the drain
    pub drain_migrations: u64,
    /// armed corruptions the delta CRCs caught and rolled back
    pub corruption_rollbacks: u64,
    /// suffix payload bytes that actually shipped across workers
    pub delta_bytes: u64,
    /// suffix payload bytes replica bases supplied instead of the wire
    pub bytes_saved: u64,
    /// full suffix payload bytes of every committed migration
    /// (`delta_bytes + bytes_saved` — the delta law's denominator)
    pub full_bytes: u64,
    /// shared prefix chunk bytes shipped (first delivery per worker)
    pub chunk_bytes: u64,
    /// prefix chunks that traveled (≤ once per chunk per worker, ever)
    pub chunks_in: u64,
    /// prefix chunk deliveries skipped because the worker already held
    /// the chunk
    pub chunks_deduped: u64,
    /// per-worker (TTFT p50 ms, TTFT p99 ms), virtual time
    pub worker_ttft_ms: Vec<(f64, f64)>,
    /// whole-cluster throughput (tok/s, virtual time)
    pub throughput_tok_s: f64,
    /// virtual wall-clock of the run in ms (slowest worker)
    pub virtual_ms: f64,
    /// FNV digest over every response's id and token stream — equal to
    /// the single-worker run's digest when `base.faults` is empty
    pub tokens_digest: u64,
    /// FNV digest folding every cluster-audit fingerprint
    pub invariant_digest: u64,
    /// per-response (request id, token-stream digest), sorted by id
    pub output_digests: Vec<(u64, u64)>,
}

/// The named sharded workloads: a nomad sequence forced through every
/// worker (delta law), a shared-prefix storm with a mid-run drain
/// (content-addressed chunks + drain hook), an imbalanced burst the
/// router must rebalance by itself, and the chaos leg whose forced
/// transfers are corrupted in flight.
pub fn sharded_matrix() -> Vec<ShardedScenario> {
    let mut nomad = Scenario::new(
        "sharded_nomad",
        TraceConfig {
            n_requests: 15,
            arrival: Arrival::Poisson { rate: 150.0 },
            prompt_len_range: (18, 26),
            max_new_range: (10, 16),
            temperature: None,
            distinct_prompts: None,
            seed: 77,
        },
    );
    nomad.max_batch = 6;
    // no prefix chunks: the whole sequence rides the delta suffix, so
    // a prompt past one 16-row group makes the return trip's replica
    // savings structural (basis group 0 never changes once written)
    nomad.prefix_sharing = false;
    let nomad = ShardedScenario {
        base: nomad,
        n_workers: 3,
        migrate_every: 2,
        corrupt_migrations: 0,
        drain_at_round: None,
        auto_rebalance: false,
    };

    let shared = Scenario::new(
        "sharded_shared_prefix_drain",
        TraceConfig {
            n_requests: 18,
            arrival: Arrival::Bursty {
                size: 6,
                period_ms: 20,
            },
            prompt_len_range: (16, 22),
            max_new_range: (8, 12),
            temperature: None,
            distinct_prompts: Some(2),
            seed: 83,
        },
    );
    let shared = ShardedScenario {
        base: shared,
        n_workers: 3,
        migrate_every: 3,
        corrupt_migrations: 0,
        drain_at_round: Some((5, 0)),
        auto_rebalance: false,
    };

    let mut storm = Scenario::new(
        "sharded_rebalance_storm",
        TraceConfig {
            n_requests: 24,
            arrival: Arrival::Bursty {
                size: 12,
                period_ms: 40,
            },
            prompt_len_range: (8, 16),
            max_new_range: (6, 12),
            temperature: None,
            distinct_prompts: None,
            seed: 89,
        },
    );
    storm.max_batch = 4;
    let storm = ShardedScenario {
        base: storm,
        n_workers: 4,
        migrate_every: 0,
        corrupt_migrations: 0,
        drain_at_round: None,
        auto_rebalance: true,
    };

    let mut chaos = Scenario::new(
        "sharded_corrupt_transfer",
        TraceConfig {
            n_requests: 12,
            arrival: Arrival::Batch,
            prompt_len_range: (12, 20),
            max_new_range: (10, 14),
            temperature: None,
            distinct_prompts: None,
            seed: 97,
        },
    );
    chaos.max_batch = 6;
    let chaos = ShardedScenario {
        base: chaos,
        n_workers: 3,
        migrate_every: 2,
        corrupt_migrations: 2,
        drain_at_round: None,
        auto_rebalance: false,
    };

    vec![nomad, shared, storm, chaos]
}

/// Serve one sharded scenario across `backends` (one per worker) and
/// report.  Like [`run_scenario`] the run is a pure function of its
/// inputs: every worker clock is virtual and re-synchronized each
/// round, migrations follow the deterministic plan, and the
/// cluster-wide invariant audit ([`Router::check`]) runs after every
/// round **and** after every forced migration and drain — so a
/// transfer that corrupted state fails the scenario with the violation
/// list, not a skewed digest.
pub fn run_sharded(
    backends: Vec<&mut dyn ExecBackend>,
    model: &str,
    sc: &ShardedScenario,
) -> Result<ShardedReport> {
    anyhow::ensure!(sc.n_workers >= 2, "a sharded scenario needs at least two workers");
    anyhow::ensure!(
        backends.len() == sc.n_workers,
        "scenario '{}' wants {} workers, got {} backends",
        sc.base.name,
        sc.n_workers,
        backends.len()
    );
    let b = &sc.base;
    let spec = backends[0].model_spec(model)?;
    let plan = CompressionPlan::ae_first_layers(&spec, (spec.n_layer / 2).max(1));
    let bytes_per_token = {
        let ccfg = CacheConfig::new(spec.clone(), plan.clone());
        ccfg.bytes_per_token()
    };
    let mut cfg = if b.faithful {
        ServeConfig::faithful(plan)
    } else {
        ServeConfig::new(plan)
    };
    cfg.max_batch = b.max_batch;
    cfg.seed = b.trace.seed;
    cfg.cache_budget = b.cache_budget_tokens.map(|t| t * bytes_per_token);
    cfg.prefix_sharing = b.prefix_sharing;
    cfg.resident_cache = b.resident_cache;
    cfg.batched_prefill = b.batched_prefill;
    cfg.adaptive_plan = b.adaptive_plan.clone();
    let rcfg = RouterConfig {
        auto_rebalance: sc.auto_rebalance,
        ..RouterConfig::default()
    };
    let mut router = Router::new(backends, model, cfg, rcfg)?;
    if let Some(cap) = b.template_capacity {
        for w in 0..router.n_workers() {
            let budget = router.engine(w).cfg.template_byte_budget;
            let e = router.engine_mut(w);
            e.waves = PrefillWave::with_template_capacity(cap);
            e.waves.set_template_byte_budget(budget);
        }
    }
    router.set_clock(&Clock::virtual_default());

    let trace = generate(&b.trace, &mut wiki(b.trace.seed));
    let requests: Vec<_> = trace.items.into_iter().map(|i| i.request).collect();
    router.begin(requests);

    // the budget law audits strictly only when no budget is configured
    // to strain: a migration can land between a peer's park rounds
    let strict = b.cache_budget_tokens.is_none();
    let audit = |router: &Router<'_>, inv: &mut Fnv, round: u64| -> Result<()> {
        let fp = router.check(strict).map_err(|v| {
            anyhow::anyhow!("scenario '{}' round {round} violated cluster invariants:\n{v}", b.name)
        })?;
        inv.push(fp);
        Ok(())
    };
    let mut inv = Fnv::new();
    let mut rounds = 0u64;
    let mut invariant_checks = 0u64;
    let mut forced_attempts = 0u64;
    let mut forced_migrations = 0u64;
    let mut corruption_rollbacks = 0u64;
    let mut drained = false;
    loop {
        rounds += 1;
        if rounds > MAX_ROUNDS {
            bail!("scenario '{}' did not converge in {MAX_ROUNDS} rounds", b.name);
        }
        let more = router.step()?;
        audit(&router, &mut inv, rounds)?;
        invariant_checks += 1;
        if !more {
            break;
        }
        if let Some((at, w)) = sc.drain_at_round {
            if rounds >= at && !drained {
                drained = true;
                router.drain(w)?;
                audit(&router, &mut inv, rounds)?;
                invariant_checks += 1;
            }
        }
        if sc.migrate_every > 0 && rounds % sc.migrate_every == 0 {
            // the nomad: the lowest-numbered live request cluster-wide
            // hops to the next worker, mid-generation
            let victim = (0..router.n_workers())
                .flat_map(|w| {
                    router
                        .live_requests(w)
                        .into_iter()
                        .map(move |(req, cache)| (req, w, cache))
                })
                .min();
            if let Some((_, src, cache_id)) = victim {
                let mut dst = (src + 1) % router.n_workers();
                while dst == src || router.is_draining(dst) {
                    dst = (dst + 1) % router.n_workers();
                }
                let corrupt = forced_attempts < sc.corrupt_migrations as u64;
                forced_attempts += 1;
                match router.migrate(src, dst, cache_id, corrupt)? {
                    MigrationOutcome::Committed { .. } => forced_migrations += 1,
                    MigrationOutcome::RolledBack { fault } => {
                        anyhow::ensure!(
                            corrupt,
                            "scenario '{}': clean forced migration rolled back: {}",
                            b.name,
                            fault.msg
                        );
                        anyhow::ensure!(
                            fault.class == ErrorClass::Corruption,
                            "scenario '{}': corrupted transfer classified {:?}, not Corruption",
                            b.name,
                            fault.class
                        );
                        corruption_rollbacks += 1;
                    }
                }
                audit(&router, &mut inv, rounds)?;
                invariant_checks += 1;
            }
        }
    }
    let responses = router.finish();

    let (tokens_digest, output_digests) = digest_responses(&responses);
    let stats = router.stats().clone();
    let worker_ttft_ms: Vec<(f64, f64)> = (0..router.n_workers())
        .map(|w| {
            let m = &router.engine(w).metrics;
            (m.ttft.percentile_ms(50.0), m.ttft.percentile_ms(99.0))
        })
        .collect();
    let (mut chunks_in, mut chunks_deduped) = (0u64, 0u64);
    let mut virtual_ms = 0f64;
    for w in 0..router.n_workers() {
        let m = &router.engine(w).metrics;
        chunks_in += m.migration_chunks_in;
        chunks_deduped += m.migration_chunks_deduped;
        virtual_ms = virtual_ms.max(m.wall.as_secs_f64() * 1e3);
    }
    let generated: usize = responses.iter().map(|r| r.generated_tokens).sum();
    let throughput_tok_s = if virtual_ms > 0.0 {
        generated as f64 / (virtual_ms / 1e3)
    } else {
        0.0
    };
    Ok(ShardedReport {
        name: b.name.to_string(),
        n_workers: sc.n_workers,
        completed: responses.iter().filter(|r| r.error.is_none()).count(),
        rounds,
        invariant_checks,
        migrations: stats.migrations,
        forced_migrations,
        rebalance_migrations: stats.rebalance_migrations,
        drain_migrations: stats.drain_migrations,
        corruption_rollbacks,
        delta_bytes: stats.delta_bytes,
        bytes_saved: stats.bytes_saved,
        full_bytes: stats.delta_bytes + stats.bytes_saved,
        chunk_bytes: stats.chunk_bytes,
        chunks_in,
        chunks_deduped,
        worker_ttft_ms,
        throughput_tok_s,
        virtual_ms,
        tokens_digest,
        invariant_digest: inv.finish(),
        output_digests,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_names_are_distinct_and_stable() {
        let names: Vec<&str> = standard_matrix().iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            [
                "bursty_admission_storm",
                "template_storm",
                "long_context_tail",
                "adversarial_duplicate_storm",
                "mixed_steady_state",
                "flapping_backend",
                "corrupted_unpark",
                "sustained_pressure",
            ]
        );
    }

    #[test]
    fn sharded_matrix_is_stable_and_greedy() {
        let names: Vec<&str> = sharded_matrix().iter().map(|s| s.base.name).collect();
        assert_eq!(
            names,
            [
                "sharded_nomad",
                "sharded_shared_prefix_drain",
                "sharded_rebalance_storm",
                "sharded_corrupt_transfer",
            ]
        );
        for sc in sharded_matrix() {
            assert!(sc.n_workers >= 3, "'{}' must shard across >= 3 workers", sc.base.name);
            // the bitwise sharded-vs-single pin requires greedy
            // sampling: temperature draws come from per-engine rngs
            assert!(
                sc.base.trace.temperature.is_none(),
                "'{}' must sample greedily",
                sc.base.name
            );
            assert!(
                sc.migrate_every > 0 || sc.auto_rebalance,
                "'{}' never migrates",
                sc.base.name
            );
        }
    }

    #[test]
    fn every_matrix_scenario_injects_at_least_one_fault() {
        for sc in standard_matrix() {
            let f = &sc.faults;
            assert!(
                f.prefill_launch.is_some()
                    || f.decode_launch.is_some()
                    || f.park > 0
                    || f.resume > 0
                    || f.corrupt_park > 0
                    || f.admission_budget_tokens.is_some(),
                "scenario '{}' has no fault plan",
                sc.name
            );
        }
    }
}
