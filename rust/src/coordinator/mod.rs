//! L3 serving coordinator: request types, admission/batch planning, the
//! prefill/decode scheduler, and metrics.

pub mod batcher;
pub mod effective;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod trace;

pub use effective::{EffStats, EffectiveCache, LatentDecoder};
pub use request::{GenRequest, GenResponse, Sampling};
pub use scheduler::{ServeConfig, ServingEngine};
