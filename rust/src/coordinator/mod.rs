//! L3 serving coordinator: request types, admission/batch planning
//! (including park/resume under memory pressure), wave-based admission
//! prefill with cross-request prefix sharing and zero-launch
//! re-admission (`prefill`), the prefill/decode scheduler with
//! batch-first faithful reconstruction and store-resident decode
//! staging (`resident`), sharded multi-worker serving with delta-sync
//! sequence migration (`router`, `migrate`), and metrics.

pub mod batcher;
pub mod clock;
pub mod effective;
pub mod invariants;
pub mod metrics;
pub(crate) mod migrate;
pub mod prefill;
pub mod request;
pub mod resident;
pub mod router;
pub mod scenario;
pub mod scheduler;
pub mod supervisor;
pub mod trace;

pub use clock::{Clock, CostModel, Stamp};
pub use effective::{
    BatchLatentDecoder, BatchedAdvance, BatchedStats, EffStats, EffTemplate, EffectiveCache,
    LatentDecoder,
};
pub use invariants::{check_cluster, check_round};
pub use metrics::{CountHistogram, ServeMetrics};
pub use prefill::{
    AdmittedLane, LaneWiseMockPrefiller, PrefillWave, PromptTemplate, TemplateCache, WaveOutput,
    WavePrefiller, WaveStats,
};
pub use request::{GenRequest, GenResponse, Sampling};
pub use resident::{stage_copy_round, SlotArena};
pub use router::{MigrationOutcome, Router, RouterConfig, RouterStats};
pub use scenario::{
    run_scenario, run_sharded, scenario_spec, sharded_matrix, standard_matrix, FaultPlan,
    Scenario, ScenarioReport, ShardedReport, ShardedScenario,
};
pub use scheduler::{RunState, ServeConfig, ServingEngine};
pub use supervisor::{ErrorClass, RecoveryAction, RetryPolicy, ServeError, StepReport};
