//! Incremental effective-cache maintenance — the decode-on-retrieval
//! working set kept in O(new token rows) per step.
//!
//! The paper's Fig. 1 dataflow reconstructs full-width KV vectors from
//! the compressed store on retrieval.  Done naively that means
//! re-gathering, re-decoding, and re-alias-resolving the *entire*
//! sequence every decode round (the pre-refactor `rebuild_effective`:
//! O(seq_len) per step).  `EffectiveCache` instead owns persistent
//! per-sequence scratch and, on each `advance`, materializes only the
//! rows past the cache manager's `decoded_upto` watermark:
//!
//! * latents are gathered for the new range only (`StreamView::
//!   decode_range_into`, zero-copy out of the block store),
//! * the AE decoder runs on the `[L, n, dl]` slice (n = new rows,
//!   usually 1) instead of `[L, max_seq, dl]`,
//! * head aliases resolve layer-by-layer for the new rows alone.
//!
//! Chunked advances are bit-identical to a one-shot `rebuild_full`
//! (randomized cross-check in `tests/incremental_equivalence.rs`); the
//! full path remains for eviction-resume, where the scratch was dropped
//! while the sequence was parked in the host tier.
//!
//! On top of the per-sequence path, [`BatchedAdvance`] makes the
//! faithful serving mode *batch-first*: each decode round the pending
//! watermark row of every live sequence is packed into one
//! `[B, L, 1, dl]` staging tensor and reconstructed with a **single**
//! batched decoder call (`{m}_decode_kv_bt`), so the round issues O(1)
//! decoder launches instead of O(B).  Sequences with bulk pending
//! ranges (prompt reconstruction, eviction-resume) fall back to the
//! per-sequence ladder, and the whole scheme degrades gracefully when
//! the artifact set lacks the batched entry (`batch_capacity() ==
//! None`).  Bitwise equivalence with the per-sequence path is asserted
//! in `tests/batched_faithful.rs` across all plan kinds.

use crate::kvcache::{CacheManager, Side, StreamRows};
use crate::model::ModelSpec;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Immutable effective-row template for one distinct prompt — the
/// copy-on-write seed behind cross-request prefix sharing (DESIGN.md
/// §6).  Holds the prompt's in-graph effective K/V rows packed
/// `[L, rows, kvd]`; every sharer's [`EffectiveCache`] references the
/// same `Arc` instead of copying the rows at admission, and sources
/// reads of rows `[0, rows)` from it (`sync_rows_into`) until a write
/// into that range forces materialization — which steady-state decode
/// never does (appends land past the prompt), so N sharers hold the
/// prompt rows once.
#[derive(Debug, Clone)]
pub struct EffTemplate {
    /// prompt rows the template covers
    pub rows: usize,
    /// `[L, rows, kvd]` effective K rows
    pub k: Vec<f32>,
    /// `[L, rows, kvd]` effective V rows
    pub v: Vec<f32>,
}

/// Runs the AE decoder over latent rows.  The serving engine implements
/// this with the `{model}_decode_kv[_t]` artifacts; tests use pure-rust
/// mocks so the reconstruction dataflow is checkable without artifacts.
pub trait LatentDecoder {
    /// `k_lat`/`v_lat` are `[L, n, dl]` row-major; write the `[L, n,
    /// kvd]` reconstructions into `k_rec`/`v_rec`.  Must be a pure
    /// per-row function of the latents (chunked calls must compose to
    /// the full-range call — that is what makes incremental maintenance
    /// equivalent to full rebuilds).
    fn decode_latents_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        n: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()>;
}

/// Batched counterpart of [`LatentDecoder`]: reconstructs one pending
/// watermark row for each of `b` sequences in a single call over a
/// packed `[b, L, 1, dl]` staging tensor.
///
/// Implementations must be pure per-slot (and per-row) maps: slot `i`
/// of a batched call must equal a per-sequence `decode_latents_into`
/// call on that slot alone, **bitwise** — this is what makes the
/// batched faithful advance equivalent to the per-sequence path (the
/// L2 `decode_kv_bt` entry satisfies it by construction; see
/// `python/tests/test_decode_parity.py`).
pub trait BatchLatentDecoder: LatentDecoder {
    /// Maximum sequences a single batched call covers, or `None` when
    /// no batched decoder is available (e.g. an artifact set built
    /// before the `decode_kv_bt` entry existed) — callers then fall
    /// back to per-sequence advances.
    fn batch_capacity(&self) -> Option<usize>;

    /// `k_lat`/`v_lat`: `[b, L, 1, dl]` row-major packed latents; write
    /// the `[b, L, 1, kvd]` reconstructions into `k_rec`/`v_rec`.
    /// `b` never exceeds `batch_capacity()`.
    fn decode_latents_batch_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        b: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()>;
}

/// Deterministic row-wise mock decoder for tests and benches: a pure
/// function of each latent row (like the real per-row decoder MLP), so
/// chunked calls compose exactly to full-range calls — the one
/// `LatentDecoder` contract the equivalence tests rely on.  Defined
/// once here so every suite tests the same purity guarantee.  Also
/// implements [`BatchLatentDecoder`] (the same pure row map, so batched
/// calls are bitwise-equal to per-sequence calls by construction) and
/// counts calls on both paths so tests can assert launch counts.
pub struct RowWiseMockDecoder {
    /// latent width the mock consumes per row
    pub ae_latent: usize,
    /// reconstruction width the mock produces per row
    pub kv_dim: usize,
    /// capacity reported through `BatchLatentDecoder::batch_capacity`;
    /// `None` simulates an artifact set without the batched entry
    pub capacity: Option<usize>,
    /// per-sequence (`decode_latents_into`) calls observed
    pub seq_calls: u64,
    /// batched (`decode_latents_batch_into`) calls observed
    pub batch_calls: u64,
}

impl RowWiseMockDecoder {
    /// Mock sized for `spec`, batch-capable with a default capacity of 8.
    pub fn for_spec(spec: &ModelSpec) -> Self {
        RowWiseMockDecoder {
            ae_latent: spec.ae_latent,
            kv_dim: spec.kv_dim(),
            capacity: Some(8),
            seq_calls: 0,
            batch_calls: 0,
        }
    }

    /// Override the reported batch capacity (None = no batched decoder).
    pub fn with_capacity(mut self, capacity: Option<usize>) -> Self {
        self.capacity = capacity;
        self
    }

    fn decode_rows(&self, lat: &[f32], rec: &mut [f32]) {
        for (row_lat, row_rec) in lat
            .chunks_exact(self.ae_latent)
            .zip(rec.chunks_exact_mut(self.kv_dim))
        {
            for (j, o) in row_rec.iter_mut().enumerate() {
                *o = row_lat[j % self.ae_latent] * 0.5
                    + row_lat[(j * 7 + 1) % self.ae_latent] * 0.25;
            }
        }
    }
}

impl LatentDecoder for RowWiseMockDecoder {
    fn decode_latents_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        _n: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()> {
        self.seq_calls += 1;
        self.decode_rows(k_lat, k_rec);
        self.decode_rows(v_lat, v_rec);
        Ok(())
    }
}

impl BatchLatentDecoder for RowWiseMockDecoder {
    fn batch_capacity(&self) -> Option<usize> {
        self.capacity
    }

    fn decode_latents_batch_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        _b: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()> {
        self.batch_calls += 1;
        self.decode_rows(k_lat, k_rec);
        self.decode_rows(v_lat, v_rec);
        Ok(())
    }
}

/// Work counters proving the per-step cost law: tests assert
/// `rows_decoded` grows by new rows per step, not by sequence length.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EffStats {
    /// from-scratch reconstructions (eviction-resume path)
    pub full_rebuilds: u64,
    /// watermark-driven advances (steady-state path)
    pub incremental_advances: u64,
    /// token rows gathered + decoded + assembled, totalled across calls
    pub rows_decoded: u64,
}

/// Per-sequence effective-cache scratch: `[L, max_seq, kvd]` K/V buffers
/// (the shape the decode_step artifacts consume) plus persistent latent
/// and reconstruction staging so per-step maintenance never reallocates.
pub struct EffectiveCache {
    n_layer: usize,
    max_seq: usize,
    kv_dim: usize,
    ae_latent: usize,
    d_head: usize,
    /// [L, S, kvd] row-major effective K
    pub k: Vec<f32>,
    /// [L, S, kvd] row-major effective V
    pub v: Vec<f32>,
    k_lat_stage: Vec<f32>,
    v_lat_stage: Vec<f32>,
    k_rec_stage: Vec<f32>,
    v_rec_stage: Vec<f32>,
    head_stage: Vec<f32>,
    /// copy-on-write prompt seed shared with every other sequence
    /// admitted from the same template (see [`EffTemplate`]); reads of
    /// rows `[0, shared.rows)` source it, the first overlapping write
    /// materializes it into the owned buffers and drops the reference
    shared: Option<Arc<EffTemplate>>,
    /// per-sequence work counters (cost-law assertions)
    pub stats: EffStats,
}

impl EffectiveCache {
    /// Zeroed scratch sized for `spec` (buffers are reused per step).
    pub fn new(spec: &ModelSpec) -> Self {
        let n = spec.n_layer * spec.max_seq * spec.kv_dim();
        EffectiveCache {
            n_layer: spec.n_layer,
            max_seq: spec.max_seq,
            kv_dim: spec.kv_dim(),
            ae_latent: spec.ae_latent,
            d_head: spec.d_head,
            k: vec![0.0; n],
            v: vec![0.0; n],
            k_lat_stage: Vec::new(),
            v_lat_stage: Vec::new(),
            k_rec_stage: Vec::new(),
            v_rec_stage: Vec::new(),
            head_stage: Vec::new(),
            shared: None,
            stats: EffStats::default(),
        }
    }

    /// Seed rows `[0, tmpl.rows)` **by reference** from a shared prompt
    /// template and advance the manager watermark — the zero-copy
    /// admission path for a sequence whose prompt another admission
    /// already computed.  No rows are copied here: reads source the
    /// template through [`EffectiveCache::sync_rows_into`], and the
    /// template materializes into the owned buffers only if something
    /// later writes into the seeded range (decode appends never do).
    pub fn seed_shared(&mut self, cache: &mut CacheManager, id: u64, tmpl: Arc<EffTemplate>) {
        debug_assert_eq!(tmpl.k.len(), self.n_layer * tmpl.rows * self.kv_dim);
        debug_assert!(tmpl.rows <= self.max_seq);
        let rows = tmpl.rows;
        self.shared = Some(tmpl);
        cache.mark_decoded(id, rows);
    }

    /// Rows currently seeded by reference from a shared template (0
    /// once materialized or when the sequence was never shared).
    pub fn shared_rows(&self) -> usize {
        self.shared.as_ref().map_or(0, |t| t.rows)
    }

    /// Copy-on-write fault: copy the shared template's rows into the
    /// owned buffers and drop the reference.  Idempotent; called
    /// automatically before any write overlapping the seeded range.
    pub fn materialize_shared(&mut self) {
        let Some(t) = self.shared.take() else { return };
        let (s, kvd, rows) = (self.max_seq, self.kv_dim, t.rows);
        for layer in 0..self.n_layer {
            let dst = layer * s * kvd;
            let src = layer * rows * kvd;
            self.k[dst..dst + rows * kvd].copy_from_slice(&t.k[src..src + rows * kvd]);
            self.v[dst..dst + rows * kvd].copy_from_slice(&t.v[src..src + rows * kvd]);
        }
    }

    /// Seed rows [0, rows) from prefill's in-graph effective cache
    /// (`k_eff`/`v_eff`: [L, S, kvd]) and advance the manager watermark:
    /// those rows need no reconstruction.  Under wave admission
    /// (`coordinator::prefill::PrefillWave`) the buffers are one lane
    /// of the batched `{m}_prefill_b` output — bit-identical to the
    /// per-request prefill's, so seeding is path-independent.
    pub fn seed(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        k_eff: &[f32],
        v_eff: &[f32],
        rows: usize,
    ) {
        self.shared = None; // owned seed supersedes any template
        let (s, kvd) = (self.max_seq, self.kv_dim);
        for layer in 0..self.n_layer {
            let base = layer * s * kvd;
            self.k[base..base + rows * kvd].copy_from_slice(&k_eff[base..base + rows * kvd]);
            self.v[base..base + rows * kvd].copy_from_slice(&v_eff[base..base + rows * kvd]);
        }
        cache.mark_decoded(id, rows);
    }

    /// Append one decoded step's in-graph effective row at `pos` for
    /// every layer (`k_rows`/`v_rows`: [L, kvd]) and advance the
    /// watermark — the fast path when reconstruction is not requested.
    pub fn push_step_row(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        pos: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        if pos < self.shared_rows() {
            // write into the template-seeded range: copy-on-write fault
            // (steady-state appends land past the prompt, so this never
            // fires outside watermark resets)
            self.materialize_shared();
        }
        let (s, kvd) = (self.max_seq, self.kv_dim);
        for layer in 0..self.n_layer {
            let dst = layer * s * kvd + pos * kvd;
            self.k[dst..dst + kvd].copy_from_slice(&k_rows[layer * kvd..(layer + 1) * kvd]);
            self.v[dst..dst + kvd].copy_from_slice(&v_rows[layer * kvd..(layer + 1) * kvd]);
        }
        cache.mark_decoded(id, pos + 1);
    }

    /// Write-through-slot path: copy rows `[from, to)` of every layer of
    /// one side into `dst`, a `[L, max_seq, kvd]` slot view (the
    /// sequence's region inside the store-resident `k_cache`/`v_cache`
    /// staging — see `coordinator::resident::SlotArena`).  This is how
    /// newly materialized effective rows reach the decode-step inputs
    /// without the old per-round full-buffer copy: cost is
    /// O(layers × (to - from) × kvd), independent of sequence length.
    /// Returns the bytes copied.
    pub fn sync_rows_into(&self, side: Side, dst: &mut [f32], from: usize, to: usize) -> usize {
        let (s, kvd) = (self.max_seq, self.kv_dim);
        debug_assert_eq!(dst.len(), self.n_layer * s * kvd);
        debug_assert!(from <= to && to <= s);
        if from >= to {
            return 0;
        }
        let src = match side {
            Side::K => &self.k,
            Side::V => &self.v,
        };
        // rows still seeded by reference come from the shared template
        // (copy-on-write: the owned buffers hold zeros there until a
        // write faults the template in); everything else from owned rows
        let mut owned_from = from;
        if let Some(t) = &self.shared {
            let p = t.rows.min(to);
            if from < p {
                let tsrc = match side {
                    Side::K => &t.k,
                    Side::V => &t.v,
                };
                for layer in 0..self.n_layer {
                    let a = layer * s * kvd + from * kvd;
                    let b = layer * s * kvd + p * kvd;
                    let ta = layer * t.rows * kvd + from * kvd;
                    let tb = layer * t.rows * kvd + p * kvd;
                    dst[a..b].copy_from_slice(&tsrc[ta..tb]);
                }
                owned_from = p;
            }
        }
        if owned_from < to {
            for layer in 0..self.n_layer {
                let (a, b) = (
                    layer * s * kvd + owned_from * kvd,
                    layer * s * kvd + to * kvd,
                );
                dst[a..b].copy_from_slice(&src[a..b]);
            }
        }
        self.n_layer * (to - from) * kvd * 4
    }

    /// The element spans [`EffectiveCache::sync_rows_into`] writes for
    /// rows `[from, to)`: one `(start, end)` per layer inside the
    /// `[L, max_seq, kvd]` slot view, shifted by `base` elements (the
    /// slot's offset within the whole `[b, L, max_seq, kvd]` region).
    /// Sorted and disjoint — exactly what
    /// `Store::note_region_writes` wants, so the engine's device
    /// residency can re-upload only these rows (DESIGN.md §7).
    pub fn row_spans(&self, base: usize, from: usize, to: usize) -> Vec<(usize, usize)> {
        let (s, kvd) = (self.max_seq, self.kv_dim);
        if from >= to {
            return Vec::new();
        }
        (0..self.n_layer)
            .map(|layer| {
                let at = base + layer * s * kvd;
                (at + from * kvd, at + to * kvd)
            })
            .collect()
    }

    /// Materialize rows past the watermark from the compressed store:
    /// O(layers × new-token rows), independent of sequence length.
    /// Returns the number of rows reconstructed.
    pub fn advance(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        dec: &mut dyn LatentDecoder,
    ) -> Result<usize> {
        let len = cache
            .seq_len(id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let from = cache.decoded_upto(id).unwrap_or(0);
        if from >= len {
            return Ok(0);
        }
        let n = len - from;
        self.reconstruct_range(cache, id, from, len, dec)?;
        cache.mark_decoded(id, len);
        self.stats.incremental_advances += 1;
        self.stats.rows_decoded += n as u64;
        Ok(n)
    }

    /// Faithful full reconstruction from row 0, regardless of the
    /// watermark — the eviction-resume path (tier.rs): the scratch was
    /// dropped while the sequence was parked, so everything is rebuilt
    /// in one decoder call over `[L, len, dl]`.
    pub fn rebuild_full(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        dec: &mut dyn LatentDecoder,
    ) -> Result<usize> {
        let len = cache
            .seq_len(id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        self.shared = None; // full rebuild overwrites any template seed
        self.k.fill(0.0);
        self.v.fill(0.0);
        if len > 0 {
            self.reconstruct_range(cache, id, 0, len, dec)?;
        }
        cache.mark_decoded(id, len);
        self.stats.full_rebuilds += 1;
        self.stats.rows_decoded += len as u64;
        Ok(len)
    }

    /// Reconstruct rows [from, to) of every layer into the effective
    /// buffers: gather -> decode -> assemble, range-restricted.
    fn reconstruct_range(
        &mut self,
        cache: &CacheManager,
        id: u64,
        from: usize,
        to: usize,
        dec: &mut dyn LatentDecoder,
    ) -> Result<()> {
        let (l, kvd, dl) = (self.n_layer, self.kv_dim, self.ae_latent);
        let n = to - from;

        // pass 1: gather the range's latents into [L, n, dl] staging
        self.k_lat_stage.resize(l * n * dl, 0.0);
        self.v_lat_stage.resize(l * n * dl, 0.0);
        let has_latent = gather_latent_rows(
            cache,
            id,
            from,
            to,
            l,
            dl,
            &mut self.k_lat_stage,
            &mut self.v_lat_stage,
        )?;

        // pass 2: one decoder call over the [L, n, dl] slice
        self.k_rec_stage.resize(l * n * kvd, 0.0);
        self.v_rec_stage.resize(l * n * kvd, 0.0);
        if has_latent {
            dec.decode_latents_into(
                &self.k_lat_stage,
                &self.v_lat_stage,
                n,
                &mut self.k_rec_stage,
                &mut self.v_rec_stage,
            )?;
        }

        // pass 3: assemble (borrow dance: the rec stages are read while
        // the effective buffers are written, so lend them out)
        let k_rec = std::mem::take(&mut self.k_rec_stage);
        let v_rec = std::mem::take(&mut self.v_rec_stage);
        let r = self.assemble_range(cache, id, from, to, &k_rec, &v_rec);
        self.k_rec_stage = k_rec;
        self.v_rec_stage = v_rec;
        r
    }

    /// Assemble reconstructed rows [from, to) into the effective buffers
    /// layer-by-layer, ascending — aliases read layer l-1's rows for the
    /// same token range, which this pass (or an earlier advance) already
    /// materialized.  `k_rec`/`v_rec` are `[L, n, kvd]` decoder outputs
    /// (only read for `Latent` streams).
    fn assemble_range(
        &mut self,
        cache: &CacheManager,
        id: u64,
        from: usize,
        to: usize,
        k_rec: &[f32],
        v_rec: &[f32],
    ) -> Result<()> {
        if from < self.shared_rows() {
            // reconstruction writing into the template-seeded range:
            // copy-on-write fault before the owned buffers are written
            self.materialize_shared();
        }
        let (l, s, kvd, dh) = (self.n_layer, self.max_seq, self.kv_dim, self.d_head);
        let n = to - from;
        debug_assert_eq!(k_rec.len(), l * n * kvd);
        let (reuse_k, reuse_v) = cache.reuse_masks();
        for layer in 0..l {
            for side in [Side::K, Side::V] {
                let stored = cache.stream(id, layer, side)?;
                let (buf, rec, reuse) = match side {
                    Side::K => (&mut self.k, k_rec, reuse_k),
                    Side::V => (&mut self.v, v_rec, reuse_v),
                };
                let (prev_part, cur_part) = buf.split_at_mut(layer * s * kvd);
                let prev: &[f32] = if layer == 0 {
                    &[]
                } else {
                    &prev_part[(layer - 1) * s * kvd..]
                };
                let dst = &mut cur_part[..s * kvd];
                match stored {
                    StreamRows::Alias => {
                        dst[from * kvd..to * kvd].copy_from_slice(&prev[from * kvd..to * kvd]);
                    }
                    StreamRows::Latent(_) => {
                        dst[from * kvd..to * kvd]
                            .copy_from_slice(&rec[layer * n * kvd..(layer + 1) * n * kvd]);
                        overwrite_reused_heads(dst, prev, &reuse[layer], from, to, kvd, dh);
                    }
                    StreamRows::Heads(view, heads) => {
                        let epr = heads.len() * dh;
                        self.head_stage.resize(n * epr, 0.0);
                        view.decode_range_into(from, to, &mut self.head_stage);
                        for (t, row) in (from..to).zip(self.head_stage.chunks_exact(epr)) {
                            for (slot, &h) in heads.iter().enumerate() {
                                dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                                    .copy_from_slice(&row[slot * dh..(slot + 1) * dh]);
                            }
                        }
                        overwrite_reused_heads(dst, prev, &reuse[layer], from, to, kvd, dh);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Gather the latent rows [from, to) of every layer of one sequence
/// into `[L, n, dl]` staging (`k_out`/`v_out` are zeroed first; non-AE
/// layers stay zero).  Returns whether any stream actually stores
/// latents — when false the decoder call can be skipped entirely.
fn gather_latent_rows(
    cache: &CacheManager,
    id: u64,
    from: usize,
    to: usize,
    n_layer: usize,
    dl: usize,
    k_out: &mut [f32],
    v_out: &mut [f32],
) -> Result<bool> {
    let n = to - from;
    debug_assert_eq!(k_out.len(), n_layer * n * dl);
    k_out.fill(0.0);
    v_out.fill(0.0);
    let mut has_latent = false;
    for layer in 0..n_layer {
        for (side, out) in [(Side::K, &mut *k_out), (Side::V, &mut *v_out)] {
            if let StreamRows::Latent(view) = cache.stream(id, layer, side)? {
                has_latent = true;
                view.decode_range_into(from, to, &mut out[layer * n * dl..(layer + 1) * n * dl]);
            }
        }
    }
    Ok(has_latent)
}

/// Work counters for the batch-first faithful path: tests assert one
/// batched decoder call per round for B > 1 live sequences.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BatchedStats {
    /// batched decoder calls issued (one per round in steady state)
    pub batched_calls: u64,
    /// watermark rows reconstructed through batched calls
    pub batched_rows: u64,
    /// sequences advanced through the per-sequence fallback (bulk
    /// pending ranges, lone rows, or no batched decoder available)
    pub fallback_advances: u64,
}

/// Batch-first planner for the faithful serving mode.
///
/// Each decode round, `advance_round` collects the pending watermark
/// row of every live sequence, packs them into one shared `[B, L, 1,
/// dl]` staging buffer (reused across rounds — no per-round
/// allocations), reconstructs all of them with a **single**
/// [`BatchLatentDecoder::decode_latents_batch_into`] call, and unpacks
/// each slot through the owning sequence's assemble pass (alias and
/// head-reuse resolution stay per-sequence).  The decode round
/// therefore issues O(1) decoder launches instead of O(B).
///
/// Fallback ladder, per sequence: sequences whose pending range is not
/// exactly one row (prompt reconstruction after prefill,
/// eviction-resume) and lone single-row sequences take the per-sequence
/// [`EffectiveCache::advance`] path (`decode_kv_t` → padded
/// `decode_kv`); when the decoder reports no batch capacity at all the
/// whole round degrades to per-sequence advances.  Every path is
/// bitwise-identical (see `tests/batched_faithful.rs`).
#[derive(Default)]
pub struct BatchedAdvance {
    k_lat: Vec<f32>,
    v_lat: Vec<f32>,
    k_rec: Vec<f32>,
    v_rec: Vec<f32>,
    /// launch accounting for the batch-first path
    pub stats: BatchedStats,
}

impl BatchedAdvance {
    /// Empty planner; staging grows on first use and is then reused.
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance every sequence in `ids` to its current length, batching
    /// the single-row (steady-state decode) reconstructions into shared
    /// decoder calls.  Returns the total rows reconstructed.
    pub fn advance_round<D: BatchLatentDecoder>(
        &mut self,
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        ids: &[u64],
        dec: &mut D,
    ) -> Result<usize> {
        let cap = dec.batch_capacity().filter(|&c| c > 1);
        let mut total = 0usize;
        let mut single: Vec<(u64, usize)> = Vec::new();
        for &id in ids {
            let len = cache
                .seq_len(id)
                .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            let from = cache.decoded_upto(id).unwrap_or(0);
            if from >= len {
                continue;
            }
            if len - from == 1 && cap.is_some() {
                single.push((id, from));
            } else {
                // bulk pending range (prompt reconstruction, resume) or
                // no batched decoder: per-sequence incremental advance
                total += Self::fallback(cache, effs, id, dec)?;
                self.stats.fallback_advances += 1;
            }
        }
        let Some(cap) = cap else {
            return Ok(total);
        };
        for group in single.chunks(cap) {
            if group.len() == 1 {
                // a lone row decodes cheaper through the unpadded
                // [L, 1, dl] per-sequence path
                total += Self::fallback(cache, effs, group[0].0, dec)?;
                self.stats.fallback_advances += 1;
            } else {
                total += self.advance_group(cache, effs, group, dec)?;
            }
        }
        Ok(total)
    }

    fn fallback<D: BatchLatentDecoder>(
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        id: u64,
        dec: &mut D,
    ) -> Result<usize> {
        effs.get_mut(&id)
            .ok_or_else(|| anyhow!("no effective cache for sequence {id}"))?
            .advance(cache, id, dec)
    }

    /// One packed decoder call over `group` (each entry one pending row).
    fn advance_group<D: BatchLatentDecoder>(
        &mut self,
        cache: &mut CacheManager,
        effs: &mut HashMap<u64, EffectiveCache>,
        group: &[(u64, usize)],
        dec: &mut D,
    ) -> Result<usize> {
        let eff0 = effs
            .get(&group[0].0)
            .ok_or_else(|| anyhow!("no effective cache for sequence {}", group[0].0))?;
        let (l, dl, kvd) = (eff0.n_layer, eff0.ae_latent, eff0.kv_dim);
        let g = group.len();

        // pack: slot b's [L, 1, dl] latents at offset b * L * dl
        self.k_lat.resize(g * l * dl, 0.0);
        self.v_lat.resize(g * l * dl, 0.0);
        let mut any_latent = false;
        for (slot, &(id, from)) in group.iter().enumerate() {
            any_latent |= gather_latent_rows(
                cache,
                id,
                from,
                from + 1,
                l,
                dl,
                &mut self.k_lat[slot * l * dl..(slot + 1) * l * dl],
                &mut self.v_lat[slot * l * dl..(slot + 1) * l * dl],
            )?;
        }

        // one decoder launch for the whole round
        self.k_rec.resize(g * l * kvd, 0.0);
        self.v_rec.resize(g * l * kvd, 0.0);
        if any_latent {
            self.k_rec.fill(0.0);
            self.v_rec.fill(0.0);
            dec.decode_latents_batch_into(
                &self.k_lat[..g * l * dl],
                &self.v_lat[..g * l * dl],
                g,
                &mut self.k_rec[..g * l * kvd],
                &mut self.v_rec[..g * l * kvd],
            )?;
            self.stats.batched_calls += 1;
        }

        // unpack: per-sequence assembly (aliases, head reuse) + watermark
        for (slot, &(id, from)) in group.iter().enumerate() {
            let eff = effs
                .get_mut(&id)
                .ok_or_else(|| anyhow!("no effective cache for sequence {id}"))?;
            eff.assemble_range(
                cache,
                id,
                from,
                from + 1,
                &self.k_rec[slot * l * kvd..(slot + 1) * l * kvd],
                &self.v_rec[slot * l * kvd..(slot + 1) * l * kvd],
            )?;
            cache.mark_decoded(id, from + 1);
            eff.stats.incremental_advances += 1;
            eff.stats.rows_decoded += 1;
        }
        self.stats.batched_rows += g as u64;
        Ok(g)
    }
}

/// Heads marked reused alias layer l-1's effective rows; they override
/// whatever the reconstruction produced for the range.
fn overwrite_reused_heads(
    dst: &mut [f32],
    prev: &[f32],
    reuse: &[bool],
    from: usize,
    to: usize,
    kvd: usize,
    dh: usize,
) {
    for (h, &r) in reuse.iter().enumerate() {
        if r {
            for t in from..to {
                dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                    .copy_from_slice(&prev[t * kvd + h * dh..t * kvd + (h + 1) * dh]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::model::memory::CompressionPlan;
    use crate::model::Arch;
    use crate::util::rng::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "test".into(),
            arch: Arch::Gpt2,
            vocab: 256,
            n_layer: 4,
            d_model: 32,
            n_head: 4,
            n_kv_head: 4,
            d_head: 8,
            ffn_dim: 64,
            max_seq: 64,
            ae_hidden: 24,
            ae_latent: 16,
            bytes_per_el: 4,
        }
    }

    fn append_random_token(m: &mut CacheManager, id: u64, rng: &mut Rng) {
        let spec = m.cfg.spec.clone();
        let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
        };
        let kl = mk(rng, spec.n_layer * spec.ae_latent);
        let vl = mk(rng, spec.n_layer * spec.ae_latent);
        let kr = mk(rng, spec.n_layer * spec.kv_dim());
        let vr = mk(rng, spec.n_layer * spec.kv_dim());
        m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
    }

    #[test]
    fn per_step_work_scales_with_new_rows_not_seq_len() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
        plan.reuse_k[1][0] = true;
        plan.reuse_v[2][1] = true;
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        let mut eff = EffectiveCache::new(&spec);
        let mut rng = Rng::new(7);
        let steps = 30;
        for _ in 0..steps {
            append_random_token(&mut m, id, &mut rng);
            assert_eq!(eff.advance(&mut m, id, &mut dec).unwrap(), 1);
        }
        // each row was decoded exactly once — O(new rows) per step; the
        // old per-round full rebuild would have decoded 1+2+...+steps
        assert_eq!(eff.stats.rows_decoded, steps as u64);
        assert_eq!(eff.stats.incremental_advances, steps as u64);
        assert_eq!(eff.stats.full_rebuilds, 0);
        // advancing with nothing new is free
        assert_eq!(eff.advance(&mut m, id, &mut dec).unwrap(), 0);
        assert_eq!(eff.stats.rows_decoded, steps as u64);
    }

    #[test]
    fn shared_seed_is_copy_on_write() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(23);
        let rows = 5usize;
        for _ in 0..rows {
            append_random_token(&mut m, id, &mut rng);
        }
        let (l, s, kvd) = (spec.n_layer, spec.max_seq, spec.kv_dim());
        let tmpl = std::sync::Arc::new(EffTemplate {
            rows,
            k: (0..l * rows * kvd).map(|i| i as f32).collect(),
            v: (0..l * rows * kvd).map(|i| -(i as f32)).collect(),
        });
        let mut eff = EffectiveCache::new(&spec);
        eff.seed_shared(&mut m, id, tmpl.clone());
        assert_eq!(eff.shared_rows(), rows);
        assert_eq!(m.decoded_upto(id), Some(rows), "shared seed moves the watermark");
        // reads source the template (owned buffers still zero)
        let mut staged = vec![0.0f32; l * s * kvd];
        eff.sync_rows_into(Side::K, &mut staged, 0, s);
        assert_eq!(staged[kvd], tmpl.k[kvd], "row 1 comes from the template");
        assert_eq!(staged[(rows - 1) * kvd], tmpl.k[(rows - 1) * kvd]);
        assert!(eff.k.iter().all(|&x| x == 0.0), "no copy happened yet");
        // a write past the seeded range keeps the template referenced
        let zk = vec![1.5; l * kvd];
        eff.push_step_row(&mut m, id, rows, &zk, &zk);
        assert_eq!(eff.shared_rows(), rows, "append must not fault the template");
        let mut synced = vec![0.0f32; l * s * kvd];
        eff.sync_rows_into(Side::K, &mut synced, 0, s);
        assert_eq!(synced[rows * kvd], 1.5, "owned rows layer on top");
        assert_eq!(synced[0], tmpl.k[0], "template rows still sourced");
        // materialization copies the rows and drops the reference; the
        // staged view is bitwise unchanged
        eff.materialize_shared();
        assert_eq!(eff.shared_rows(), 0);
        let mut after = vec![0.0f32; l * s * kvd];
        eff.sync_rows_into(Side::K, &mut after, 0, s);
        for (a, b) in synced.iter().zip(&after) {
            assert_eq!(a.to_bits(), b.to_bits(), "materialization must be invisible");
        }
        // rebuild_full drops any template seed before refilling
        let mut eff2 = EffectiveCache::new(&spec);
        eff2.seed_shared(&mut m, id, tmpl);
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        eff2.rebuild_full(&mut m, id, &mut dec).unwrap();
        assert_eq!(eff2.shared_rows(), 0);
    }

    #[test]
    fn alias_layers_follow_previous_layer() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[2] = vec![true; spec.n_kv_head];
        plan.reuse_v[2] = vec![true; spec.n_kv_head];
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        let mut eff = EffectiveCache::new(&spec);
        let mut rng = Rng::new(9);
        for _ in 0..5 {
            append_random_token(&mut m, id, &mut rng);
        }
        eff.advance(&mut m, id, &mut dec).unwrap();
        let (s, kvd) = (spec.max_seq, spec.kv_dim());
        let rows = 5 * kvd;
        assert_eq!(
            &eff.k[2 * s * kvd..2 * s * kvd + rows],
            &eff.k[s * kvd..s * kvd + rows],
            "fully-aliased layer must mirror layer l-1"
        );
        // non-aliased layers hold the exact stored raw rows
        assert_ne!(
            &eff.k[..rows],
            &eff.k[s * kvd..s * kvd + rows],
            "distinct layers should differ"
        );
    }
}
