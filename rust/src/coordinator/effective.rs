//! Incremental effective-cache maintenance — the decode-on-retrieval
//! working set kept in O(new token rows) per step.
//!
//! The paper's Fig. 1 dataflow reconstructs full-width KV vectors from
//! the compressed store on retrieval.  Done naively that means
//! re-gathering, re-decoding, and re-alias-resolving the *entire*
//! sequence every decode round (the pre-refactor `rebuild_effective`:
//! O(seq_len) per step).  `EffectiveCache` instead owns persistent
//! per-sequence scratch and, on each `advance`, materializes only the
//! rows past the cache manager's `decoded_upto` watermark:
//!
//! * latents are gathered for the new range only (`StreamView::
//!   decode_range_into`, zero-copy out of the block store),
//! * the AE decoder runs on the `[L, n, dl]` slice (n = new rows,
//!   usually 1) instead of `[L, max_seq, dl]`,
//! * head aliases resolve layer-by-layer for the new rows alone.
//!
//! Chunked advances are bit-identical to a one-shot `rebuild_full`
//! (randomized cross-check in `tests/incremental_equivalence.rs`); the
//! full path remains for eviction-resume, where the scratch was dropped
//! while the sequence was parked in the host tier.

use crate::kvcache::{CacheManager, Side, StreamRows};
use crate::model::ModelSpec;
use anyhow::{anyhow, Result};

/// Runs the AE decoder over latent rows.  The serving engine implements
/// this with the `{model}_decode_kv[_t]` artifacts; tests use pure-rust
/// mocks so the reconstruction dataflow is checkable without artifacts.
pub trait LatentDecoder {
    /// `k_lat`/`v_lat` are `[L, n, dl]` row-major; write the `[L, n,
    /// kvd]` reconstructions into `k_rec`/`v_rec`.  Must be a pure
    /// per-row function of the latents (chunked calls must compose to
    /// the full-range call — that is what makes incremental maintenance
    /// equivalent to full rebuilds).
    fn decode_latents_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        n: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()>;
}

/// Deterministic row-wise mock decoder for tests and benches: a pure
/// function of each latent row (like the real per-row decoder MLP), so
/// chunked calls compose exactly to full-range calls — the one
/// `LatentDecoder` contract the equivalence tests rely on.  Defined
/// once here so every suite tests the same purity guarantee.
pub struct RowWiseMockDecoder {
    pub ae_latent: usize,
    pub kv_dim: usize,
}

impl RowWiseMockDecoder {
    pub fn for_spec(spec: &ModelSpec) -> Self {
        RowWiseMockDecoder {
            ae_latent: spec.ae_latent,
            kv_dim: spec.kv_dim(),
        }
    }
}

impl LatentDecoder for RowWiseMockDecoder {
    fn decode_latents_into(
        &mut self,
        k_lat: &[f32],
        v_lat: &[f32],
        _n: usize,
        k_rec: &mut [f32],
        v_rec: &mut [f32],
    ) -> Result<()> {
        for (lat, rec) in [(k_lat, &mut *k_rec), (v_lat, &mut *v_rec)] {
            for (row_lat, row_rec) in lat
                .chunks_exact(self.ae_latent)
                .zip(rec.chunks_exact_mut(self.kv_dim))
            {
                for (j, o) in row_rec.iter_mut().enumerate() {
                    *o = row_lat[j % self.ae_latent] * 0.5
                        + row_lat[(j * 7 + 1) % self.ae_latent] * 0.25;
                }
            }
        }
        Ok(())
    }
}

/// Work counters proving the per-step cost law: tests assert
/// `rows_decoded` grows by new rows per step, not by sequence length.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EffStats {
    pub full_rebuilds: u64,
    pub incremental_advances: u64,
    /// token rows gathered + decoded + assembled, totalled across calls
    pub rows_decoded: u64,
}

/// Per-sequence effective-cache scratch: `[L, max_seq, kvd]` K/V buffers
/// (the shape the decode_step artifacts consume) plus persistent latent
/// and reconstruction staging so per-step maintenance never reallocates.
pub struct EffectiveCache {
    n_layer: usize,
    max_seq: usize,
    kv_dim: usize,
    ae_latent: usize,
    d_head: usize,
    /// [L, S, kvd] row-major effective K
    pub k: Vec<f32>,
    /// [L, S, kvd] row-major effective V
    pub v: Vec<f32>,
    k_lat_stage: Vec<f32>,
    v_lat_stage: Vec<f32>,
    k_rec_stage: Vec<f32>,
    v_rec_stage: Vec<f32>,
    head_stage: Vec<f32>,
    pub stats: EffStats,
}

impl EffectiveCache {
    pub fn new(spec: &ModelSpec) -> Self {
        let n = spec.n_layer * spec.max_seq * spec.kv_dim();
        EffectiveCache {
            n_layer: spec.n_layer,
            max_seq: spec.max_seq,
            kv_dim: spec.kv_dim(),
            ae_latent: spec.ae_latent,
            d_head: spec.d_head,
            k: vec![0.0; n],
            v: vec![0.0; n],
            k_lat_stage: Vec::new(),
            v_lat_stage: Vec::new(),
            k_rec_stage: Vec::new(),
            v_rec_stage: Vec::new(),
            head_stage: Vec::new(),
            stats: EffStats::default(),
        }
    }

    /// Seed rows [0, rows) from prefill's in-graph effective cache
    /// (`k_eff`/`v_eff`: [L, S, kvd]) and advance the manager watermark:
    /// those rows need no reconstruction.
    pub fn seed(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        k_eff: &[f32],
        v_eff: &[f32],
        rows: usize,
    ) {
        let (s, kvd) = (self.max_seq, self.kv_dim);
        for layer in 0..self.n_layer {
            let base = layer * s * kvd;
            self.k[base..base + rows * kvd].copy_from_slice(&k_eff[base..base + rows * kvd]);
            self.v[base..base + rows * kvd].copy_from_slice(&v_eff[base..base + rows * kvd]);
        }
        cache.mark_decoded(id, rows);
    }

    /// Append one decoded step's in-graph effective row at `pos` for
    /// every layer (`k_rows`/`v_rows`: [L, kvd]) and advance the
    /// watermark — the fast path when reconstruction is not requested.
    pub fn push_step_row(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        pos: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        let (s, kvd) = (self.max_seq, self.kv_dim);
        for layer in 0..self.n_layer {
            let dst = layer * s * kvd + pos * kvd;
            self.k[dst..dst + kvd].copy_from_slice(&k_rows[layer * kvd..(layer + 1) * kvd]);
            self.v[dst..dst + kvd].copy_from_slice(&v_rows[layer * kvd..(layer + 1) * kvd]);
        }
        cache.mark_decoded(id, pos + 1);
    }

    /// Materialize rows past the watermark from the compressed store:
    /// O(layers × new-token rows), independent of sequence length.
    /// Returns the number of rows reconstructed.
    pub fn advance(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        dec: &mut dyn LatentDecoder,
    ) -> Result<usize> {
        let len = cache
            .seq_len(id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let from = cache.decoded_upto(id).unwrap_or(0);
        if from >= len {
            return Ok(0);
        }
        let n = len - from;
        self.reconstruct_range(cache, id, from, len, dec)?;
        cache.mark_decoded(id, len);
        self.stats.incremental_advances += 1;
        self.stats.rows_decoded += n as u64;
        Ok(n)
    }

    /// Faithful full reconstruction from row 0, regardless of the
    /// watermark — the eviction-resume path (tier.rs): the scratch was
    /// dropped while the sequence was parked, so everything is rebuilt
    /// in one decoder call over `[L, len, dl]`.
    pub fn rebuild_full(
        &mut self,
        cache: &mut CacheManager,
        id: u64,
        dec: &mut dyn LatentDecoder,
    ) -> Result<usize> {
        let len = cache
            .seq_len(id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        self.k.fill(0.0);
        self.v.fill(0.0);
        if len > 0 {
            self.reconstruct_range(cache, id, 0, len, dec)?;
        }
        cache.mark_decoded(id, len);
        self.stats.full_rebuilds += 1;
        self.stats.rows_decoded += len as u64;
        Ok(len)
    }

    /// Reconstruct rows [from, to) of every layer into the effective
    /// buffers: gather -> decode -> assemble, range-restricted.
    fn reconstruct_range(
        &mut self,
        cache: &CacheManager,
        id: u64,
        from: usize,
        to: usize,
        dec: &mut dyn LatentDecoder,
    ) -> Result<()> {
        let (l, s, kvd, dl, dh) = (
            self.n_layer,
            self.max_seq,
            self.kv_dim,
            self.ae_latent,
            self.d_head,
        );
        let n = to - from;

        // pass 1: gather the range's latents into [L, n, dl] staging
        self.k_lat_stage.resize(l * n * dl, 0.0);
        self.v_lat_stage.resize(l * n * dl, 0.0);
        self.k_lat_stage.fill(0.0);
        self.v_lat_stage.fill(0.0);
        let mut has_latent = false;
        for layer in 0..l {
            for (side, stage) in [
                (Side::K, &mut self.k_lat_stage),
                (Side::V, &mut self.v_lat_stage),
            ] {
                if let StreamRows::Latent(view) = cache.stream(id, layer, side)? {
                    has_latent = true;
                    view.decode_range_into(
                        from,
                        to,
                        &mut stage[layer * n * dl..(layer + 1) * n * dl],
                    );
                }
            }
        }

        // pass 2: one decoder call over the [L, n, dl] slice
        self.k_rec_stage.resize(l * n * kvd, 0.0);
        self.v_rec_stage.resize(l * n * kvd, 0.0);
        if has_latent {
            dec.decode_latents_into(
                &self.k_lat_stage,
                &self.v_lat_stage,
                n,
                &mut self.k_rec_stage,
                &mut self.v_rec_stage,
            )?;
        }

        // pass 3: assemble the new rows layer-by-layer, ascending —
        // aliases read layer l-1's rows for the same token range, which
        // this pass (or an earlier advance) already materialized
        let (reuse_k, reuse_v) = cache.reuse_masks();
        for layer in 0..l {
            for side in [Side::K, Side::V] {
                let stored = cache.stream(id, layer, side)?;
                let (buf, rec, reuse) = match side {
                    Side::K => (&mut self.k, &self.k_rec_stage, reuse_k),
                    Side::V => (&mut self.v, &self.v_rec_stage, reuse_v),
                };
                let (prev_part, cur_part) = buf.split_at_mut(layer * s * kvd);
                let prev: &[f32] = if layer == 0 {
                    &[]
                } else {
                    &prev_part[(layer - 1) * s * kvd..]
                };
                let dst = &mut cur_part[..s * kvd];
                match stored {
                    StreamRows::Alias => {
                        dst[from * kvd..to * kvd].copy_from_slice(&prev[from * kvd..to * kvd]);
                    }
                    StreamRows::Latent(_) => {
                        dst[from * kvd..to * kvd]
                            .copy_from_slice(&rec[layer * n * kvd..(layer + 1) * n * kvd]);
                        overwrite_reused_heads(dst, prev, &reuse[layer], from, to, kvd, dh);
                    }
                    StreamRows::Heads(view, heads) => {
                        let epr = heads.len() * dh;
                        self.head_stage.resize(n * epr, 0.0);
                        view.decode_range_into(from, to, &mut self.head_stage);
                        for (t, row) in (from..to).zip(self.head_stage.chunks_exact(epr)) {
                            for (slot, &h) in heads.iter().enumerate() {
                                dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                                    .copy_from_slice(&row[slot * dh..(slot + 1) * dh]);
                            }
                        }
                        overwrite_reused_heads(dst, prev, &reuse[layer], from, to, kvd, dh);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Heads marked reused alias layer l-1's effective rows; they override
/// whatever the reconstruction produced for the range.
fn overwrite_reused_heads(
    dst: &mut [f32],
    prev: &[f32],
    reuse: &[bool],
    from: usize,
    to: usize,
    kvd: usize,
    dh: usize,
) {
    for (h, &r) in reuse.iter().enumerate() {
        if r {
            for t in from..to {
                dst[t * kvd + h * dh..t * kvd + (h + 1) * dh]
                    .copy_from_slice(&prev[t * kvd + h * dh..t * kvd + (h + 1) * dh]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::model::memory::CompressionPlan;
    use crate::model::Arch;
    use crate::util::rng::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "test".into(),
            arch: Arch::Gpt2,
            vocab: 256,
            n_layer: 4,
            d_model: 32,
            n_head: 4,
            n_kv_head: 4,
            d_head: 8,
            ffn_dim: 64,
            max_seq: 64,
            ae_hidden: 24,
            ae_latent: 16,
            bytes_per_el: 4,
        }
    }

    fn append_random_token(m: &mut CacheManager, id: u64, rng: &mut Rng) {
        let spec = m.cfg.spec.clone();
        let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
        };
        let kl = mk(rng, spec.n_layer * spec.ae_latent);
        let vl = mk(rng, spec.n_layer * spec.ae_latent);
        let kr = mk(rng, spec.n_layer * spec.kv_dim());
        let vr = mk(rng, spec.n_layer * spec.kv_dim());
        m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
    }

    #[test]
    fn per_step_work_scales_with_new_rows_not_seq_len() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer / 2);
        plan.reuse_k[1][0] = true;
        plan.reuse_v[2][1] = true;
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        let mut eff = EffectiveCache::new(&spec);
        let mut rng = Rng::new(7);
        let steps = 30;
        for _ in 0..steps {
            append_random_token(&mut m, id, &mut rng);
            assert_eq!(eff.advance(&mut m, id, &mut dec).unwrap(), 1);
        }
        // each row was decoded exactly once — O(new rows) per step; the
        // old per-round full rebuild would have decoded 1+2+...+steps
        assert_eq!(eff.stats.rows_decoded, steps as u64);
        assert_eq!(eff.stats.incremental_advances, steps as u64);
        assert_eq!(eff.stats.full_rebuilds, 0);
        // advancing with nothing new is free
        assert_eq!(eff.advance(&mut m, id, &mut dec).unwrap(), 0);
        assert_eq!(eff.stats.rows_decoded, steps as u64);
    }

    #[test]
    fn alias_layers_follow_previous_layer() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[2] = vec![true; spec.n_kv_head];
        plan.reuse_v[2] = vec![true; spec.n_kv_head];
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut dec = RowWiseMockDecoder::for_spec(&spec);
        let mut eff = EffectiveCache::new(&spec);
        let mut rng = Rng::new(9);
        for _ in 0..5 {
            append_random_token(&mut m, id, &mut rng);
        }
        eff.advance(&mut m, id, &mut dec).unwrap();
        let (s, kvd) = (spec.max_seq, spec.kv_dim());
        let rows = 5 * kvd;
        assert_eq!(
            &eff.k[2 * s * kvd..2 * s * kvd + rows],
            &eff.k[s * kvd..s * kvd + rows],
            "fully-aliased layer must mirror layer l-1"
        );
        // non-aliased layers hold the exact stored raw rows
        assert_ne!(
            &eff.k[..rows],
            &eff.k[s * kvd..s * kvd + rows],
            "distinct layers should differ"
        );
    }
}
