//! Virtual/wall clock abstraction for the serving loop.
//!
//! Every timing consumer in the coordinator (`scheduler`, `metrics`,
//! `trace`, the server front-end) reads time as a [`Stamp`] from a
//! [`Clock`] instead of calling `Instant::now()` directly.  Under
//! [`Clock::Wall`] a stamp is real elapsed time since the clock's epoch,
//! so production serving behaves exactly as before.  Under
//! [`Clock::Virtual`] time only moves when the scheduler *charges* it —
//! a deterministic [`CostModel`] prices each prefill launch, decode
//! round, and tier transfer — so a scenario replayed from the same seed
//! produces bit-identical TTFT/latency numbers, timing fields included
//! (DESIGN.md §8).
//!
//! The rule that keeps one code path serving both modes: measure
//! elapsed work as `clock.now() - t0` and advance virtual time with
//! `clock.charge(cost)` *between* the two reads.  Under a wall clock the
//! charge is a no-op and the subtraction measures real time; under a
//! virtual clock the subtraction yields exactly the charged cost.

use std::ops::Add;
use std::time::{Duration, Instant};

/// A point in time relative to a [`Clock`]'s epoch.
///
/// Stamps are plain durations-since-epoch, so they are `Copy`, totally
/// ordered, and serialize as integers — unlike `Instant`, which cannot
/// leave the process and therefore cannot appear in a replayable trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Stamp(Duration);

impl Stamp {
    /// The clock epoch itself.
    pub const ZERO: Stamp = Stamp(Duration::ZERO);

    /// Stamp at `d` past the epoch.
    pub fn from_duration(d: Duration) -> Stamp {
        Stamp(d)
    }

    /// Stamp at `ms` milliseconds past the epoch (test/scenario helper).
    pub fn from_ms(ms: u64) -> Stamp {
        Stamp(Duration::from_millis(ms))
    }

    /// Offset from the epoch.
    pub fn as_duration(self) -> Duration {
        self.0
    }

    /// Elapsed time since `earlier`, clamped to zero when `earlier` is
    /// actually later (mirrors `Instant::saturating_duration_since`).
    pub fn saturating_since(self, earlier: Stamp) -> Duration {
        self.0.saturating_sub(earlier.0)
    }
}

impl Add<Duration> for Stamp {
    type Output = Stamp;

    fn add(self, rhs: Duration) -> Stamp {
        Stamp(self.0 + rhs)
    }
}

/// Deterministic price list for scheduler work under a virtual clock.
///
/// The magnitudes are loosely calibrated to the real-artifact numbers in
/// `BENCH_decode_hotpath.json` (a prefill launch costs a couple of ms, a
/// decode round ~1.5 ms plus per-row work) so virtual TTFT/throughput
/// figures land in a realistic range, but their only hard requirement is
/// determinism: integer nanoseconds, no floating point, no environment
/// dependence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Fixed cost of one prefill launch (compile-cache hit assumed).
    pub prefill_launch: Duration,
    /// Per prompt-row cost within a prefill launch.
    pub prefill_row: Duration,
    /// Fixed cost of one decode round (a single batched launch).
    pub decode_launch: Duration,
    /// Per live-sequence cost within a decode round.
    pub decode_row: Duration,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            prefill_launch: Duration::from_micros(3000),
            prefill_row: Duration::from_micros(40),
            decode_launch: Duration::from_micros(1500),
            decode_row: Duration::from_micros(25),
        }
    }
}

impl CostModel {
    /// Price of an admission wave: `launches` prefill launches staging
    /// `rows` prompt rows in total (shared-prefix rows that launched no
    /// work are excluded by the caller).
    pub fn prefill_cost(&self, launches: u64, rows: usize) -> Duration {
        self.prefill_launch * launches as u32 + self.prefill_row * rows as u32
    }

    /// Price of one decode round advancing `rows` live sequences.
    pub fn decode_cost(&self, rows: usize) -> Duration {
        self.decode_launch + self.decode_row * rows as u32
    }
}

/// Time source for the serving loop: real (`Wall`) or charged
/// (`Virtual`).
#[derive(Debug, Clone)]
pub enum Clock {
    /// Real time; stamps measure elapsed wall time since `epoch`.
    Wall {
        /// Process instant all stamps are measured from.
        epoch: Instant,
    },
    /// Deterministic time; only [`Clock::charge`] and
    /// [`Clock::advance_to`] move it.
    Virtual {
        /// Current offset from the epoch.
        now: Duration,
        /// Price list used by the scheduler's charge sites.
        costs: CostModel,
    },
}

impl Clock {
    /// Wall clock with its epoch at the moment of the call.
    pub fn wall() -> Clock {
        Clock::Wall {
            epoch: Instant::now(),
        }
    }

    /// Virtual clock starting at the epoch with the given price list.
    pub fn virtual_with(costs: CostModel) -> Clock {
        Clock::Virtual {
            now: Duration::ZERO,
            costs,
        }
    }

    /// Virtual clock with the default [`CostModel`].
    pub fn virtual_default() -> Clock {
        Clock::virtual_with(CostModel::default())
    }

    /// True when time only moves via `charge`/`advance_to`.
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }

    /// Current time as a stamp past the epoch.
    pub fn now(&self) -> Stamp {
        match self {
            Clock::Wall { epoch } => Stamp(epoch.elapsed()),
            Clock::Virtual { now, .. } => Stamp(*now),
        }
    }

    /// Advance virtual time by `cost`; no-op under a wall clock (the
    /// real work being priced took real time there).
    pub fn charge(&mut self, cost: Duration) {
        if let Clock::Virtual { now, .. } = self {
            *now += cost;
        }
    }

    /// Jump virtual time forward to `t` (never backward); no-op under a
    /// wall clock.  Used to skip idle gaps until the next trace arrival.
    pub fn advance_to(&mut self, t: Stamp) {
        if let Clock::Virtual { now, .. } = self {
            *now = (*now).max(t.0);
        }
    }

    /// Price list for charge sites (the default model under a wall
    /// clock, where charges are no-ops anyway).
    pub fn costs(&self) -> CostModel {
        match self {
            Clock::Wall { .. } => CostModel::default(),
            Clock::Virtual { costs, .. } => *costs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamp_ordering_and_arith() {
        let a = Stamp::from_ms(10);
        let b = Stamp::from_ms(25);
        assert!(a < b);
        assert_eq!(b.saturating_since(a), Duration::from_millis(15));
        assert_eq!(a.saturating_since(b), Duration::ZERO);
        assert_eq!(a + Duration::from_millis(15), b);
        assert_eq!(Stamp::ZERO.as_duration(), Duration::ZERO);
    }

    #[test]
    fn virtual_clock_moves_only_when_charged() {
        let mut c = Clock::virtual_default();
        assert!(c.is_virtual());
        let t0 = c.now();
        assert_eq!(t0, Stamp::ZERO);
        c.charge(Duration::from_millis(3));
        assert_eq!(c.now().saturating_since(t0), Duration::from_millis(3));
        // advance_to never moves backward
        c.advance_to(Stamp::from_ms(1));
        assert_eq!(c.now(), Stamp::from_ms(3));
        c.advance_to(Stamp::from_ms(10));
        assert_eq!(c.now(), Stamp::from_ms(10));
    }

    #[test]
    fn wall_clock_ignores_charges() {
        let mut c = Clock::wall();
        assert!(!c.is_virtual());
        let t0 = c.now();
        c.charge(Duration::from_secs(100));
        c.advance_to(Stamp::from_ms(1_000_000));
        // real elapsed time is tiny, not the charged 100 s
        assert!(c.now().saturating_since(t0) < Duration::from_secs(5));
    }

    #[test]
    fn cost_model_prices_are_linear() {
        let m = CostModel::default();
        assert_eq!(
            m.prefill_cost(2, 10),
            m.prefill_launch * 2 + m.prefill_row * 10
        );
        assert_eq!(m.decode_cost(8), m.decode_launch + m.decode_row * 8);
        assert!(m.decode_cost(0) > Duration::ZERO);
    }

    #[test]
    fn identical_charge_sequences_are_bit_identical() {
        let run = || {
            let mut c = Clock::virtual_default();
            let costs = c.costs();
            c.charge(costs.prefill_cost(1, 24));
            for b in [4usize, 8, 8, 6] {
                c.charge(costs.decode_cost(b));
            }
            c.now()
        };
        assert_eq!(run(), run());
    }
}
