//! Whole-stack invariant checks for the scenario harness (DESIGN.md §8).
//!
//! [`check_round`] audits every cross-layer consistency law the serving
//! stack promises, against the scheduler's own live set: the prefix
//! trie's refcounts, sequence leaks between the scheduler and the cache
//! manager, the soft cache budget, slot/region coherence of the
//! store-resident staging path, dirty-span well-formedness, host-tier
//! accounting, and metrics conservation.  The harness runs it after
//! every scheduler round — including rounds that *failed* with an
//! injected fault, which is where transactional bugs hide.
//!
//! On success the checker returns a fingerprint of the audited state,
//! which the scenario folds into its invariant digest: two runs that
//! pass the same checks *in different states* still produce different
//! digests, so the determinism assertion covers the trajectory, not
//! just the absence of violations.

use super::scheduler::{RunState, ServingEngine};

/// The store-resident staging regions audited for slot/span coherence.
const REGIONS: [&str; 2] = ["k_cache", "v_cache"];

/// Audit every whole-stack invariant after one scheduler round.
///
/// `strict_budget` enables the soft-budget law; pass `false` for the
/// check immediately after a round that returned an error — a fault
/// injected between admission and parking legitimately leaves the
/// round over budget (the next successful round must repair it), while
/// every *structural* invariant must hold even then.
///
/// Returns an FNV-1a fingerprint of the audited counters on success,
/// or all violations (newline-joined) on failure.  The conservation
/// laws assume the engine serves one run, as the scenario harness does.
pub fn check_round(
    s: &ServingEngine<'_>,
    state: &RunState,
    strict_budget: bool,
) -> Result<u64, String> {
    let mut errs: Vec<String> = Vec::new();
    let active = state.active_seqs();

    // -- prefix trie: refcounts re-derivable from live sequences + pins
    //    (admission-template pins plus the chains a router delivered to
    //    this worker — migration pins hold delivered chunks resident so
    //    "ships at most once per worker" stays sound)
    let mut pinned = s.waves.pinned_leaves();
    pinned.extend_from_slice(&s.migration_pins);
    if let Err(e) = s.cache.prefix_integrity(&pinned) {
        errs.push(format!("prefix integrity: {e}"));
    }

    // -- sequence leaks: the cache manager must track exactly the
    //    scheduler's active set (a failed wave that left sequences
    //    behind shows up here)
    let cache_ids = s.cache.sequence_ids();
    let mut active_ids: Vec<u64> = active.iter().map(|a| a.cache_id).collect();
    active_ids.sort_unstable();
    if active_ids.windows(2).any(|w| w[0] == w[1]) {
        errs.push(format!("duplicate cache_id in active set: {active_ids:?}"));
    }
    if cache_ids != active_ids {
        errs.push(format!(
            "sequence leak: cache manager tracks {cache_ids:?}, scheduler owns {active_ids:?}"
        ));
    }

    // -- soft budget law: after parking ran, the unparked working set
    //    plus one round of worst-case growth fits the budget net of the
    //    shared prefix store, or parking is already maximal (one
    //    survivor — rounds must keep completing)
    if strict_budget {
        if let Some(budget) = s.cfg.cache_budget {
            let shared = s.cache.prefix_stats().shared_bytes;
            let unparked: Vec<&_> = active.iter().filter(|a| !a.parked).collect();
            let bytes: usize = unparked
                .iter()
                .map(|a| s.cache.seq_stored_bytes(a.cache_id))
                .sum();
            let projected = bytes + unparked.len() * s.cache.cfg.bytes_per_token()
                * s.cache.cfg.block_size;
            if unparked.len() > 1 && projected > budget.saturating_sub(shared) {
                errs.push(format!(
                    "budget law: {} unparked sequences project {projected} B \
                     over budget {budget} B (shared {shared} B)",
                    unparked.len()
                ));
            }
        }
    }

    // -- slot coherence: every assigned slot has a unique, live,
    //    unparked owner whose sync watermark never outruns its decoded
    //    rows
    let assigned: Vec<(usize, u64)> = s
        .arena
        .assignments()
        .iter()
        .enumerate()
        .filter_map(|(slot, id)| id.map(|id| (slot, id)))
        .collect();
    for (slot, id) in &assigned {
        if assigned.iter().any(|(s2, id2)| id2 == id && s2 != slot) {
            errs.push(format!("sequence {id} owns more than one slot"));
        }
        match active.iter().find(|a| a.cache_id == *id) {
            None => errs.push(format!("slot {slot} owned by retired sequence {id}")),
            Some(a) if a.parked => {
                errs.push(format!("slot {slot} owned by parked sequence {id}"))
            }
            Some(_) => {}
        }
        if let (Some(synced), Some(decoded)) =
            (s.arena.synced_upto(*id), s.cache.decoded_upto(*id))
        {
            if synced > decoded {
                errs.push(format!(
                    "slot {slot}: sequence {id} synced {synced} rows but decoded only {decoded}"
                ));
            }
        }
    }

    // -- region/epoch coherence and dirty-span well-formedness
    if s.arena.capacity() > 0 && REGIONS.iter().all(|r| s.store.is_resident_region(r)) {
        let store_epochs = (s.store.region_epoch(REGIONS[0]), s.store.region_epoch(REGIONS[1]));
        if s.arena.region_epochs() != store_epochs {
            errs.push(format!(
                "region epochs diverged: arena {:?} vs store {store_epochs:?}",
                s.arena.region_epochs()
            ));
        }
    }
    for name in REGIONS {
        let Some(spans) = s.store.region_spans(name) else {
            continue;
        };
        let elems = s.store.get(name).map(|t| t.len()).unwrap_or(0);
        for w in spans.windows(2) {
            if w[0].1 > w[1].0 {
                errs.push(format!("{name}: dirty spans unsorted/overlapping: {spans:?}"));
                break;
            }
        }
        for &(a, b) in &spans {
            if a >= b || b > elems {
                errs.push(format!(
                    "{name}: dirty span ({a}, {b}) malformed for region of {elems} elements"
                ));
                break;
            }
        }
    }

    // -- tier coherence: the scheduler's parked flags, the cache
    //    manager's parked state, and the host tier's ledger must agree
    let parked_flags = active.iter().filter(|a| a.parked).count();
    if parked_flags != s.tier.parked_count() {
        errs.push(format!(
            "tier ledger holds {} sequences, scheduler flags {parked_flags} as parked",
            s.tier.parked_count()
        ));
    }
    for a in active {
        if a.parked != s.tier.is_parked(a.cache_id) {
            errs.push(format!(
                "sequence {}: scheduler parked={} but tier says {}",
                a.cache_id,
                a.parked,
                s.tier.is_parked(a.cache_id)
            ));
        }
        if a.parked != s.cache.seq_parked(a.cache_id) {
            errs.push(format!(
                "sequence {}: scheduler parked={} but cache manager says {}",
                a.cache_id,
                a.parked,
                s.cache.seq_parked(a.cache_id)
            ));
        }
        if a.pos > s.spec.max_seq {
            errs.push(format!(
                "sequence {} position {} exceeds max_seq {}",
                a.cache_id, a.pos, s.spec.max_seq
            ));
        }
    }

    // -- effective-cache scratch: exactly the live unparked sequences
    //    hold one (parked/retired scratch that lingers is a working-set
    //    leak; a missing one would crash the next decode round)
    let mut eff_ids: Vec<u64> = s.eff.keys().copied().collect();
    eff_ids.sort_unstable();
    let mut unparked_ids: Vec<u64> = active
        .iter()
        .filter(|a| !a.parked)
        .map(|a| a.cache_id)
        .collect();
    unparked_ids.sort_unstable();
    if eff_ids != unparked_ids {
        errs.push(format!(
            "effective-cache scratch for {eff_ids:?} but live unparked set is {unparked_ids:?}"
        ));
    }

    // -- plan coherence (DESIGN.md §11): every live unparked sequence's
    //    measured stored bytes equal what the layout law predicts from
    //    its length, prefix, and demotion state
    //    (`CacheManager::seq_predicted_bytes`) — storage can never
    //    drift from the declared policy.  Trivially exact under the
    //    legacy uniform policy too, so it runs unconditionally.
    for a in active.iter().filter(|a| !a.parked) {
        let predicted = s.cache.seq_predicted_bytes(a.cache_id);
        let stored = s.cache.seq_stored_bytes(a.cache_id);
        if predicted != stored {
            errs.push(format!(
                "plan coherence: sequence {} stores {stored} B but the plan \
                 layout predicts {predicted} B",
                a.cache_id
            ));
        }
    }

    // -- metrics conservation
    let m = &s.metrics;
    let emitted: u64 = active.iter().map(|a| a.output.len() as u64).sum::<u64>()
        + state
            .done_responses()
            .iter()
            .map(|r| r.generated_tokens as u64)
            .sum::<u64>();
    // migration nets out: tokens a sequence carried away still count as
    // generated *here*, tokens it brought along were generated elsewhere
    if m.tokens_generated + m.tokens_migrated_in != emitted + m.tokens_migrated_out {
        errs.push(format!(
            "token conservation: metrics count {} generated + {} migrated in \
             but sequences hold {emitted} + {} migrated out",
            m.tokens_generated, m.tokens_migrated_in, m.tokens_migrated_out
        ));
    }
    // every response is exactly one of: clean completion, quarantined
    // sequence, rejected request — nothing double-counted, none lost
    if m.requests_completed + m.quarantines + m.rejects != state.done_responses().len() as u64 {
        errs.push(format!(
            "completion conservation: {} clean + {} quarantined + {} rejected \
             but {} responses exist",
            m.requests_completed,
            m.quarantines,
            m.rejects,
            state.done_responses().len()
        ));
    }
    let errored = state
        .done_responses()
        .iter()
        .filter(|r| r.error.is_some())
        .count() as u64;
    if errored != m.quarantines + m.rejects {
        errs.push(format!(
            "error conservation: {errored} errored responses but {} quarantines \
             + {} rejects recorded",
            m.quarantines, m.rejects
        ));
    }
    let admitted_total = m.wave_admitted.total() as usize;
    if m.queue_latency.len() != admitted_total || m.ttft.len() != admitted_total {
        errs.push(format!(
            "latency-sample conservation: {} queue / {} ttft samples for {admitted_total} admissions",
            m.queue_latency.len(),
            m.ttft.len()
        ));
    }
    if m.decode_slots_used > m.decode_slots_total {
        errs.push(format!(
            "slot accounting: {} slots used out of {} paid for",
            m.decode_slots_used, m.decode_slots_total
        ));
    }
    if m.auto_resumes > m.auto_parks {
        errs.push(format!(
            "park/resume accounting: {} resumes exceed {} parks",
            m.auto_resumes, m.auto_parks
        ));
    }

    if !errs.is_empty() {
        return Err(errs.join("\n"));
    }
    let mut fp = Fnv::new();
    fp.push(active_ids.len() as u64);
    for id in &active_ids {
        fp.push(*id);
    }
    fp.push(state.n_waiting() as u64);
    fp.push(state.n_done() as u64);
    fp.push(m.tokens_generated);
    fp.push(m.prefill_launches);
    fp.push(m.shared_admissions);
    fp.push(m.auto_parks);
    fp.push(m.auto_resumes);
    // recovery trajectory: retry timing, quarantines, and the ladder
    // rung are part of the determinism contract (DESIGN.md §9)
    fp.push(m.retries);
    fp.push(m.backoff.as_nanos() as u64);
    fp.push(m.quarantines);
    fp.push(m.rejects);
    fp.push(m.demotions);
    fp.push(m.region_demotions);
    fp.push(m.template_sheds);
    // migration trajectory: placements, delta volumes, and rollbacks
    // are part of the sharded determinism contract (DESIGN.md §10)
    fp.push(m.migrations_in);
    fp.push(m.migrations_out);
    fp.push(m.tokens_migrated_in);
    fp.push(m.tokens_migrated_out);
    fp.push(m.migration_delta_bytes);
    fp.push(m.migration_failures);
    fp.push(s.tier.stats.checksum_failures);
    fp.push(s.pressure() as u64);
    fp.push(parked_flags as u64);
    fp.push(s.cache.prefix_stats().shared_bytes as u64);
    fp.push(s.live_cache_bytes(active) as u64);
    // the clock itself is part of the audited state: timing must be as
    // reproducible as the token streams
    fp.push(s.clock.now().as_duration().as_nanos() as u64);
    Ok(fp.finish())
}

/// Audit a whole sharded cluster (DESIGN.md §10): run [`check_round`]
/// on every worker, then the cross-worker conservation laws no single
/// worker can see —
///
/// * **placement uniqueness**: every request id lives on exactly one
///   worker, whether queued, active, or completed (a migration that
///   forked or dropped a sequence shows up here);
/// * **request conservation**: queued + active + completed across the
///   cluster equals `expected_requests` (nothing lost in transit);
/// * **migration symmetry**: globally, sequences and tokens migrated in
///   equal those migrated out — transfers move work, never mint it.
///
/// Per-worker prefix refcount integrity (including migration-delivered
/// chunk pins) is covered by the inner [`check_round`] calls.  Returns
/// a cluster fingerprint folding every worker's round fingerprint, so
/// sharded determinism pins cover the whole trajectory.
pub fn check_cluster(
    workers: &[(&ServingEngine<'_>, &RunState)],
    expected_requests: usize,
    strict_budget: bool,
) -> Result<u64, String> {
    let mut errs: Vec<String> = Vec::new();
    let mut fp = Fnv::new();
    fp.push(workers.len() as u64);
    let mut req_ids: Vec<u64> = Vec::new();
    let (mut mig_in, mut mig_out) = (0u64, 0u64);
    let (mut tok_in, mut tok_out) = (0u64, 0u64);
    for (w, (s, state)) in workers.iter().enumerate() {
        match check_round(s, state, strict_budget) {
            Ok(worker_fp) => fp.push(worker_fp),
            Err(e) => {
                for line in e.lines() {
                    errs.push(format!("worker {w}: {line}"));
                }
            }
        }
        req_ids.extend(state.waiting_requests().iter().map(|r| r.id));
        req_ids.extend(state.active_seqs().iter().map(|a| a.req.id));
        req_ids.extend(state.done_responses().iter().map(|r| r.id));
        mig_in += s.metrics.migrations_in;
        mig_out += s.metrics.migrations_out;
        tok_in += s.metrics.tokens_migrated_in;
        tok_out += s.metrics.tokens_migrated_out;
    }
    req_ids.sort_unstable();
    if let Some(w) = req_ids.windows(2).find(|w| w[0] == w[1]) {
        errs.push(format!(
            "placement uniqueness: request {} exists on more than one worker",
            w[0]
        ));
    }
    if req_ids.len() != expected_requests {
        errs.push(format!(
            "request conservation: cluster holds {} requests, {expected_requests} were submitted",
            req_ids.len()
        ));
    }
    if mig_in != mig_out || tok_in != tok_out {
        errs.push(format!(
            "migration symmetry: {mig_in} sequences / {tok_in} tokens migrated in \
             but {mig_out} / {tok_out} migrated out"
        ));
    }
    if !errs.is_empty() {
        return Err(errs.join("\n"));
    }
    fp.push(req_ids.len() as u64);
    fp.push(mig_in);
    fp.push(tok_in);
    Ok(fp.finish())
}

/// Minimal FNV-1a accumulator over `u64` words (the digest primitive
/// every scenario fingerprint uses — no hasher state beyond one word,
/// so digests are identical across platforms and runs).
pub(crate) struct Fnv(u64);

impl Fnv {
    /// Fresh accumulator at the FNV offset basis.
    pub(crate) fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    /// Fold one word in.
    pub(crate) fn push(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    /// Current digest.
    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_order_sensitive_and_stable() {
        let mut a = Fnv::new();
        a.push(1);
        a.push(2);
        let mut b = Fnv::new();
        b.push(2);
        b.push(1);
        assert_ne!(a.finish(), b.finish());
        let mut c = Fnv::new();
        c.push(1);
        c.push(2);
        assert_eq!(a.finish(), c.finish());
    }
}
