//! Admission control and batch planning (pure logic, unit-testable
//! without the runtime).
//!
//! The scheduler consumes `BatchPlan`s: which waiting requests to admit
//! given the free decode slots and the cache budget, and which compiled
//! decode batch size to run a round at.  Policy: FIFO admission (no
//! starvation), admit while slots and memory allow, pick the smallest
//! compiled batch size covering the live set (padding wastes compute).

use crate::model::memory::{kv_bytes_per_token, CompressionPlan};
use crate::model::ModelSpec;

#[derive(Debug, Clone, PartialEq)]
pub struct BatchPlan {
    /// indices into the waiting queue to admit now (FIFO prefix)
    pub admit: usize,
    /// compiled decode batch size to use for the next round
    pub decode_batch: usize,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// compiled decode batch sizes available (ascending)
    pub decode_batches: Vec<usize>,
    /// bytes available for the compressed cache (admission control);
    /// None = unlimited
    pub cache_budget: Option<usize>,
}

/// Worst-case cache bytes one request needs: its prompt plus its token
/// budget at the plan's per-token rate.
pub fn request_cache_bytes(
    spec: &ModelSpec,
    plan: &CompressionPlan,
    prompt_len: usize,
    max_new: usize,
) -> usize {
    let tokens = (prompt_len + max_new).min(spec.max_seq);
    kv_bytes_per_token(spec, plan) * tokens
}

pub fn plan_round(
    cfg: &BatcherConfig,
    spec: &ModelSpec,
    plan: &CompressionPlan,
    live: usize,
    live_cache_bytes: usize,
    waiting: &[(usize, usize)], // (prompt_len, max_new) per waiting request
) -> BatchPlan {
    let mut admit = 0;
    let mut projected = live_cache_bytes;
    while admit < waiting.len() && live + admit < cfg.max_batch {
        let (p, m) = waiting[admit];
        let need = request_cache_bytes(spec, plan, p, m);
        if let Some(budget) = cfg.cache_budget {
            if projected + need > budget {
                break;
            }
        }
        projected += need;
        admit += 1;
    }
    let target = (live + admit).max(1);
    let decode_batch = cfg
        .decode_batches
        .iter()
        .copied()
        .find(|&b| b >= target)
        .unwrap_or_else(|| *cfg.decode_batches.last().unwrap());
    BatchPlan {
        admit,
        decode_batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn cfg(budget: Option<usize>) -> BatcherConfig {
        BatcherConfig {
            max_batch: 8,
            decode_batches: vec![1, 8],
            cache_budget: budget,
        }
    }

    #[test]
    fn admits_fifo_up_to_slots() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let waiting = vec![(10, 20); 12];
        let p = plan_round(&cfg(None), &spec, &plan, 3, 0, &waiting);
        assert_eq!(p.admit, 5); // 3 live + 5 = 8
        assert_eq!(p.decode_batch, 8);
    }

    #[test]
    fn single_sequence_uses_small_batch() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let p = plan_round(&cfg(None), &spec, &plan, 1, 0, &[]);
        assert_eq!(p.decode_batch, 1);
    }

    #[test]
    fn budget_blocks_admission() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let one = request_cache_bytes(&spec, &plan, 10, 20);
        let waiting = vec![(10, 20); 6];
        let p = plan_round(&cfg(Some(one * 3)), &spec, &plan, 0, 0, &waiting);
        assert_eq!(p.admit, 3);
    }

    #[test]
    fn compression_admits_more_under_same_budget() {
        let spec = gpt2_774m();
        let base = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let comp = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let budget = request_cache_bytes(&spec, &base, 50, 50) * 2;
        let waiting = vec![(50, 50); 8];
        let p_base = plan_round(&cfg(Some(budget)), &spec, &base, 0, 0, &waiting);
        let p_comp = plan_round(&cfg(Some(budget)), &spec, &comp, 0, 0, &waiting);
        assert_eq!(p_base.admit, 2);
        assert_eq!(p_comp.admit, 4); // the paper's larger-batch claim
    }

    #[test]
    fn plan_invariants_random_traffic() {
        check(60, |rng| {
            let spec = gpt2_774m();
            let plan = CompressionPlan::ae_first_layers(&spec, rng.below(37));
            let live = rng.below(9);
            let waiting: Vec<(usize, usize)> = (0..rng.below(20))
                .map(|_| (rng.range(1, 200), rng.range(1, 100)))
                .collect();
            let budget = if rng.bool(0.5) {
                Some(rng.range(1, 1 << 30))
            } else {
                None
            };
            let c = BatcherConfig {
                max_batch: 8,
                decode_batches: vec![1, 8],
                cache_budget: budget,
            };
            let p = plan_round(&c, &spec, &plan, live, 0, &waiting);
            prop_assert!(p.admit <= waiting.len());
            prop_assert!(live + p.admit <= c.max_batch || p.admit == 0);
            prop_assert!(p.decode_batch == 1 || p.decode_batch == 8);
            prop_assert!(p.decode_batch >= (live + p.admit).min(8).max(1));
            Ok(())
        });
    }
}
