//! Admission control and batch planning (pure logic, unit-testable
//! without the runtime).
//!
//! The scheduler consumes `BatchPlan`s: which waiting requests to admit
//! given the free decode slots and the cache budget, and which compiled
//! decode batch size to run a round at.  Policy: FIFO admission (no
//! starvation), admit while slots and memory allow, pick the smallest
//! compiled batch size covering the live set (padding wastes compute).
//!
//! Under memory pressure the same module plans the park/resume side:
//! [`plan_parking`] picks which live sequences to spill to the host
//! tier (lowest priority first, never all of them) and [`plan_resume`]
//! picks which parked sequences fit again (oldest first).  The
//! scheduler executes those decisions through
//! `ServingEngine::park_sequence` / `resume_sequence`, which move the
//! sequences' actual encoded bytes (`CacheManager::
//! extract_sequence_bytes`) and rebuild on resume via `rebuild_full`.

use crate::model::memory::{kv_bytes_per_token, CompressionPlan};
use crate::model::ModelSpec;

#[derive(Debug, Clone, PartialEq)]
/// One round's admission decision.
pub struct BatchPlan {
    /// indices into the waiting queue to admit now (FIFO prefix)
    pub admit: usize,
    /// compiled decode batch size to use for the next round
    pub decode_batch: usize,
}

#[derive(Debug, Clone)]
/// Slot, compiled-batch, and budget limits admission plans under.
pub struct BatcherConfig {
    /// concurrent decode sequences targeted
    pub max_batch: usize,
    /// compiled decode batch sizes available (ascending)
    pub decode_batches: Vec<usize>,
    /// bytes available for the compressed cache (admission control);
    /// None = unlimited
    pub cache_budget: Option<usize>,
}

/// Worst-case cache bytes one request needs: its prompt plus its token
/// budget at the plan's per-token rate.
pub fn request_cache_bytes(
    spec: &ModelSpec,
    plan: &CompressionPlan,
    prompt_len: usize,
    max_new: usize,
) -> usize {
    let tokens = (prompt_len + max_new).min(spec.max_seq);
    kv_bytes_per_token(spec, plan) * tokens
}

/// Plan one admission round: FIFO-admit while slots and the budget
/// allow, then pick the smallest compiled batch covering the live set.
pub fn plan_round(
    cfg: &BatcherConfig,
    spec: &ModelSpec,
    plan: &CompressionPlan,
    live: usize,
    live_cache_bytes: usize,
    waiting: &[(usize, usize)], // (prompt_len, max_new) per waiting request
) -> BatchPlan {
    let mut admit = 0;
    let mut projected = live_cache_bytes;
    while admit < waiting.len() && live + admit < cfg.max_batch {
        let (p, m) = waiting[admit];
        let need = request_cache_bytes(spec, plan, p, m);
        if let Some(budget) = cfg.cache_budget {
            if projected + need > budget {
                break;
            }
        }
        projected += need;
        admit += 1;
    }
    let target = (live + admit).max(1);
    let decode_batch = cfg
        .decode_batches
        .iter()
        .copied()
        .find(|&b| b >= target)
        .unwrap_or_else(|| *cfg.decode_batches.last().unwrap());
    BatchPlan {
        admit,
        decode_batch,
    }
}

/// Worst-case device-cache growth of one live sequence across one decode
/// round: each of its stored streams may start a fresh block when the
/// appended token crosses a block boundary.
pub fn round_headroom_bytes(spec: &ModelSpec, plan: &CompressionPlan, block_size: usize) -> usize {
    kv_bytes_per_token(spec, plan) * block_size
}

/// Which live sequences to park so the projected next round fits
/// `budget`.
///
/// `live` is `(id, stored_bytes)` in admission order (oldest / highest
/// priority first); `headroom` is the per-sequence worst-case growth of
/// one round ([`round_headroom_bytes`]).  Victims are chosen lowest
/// priority first (latest admitted), and the oldest sequence is never
/// parked — at least one sequence must keep decoding so rounds complete
/// and memory eventually frees.  Returns victim ids, park order.
pub fn plan_parking(budget: usize, headroom: usize, live: &[(u64, usize)]) -> Vec<u64> {
    let mut total: usize = live.iter().map(|l| l.1).sum();
    let mut count = live.len();
    let mut park = Vec::new();
    for &(id, bytes) in live.iter().skip(1).rev() {
        if total + count * headroom <= budget {
            break;
        }
        park.push(id);
        total -= bytes;
        count -= 1;
    }
    park
}

/// Which parked sequences fit back on the device: oldest first, admitted
/// while the projected total (current live bytes + headroom for every
/// running sequence + the candidate's own payload) stays under `budget`.
///
/// `parked` is `(id, stored_bytes)` in admission order (oldest first).
pub fn plan_resume(
    budget: usize,
    headroom: usize,
    live_bytes: usize,
    live_count: usize,
    parked: &[(u64, usize)],
) -> Vec<u64> {
    let mut total = live_bytes;
    let mut count = live_count;
    let mut resume = Vec::new();
    for &(id, bytes) in parked {
        if total + bytes + (count + 1) * headroom > budget {
            break;
        }
        resume.push(id);
        total += bytes;
        count += 1;
    }
    resume
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn cfg(budget: Option<usize>) -> BatcherConfig {
        BatcherConfig {
            max_batch: 8,
            decode_batches: vec![1, 8],
            cache_budget: budget,
        }
    }

    #[test]
    fn admits_fifo_up_to_slots() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let waiting = vec![(10, 20); 12];
        let p = plan_round(&cfg(None), &spec, &plan, 3, 0, &waiting);
        assert_eq!(p.admit, 5); // 3 live + 5 = 8
        assert_eq!(p.decode_batch, 8);
    }

    #[test]
    fn single_sequence_uses_small_batch() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let p = plan_round(&cfg(None), &spec, &plan, 1, 0, &[]);
        assert_eq!(p.decode_batch, 1);
    }

    #[test]
    fn budget_blocks_admission() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let one = request_cache_bytes(&spec, &plan, 10, 20);
        let waiting = vec![(10, 20); 6];
        let p = plan_round(&cfg(Some(one * 3)), &spec, &plan, 0, 0, &waiting);
        assert_eq!(p.admit, 3);
    }

    #[test]
    fn compression_admits_more_under_same_budget() {
        let spec = gpt2_774m();
        let base = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let comp = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let budget = request_cache_bytes(&spec, &base, 50, 50) * 2;
        let waiting = vec![(50, 50); 8];
        let p_base = plan_round(&cfg(Some(budget)), &spec, &base, 0, 0, &waiting);
        let p_comp = plan_round(&cfg(Some(budget)), &spec, &comp, 0, 0, &waiting);
        assert_eq!(p_base.admit, 2);
        assert_eq!(p_comp.admit, 4); // the paper's larger-batch claim
    }

    #[test]
    fn parking_picks_lowest_priority_and_keeps_one_live() {
        // three live sequences, admission order 1 < 2 < 3; only ~one fits
        let live = vec![(1u64, 100usize), (2, 100), (3, 100)];
        let park = plan_parking(150, 10, &live);
        assert_eq!(park, vec![3, 2], "latest admitted park first");
        // budget below even one sequence: everything but the oldest parks
        let park = plan_parking(10, 10, &live);
        assert_eq!(park, vec![3, 2]);
        // plenty of budget: nobody parks
        assert!(plan_parking(1 << 20, 10, &live).is_empty());
        assert!(plan_parking(0, 0, &[(7, 500)]).is_empty(), "sole sequence never parks");
    }

    #[test]
    fn resume_is_fifo_and_budget_bounded() {
        let parked = vec![(4u64, 100usize), (5, 100), (6, 100)];
        // room for two more after the running set
        let resume = plan_resume(350, 10, 100, 1, &parked);
        assert_eq!(resume, vec![4, 5], "oldest parked resume first");
        assert!(plan_resume(120, 10, 100, 1, &parked).is_empty());
        let all = plan_resume(1 << 20, 10, 0, 0, &parked);
        assert_eq!(all, vec![4, 5, 6]);
    }

    #[test]
    fn park_resume_plans_compose() {
        check(50, |rng| {
            let n = rng.range(1, 10);
            let live: Vec<(u64, usize)> =
                (0..n).map(|i| (i as u64, rng.range(1, 5000))).collect();
            let budget = rng.range(1, 20_000);
            let headroom = rng.range(0, 300);
            let park = plan_parking(budget, headroom, &live);
            prop_assert!(park.len() < live.len(), "must keep one sequence live");
            // victims come from the tail of the admission order
            let ids: Vec<u64> = live.iter().map(|l| l.0).collect();
            let keep = live.len() - park.len();
            for (i, id) in park.iter().enumerate() {
                prop_assert!(
                    *id == ids[live.len() - 1 - i],
                    "park order must be strictly latest-first"
                );
            }
            let kept_bytes: usize = live[..keep].iter().map(|l| l.1).sum();
            // after parking, either we fit or nothing more could be parked
            prop_assert!(
                kept_bytes + keep * headroom <= budget || keep == 1,
                "parked too little: {kept_bytes} + {keep}*{headroom} > {budget}"
            );
            // resuming the victims immediately must not overflow
            let parked: Vec<(u64, usize)> = park
                .iter()
                .rev()
                .map(|id| live[ids.iter().position(|x| x == id).unwrap()])
                .collect();
            let resume = plan_resume(budget, headroom, kept_bytes, keep, &parked);
            let resumed_bytes: usize =
                resume.iter().map(|id| parked.iter().find(|p| p.0 == *id).unwrap().1).sum();
            prop_assert!(
                kept_bytes + resumed_bytes + (keep + resume.len()) * headroom <= budget
                    || resume.is_empty(),
                "resume plan overflows the budget"
            );
            Ok(())
        });
    }

    #[test]
    fn plan_invariants_random_traffic() {
        check(60, |rng| {
            let spec = gpt2_774m();
            let plan = CompressionPlan::ae_first_layers(&spec, rng.below(37));
            let live = rng.below(9);
            let waiting: Vec<(usize, usize)> = (0..rng.below(20))
                .map(|_| (rng.range(1, 200), rng.range(1, 100)))
                .collect();
            let budget = if rng.bool(0.5) {
                Some(rng.range(1, 1 << 30))
            } else {
                None
            };
            let c = BatcherConfig {
                max_batch: 8,
                decode_batches: vec![1, 8],
                cache_budget: budget,
            };
            let p = plan_round(&c, &spec, &plan, live, 0, &waiting);
            prop_assert!(p.admit <= waiting.len());
            prop_assert!(live + p.admit <= c.max_batch || p.admit == 0);
            prop_assert!(p.decode_batch == 1 || p.decode_batch == 8);
            prop_assert!(p.decode_batch >= (live + p.admit).min(8).max(1));
            Ok(())
        });
    }
}
