//! Admission control and batch planning (pure logic, unit-testable
//! without the runtime).
//!
//! The scheduler consumes `BatchPlan`s: which waiting requests to admit
//! given the free decode slots and the cache budget, and which compiled
//! decode batch size to run a round at.  Policy: FIFO admission (no
//! starvation), admit while slots and memory allow, pick the smallest
//! compiled batch size covering the live set (padding wastes compute).
//!
//! Under memory pressure the same module plans the park/resume side:
//! [`plan_parking`] picks which live sequences to spill to the host
//! tier (cost-aware: largest stored bytes per remaining token first,
//! never all of them) and [`plan_resume`] picks which parked sequences
//! fit again (oldest first).  The scheduler executes those decisions
//! through `ServingEngine::park_sequence` / `resume_sequence`, which
//! move the sequences' actual encoded bytes (`CacheManager::
//! extract_sequence_bytes`) and rebuild on resume via `rebuild_full`.
//!
//! [`plan_slots`] is the slot side of the store-resident effective
//! cache (`coordinator::resident`): a stable sequence→decode-slot
//! assignment, so admissions and retirements never shuffle unrelated
//! sequences into different slots (each move costs a full slot
//! rebuild).

use crate::model::memory::{kv_bytes_per_token, CompressionPlan};
use crate::model::ModelSpec;

#[derive(Debug, Clone, PartialEq)]
/// One round's admission decision: the wave of waiting requests to
/// prefill together plus the shapes the round runs at.
pub struct BatchPlan {
    /// indices into the waiting queue to admit now (FIFO prefix) — the
    /// *admission wave*: all of them prefill through one batched
    /// `{m}_prefill_b` launch when the artifact set has it
    pub admit: usize,
    /// compiled decode batch size to use for the next round
    pub decode_batch: usize,
    /// padded prompt-length bucket of the whole wave ([`wave_bucket`];
    /// 0 when nothing is admitted) — the admission-side counterpart of
    /// `decode_batch`: the rows per lane the wave carries once its
    /// prompts are padded to a shared length.  Planning metadata: the
    /// compiled `[B, S]` entry always runs at S = max_seq, and
    /// `PrefillWave` recomputes finer per-capacity-chunk buckets for
    /// its padding accounting (`WaveStats::padded_rows`)
    pub wave_s: usize,
}

#[derive(Debug, Clone)]
/// Slot, compiled-batch, and budget limits admission plans under.
pub struct BatcherConfig {
    /// concurrent decode sequences targeted
    pub max_batch: usize,
    /// compiled decode batch sizes available (ascending)
    pub decode_batches: Vec<usize>,
    /// bytes available for the compressed cache (admission control);
    /// None = unlimited
    pub cache_budget: Option<usize>,
}

/// Worst-case cache bytes one request needs: its prompt plus its token
/// budget at the plan's per-token rate.
pub fn request_cache_bytes(
    spec: &ModelSpec,
    plan: &CompressionPlan,
    prompt_len: usize,
    max_new: usize,
) -> usize {
    let tokens = (prompt_len + max_new).min(spec.max_seq);
    kv_bytes_per_token(spec, plan) * tokens
}

/// Padded prompt-length bucket for one admission wave: the smallest
/// power of two covering every (clamped) prompt in the wave, capped at
/// `max_seq`.  Power-of-two buckets keep the set of distinct padded
/// shapes small while bounding per-lane padding waste below 2× — the
/// standard bucketing compromise for batched prompt processing.
/// Returns 0 for an empty wave.
pub fn wave_bucket(prompt_lens: impl IntoIterator<Item = usize>, max_seq: usize) -> usize {
    let longest = prompt_lens
        .into_iter()
        .map(|p| p.clamp(1, max_seq.saturating_sub(1)))
        .max();
    match longest {
        None => 0,
        Some(l) => l.next_power_of_two().min(max_seq),
    }
}

/// Map each admission-wave lane to the earliest earlier lane carrying
/// an identical clamped prompt, or `None` for the first occurrence —
/// the within-wave half of cross-request prefix sharing: prefill only
/// ever sees the clamped tokens, so equal keys are the *same*
/// computation and every duplicate lane can be admitted from the first
/// lane's outputs with zero launches (launches ∝ distinct prompts).
pub fn plan_dedup(keys: &[&[u8]]) -> Vec<Option<usize>> {
    use std::collections::hash_map::Entry;
    let mut seen: std::collections::HashMap<&[u8], usize> = std::collections::HashMap::new();
    keys.iter()
        .enumerate()
        .map(|(i, &k)| match seen.entry(k) {
            Entry::Occupied(e) => Some(*e.get()),
            Entry::Vacant(v) => {
                v.insert(i);
                None
            }
        })
        .collect()
}

/// Plan one admission round: FIFO-admit while slots and the budget
/// allow, then pick the smallest compiled batch covering the live set.
pub fn plan_round(
    cfg: &BatcherConfig,
    spec: &ModelSpec,
    plan: &CompressionPlan,
    live: usize,
    live_cache_bytes: usize,
    waiting: &[(usize, usize)], // (prompt_len, max_new) per waiting request
) -> BatchPlan {
    let mut admit = 0;
    let mut projected = live_cache_bytes;
    while admit < waiting.len() && live + admit < cfg.max_batch {
        let (p, m) = waiting[admit];
        let need = request_cache_bytes(spec, plan, p, m);
        if let Some(budget) = cfg.cache_budget {
            if projected + need > budget {
                break;
            }
        }
        projected += need;
        admit += 1;
    }
    let target = (live + admit).max(1);
    let decode_batch = cfg
        .decode_batches
        .iter()
        .copied()
        .find(|&b| b >= target)
        .unwrap_or_else(|| *cfg.decode_batches.last().unwrap());
    BatchPlan {
        admit,
        decode_batch,
        wave_s: wave_bucket(waiting[..admit].iter().map(|w| w.0), spec.max_seq),
    }
}

/// Worst-case device-cache growth of one live sequence across one decode
/// round: each of its stored streams may start a fresh block when the
/// appended token crosses a block boundary.
///
/// Priced by the Eq. 3 model (`spec.bytes_per_el` for every non-int8
/// stream).  When the runtime stores raw rows in a narrower format
/// (f16), prefer `CacheConfig::bytes_per_token() * block_size` — the
/// scheduler does — so headroom stays in the same units as the measured
/// `seq_stored_bytes` it is compared against.  Admission projections
/// (`request_cache_bytes`) intentionally keep the conservative f32
/// model: over-reserving at admit time is safe, under-reserving is not.
pub fn round_headroom_bytes(spec: &ModelSpec, plan: &CompressionPlan, block_size: usize) -> usize {
    kv_bytes_per_token(spec, plan) * block_size
}

/// Which live sequences to park so the projected next round fits
/// `budget` — **cost-aware** victim selection.
///
/// `live` is `(id, stored_bytes, remaining_tokens)` in admission order
/// (oldest first); `headroom` is the per-sequence worst-case growth of
/// one round ([`round_headroom_bytes`]).  Victims are chosen by
/// descending *stored bytes per remaining token*: parking a sequence
/// frees its bytes for the rest of its lifetime, so the best victim is
/// the one paying the most device memory per token of work it still
/// owes (a near-finished hog parks before a fresh cheap sequence).
/// Ties park latest-admitted first (the old LIFO policy, so uniform
/// workloads behave as before).  At least one sequence always stays
/// live — rounds must keep completing so memory eventually frees.
/// Returns victim ids in park order.
pub fn plan_parking(budget: usize, headroom: usize, live: &[(u64, usize, usize)]) -> Vec<u64> {
    let mut total: usize = live.iter().map(|l| l.1).sum();
    let mut count = live.len();
    // victim order: largest bytes-per-remaining-token first; ties latest
    // admitted first (input is admission-ordered, so higher index =
    // later admission)
    let mut order: Vec<usize> = (0..live.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = live[a].1 as f64 / live[a].2.max(1) as f64;
        let rb = live[b].1 as f64 / live[b].2.max(1) as f64;
        rb.partial_cmp(&ra)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.cmp(&a))
    });
    let mut park = Vec::new();
    for &i in &order {
        if count <= 1 || total + count * headroom <= budget {
            break;
        }
        park.push(live[i].0);
        total -= live[i].1;
        count -= 1;
    }
    park
}

/// Slot-stable assignment for the store-resident effective cache: map
/// `live` sequences onto `b` decode slots, disturbing as few existing
/// assignments as possible.
///
/// `current` is the present slot→sequence map (any length; slots past
/// `b` are dropped).  Sequences keep their slot whenever it is still
/// inside `[0, b)`; remaining live sequences take the lowest free slots
/// in the order given.  Admissions and retirements therefore never move
/// an unrelated sequence — each move would force a full slot rebuild
/// (`O(L·S·kvd)` staged bytes), so stability is the point.  Requires
/// `live.len() <= b`; sequences in `current` but not in `live` are
/// dropped (their slot frees up).
pub fn plan_slots(current: &[Option<u64>], live: &[u64], b: usize) -> Vec<Option<u64>> {
    debug_assert!(live.len() <= b, "more live sequences than slots");
    let mut next: Vec<Option<u64>> = vec![None; b];
    for (slot, id) in current.iter().enumerate().take(b) {
        if let Some(id) = id {
            if live.contains(id) {
                next[slot] = Some(*id);
            }
        }
    }
    for &id in live {
        if next.iter().any(|x| *x == Some(id)) {
            continue;
        }
        if let Some(slot) = (0..b).find(|&s| next[s].is_none()) {
            next[slot] = Some(id);
        }
    }
    next
}

/// Which parked sequences fit back on the device: oldest first, admitted
/// while the projected total (current live bytes + headroom for every
/// running sequence + the candidate's own payload) stays under `budget`.
///
/// `parked` is `(id, stored_bytes)` in admission order (oldest first).
pub fn plan_resume(
    budget: usize,
    headroom: usize,
    live_bytes: usize,
    live_count: usize,
    parked: &[(u64, usize)],
) -> Vec<u64> {
    let mut total = live_bytes;
    let mut count = live_count;
    let mut resume = Vec::new();
    for &(id, bytes) in parked {
        if total + bytes + (count + 1) * headroom > budget {
            break;
        }
        resume.push(id);
        total += bytes;
        count += 1;
    }
    resume
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn cfg(budget: Option<usize>) -> BatcherConfig {
        BatcherConfig {
            max_batch: 8,
            decode_batches: vec![1, 8],
            cache_budget: budget,
        }
    }

    #[test]
    fn admits_fifo_up_to_slots() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let waiting = vec![(10, 20); 12];
        let p = plan_round(&cfg(None), &spec, &plan, 3, 0, &waiting);
        assert_eq!(p.admit, 5); // 3 live + 5 = 8
        assert_eq!(p.decode_batch, 8);
    }

    #[test]
    fn wave_bucket_covers_longest_prompt_power_of_two() {
        assert_eq!(
            wave_bucket(std::iter::empty::<usize>(), 128),
            0,
            "empty wave has no bucket"
        );
        assert_eq!(wave_bucket([1], 128), 1);
        assert_eq!(wave_bucket([9, 1, 17], 128), 32);
        assert_eq!(wave_bucket([33, 64], 128), 64);
        // prompts at/over max_seq clamp to the compiled shape
        assert_eq!(wave_bucket([500], 128), 128);
        assert_eq!(wave_bucket([0], 128), 1, "plen clamps to >= 1");
    }

    #[test]
    fn plan_round_reports_wave_bucket_of_admitted_prefix() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        // 3 live + 5 admitted; the long prompt is *not* admitted (slot
        // limit) so it must not widen the wave bucket
        let mut waiting = vec![(10, 20); 5];
        waiting.push((spec.max_seq, 20));
        let p = plan_round(&cfg(None), &spec, &plan, 3, 0, &waiting);
        assert_eq!(p.admit, 5);
        assert_eq!(p.wave_s, 16);
        // nothing admitted -> no wave
        let p = plan_round(&cfg(None), &spec, &plan, 8, 0, &waiting);
        assert_eq!((p.admit, p.wave_s), (0, 0));
    }

    #[test]
    fn dedup_maps_duplicates_to_earliest_lane() {
        let keys: Vec<&[u8]> = vec![b"sys+a", b"sys+b", b"sys+a", b"sys+a", b"sys+b", b"c"];
        assert_eq!(
            plan_dedup(&keys),
            vec![None, None, Some(0), Some(0), Some(1), None]
        );
        assert_eq!(plan_dedup(&[]), Vec::<Option<usize>>::new());
        // distinct prompts never alias
        let distinct: Vec<&[u8]> = vec![b"a", b"b", b"ab"];
        assert!(plan_dedup(&distinct).iter().all(Option::is_none));
    }

    #[test]
    fn single_sequence_uses_small_batch() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let p = plan_round(&cfg(None), &spec, &plan, 1, 0, &[]);
        assert_eq!(p.decode_batch, 1);
    }

    #[test]
    fn budget_blocks_admission() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let one = request_cache_bytes(&spec, &plan, 10, 20);
        let waiting = vec![(10, 20); 6];
        let p = plan_round(&cfg(Some(one * 3)), &spec, &plan, 0, 0, &waiting);
        assert_eq!(p.admit, 3);
    }

    #[test]
    fn compression_admits_more_under_same_budget() {
        let spec = gpt2_774m();
        let base = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let comp = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let budget = request_cache_bytes(&spec, &base, 50, 50) * 2;
        let waiting = vec![(50, 50); 8];
        let p_base = plan_round(&cfg(Some(budget)), &spec, &base, 0, 0, &waiting);
        let p_comp = plan_round(&cfg(Some(budget)), &spec, &comp, 0, 0, &waiting);
        assert_eq!(p_base.admit, 2);
        assert_eq!(p_comp.admit, 4); // the paper's larger-batch claim
    }

    #[test]
    fn parking_picks_lowest_priority_and_keeps_one_live() {
        // uniform cost rates: ties fall back to LIFO, admission order
        // 1 < 2 < 3; only ~one fits
        let live = vec![(1u64, 100usize, 5usize), (2, 100, 5), (3, 100, 5)];
        let park = plan_parking(150, 10, &live);
        assert_eq!(park, vec![3, 2], "equal cost rates park latest first");
        // budget below even one sequence: everything but one parks
        let park = plan_parking(10, 10, &live);
        assert_eq!(park, vec![3, 2]);
        // plenty of budget: nobody parks
        assert!(plan_parking(1 << 20, 10, &live).is_empty());
        assert!(
            plan_parking(0, 0, &[(7, 500, 1)]).is_empty(),
            "sole sequence never parks"
        );
    }

    #[test]
    fn parking_prefers_largest_stored_bytes_per_remaining_token() {
        // cost rates: id 1 = 100/1 = 100, id 2 = 100/10 = 10,
        // id 3 = 90/2 = 45 — victims must come in rate order (1, then
        // 3), keeping the cheapest-to-keep sequence (2) live even
        // though it was admitted after 1
        let live = vec![(1u64, 100usize, 1usize), (2, 100, 10), (3, 90, 2)];
        let park = plan_parking(50, 0, &live);
        assert_eq!(park, vec![1, 3], "must evict by bytes-per-remaining-token");
        // a budget one park satisfies stops after the worst offender
        let park = plan_parking(195, 0, &live);
        assert_eq!(park, vec![1]);
        // zero remaining tokens is clamped, not divided by
        let live = vec![(1u64, 10usize, 0usize), (2, 500, 1)];
        let park = plan_parking(15, 0, &live);
        assert_eq!(park, vec![2], "rate uses max(remaining, 1)");
    }

    #[test]
    fn slot_plan_is_stable_across_churn() {
        // three held slots; seq 2 retires: nobody else moves
        let cur = vec![Some(1u64), Some(2), Some(3), None];
        let next = plan_slots(&cur, &[1, 3], 4);
        assert_eq!(next, vec![Some(1), None, Some(3), None]);
        // a new admission takes the lowest free slot, others unmoved
        let next = plan_slots(&next, &[1, 3, 9], 4);
        assert_eq!(next, vec![Some(1), Some(9), Some(3), None]);
        // shrinking b drops out-of-range assignments; survivors that
        // fit keep their slot, displaced ones take the free slots
        let next = plan_slots(&[Some(1), Some(9), Some(3), None], &[1, 3], 2);
        assert_eq!(next, vec![Some(1), Some(3)]);
        // growing b moves nobody
        let next = plan_slots(&[Some(1), Some(3)], &[1, 3], 4);
        assert_eq!(next, vec![Some(1), Some(3), None, None]);
        // from empty: live order fills ascending slots
        assert_eq!(
            plan_slots(&[], &[7, 8], 3),
            vec![Some(7), Some(8), None]
        );
    }

    #[test]
    fn resume_is_fifo_and_budget_bounded() {
        let parked = vec![(4u64, 100usize), (5, 100), (6, 100)];
        // room for two more after the running set
        let resume = plan_resume(350, 10, 100, 1, &parked);
        assert_eq!(resume, vec![4, 5], "oldest parked resume first");
        assert!(plan_resume(120, 10, 100, 1, &parked).is_empty());
        let all = plan_resume(1 << 20, 10, 0, 0, &parked);
        assert_eq!(all, vec![4, 5, 6]);
    }

    #[test]
    fn park_resume_plans_compose() {
        check(50, |rng| {
            let n = rng.range(1, 10);
            let live: Vec<(u64, usize, usize)> = (0..n)
                .map(|i| (i as u64, rng.range(1, 5000), rng.range(0, 60)))
                .collect();
            let budget = rng.range(1, 20_000);
            let headroom = rng.range(0, 300);
            let park = plan_parking(budget, headroom, &live);
            prop_assert!(park.len() < live.len(), "must keep one sequence live");
            let ids: Vec<u64> = live.iter().map(|l| l.0).collect();
            let keep = live.len() - park.len();
            // victims come in non-increasing bytes-per-remaining-token
            // order (ties resolved latest-admitted-first)
            let rate = |id: &u64| {
                let l = &live[ids.iter().position(|x| x == id).unwrap()];
                l.1 as f64 / l.2.max(1) as f64
            };
            for w in park.windows(2) {
                prop_assert!(
                    rate(&w[0]) >= rate(&w[1]),
                    "park order must be worst cost rate first"
                );
            }
            let kept_bytes: usize = live
                .iter()
                .filter(|l| !park.contains(&l.0))
                .map(|l| l.1)
                .sum();
            // after parking, either we fit or nothing more could be parked
            prop_assert!(
                kept_bytes + keep * headroom <= budget || keep == 1,
                "parked too little: {kept_bytes} + {keep}*{headroom} > {budget}"
            );
            // resuming the victims immediately must not overflow
            let parked: Vec<(u64, usize)> = park
                .iter()
                .rev()
                .map(|id| {
                    let l = &live[ids.iter().position(|x| x == id).unwrap()];
                    (l.0, l.1)
                })
                .collect();
            let resume = plan_resume(budget, headroom, kept_bytes, keep, &parked);
            let resumed_bytes: usize =
                resume.iter().map(|id| parked.iter().find(|p| p.0 == *id).unwrap().1).sum();
            prop_assert!(
                kept_bytes + resumed_bytes + (keep + resume.len()) * headroom <= budget
                    || resume.is_empty(),
                "resume plan overflows the budget"
            );
            Ok(())
        });
    }

    #[test]
    fn plan_invariants_random_traffic() {
        check(60, |rng| {
            let spec = gpt2_774m();
            let plan = CompressionPlan::ae_first_layers(&spec, rng.below(37));
            let live = rng.below(9);
            let waiting: Vec<(usize, usize)> = (0..rng.below(20))
                .map(|_| (rng.range(1, 200), rng.range(1, 100)))
                .collect();
            let budget = if rng.bool(0.5) {
                Some(rng.range(1, 1 << 30))
            } else {
                None
            };
            let c = BatcherConfig {
                max_batch: 8,
                decode_batches: vec![1, 8],
                cache_budget: budget,
            };
            let p = plan_round(&c, &spec, &plan, live, 0, &waiting);
            prop_assert!(p.admit <= waiting.len());
            prop_assert!(live + p.admit <= c.max_batch || p.admit == 0);
            prop_assert!(p.decode_batch == 1 || p.decode_batch == 8);
            prop_assert!(p.decode_batch >= (live + p.admit).min(8).max(1));
            Ok(())
        });
    }
}
