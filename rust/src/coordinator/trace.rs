//! Serving workload traces: generation and replay.
//!
//! The paper's system evaluation sweeps batch size and sequence length;
//! serving papers additionally characterize arrival processes.  This
//! module generates deterministic traces (Poisson or bursty arrivals,
//! configurable prompt/output length distributions) and the
//! `serving_batch` example replays them against the coordinator.
//! Traces serialize to JSON so a run can be archived in EXPERIMENTS.md
//! and replayed bit-identically.

use super::clock::Stamp;
use super::request::{GenRequest, Sampling};
use crate::data::corpus::Corpus;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq)]
/// Request arrival process for synthetic workloads.
pub enum Arrival {
    /// exponential inter-arrival times at `rate` req/s
    Poisson { rate: f64 },
    /// bursts of `size` back-to-back requests every `period_ms`
    Bursty { size: usize, period_ms: u64 },
    /// everything at t=0 (offline / throughput mode)
    Batch,
}

#[derive(Debug, Clone)]
/// Parameters a synthetic trace is generated from.
pub struct TraceConfig {
    /// total requests to generate
    pub n_requests: usize,
    /// arrival process
    pub arrival: Arrival,
    /// inclusive prompt-length range
    pub prompt_len_range: (usize, usize),
    /// inclusive generation-budget range
    pub max_new_range: (usize, usize),
    /// None = greedy, Some(t) = temperature sampling
    pub temperature: Option<f32>,
    /// Some(n): draw every prompt from a pre-generated pool of `n`
    /// distinct prompts (template/duplicate-storm workloads exercising
    /// the prefix trie); None: every prompt is fresh
    pub distinct_prompts: Option<usize>,
    /// trace rng seed (traces are reproducible)
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 16,
            arrival: Arrival::Poisson { rate: 4.0 },
            prompt_len_range: (12, 32),
            max_new_range: (16, 48),
            temperature: None,
            distinct_prompts: None,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone)]
/// One timed request of a trace.
pub struct TraceItem {
    /// arrival offset from trace start
    pub at: Duration,
    /// the request itself
    pub request: GenRequest,
}

#[derive(Debug, Clone, Default)]
/// A reproducible request trace (generate once, serve anywhere).
pub struct Trace {
    /// requests in arrival order
    pub items: Vec<TraceItem>,
}

/// Generate a trace from `cfg` with prompts drawn from `corpus`
/// (deterministic per seed).
pub fn generate(cfg: &TraceConfig, corpus: &mut Corpus) -> Trace {
    let mut rng = Rng::new(cfg.seed ^ 0x7ACE);
    // Template workloads draw from a fixed prompt pool so the prefix
    // trie sees genuine duplicates.
    let pool: Vec<Vec<u8>> = match cfg.distinct_prompts {
        Some(n) if n > 0 => (0..n)
            .map(|_| {
                let plen = rng.range(cfg.prompt_len_range.0, cfg.prompt_len_range.1 + 1);
                corpus.tokens(plen)
            })
            .collect(),
        _ => Vec::new(),
    };
    let mut items = Vec::with_capacity(cfg.n_requests);
    let mut t = Duration::ZERO;
    for i in 0..cfg.n_requests {
        match cfg.arrival {
            Arrival::Poisson { rate } => {
                t += Duration::from_secs_f64(rng.exponential(rate));
            }
            Arrival::Bursty { size, period_ms } => {
                if i > 0 && i % size == 0 {
                    t += Duration::from_millis(period_ms);
                }
            }
            Arrival::Batch => {}
        }
        let prompt = if pool.is_empty() {
            let plen = rng.range(cfg.prompt_len_range.0, cfg.prompt_len_range.1 + 1);
            corpus.tokens(plen)
        } else {
            pool[rng.below(pool.len())].clone()
        };
        let max_new = rng.range(cfg.max_new_range.0, cfg.max_new_range.1 + 1);
        // quantize to the whole microseconds to_json stores, so a
        // serialized trace replays with bit-identical arrival stamps
        let at = Duration::from_micros(t.as_micros() as u64);
        items.push(TraceItem {
            at,
            request: GenRequest {
                id: i as u64,
                prompt,
                max_new_tokens: max_new,
                sampling: match cfg.temperature {
                    Some(temp) => Sampling::Temperature(temp),
                    None => Sampling::Greedy,
                },
                stop_byte: None,
                // the trace offset IS the arrival: under a virtual
                // clock the scheduler gates admission on it, so replay
                // reproduces identical queue_latency/TTFT numbers
                arrival: Some(Stamp::from_duration(at)),
            },
        });
    }
    Trace { items }
}

impl Trace {
    /// Summed prompt lengths.
    pub fn total_prompt_tokens(&self) -> usize {
        self.items.iter().map(|i| i.request.prompt.len()).sum()
    }

    /// Summed generation budgets.
    pub fn total_max_new(&self) -> usize {
        self.items.iter().map(|i| i.request.max_new_tokens).sum()
    }

    /// Serialize for replay.
    pub fn to_json(&self) -> Json {
        json::arr(self.items.iter().map(|i| {
            json::obj(vec![
                ("at_us", json::num(i.at.as_micros() as f64)),
                ("id", json::num(i.request.id as f64)),
                (
                    "prompt",
                    json::s(&String::from_utf8_lossy(&i.request.prompt)),
                ),
                ("max_new", json::num(i.request.max_new_tokens as f64)),
                (
                    "temperature",
                    match i.request.sampling {
                        Sampling::Greedy => Json::Null,
                        Sampling::Temperature(t) => json::num(t as f64),
                    },
                ),
            ])
        }))
    }

    /// Parse a trace serialized by `to_json`.
    pub fn from_json(j: &Json) -> Result<Trace> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("trace must be array"))?;
        let mut items = Vec::with_capacity(arr.len());
        for e in arr {
            let at =
                Duration::from_micros(e.get("at_us").and_then(Json::as_i64).unwrap_or(0) as u64);
            let prompt = e
                .get("prompt")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("trace item missing prompt"))?
                .as_bytes()
                .to_vec();
            let sampling = match e.get("temperature") {
                Some(Json::Num(t)) => Sampling::Temperature(*t as f32),
                _ => Sampling::Greedy,
            };
            items.push(TraceItem {
                at,
                request: GenRequest {
                    id: e.get("id").and_then(Json::as_i64).unwrap_or(0) as u64,
                    prompt,
                    max_new_tokens: e
                        .get("max_new")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("trace item missing max_new"))?,
                    sampling,
                    stop_byte: None,
                    arrival: Some(Stamp::from_duration(at)),
                },
            });
        }
        Ok(Trace { items })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::wiki;
    use crate::prop_assert;

    #[test]
    fn deterministic_generation() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg, &mut wiki(3));
        let b = generate(&cfg, &mut wiki(3));
        assert_eq!(a.items.len(), b.items.len());
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.request.prompt, y.request.prompt);
        }
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let cfg = TraceConfig {
            n_requests: 50,
            ..Default::default()
        };
        let t = generate(&cfg, &mut wiki(0));
        for w in t.items.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        assert!(t.items.last().unwrap().at > Duration::ZERO);
    }

    #[test]
    fn bursty_arrivals_grouped() {
        let cfg = TraceConfig {
            n_requests: 9,
            arrival: Arrival::Bursty {
                size: 3,
                period_ms: 100,
            },
            ..Default::default()
        };
        let t = generate(&cfg, &mut wiki(1));
        assert_eq!(t.items[0].at, t.items[2].at);
        assert_eq!(t.items[3].at, Duration::from_millis(100));
        assert_eq!(t.items[8].at, Duration::from_millis(200));
    }

    #[test]
    fn lengths_within_ranges() {
        let cfg = TraceConfig {
            n_requests: 40,
            prompt_len_range: (5, 9),
            max_new_range: (2, 4),
            ..Default::default()
        };
        let t = generate(&cfg, &mut wiki(2));
        for i in &t.items {
            assert!((5..=9).contains(&i.request.prompt.len()));
            assert!((2..=4).contains(&i.request.max_new_tokens));
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = TraceConfig {
            n_requests: 5,
            temperature: Some(0.7),
            ..Default::default()
        };
        let t = generate(&cfg, &mut wiki(4));
        let j = t.to_json();
        let t2 = Trace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(t.items.len(), t2.items.len());
        for (a, b) in t.items.iter().zip(&t2.items) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.request.max_new_tokens, b.request.max_new_tokens);
            assert_eq!(a.at.as_micros(), b.at.as_micros());
        }
    }

    #[test]
    fn json_roundtrip_property() {
        // random TraceConfig -> generate -> serialize -> parse -> equal,
        // arrival stamps included (the replay-determinism contract)
        crate::util::prop::check(40, |rng| {
            let arrival = match rng.below(3) {
                0 => Arrival::Poisson {
                    rate: 1.0 + rng.f64() * 200.0,
                },
                1 => Arrival::Bursty {
                    size: rng.range(1, 6),
                    period_ms: rng.range(1, 250) as u64,
                },
                _ => Arrival::Batch,
            };
            let plo = rng.range(1, 12);
            let mlo = rng.range(1, 8);
            let cfg = TraceConfig {
                n_requests: rng.below(12),
                arrival,
                prompt_len_range: (plo, plo + rng.below(12)),
                max_new_range: (mlo, mlo + rng.below(8)),
                temperature: if rng.bool(0.5) {
                    Some(rng.f32() * 1.5 + 0.05)
                } else {
                    None
                },
                distinct_prompts: if rng.bool(0.3) {
                    Some(rng.range(1, 4))
                } else {
                    None
                },
                seed: rng.next_u64(),
            };
            let t = generate(&cfg, &mut wiki(cfg.seed));
            let t2 = Trace::from_json(&Json::parse(&t.to_json().to_string()).unwrap())
                .map_err(|e| format!("parse failed: {e}"))?;
            prop_assert!(t.items.len() == t2.items.len(), "length changed");
            for (a, b) in t.items.iter().zip(&t2.items) {
                prop_assert!(a.at == b.at, "at drifted: {:?} vs {:?}", a.at, b.at);
                prop_assert!(a.request.id == b.request.id, "id changed");
                prop_assert!(a.request.prompt == b.request.prompt, "prompt changed");
                prop_assert!(
                    a.request.max_new_tokens == b.request.max_new_tokens,
                    "max_new changed"
                );
                prop_assert!(
                    a.request.sampling == b.request.sampling,
                    "sampling drifted: {:?} vs {:?}",
                    a.request.sampling,
                    b.request.sampling
                );
                prop_assert!(
                    a.request.arrival == b.request.arrival,
                    "arrival stamp drifted"
                );
            }
            Ok(())
        });
    }
}
