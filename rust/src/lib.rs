//! KV-CAR: KV cache compression using autoencoders and cross-layer KV
//! reuse — a full-system reproduction.
//!
//! Three-layer architecture (DESIGN.md):
//!
//! * **L3 (this crate)** — serving coordinator: request router, continuous
//!   batcher, prefill/decode scheduler, and the compressed paged KV-cache
//!   manager where KV-CAR's mechanisms (latent storage, head-reuse
//!   aliasing, Eq. 4 int8) are first-class block formats.  Also the
//!   training driver (Algorithms 1-2 run from rust over AOT'd step
//!   artifacts), the evaluation harness, and the A40 memory simulator
//!   that regenerates the paper's Figs. 2-3.
//! * **L2 (python/compile, build time)** — JAX transformer (GPT-2-style
//!   and TinyLlama-style) with the AE/reuse/quant mechanisms behind
//!   runtime masks, lowered once to HLO text.
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels: fused
//!   autoencoder halves, decode attention, Eq. 4 quantization.
//!
//! Python never runs at serve time: the `runtime` module loads the HLO
//! artifacts via PJRT and everything else is rust.

// Every public item must carry rustdoc (enforced as -D warnings by the
// `cargo doc` CI step) so the kvcache/coordinator API surface — the
// L2<->L3 contract — can't grow undocumented.
#![warn(missing_docs)]

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kvcache;
pub mod memsim;
pub mod model;
pub mod runtime;
pub mod server;
pub mod train;
pub mod util;
