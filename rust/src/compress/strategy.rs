//! Adaptive per-row-region compression policy: the rung vocabulary
//! ([`Rung`] viewed abstractly through [`CompressionStrategy`]), block-
//! aligned per-region assignments ([`RegionSpec`]), and the serde
//! round-trippable [`PlanManifest`] that configures the adaptive serving
//! path (`ServeConfig::adaptive_plan`, DESIGN.md §11).
//!
//! A manifest carries two orthogonal dimensions of the design space:
//!
//! * **per-layer / per-head** — the embedded [`CompressionPlan`] (AE
//!   layers, head-reuse masks, Eq. 4 quantization), which induces the
//!   per-stream store kinds and row widths exactly as the uniform path
//!   always has;
//! * **per-row-region** — an ordered, gap-free, block-aligned list of
//!   row regions, each pinning a *format rung* (raw f32, raw f16, int8)
//!   or deferring to the plan's own formats ([`Rung::Plan`]).
//!
//! Region rungs are format rungs only: the AE-latent and head-reuse
//! rungs change stream *shapes* (elements per row), so they live on the
//! plan axis where every row of a stream shares one width — which is
//! what keeps block storage, the `ParkedBytes` wire format, and the
//! delta-transfer manifests derivable from `(manifest, len)` alone.

use crate::kvcache::Format;
use crate::model::memory::CompressionPlan;
use crate::util::json::{self, Json};
use std::fmt;

/// One compression rung viewed abstractly: what the serving stack needs
/// to know about a storage mechanism without naming it.  Implemented by
/// the unit strategies below for every rung the repo ships (raw
/// f32/f16, int8, AE-latent, head-reuse) — the format rungs drive
/// per-region block encoding, the shape rungs document the plan axis.
pub trait CompressionStrategy {
    /// Short stable identifier (for format rungs, also the manifest
    /// JSON token accepted by [`Rung::parse`]).
    fn name(&self) -> &'static str;

    /// The block format this rung pins every byte-bearing stream to,
    /// or `None` when the rung defers to (or reshapes) the plan-derived
    /// per-stream formats instead of overriding them.
    fn format(&self) -> Option<Format>;

    /// Whether storing f32 rows under this rung reads back bit-exactly.
    fn lossless(&self) -> bool;

    /// Encoded bytes for one row of `elements` f32 values under this
    /// rung, or `None` when the rung does not pin a format.
    fn row_bytes(&self, elements: usize) -> Option<usize> {
        self.format().map(|f| f.row_bytes(elements))
    }
}

/// Raw f32 storage: 4 bytes per element, bit-exact.
pub struct RawF32Strategy;

impl CompressionStrategy for RawF32Strategy {
    fn name(&self) -> &'static str {
        "raw_f32"
    }
    fn format(&self) -> Option<Format> {
        Some(Format::F32)
    }
    fn lossless(&self) -> bool {
        true
    }
}

/// Raw f16 storage: 2 bytes per element, round-to-nearest-even lossy.
pub struct RawF16Strategy;

impl CompressionStrategy for RawF16Strategy {
    fn name(&self) -> &'static str {
        "raw_f16"
    }
    fn format(&self) -> Option<Format> {
        Some(Format::F16)
    }
    fn lossless(&self) -> bool {
        false
    }
}

/// Eq. 4 per-row affine int8 storage: 1 byte per element plus the
/// 8-byte scale/zeropoint header, quantization-lossy.
pub struct Int8Strategy;

impl CompressionStrategy for Int8Strategy {
    fn name(&self) -> &'static str {
        "int8"
    }
    fn format(&self) -> Option<Format> {
        Some(Format::Int8)
    }
    fn lossless(&self) -> bool {
        false
    }
}

/// Defer to the plan-derived per-stream formats (the legacy uniform
/// path's behaviour, and the open-tail default of every manifest).
pub struct PlanDefaultStrategy;

impl CompressionStrategy for PlanDefaultStrategy {
    fn name(&self) -> &'static str {
        "plan"
    }
    fn format(&self) -> Option<Format> {
        None
    }
    fn lossless(&self) -> bool {
        false
    }
}

/// AE-latent storage (plan axis): rows are `ae_latent`-wide encoder
/// outputs, reconstructed by the decoder artifact on retrieval.  A
/// shape rung — it narrows the stream rather than pinning a format.
pub struct AeLatentStrategy;

impl CompressionStrategy for AeLatentStrategy {
    fn name(&self) -> &'static str {
        "ae_latent"
    }
    fn format(&self) -> Option<Format> {
        None
    }
    fn lossless(&self) -> bool {
        false
    }
}

/// Head-reuse storage (plan axis): aliased heads store nothing and
/// resolve from layer l-1 on retrieval.  A shape rung.
pub struct HeadReuseStrategy;

impl CompressionStrategy for HeadReuseStrategy {
    fn name(&self) -> &'static str {
        "head_reuse"
    }
    fn format(&self) -> Option<Format> {
        None
    }
    fn lossless(&self) -> bool {
        false
    }
}

/// Every strategy the repo ships, format rungs first — the sweep base
/// the autotuner and the strategy-contract tests enumerate.
pub fn strategies() -> [&'static dyn CompressionStrategy; 6] {
    [
        &RawF32Strategy,
        &RawF16Strategy,
        &Int8Strategy,
        &PlanDefaultStrategy,
        &AeLatentStrategy,
        &HeadReuseStrategy,
    ]
}

/// A region's storage rung: one of the format rungs, or deference to
/// the plan's own per-stream formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rung {
    /// defer to the plan-derived per-stream formats (legacy behaviour)
    Plan,
    /// pin every byte-bearing stream to raw f32
    RawF32,
    /// pin every byte-bearing stream to raw f16
    RawF16,
    /// pin every byte-bearing stream to Eq. 4 int8
    Int8,
}

impl Rung {
    /// Every rung, manifest-token order.
    pub const ALL: [Rung; 4] = [Rung::Plan, Rung::RawF32, Rung::RawF16, Rung::Int8];

    /// The manifest JSON token for this rung.
    pub fn token(self) -> &'static str {
        match self {
            Rung::Plan => "plan",
            Rung::RawF32 => "raw_f32",
            Rung::RawF16 => "raw_f16",
            Rung::Int8 => "int8",
        }
    }

    /// Parse a manifest token ([`Rung::token`] inverse); unknown tokens
    /// are a typed [`ManifestError::UnknownRung`], never a panic.
    pub fn parse(token: &str) -> Result<Rung, ManifestError> {
        Rung::ALL
            .into_iter()
            .find(|r| r.token() == token)
            .ok_or_else(|| ManifestError::UnknownRung(token.to_string()))
    }

    /// The strategy object implementing this rung.
    pub fn strategy(self) -> &'static dyn CompressionStrategy {
        match self {
            Rung::Plan => &PlanDefaultStrategy,
            Rung::RawF32 => &RawF32Strategy,
            Rung::RawF16 => &RawF16Strategy,
            Rung::Int8 => &Int8Strategy,
        }
    }

    /// The block format this rung pins byte-bearing streams to (`None`
    /// for [`Rung::Plan`], which defers to the plan-derived formats).
    pub fn format_override(self) -> Option<Format> {
        self.strategy().format()
    }
}

/// One contiguous row region `[start, end)` of a manifest and the rung
/// its rows are stored under.  `end = None` is the open tail covering
/// every row from `start` onward — exactly one region (the last) is
/// open, so every row a sequence ever grows to has a rung.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionSpec {
    /// first row of the region (block-aligned)
    pub start: usize,
    /// one past the last row (block-aligned), or `None` for the open tail
    pub end: Option<usize>,
    /// storage rung for rows in the region
    pub rung: Rung,
}

/// Typed rejection of a malformed [`PlanManifest`] — every structural
/// defect a manifest can carry gets its own variant so callers (and the
/// serde fuzz tests) can assert the *reason*, not just failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ManifestError {
    /// the region list is empty (no row would have a rung)
    Empty,
    /// a region boundary is not a multiple of the block size
    Misaligned {
        /// the offending boundary row
        row: usize,
        /// the block size it must divide by
        block_size: usize,
    },
    /// rows between regions are covered by no region
    Gap {
        /// row the next region had to start at
        expected: usize,
        /// row it actually starts at
        got: usize,
    },
    /// a region starts before its predecessor ends
    Overlap {
        /// row the next region had to start at
        expected: usize,
        /// row it actually starts at
        got: usize,
    },
    /// a non-final region has no end (the tail would be unreachable)
    UnboundedInterior {
        /// index of the offending region
        index: usize,
    },
    /// the final region is bounded (rows past it would have no rung)
    BoundedTail,
    /// a bounded region covers no rows
    EmptyRegion {
        /// the region's start row
        start: usize,
    },
    /// a rung token [`Rung::parse`] does not recognize
    UnknownRung(String),
    /// the embedded compression plan failed its own validation
    Plan(String),
    /// the JSON is unparseable or structurally wrong for a manifest
    Parse(String),
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Empty => write!(f, "manifest has no regions"),
            ManifestError::Misaligned { row, block_size } => {
                write!(f, "region boundary {row} is not {block_size}-row aligned")
            }
            ManifestError::Gap { expected, got } => {
                write!(f, "rows [{expected}, {got}) are covered by no region")
            }
            ManifestError::Overlap { expected, got } => {
                write!(f, "region starting at {got} overlaps rows [{got}, {expected})")
            }
            ManifestError::UnboundedInterior { index } => {
                write!(f, "non-final region {index} has no end")
            }
            ManifestError::BoundedTail => {
                write!(f, "final region is bounded (tail rows would have no rung)")
            }
            ManifestError::EmptyRegion { start } => {
                write!(f, "region starting at {start} covers no rows")
            }
            ManifestError::UnknownRung(tok) => write!(f, "unknown rung token {tok:?}"),
            ManifestError::Plan(msg) => write!(f, "embedded plan is invalid: {msg}"),
            ManifestError::Parse(msg) => write!(f, "manifest JSON is malformed: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {}

/// A complete adaptive storage policy: a per-layer/per-head
/// [`CompressionPlan`] plus an ordered, gap-free, block-aligned list of
/// per-row-region rung assignments.  Serde round-trippable via
/// [`PlanManifest::to_json`] / [`PlanManifest::from_json`]; the serving
/// stack validates it against the engine's block size before use.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanManifest {
    /// per-layer / per-head axis: store kinds and row widths
    pub plan: CompressionPlan,
    /// per-row-region axis: ordered regions covering [0, ∞)
    pub regions: Vec<RegionSpec>,
}

impl PlanManifest {
    /// The uniform manifest: one open [`Rung::Plan`] region — by
    /// construction byte-identical to the legacy single-rung path.
    pub fn uniform(plan: CompressionPlan) -> Self {
        Self::uniform_rung(plan, Rung::Plan)
    }

    /// One open region pinning every row to `rung`.
    pub fn uniform_rung(plan: CompressionPlan, rung: Rung) -> Self {
        PlanManifest {
            plan,
            regions: vec![RegionSpec {
                start: 0,
                end: None,
                rung,
            }],
        }
    }

    /// Validate the manifest against `block_size`: regions must be
    /// non-empty, start at row 0, tile the row axis with no gap or
    /// overlap, end with exactly one open tail, sit on block
    /// boundaries, and embed a valid plan.  Pass `block_size = 1` to
    /// defer alignment (what [`PlanManifest::from_json`] does — the
    /// engine re-validates with its real block size).
    pub fn validate(&self, block_size: usize) -> Result<(), ManifestError> {
        if self.regions.is_empty() {
            return Err(ManifestError::Empty);
        }
        let mut expected = 0usize;
        let last = self.regions.len() - 1;
        for (i, r) in self.regions.iter().enumerate() {
            if r.start % block_size != 0 {
                return Err(ManifestError::Misaligned {
                    row: r.start,
                    block_size,
                });
            }
            match r.start.cmp(&expected) {
                std::cmp::Ordering::Greater => {
                    return Err(ManifestError::Gap {
                        expected,
                        got: r.start,
                    })
                }
                std::cmp::Ordering::Less => {
                    return Err(ManifestError::Overlap {
                        expected,
                        got: r.start,
                    })
                }
                std::cmp::Ordering::Equal => {}
            }
            match r.end {
                Some(end) => {
                    if i == last {
                        return Err(ManifestError::BoundedTail);
                    }
                    if end % block_size != 0 {
                        return Err(ManifestError::Misaligned {
                            row: end,
                            block_size,
                        });
                    }
                    if end <= r.start {
                        return Err(ManifestError::EmptyRegion { start: r.start });
                    }
                    expected = end;
                }
                None => {
                    if i != last {
                        return Err(ManifestError::UnboundedInterior { index: i });
                    }
                }
            }
        }
        self.plan.validate().map_err(ManifestError::Plan)
    }

    /// The rung governing `row` (the open tail's rung for rows past
    /// every bounded region; [`Rung::Plan`] on an invalid manifest that
    /// covers nothing).
    pub fn rung_at(&self, row: usize) -> Rung {
        for r in &self.regions {
            if row >= r.start && r.end.map_or(true, |e| row < e) {
                return r.rung;
            }
        }
        Rung::Plan
    }

    /// Whether every region defers to the plan (the manifest is the
    /// uniform legacy policy, whatever its region boundaries).
    pub fn is_uniform_plan(&self) -> bool {
        self.regions.iter().all(|r| r.rung == Rung::Plan)
    }

    /// Serialize to the version-1 manifest JSON schema:
    ///
    /// ```json
    /// {"version": 1,
    ///  "plan": {"ae_layers": [...], "reuse_k": [[...]],
    ///           "reuse_v": [[...]], "quant_int8": false},
    ///  "regions": [{"start": 0, "end": 16, "rung": "raw_f32"},
    ///              {"start": 16, "rung": "plan"}]}
    /// ```
    pub fn to_json(&self) -> String {
        let bools = |v: &[bool]| json::arr(v.iter().map(|&b| Json::Bool(b)));
        let mat = |m: &[Vec<bool>]| json::arr(m.iter().map(|r| bools(r)));
        let regions = json::arr(self.regions.iter().map(|r| {
            let mut fields = vec![("start", json::num(r.start as f64))];
            if let Some(end) = r.end {
                fields.push(("end", json::num(end as f64)));
            }
            fields.push(("rung", json::s(r.rung.token())));
            json::obj(fields)
        }));
        json::obj(vec![
            ("version", json::num(1.0)),
            (
                "plan",
                json::obj(vec![
                    ("ae_layers", bools(&self.plan.ae_layers)),
                    ("reuse_k", mat(&self.plan.reuse_k)),
                    ("reuse_v", mat(&self.plan.reuse_v)),
                    ("quant_int8", Json::Bool(self.plan.quant_int8)),
                ]),
            ),
            ("regions", regions),
        ])
        .to_string()
    }

    /// Parse and structurally validate a version-1 manifest.  Every
    /// failure is a typed [`ManifestError`] (parse, unknown rung, gap,
    /// overlap, …), never a panic.  Alignment is deferred
    /// (`validate(1)`) because the block size belongs to the engine the
    /// manifest is eventually installed into.
    pub fn from_json(text: &str) -> Result<Self, ManifestError> {
        let v = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let version = parse_row(&v, "version")?;
        if version != 1 {
            return Err(ManifestError::Parse(format!(
                "unsupported manifest version {version}"
            )));
        }
        let p = v
            .get("plan")
            .ok_or_else(|| ManifestError::Parse("missing \"plan\"".into()))?;
        let plan = CompressionPlan {
            ae_layers: parse_bools(field(p, "ae_layers")?, "plan.ae_layers")?,
            reuse_k: parse_bool_matrix(field(p, "reuse_k")?, "plan.reuse_k")?,
            reuse_v: parse_bool_matrix(field(p, "reuse_v")?, "plan.reuse_v")?,
            quant_int8: field(p, "quant_int8")?
                .as_bool()
                .ok_or_else(|| ManifestError::Parse("plan.quant_int8 must be a bool".into()))?,
        };
        let rs = v
            .get("regions")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("\"regions\" must be an array".into()))?;
        let mut regions = Vec::with_capacity(rs.len());
        for r in rs {
            let start = parse_row(r, "start")?;
            let end = match r.get("end") {
                None | Some(Json::Null) => None,
                Some(_) => Some(parse_row(r, "end")?),
            };
            let rung = Rung::parse(
                r.get("rung")
                    .and_then(Json::as_str)
                    .ok_or_else(|| ManifestError::Parse("region \"rung\" must be a string".into()))?,
            )?;
            regions.push(RegionSpec { start, end, rung });
        }
        let m = PlanManifest { plan, regions };
        m.validate(1)?;
        Ok(m)
    }

    /// Random *valid* manifest over an `n_layer`-layer,
    /// `n_kv_head`-head model with `block_size`-aligned regions cut
    /// below `max_rows` — the generator the differential property tests
    /// drive the adaptive path with (mirrors [`CompressionPlan::random`]).
    pub fn random(
        rng: &mut crate::util::rng::Rng,
        n_layer: usize,
        n_kv_head: usize,
        block_size: usize,
        max_rows: usize,
    ) -> Self {
        let plan = CompressionPlan::random(rng, n_layer, n_kv_head);
        let max_blocks = (max_rows / block_size).max(1);
        let mut cuts: Vec<usize> = (0..rng.below(4))
            .map(|_| rng.below(max_blocks) * block_size)
            .filter(|&c| c > 0)
            .collect();
        cuts.sort_unstable();
        cuts.dedup();
        let pick = |rng: &mut crate::util::rng::Rng| Rung::ALL[rng.below(4)];
        let mut regions = Vec::with_capacity(cuts.len() + 1);
        let mut start = 0usize;
        for cut in cuts {
            regions.push(RegionSpec {
                start,
                end: Some(cut),
                rung: pick(rng),
            });
            start = cut;
        }
        regions.push(RegionSpec {
            start,
            end: None,
            rung: pick(rng),
        });
        let m = PlanManifest { plan, regions };
        debug_assert!(m.validate(block_size).is_ok());
        m
    }
}

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json, ManifestError> {
    v.get(key)
        .ok_or_else(|| ManifestError::Parse(format!("missing plan field {key:?}")))
}

fn parse_row(v: &Json, key: &str) -> Result<usize, ManifestError> {
    let n = v
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| ManifestError::Parse(format!("{key:?} must be a number")))?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(ManifestError::Parse(format!(
            "{key:?} must be a non-negative integer, got {n}"
        )));
    }
    Ok(n as usize)
}

fn parse_bools(v: &Json, what: &str) -> Result<Vec<bool>, ManifestError> {
    v.as_arr()
        .ok_or_else(|| ManifestError::Parse(format!("{what} must be an array")))?
        .iter()
        .map(|b| {
            b.as_bool()
                .ok_or_else(|| ManifestError::Parse(format!("{what} must hold bools")))
        })
        .collect()
}

fn parse_bool_matrix(v: &Json, what: &str) -> Result<Vec<Vec<bool>>, ManifestError> {
    v.as_arr()
        .ok_or_else(|| ManifestError::Parse(format!("{what} must be an array")))?
        .iter()
        .map(|row| parse_bools(row, what))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn plan() -> CompressionPlan {
        CompressionPlan::none(3, 4)
    }

    #[test]
    fn strategy_row_bytes_match_block_formats() {
        assert_eq!(RawF32Strategy.row_bytes(64), Some(256));
        assert_eq!(RawF16Strategy.row_bytes(64), Some(128));
        assert_eq!(Int8Strategy.row_bytes(64), Some(72));
        assert_eq!(PlanDefaultStrategy.row_bytes(64), None);
        assert!(RawF32Strategy.lossless());
        assert!(!Int8Strategy.lossless());
        // names are distinct and stable — they key the manifest schema
        let names: std::collections::BTreeSet<_> =
            strategies().iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), strategies().len());
    }

    #[test]
    fn rung_tokens_round_trip() {
        for rung in Rung::ALL {
            assert_eq!(Rung::parse(rung.token()), Ok(rung));
            assert_eq!(rung.strategy().format(), rung.format_override());
        }
        assert_eq!(
            Rung::parse("fp4"),
            Err(ManifestError::UnknownRung("fp4".into()))
        );
    }

    #[test]
    fn uniform_manifest_validates_and_covers_every_row() {
        let m = PlanManifest::uniform(plan());
        m.validate(16).expect("uniform manifest is valid");
        assert!(m.is_uniform_plan());
        assert_eq!(m.rung_at(0), Rung::Plan);
        assert_eq!(m.rung_at(10_000), Rung::Plan);
    }

    #[test]
    fn rung_at_respects_region_boundaries() {
        let m = PlanManifest {
            plan: plan(),
            regions: vec![
                RegionSpec {
                    start: 0,
                    end: Some(16),
                    rung: Rung::RawF32,
                },
                RegionSpec {
                    start: 16,
                    end: Some(48),
                    rung: Rung::Int8,
                },
                RegionSpec {
                    start: 48,
                    end: None,
                    rung: Rung::Plan,
                },
            ],
        };
        m.validate(16).expect("manifest is valid");
        assert!(!m.is_uniform_plan());
        assert_eq!(m.rung_at(0), Rung::RawF32);
        assert_eq!(m.rung_at(15), Rung::RawF32);
        assert_eq!(m.rung_at(16), Rung::Int8);
        assert_eq!(m.rung_at(47), Rung::Int8);
        assert_eq!(m.rung_at(48), Rung::Plan);
    }

    #[test]
    fn malformed_manifests_are_typed_errors() {
        let region = |start, end, rung| RegionSpec { start, end, rung };
        let m = |regions| PlanManifest {
            plan: plan(),
            regions,
        };
        assert_eq!(m(vec![]).validate(16), Err(ManifestError::Empty));
        assert_eq!(
            m(vec![region(16, None, Rung::Plan)]).validate(16),
            Err(ManifestError::Gap {
                expected: 0,
                got: 16
            })
        );
        assert_eq!(
            m(vec![
                region(0, Some(32), Rung::Int8),
                region(16, None, Rung::Plan)
            ])
            .validate(16),
            Err(ManifestError::Overlap {
                expected: 32,
                got: 16
            })
        );
        assert_eq!(
            m(vec![
                region(0, Some(16), Rung::Int8),
                region(32, None, Rung::Plan)
            ])
            .validate(16),
            Err(ManifestError::Gap {
                expected: 16,
                got: 32
            })
        );
        assert_eq!(
            m(vec![
                region(0, None, Rung::Int8),
                region(16, None, Rung::Plan)
            ])
            .validate(16),
            Err(ManifestError::UnboundedInterior { index: 0 })
        );
        assert_eq!(
            m(vec![region(0, Some(16), Rung::Plan)]).validate(16),
            Err(ManifestError::BoundedTail)
        );
        assert_eq!(
            m(vec![
                region(0, Some(0), Rung::Int8),
                region(0, None, Rung::Plan)
            ])
            .validate(16),
            Err(ManifestError::EmptyRegion { start: 0 })
        );
        assert_eq!(
            m(vec![
                region(0, Some(24), Rung::Int8),
                region(24, None, Rung::Plan)
            ])
            .validate(16),
            Err(ManifestError::Misaligned {
                row: 24,
                block_size: 16
            })
        );
        // an invalid embedded plan is typed too, not a panic
        let mut bad = PlanManifest::uniform(plan());
        bad.plan.reuse_k[0][0] = true;
        assert!(matches!(bad.validate(16), Err(ManifestError::Plan(_))));
    }

    #[test]
    fn json_round_trips_uniform_and_mixed() {
        let uniform = PlanManifest::uniform(plan());
        assert_eq!(
            PlanManifest::from_json(&uniform.to_json()).expect("round trip"),
            uniform
        );
        let mixed = PlanManifest {
            plan: plan().with_quant(),
            regions: vec![
                RegionSpec {
                    start: 0,
                    end: Some(16),
                    rung: Rung::RawF32,
                },
                RegionSpec {
                    start: 16,
                    end: None,
                    rung: Rung::Int8,
                },
            ],
        };
        assert_eq!(
            PlanManifest::from_json(&mixed.to_json()).expect("round trip"),
            mixed
        );
    }

    #[test]
    fn json_rejections_are_typed() {
        assert!(matches!(
            PlanManifest::from_json("not json"),
            Err(ManifestError::Parse(_))
        ));
        assert!(matches!(
            PlanManifest::from_json("{\"version\": 2}"),
            Err(ManifestError::Parse(_))
        ));
        let unknown_rung = r#"{"version": 1,
            "plan": {"ae_layers": [false], "reuse_k": [[false]],
                     "reuse_v": [[false]], "quant_int8": false},
            "regions": [{"start": 0, "rung": "fp4"}]}"#;
        assert_eq!(
            PlanManifest::from_json(unknown_rung),
            Err(ManifestError::UnknownRung("fp4".into()))
        );
        // structurally parsed, semantically overlapping → typed Overlap
        let overlapping = r#"{"version": 1,
            "plan": {"ae_layers": [false], "reuse_k": [[false]],
                     "reuse_v": [[false]], "quant_int8": false},
            "regions": [{"start": 0, "end": 32, "rung": "int8"},
                        {"start": 16, "rung": "plan"}]}"#;
        assert_eq!(
            PlanManifest::from_json(overlapping),
            Err(ManifestError::Overlap {
                expected: 32,
                got: 16
            })
        );
        let fractional = r#"{"version": 1,
            "plan": {"ae_layers": [false], "reuse_k": [[false]],
                     "reuse_v": [[false]], "quant_int8": false},
            "regions": [{"start": 0.5, "rung": "plan"}]}"#;
        assert!(matches!(
            PlanManifest::from_json(fractional),
            Err(ManifestError::Parse(_))
        ));
    }

    #[test]
    fn arbitrary_manifests_round_trip_exactly() {
        prop::check(200, |rng: &mut Rng| {
            let m = PlanManifest::random(rng, 4, 4, 16, 96);
            crate::prop_assert!(m.validate(16).is_ok(), "generator must emit valid manifests");
            let back = PlanManifest::from_json(&m.to_json())
                .map_err(|e| format!("round trip failed: {e}"))?;
            crate::prop_assert!(back == m, "round trip changed the manifest");
            Ok(())
        });
    }
}
