//! KV-CAR compression machinery on the rust side: Eq. 4 int8 packing,
//! Alg. 2 similarity analysis, plan construction, and the adaptive
//! per-row-region strategy layer (rungs, manifests, DESIGN.md §11).

pub mod planner;
pub mod quant;
pub mod similarity;
pub mod strategy;
