//! KV-CAR compression machinery on the rust side: Eq. 4 int8 packing,
//! Alg. 2 similarity analysis, and plan construction.

pub mod planner;
pub mod quant;
pub mod similarity;
