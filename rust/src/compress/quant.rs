//! Eq. 4 int8 affine quantization on real byte buffers.
//!
//! This is the storage-side twin of the Pallas quant kernel
//! (`python/compile/kernels/quant.py`): the kernel simulates
//! quantize->dequantize inside the XLA graph (for accuracy evaluation),
//! while this module actually *packs* latent vectors into i8 bytes inside
//! the rust KV cache — the component that realizes the memory savings.
//!
//!   scale     = 255 / (max(x) - min(x))
//!   zeropoint = -round(scale * min(x)) - 128
//!   q         = clamp(round(scale * x + zeropoint), -128, 127)   (Eq. 4)

/// Per-vector header bytes when packed: f32 scale + f32 zeropoint.  The
/// single source of truth for the int8 row layout — `Format::row_bytes`
/// and the Eq. 3 accounting in `model::memory` both reference it.
pub const QUANT_HEADER_BYTES: usize = 8;

/// A quantized vector: i8 codes + per-vector affine header.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantVec {
    /// one signed code per input element
    pub codes: Vec<i8>,
    /// dequantization step (Eq. 4 scale)
    pub scale: f32,
    /// value code 0 maps back to
    pub zeropoint: f32,
}

impl QuantVec {
    /// Codes plus the (scale, zeropoint) header.
    pub fn stored_bytes(&self) -> usize {
        self.codes.len() + QUANT_HEADER_BYTES
    }
}

/// Eq. 4 affine parameters for a vector: (scale, zeropoint).
pub fn affine_params(x: &[f32]) -> (f32, f32) {
    debug_assert!(!x.is_empty());
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = 255.0 / (hi - lo).max(1e-8);
    // round-half-to-even everywhere, matching jnp.round in the L1/L2
    // reference (keeps in-graph quant sim and rust packing bit-identical)
    let zeropoint = -(scale * lo).round_ties_even() - 128.0;
    (scale, zeropoint)
}

/// Eq. 4 affine quantization into an owned `QuantVec`.
pub fn quantize(x: &[f32]) -> QuantVec {
    let (scale, zeropoint) = affine_params(x);
    let codes = x
        .iter()
        .map(|&v| {
            (scale * v + zeropoint)
                .round_ties_even()
                .clamp(-128.0, 127.0) as i8
        })
        .collect();
    QuantVec {
        codes,
        scale,
        zeropoint,
    }
}

/// Quantize straight into a caller byte buffer (each code is the i8's
/// two's-complement byte), no allocation — the block store's bulk-encode
/// path.  Returns (scale, zeropoint).
pub fn quantize_into(x: &[f32], codes: &mut [u8]) -> (f32, f32) {
    debug_assert_eq!(x.len(), codes.len());
    let (scale, zeropoint) = affine_params(x);
    for (c, &v) in codes.iter_mut().zip(x) {
        *c = (scale * v + zeropoint)
            .round_ties_even()
            .clamp(-128.0, 127.0) as i8 as u8;
    }
    (scale, zeropoint)
}

/// Dequantize codes read as raw two's-complement bytes, no allocation —
/// the block store's bulk-decode path.
pub fn dequantize_codes_into(codes: &[u8], scale: f32, zeropoint: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let inv = 1.0 / scale;
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = (c as i8 as f32 - zeropoint) * inv;
    }
}

/// Dequantize a `QuantVec` into `out`.
pub fn dequantize_into(q: &QuantVec, out: &mut [f32]) {
    debug_assert_eq!(q.codes.len(), out.len());
    let inv = 1.0 / q.scale;
    for (o, &c) in out.iter_mut().zip(&q.codes) {
        *o = (c as f32 - q.zeropoint) * inv;
    }
}

/// Dequantize into a fresh buffer.
pub fn dequantize(q: &QuantVec) -> Vec<f32> {
    let mut out = vec![0.0; q.codes.len()];
    dequantize_into(q, &mut out);
    out
}

/// Max absolute round-trip error bound for a vector: one quantization step.
pub fn error_bound(x: &[f32]) -> f32 {
    let lo = x.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    (hi - lo).max(1e-8) / 255.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_error_within_bound() {
        check(100, |rng| {
            let n = rng.range(1, 512);
            let scale = 10f32.powf(rng.f32() * 4.0 - 2.0);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, scale)).collect();
            let q = quantize(&x);
            let y = dequantize(&q);
            let bound = error_bound(&x) + 1e-6;
            for (a, b) in x.iter().zip(&y) {
                prop_assert!(
                    (a - b).abs() <= bound,
                    "err {} > bound {bound}",
                    (a - b).abs()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn matches_python_reference_values() {
        // cross-checked against compile/kernels/ref.py quantize()
        let x = [0.0f32, 1.0, 2.0, 3.0];
        let q = quantize(&x);
        assert_eq!(q.scale, 85.0);
        assert_eq!(q.zeropoint, -128.0);
        assert_eq!(q.codes, vec![-128, -43, 42, 127]);
    }

    #[test]
    fn constant_vector_is_finite() {
        let x = [2.5f32; 16];
        let q = quantize(&x);
        let y = dequantize(&q);
        assert!(y.iter().all(|v| v.is_finite()));
        // degenerate range: reconstruction error stays within one step of
        // the (clamped) scale
        assert!(y.iter().all(|v| (v - 2.5).abs() < 2.5 + 1.0));
    }

    #[test]
    fn storage_accounting() {
        let q = quantize(&[1.0; 64]);
        assert_eq!(q.stored_bytes(), 72); // 64 codes + 8-byte header
    }

    #[test]
    fn in_place_codec_is_bit_identical() {
        check(60, |rng| {
            let n = rng.range(1, 256);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let q = quantize(&x);
            let mut codes = vec![0u8; n];
            let (scale, zeropoint) = quantize_into(&x, &mut codes);
            prop_assert!(scale.to_bits() == q.scale.to_bits(), "scale mismatch");
            prop_assert!(
                zeropoint.to_bits() == q.zeropoint.to_bits(),
                "zeropoint mismatch"
            );
            for (a, &b) in q.codes.iter().zip(&codes) {
                prop_assert!(*a as u8 == b, "code mismatch: {a} vs {}", b as i8);
            }
            let mut out_a = vec![0.0f32; n];
            let mut out_b = vec![0.0f32; n];
            dequantize_into(&q, &mut out_a);
            dequantize_codes_into(&codes, scale, zeropoint, &mut out_b);
            for (a, b) in out_a.iter().zip(&out_b) {
                prop_assert!(a.to_bits() == b.to_bits(), "dequant mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn codes_span_full_range() {
        let x: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let q = quantize(&x);
        assert_eq!(*q.codes.first().unwrap(), -128);
        assert_eq!(*q.codes.last().unwrap(), 127);
    }

    #[test]
    fn monotone_inputs_monotone_codes() {
        check(50, |rng| {
            let n = rng.range(2, 128);
            let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            x.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q = quantize(&x);
            for w in q.codes.windows(2) {
                prop_assert!(w[0] <= w[1], "codes not monotone");
            }
            Ok(())
        });
    }
}
