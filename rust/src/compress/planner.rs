//! Compression planning: assemble a `CompressionPlan` (model/memory.rs)
//! from the paper's configurations and from measured head similarities,
//! and express plans as the runtime mask vectors the AOT artifacts take.

use super::similarity::Selection;
use super::strategy::{PlanManifest, RegionSpec, Rung};
use crate::model::memory::CompressionPlan;
use crate::model::ModelSpec;

/// Runtime masks in artifact layout: compress [L], reuse [L*Hkv] row-major,
/// quant scalar — exactly the f32 inputs of eval_loss/prefill/decode_step.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeMasks {
    /// [L] 1.0 where the AE round-trip applies
    pub compress: Vec<f32>,
    /// [L * Hkv] row-major 1.0 where K head (l, h) aliases layer l-1
    pub reuse_k: Vec<f32>,
    /// [L * Hkv] row-major 1.0 where V head (l, h) aliases layer l-1
    pub reuse_v: Vec<f32>,
    /// 1.0 to apply the Eq. 4 int8 sim to latents
    pub quant: f32,
}

/// Lower a boolean plan to the f32 mask tensors the artifacts consume.
pub fn to_masks(plan: &CompressionPlan) -> RuntimeMasks {
    let fl = |b: &bool| if *b { 1.0 } else { 0.0 };
    RuntimeMasks {
        compress: plan.ae_layers.iter().map(fl).collect(),
        reuse_k: plan.reuse_k.iter().flatten().map(fl).collect(),
        reuse_v: plan.reuse_v.iter().flatten().map(fl).collect(),
        quant: if plan.quant_int8 { 1.0 } else { 0.0 },
    }
}

/// Attach a reuse selection (from similarity analysis) to a plan.
pub fn with_selection(mut plan: CompressionPlan, sel: &Selection) -> CompressionPlan {
    plan.reuse_k = sel.reuse_k.clone();
    plan.reuse_v = sel.reuse_v.clone();
    plan
}

/// The paper's Table II configuration: AE on the first k layers.
pub fn table2_plan(spec: &ModelSpec, k_layers: usize) -> CompressionPlan {
    CompressionPlan::ae_first_layers(spec, k_layers)
}

/// The paper's Table IV combined configuration: selective head reuse plus
/// AE on every layer that keeps its own storage (no AE on fully-reused
/// layers — their storage is already zero).
pub fn combined_plan(spec: &ModelSpec, sel: &Selection, ae_layers: usize) -> CompressionPlan {
    let mut plan = with_selection(
        CompressionPlan::none(spec.n_layer, spec.n_kv_head),
        sel,
    );
    let mut placed = 0;
    for l in 0..spec.n_layer {
        if placed >= ae_layers {
            break;
        }
        let fully_reused = plan.reuse_k[l].iter().all(|&r| r)
            && plan.reuse_v[l].iter().all(|&r| r);
        if !fully_reused {
            plan.ae_layers[l] = true;
            placed += 1;
        }
    }
    plan
}

/// The labelled candidate manifests `kvcar autotune` sweeps (DESIGN.md
/// §11): the uniform rungs (raw f32 reference first, then f16 and
/// int8), the paper's AE plans (half and all layers), and two mixed
/// region shapes — the attention-sink block pinned raw f32, a cold
/// early region demoted to a cheap rung, and the recent tail kept at
/// the plan's own rung.  Every manifest validates against `block_size`
/// by construction; the first entry is always the lossless reference
/// the accuracy axis is measured against.
pub fn candidate_manifests(
    spec: &ModelSpec,
    block_size: usize,
) -> Vec<(&'static str, PlanManifest)> {
    let none = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let ae = CompressionPlan::ae_first_layers(spec, (spec.n_layer / 2).max(1));
    let ae_all = CompressionPlan::ae_first_layers(spec, spec.n_layer);
    let bs = block_size;
    // block-aligned cold/recent boundary near the sequence midpoint,
    // always past the sink block so the middle region is non-empty
    let mid = bs * ((spec.max_seq / bs) / 2).max(2);
    let sink_regions = |cold: Rung, tail: Rung| {
        vec![
            RegionSpec { start: 0, end: Some(bs), rung: Rung::RawF32 },
            RegionSpec { start: bs, end: Some(mid), rung: cold },
            RegionSpec { start: mid, end: None, rung: tail },
        ]
    };
    vec![
        (
            "uniform_raw_f32",
            PlanManifest::uniform_rung(none.clone(), Rung::RawF32),
        ),
        (
            "uniform_raw_f16",
            PlanManifest::uniform_rung(none.clone(), Rung::RawF16),
        ),
        (
            "uniform_int8",
            PlanManifest::uniform_rung(none, Rung::Int8),
        ),
        ("ae_half_plan", PlanManifest::uniform(ae.clone())),
        ("ae_all_plan", PlanManifest::uniform(ae_all)),
        (
            "sink_cold_int8",
            PlanManifest {
                plan: ae.clone(),
                regions: sink_regions(Rung::Int8, Rung::Plan),
            },
        ),
        (
            "sink_cold_f16",
            PlanManifest {
                plan: ae,
                regions: sink_regions(Rung::RawF16, Rung::RawF32),
            },
        ),
    ]
}

/// Greedy layer-budget search: the largest k such that AE-on-k-layers
/// stays within `max_ppl_increase` according to a caller-supplied
/// evaluation oracle (the rust eval harness running the eval_loss
/// artifact).  Mirrors the paper's per-dataset "up to N layers" sweep.
pub fn max_layers_within_budget(
    spec: &ModelSpec,
    baseline_ppl: f64,
    max_ppl_increase: f64,
    mut eval_ppl: impl FnMut(&CompressionPlan) -> f64,
) -> (usize, f64) {
    let mut best = (0, baseline_ppl);
    for k in 1..=spec.n_layer {
        let plan = table2_plan(spec, k);
        let ppl = eval_ppl(&plan);
        if ppl <= baseline_ppl + max_ppl_increase {
            best = (k, ppl);
        } else {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::model::memory::plan_savings;

    #[test]
    fn masks_layout() {
        let spec = gpt2_774m();
        let mut plan = CompressionPlan::ae_first_layers(&spec, 2);
        plan.reuse_k[3][5] = true;
        plan.quant_int8 = true;
        let m = to_masks(&plan);
        assert_eq!(m.compress.len(), 36);
        assert_eq!(m.compress[1], 1.0);
        assert_eq!(m.compress[2], 0.0);
        assert_eq!(m.reuse_k.len(), 36 * 20);
        assert_eq!(m.reuse_k[3 * 20 + 5], 1.0);
        assert_eq!(m.reuse_k.iter().sum::<f32>(), 1.0);
        assert_eq!(m.quant, 1.0);
    }

    #[test]
    fn combined_plan_skips_fully_reused_layers() {
        let spec = gpt2_774m();
        let mut sel = Selection::new(spec.n_layer, spec.n_kv_head);
        sel.reuse_k[1] = vec![true; spec.n_kv_head];
        sel.reuse_v[1] = vec![true; spec.n_kv_head];
        let plan = combined_plan(&spec, &sel, 3);
        assert!(!plan.ae_layers[1], "fully reused layer must not get an AE");
        assert_eq!(plan.n_ae_layers(), 3);
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn combined_savings_exceed_parts() {
        let spec = gpt2_774m();
        let sel = Selection::all_alternating(spec.n_layer, spec.n_kv_head, true, false);
        let heads_only = with_selection(
            CompressionPlan::none(spec.n_layer, spec.n_kv_head),
            &sel,
        );
        let combined = combined_plan(&spec, &sel, spec.n_layer);
        assert!(plan_savings(&spec, &combined) > plan_savings(&spec, &heads_only));
    }

    #[test]
    fn candidate_manifests_validate_and_lead_with_the_raw_reference() {
        let spec = gpt2_774m();
        let cands = candidate_manifests(&spec, 16);
        assert_eq!(cands[0].0, "uniform_raw_f32");
        assert_eq!(
            cands[0].1.rung_at(0),
            crate::compress::strategy::Rung::RawF32
        );
        let mut labels: Vec<&str> = cands.iter().map(|(l, _)| *l).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), cands.len(), "labels must be unique");
        for (label, m) in &cands {
            m.validate(16)
                .unwrap_or_else(|e| panic!("candidate {label} invalid: {e}"));
        }
        // the mixed candidates pin the sink block raw f32
        let (_, sink) = cands
            .iter()
            .find(|(l, _)| *l == "sink_cold_int8")
            .expect("sink candidate present");
        assert_eq!(sink.rung_at(0), crate::compress::strategy::Rung::RawF32);
        assert_eq!(sink.rung_at(16), crate::compress::strategy::Rung::Int8);
    }

    #[test]
    fn budget_search_monotone_oracle() {
        let spec = gpt2_774m();
        // fake oracle: ppl grows 0.1 per compressed layer
        let (k, ppl) =
            max_layers_within_budget(&spec, 20.0, 1.05, |p| 20.0 + 0.1 * p.n_ae_layers() as f64);
        assert_eq!(k, 10);
        assert!((ppl - 21.0).abs() < 1e-9);
    }
}
