//! Alg. 2 steps 1-3: similarity-guided head selection.
//!
//! The `kv_stats` artifact produces mean adjacent-layer L1 distances per
//! KV head (`dk`, `dv`, each [L, Hkv]; row 0 is meaningless — layer 0 has
//! no predecessor).  This module averages them across evaluation batches,
//! then selects heads to reuse either by an absolute threshold (the
//! paper's "empirically determined threshold") or by a top-N budget (the
//! paper's "19 key / 25 value / 36 key-and-value" configurations).

#[derive(Debug, Clone)]
/// Per-(layer, head) K/V distance-to-previous-layer matrices
/// (Alg. 2's similarity statistics).
pub struct HeadDistances {
    /// layers covered
    pub n_layer: usize,
    /// KV heads per layer
    pub n_kv_head: usize,
    /// [L][Hkv] mean L1 distance |head(l) - head(l-1)|; row 0 unused
    pub dk: Vec<Vec<f64>>,
    /// [L][Hkv] mean L1 V distances; row 0 unused
    pub dv: Vec<Vec<f64>>,
    batches: usize,
}

impl HeadDistances {
    /// Zeroed distance matrices.
    pub fn new(n_layer: usize, n_kv_head: usize) -> Self {
        HeadDistances {
            n_layer,
            n_kv_head,
            dk: vec![vec![0.0; n_kv_head]; n_layer],
            dv: vec![vec![0.0; n_kv_head]; n_layer],
            batches: 0,
        }
    }

    /// Accumulate one batch's [L*Hkv] row-major stats from the artifact.
    pub fn accumulate(&mut self, dk_flat: &[f32], dv_flat: &[f32]) {
        assert_eq!(dk_flat.len(), self.n_layer * self.n_kv_head);
        assert_eq!(dv_flat.len(), self.n_layer * self.n_kv_head);
        for l in 0..self.n_layer {
            for h in 0..self.n_kv_head {
                self.dk[l][h] += dk_flat[l * self.n_kv_head + h] as f64;
                self.dv[l][h] += dv_flat[l * self.n_kv_head + h] as f64;
            }
        }
        self.batches += 1;
    }

    /// Mean over accumulated batches.
    pub fn finalize(mut self) -> Self {
        let n = self.batches.max(1) as f64;
        for row in self.dk.iter_mut().chain(self.dv.iter_mut()) {
            for v in row.iter_mut() {
                *v /= n;
            }
        }
        self.batches = 1;
        self
    }

    fn candidates(&self, which: Which) -> Vec<Candidate> {
        let mut out = Vec::new();
        let src = match which {
            Which::K => &self.dk,
            Which::V => &self.dv,
        };
        for l in 1..self.n_layer {
            for h in 0..self.n_kv_head {
                out.push(Candidate {
                    layer: l,
                    head: h,
                    which,
                    distance: src[l][h],
                });
            }
        }
        out
    }

    /// Heads whose distance falls below `threshold` (paper's Alg. 2).
    pub fn select_by_threshold(&self, threshold: f64) -> Selection {
        let mut sel = Selection::new(self.n_layer, self.n_kv_head);
        for c in self
            .candidates(Which::K)
            .into_iter()
            .chain(self.candidates(Which::V))
        {
            if c.distance < threshold {
                sel.set(&c);
            }
        }
        sel
    }

    /// The `n_k` most-similar K heads and `n_v` most-similar V heads
    /// (Table III's selective configurations).
    pub fn select_top(&self, n_k: usize, n_v: usize) -> Selection {
        let mut sel = Selection::new(self.n_layer, self.n_kv_head);
        for (which, n) in [(Which::K, n_k), (Which::V, n_v)] {
            let mut cands = self.candidates(which);
            cands.sort_by(|a, b| a.distance.partial_cmp(&b.distance).unwrap());
            for c in cands.into_iter().take(n) {
                sel.set(&c);
            }
        }
        sel
    }

    /// Threshold that would select exactly `n` heads of the given kind —
    /// how the paper's "empirical threshold" is actually picked.
    pub fn threshold_for_budget(&self, which_k: bool, n: usize) -> f64 {
        let mut d: Vec<f64> = self
            .candidates(if which_k { Which::K } else { Which::V })
            .iter()
            .map(|c| c.distance)
            .collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if n == 0 {
            return 0.0;
        }
        d.get(n - 1).copied().unwrap_or(f64::INFINITY) + f64::EPSILON
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// K or V selection for a reuse candidate.
pub enum Which {
    /// key head
    K,
    /// value head
    V,
}

#[derive(Debug, Clone, Copy)]
/// One reusable head with its measured distance.
pub struct Candidate {
    /// layer index (>= 1)
    pub layer: usize,
    /// KV head index
    pub head: usize,
    /// K or V side
    pub which: Which,
    /// mean L1 distance to the same head one layer below
    pub distance: f64,
}

/// Boolean reuse masks, the shape the artifacts and the cache manager use.
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    /// [L][Hkv] true where K head (l, h) aliases layer l-1
    pub reuse_k: Vec<Vec<bool>>,
    /// [L][Hkv] true where V head (l, h) aliases layer l-1
    pub reuse_v: Vec<Vec<bool>>,
}

impl Selection {
    /// All-false selection (nothing reused).
    pub fn new(n_layer: usize, n_kv_head: usize) -> Self {
        Selection {
            reuse_k: vec![vec![false; n_kv_head]; n_layer],
            reuse_v: vec![vec![false; n_kv_head]; n_layer],
        }
    }

    fn set(&mut self, c: &Candidate) {
        match c.which {
            Which::K => self.reuse_k[c.layer][c.head] = true,
            Which::V => self.reuse_v[c.layer][c.head] = true,
        }
    }

    /// Selected K pairs.
    pub fn count_k(&self) -> usize {
        self.reuse_k.iter().flatten().filter(|&&b| b).count()
    }

    /// Selected V pairs.
    pub fn count_v(&self) -> usize {
        self.reuse_v.iter().flatten().filter(|&&b| b).count()
    }

    /// All K and V heads of layers 1, 3, 5, ... (the paper's "all key and
    /// value heads replaced" upper bound — alternating layers so every
    /// reused layer has a stored predecessor).
    pub fn all_alternating(n_layer: usize, n_kv_head: usize, k: bool, v: bool) -> Selection {
        let mut s = Selection::new(n_layer, n_kv_head);
        for l in (1..n_layer).step_by(2) {
            if k {
                s.reuse_k[l] = vec![true; n_kv_head];
            }
            if v {
                s.reuse_v[l] = vec![true; n_kv_head];
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats() -> HeadDistances {
        let mut hd = HeadDistances::new(4, 2);
        // layer 1 head 0 is very similar; layer 3 head 1 moderately
        let dk = [
            9.0, 9.0, // layer 0 (ignored)
            0.1, 5.0, // layer 1
            4.0, 4.0, // layer 2
            3.0, 0.5, // layer 3
        ];
        let dv = [9.0, 9.0, 6.0, 0.2, 5.0, 5.0, 0.3, 4.0];
        hd.accumulate(&dk.map(|x| x as f32), &dv.map(|x| x as f32));
        hd.finalize()
    }

    #[test]
    fn threshold_selection_ignores_layer0() {
        let sel = fake_stats().select_by_threshold(1.0);
        assert!(!sel.reuse_k[0][0] && !sel.reuse_k[0][1]);
        assert!(sel.reuse_k[1][0]);
        assert!(sel.reuse_k[3][1]);
        assert!(sel.reuse_v[1][1]);
        assert!(sel.reuse_v[3][0]);
        assert_eq!(sel.count_k(), 2);
        assert_eq!(sel.count_v(), 2);
    }

    #[test]
    fn top_n_selects_most_similar() {
        let sel = fake_stats().select_top(1, 2);
        assert_eq!(sel.count_k(), 1);
        assert!(sel.reuse_k[1][0]); // distance 0.1 is the global K min
        assert_eq!(sel.count_v(), 2);
        assert!(sel.reuse_v[1][1] && sel.reuse_v[3][0]);
    }

    #[test]
    fn budget_threshold_consistent_with_top_n() {
        let hd = fake_stats();
        let th = hd.threshold_for_budget(true, 2);
        let by_th = hd.select_by_threshold(th);
        assert_eq!(by_th.count_k(), 2);
    }

    #[test]
    fn accumulate_averages() {
        let mut hd = HeadDistances::new(2, 1);
        hd.accumulate(&[0.0, 2.0], &[0.0, 4.0]);
        hd.accumulate(&[0.0, 4.0], &[0.0, 8.0]);
        let hd = hd.finalize();
        assert_eq!(hd.dk[1][0], 3.0);
        assert_eq!(hd.dv[1][0], 6.0);
    }

    #[test]
    fn alternating_upper_bound() {
        let s = Selection::all_alternating(6, 4, true, true);
        assert_eq!(s.count_k(), 12);
        assert!(!s.reuse_k[0].iter().any(|&b| b));
        assert!(s.reuse_k[1].iter().all(|&b| b));
        assert!(!s.reuse_k[2].iter().any(|&b| b));
    }
}
