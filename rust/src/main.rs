//! kvcar — KV-CAR coordinator CLI.
//!
//! Subcommands:
//!   info                      artifact + model inventory
//!   pretrain                  base-LM pretraining (stage 0)
//!   train-ae                  Alg. 1 staged autoencoder training
//!   analyze                   Alg. 2 head-similarity analysis
//!   train-reuse               Alg. 2 reuse finetuning
//!   eval                      perplexity / zero-shot under a plan
//!   serve                     demo serve of a synthetic workload
//!   memplan                   Fig. 2/3 OOM-frontier table
//!
//! Common flags: --model gpt2t|tinyllama_t  --artifacts DIR  --seed N

use anyhow::{anyhow, Result};
use kvcar::compress::planner::{self, to_masks};
use kvcar::compress::similarity::Selection;
use kvcar::coordinator::{GenRequest, Router, RouterConfig, Sampling, ServeConfig, ServingEngine};
use kvcar::data::corpus;
use kvcar::data::tasks::Task;
use kvcar::eval::{perplexity, zero_shot};
use kvcar::memsim::{frontier, FigureCompression, GpuModel, FIGURE_BATCHES};
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::model::ModelSpec;
use kvcar::runtime::{Engine, Store};
use kvcar::train::{TrainConfig, Trainer};
use kvcar::util::cli::Args;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts(args: &Args) -> PathBuf {
    args.opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(kvcar::runtime::artifacts_dir)
}

fn plan_from_args(args: &Args, spec: &ModelSpec) -> CompressionPlan {
    let mut plan = CompressionPlan::ae_first_layers(spec, args.usize("ae-layers", 0));
    if args.bool("quant") {
        plan.quant_int8 = true;
    }
    if args.bool("reuse-all-alternating") {
        let sel = Selection::all_alternating(spec.n_layer, spec.n_kv_head, true, true);
        plan = planner::with_selection(plan, &sel);
    }
    plan
}

fn run(args: &Args) -> Result<()> {
    let model = args.str("model", "gpt2t");
    match args.command.as_deref() {
        Some("info") => {
            let engine = Engine::new(&artifacts(args))?;
            println!("models: {:?}", engine.manifest.models);
            for (name, e) in &engine.manifest.entries {
                println!(
                    "  {name:<32} {} in / {} out",
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
            Ok(())
        }
        Some("pretrain") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let mut c = corpus::by_name(&args.str("corpus", "wiki"), args.u64("seed", 0))
                .ok_or_else(|| anyhow!("unknown corpus"))?;
            let log = tr.pretrain(&mut c, args.usize("steps", 300))?;
            println!(
                "pretrain: {:.4} -> {:.4} in {} ms",
                log.first(),
                log.last(),
                log.wall_ms
            );
            tr.checkpoint(&PathBuf::from(args.str("out", "checkpoints")), "pretrained")?;
            Ok(())
        }
        Some("train-ae") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "pretrained"))?;
            let mut c = corpus::by_name(&args.str("corpus", "wiki"), args.u64("seed", 0))
                .ok_or_else(|| anyhow!("unknown corpus"))?;
            let n = args.usize("ae-layers", tr.spec.n_layer / 2);
            let layers: Vec<usize> = (0..n).collect();
            tr.ae_stage1(&mut c, &layers, args.usize("stage1-steps", 60))?;
            tr.ae_stage2(&mut c, &layers, args.usize("stage2-steps", 120))?;
            tr.checkpoint(&ckpt, "ae")?;
            println!("saved checkpoint 'ae'");
            Ok(())
        }
        Some("analyze") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "pretrained")).ok();
            let mut c = corpus::by_name("wiki", args.u64("seed", 0)).unwrap();
            let hd = tr.analyze_heads(&mut c, args.usize("batches", 4))?;
            println!("adjacent-layer head L1 distances (K):");
            for l in 1..hd.n_layer {
                let row: Vec<String> = hd.dk[l].iter().map(|d| format!("{d:.4}")).collect();
                println!("  layer {l:>2}: {}", row.join("  "));
            }
            Ok(())
        }
        Some("train-reuse") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "ae"))?;
            let mut c = corpus::by_name("wiki", args.u64("seed", 0)).unwrap();
            let hd = tr.analyze_heads(&mut c, 4)?;
            let sel = hd.select_top(args.usize("reuse-k", 2), args.usize("reuse-v", 2));
            let plan = planner::with_selection(plan_from_args(args, &tr.spec), &sel);
            tr.reuse_finetune(&mut c, &to_masks(&plan), args.usize("steps", 120))?;
            tr.checkpoint(&ckpt, "reuse")?;
            println!("saved checkpoint 'reuse'");
            Ok(())
        }
        Some("eval") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut store = Store::new();
            engine.load_params(&model, &mut store)?;
            let spec = ModelSpec::from_manifest(&engine.manifest.raw, &model)?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            if let Some(tag) = args.opt("from") {
                store.load_params(
                    &ckpt.join(format!("{model}_{tag}.bin")),
                    &ckpt.join(format!("{model}_{tag}.json")),
                )?;
            }
            let plan = plan_from_args(args, &spec);
            let masks = to_masks(&plan);
            let which = args.str("dataset", "wiki");
            match which.as_str() {
                "wiki" | "c4" => {
                    let mut c = corpus::by_name(&which, args.u64("seed", 1)).unwrap();
                    let ppl = perplexity(
                        &mut engine,
                        &mut store,
                        &spec,
                        &model,
                        &mut c,
                        args.usize("batches", 8),
                        &masks,
                    )?;
                    println!(
                        "{model} {which}: ppl {ppl:.3}  (savings {:.2}%)",
                        plan_savings(&spec, &plan) * 100.0
                    );
                }
                "piqa" | "wino" => {
                    let task = Task::by_name(&which).unwrap();
                    let r = zero_shot(
                        &mut engine,
                        &mut store,
                        &spec,
                        &model,
                        task,
                        args.usize("items", 200),
                        args.u64("seed", 1),
                        &masks,
                    )?;
                    println!(
                        "{model} {which}: acc {:.4} ({}/{})  (savings {:.2}%)",
                        r.accuracy(),
                        r.correct,
                        r.items,
                        plan_savings(&spec, &plan) * 100.0
                    );
                }
                other => return Err(anyhow!("unknown dataset {other}")),
            }
            Ok(())
        }
        Some("serve") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let spec = ModelSpec::from_manifest(&engine.manifest.raw, &model)?;
            let plan = plan_from_args(args, &spec);
            println!(
                "serving {} with plan: {} AE layers, {} reused heads, int8={} (savings {:.1}%)",
                model,
                plan.n_ae_layers(),
                plan.n_reused_heads(),
                plan.quant_int8,
                plan_savings(&spec, &plan) * 100.0
            );
            // --faithful routes through ServeConfig::faithful, which
            // pins lossless f32 raw rows (f16 rounding would silently
            // break its bit-exactness vs the in-graph path); --raw-f32
            // forces f32 for the in-graph mode too
            let base = if args.bool("faithful") {
                ServeConfig::faithful(plan)
            } else {
                ServeConfig::new(plan)
            };
            let cfg = ServeConfig {
                max_batch: args.usize("batch", 8),
                seed: args.u64("seed", 0),
                cache_budget: args.opt("cache-budget").and_then(|v| v.parse().ok()),
                // --copy-staging selects the legacy per-round full-copy
                // k/v staging (perf A/B against the resident default)
                resident_cache: !args.bool("copy-staging"),
                // --no-device-residency forces a full device upload of
                // the resident k/v regions every round instead of
                // dirty-span delta patches (host→device byte A/B;
                // outputs are identical)
                device_residency: !args.bool("no-device-residency"),
                // --per-request-prefill forces one prefill launch per
                // admitted request (launch-count A/B against the
                // batched admission-wave default)
                batched_prefill: !args.bool("per-request-prefill"),
                // --no-prefix-sharing disables cross-request prompt
                // dedup and prefix-chunk reuse (the O(requests)
                // launch/byte baseline; outputs are identical)
                prefix_sharing: !args.bool("no-prefix-sharing"),
                raw_format: if args.bool("raw-f32") {
                    kvcar::kvcache::Format::F32
                } else {
                    base.raw_format
                },
                // --template-budget caps the admission template cache's
                // host bytes (default 64 MiB)
                template_byte_budget: args.usize("template-budget", base.template_byte_budget),
                ..base
            };
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            let mut c = corpus::wiki(args.u64("seed", 0));
            let n = args.usize("requests", 16);
            let reqs: Vec<GenRequest> = (0..n)
                .map(|i| {
                    let prompt = c.tokens(args.usize("prompt-len", 24));
                    GenRequest {
                        id: i as u64,
                        prompt,
                        max_new_tokens: args.usize("max-new", 32),
                        sampling: Sampling::Greedy,
                        stop_byte: None,
                        arrival: None,
                    }
                })
                .collect();
            // --workers N serves the workload sharded: N router workers
            // (one engine each over the same artifacts), hash-affinity
            // placement, and live-migration rebalance (DESIGN.md §10)
            let workers = args.usize("workers", 1);
            if workers > 1 {
                let dir = artifacts(args);
                let mut extra: Vec<Engine> = (1..workers)
                    .map(|_| Engine::new(&dir))
                    .collect::<Result<_>>()?;
                let mut backends: Vec<&mut dyn kvcar::runtime::backend::ExecBackend> =
                    Vec::with_capacity(workers);
                backends.push(&mut engine);
                for e in extra.iter_mut() {
                    backends.push(e);
                }
                let mut router = Router::new(backends, &model, cfg, RouterConfig::default())?;
                if let Some(tag) = args.opt("from") {
                    for w in 0..router.n_workers() {
                        router.engine_mut(w).store.load_params(
                            &ckpt.join(format!("{model}_{tag}.bin")),
                            &ckpt.join(format!("{model}_{tag}.json")),
                        )?;
                    }
                }
                let responses = router.run(reqs)?;
                for r in responses.iter().take(3) {
                    println!("  req {}: {:?}", r.id, String::from_utf8_lossy(&r.output));
                }
                for w in 0..router.n_workers() {
                    router.engine(w).metrics.print_summary(&format!("{model} worker {w}"));
                }
                let st = router.stats();
                println!(
                    "  router: {} migrations ({} rebalance, {} failed), \
                     {:.1} KiB delta shipped / {:.1} KiB basis-saved, \
                     {} placements overridden",
                    st.migrations,
                    st.rebalance_migrations,
                    st.failed_migrations,
                    st.delta_bytes as f64 / 1024.0,
                    st.bytes_saved as f64 / 1024.0,
                    st.placements_overridden
                );
                return Ok(());
            }
            let mut serving = ServingEngine::new(&mut engine, &model, cfg)?;
            if let Some(tag) = args.opt("from") {
                serving.store.load_params(
                    &ckpt.join(format!("{model}_{tag}.bin")),
                    &ckpt.join(format!("{model}_{tag}.json")),
                )?;
            }
            let responses = serving.run(reqs)?;
            for r in responses.iter().take(3) {
                println!("  req {}: {:?}", r.id, String::from_utf8_lossy(&r.output));
            }
            serving.metrics.print_summary(&model);
            let ps = serving.cache.pool_stats();
            println!(
                "  cache peak bytes {} (recycles {})",
                ps.peak_live_bytes, ps.recycles
            );
            Ok(())
        }
        Some("memplan") => {
            let spec = match args.str("paper-model", "gpt2-774m").as_str() {
                "gpt2-774m" => kvcar::model::gpt2_774m(),
                "tinyllama-1.1b" => kvcar::model::tinyllama_1_1b(),
                other => return Err(anyhow!("unknown paper model {other}")),
            };
            let gpu = GpuModel::a40_for(&spec);
            println!(
                "max sequence length before OOM — {} on {}",
                spec.name, gpu.name
            );
            print!("{:>8}", "batch");
            for c in FigureCompression::all() {
                print!("{:>18}", c.label());
            }
            println!();
            for &b in &FIGURE_BATCHES {
                print!("{b:>8}");
                for c in FigureCompression::all() {
                    let f = frontier(&gpu, &spec, c.ratio(), &[b]);
                    print!("{:>18}", f[0].max_seq);
                }
                println!();
            }
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown command '{other}' (see src/main.rs docs)")),
        None => {
            println!("kvcar — see `rust/src/main.rs` header for subcommands");
            Ok(())
        }
    }
}
