//! kvcar — KV-CAR coordinator CLI.
//!
//! Subcommands:
//!   info                      artifact + model inventory
//!   pretrain                  base-LM pretraining (stage 0)
//!   train-ae                  Alg. 1 staged autoencoder training
//!   analyze                   Alg. 2 head-similarity analysis
//!   train-reuse               Alg. 2 reuse finetuning
//!   eval                      perplexity / zero-shot under a plan
//!   serve                     demo serve of a synthetic workload
//!   autotune                  sweep adaptive plan manifests, emit the
//!                             bytes-vs-accuracy Pareto frontier into
//!                             BENCH_plans.json (--out overrides)
//!   memplan                   Fig. 2/3 OOM-frontier table
//!
//! Common flags: --model gpt2t|tinyllama_t  --artifacts DIR  --seed N

use anyhow::{anyhow, Result};
use kvcar::compress::planner::{self, candidate_manifests, to_masks};
use kvcar::compress::similarity::Selection;
use kvcar::compress::strategy::PlanManifest;
use kvcar::coordinator::{
    scenario_spec, GenRequest, GenResponse, Router, RouterConfig, Sampling, ServeConfig,
    ServingEngine,
};
use kvcar::data::corpus;
use kvcar::data::tasks::Task;
use kvcar::eval::{perplexity, zero_shot};
use kvcar::kvcache::{CacheConfig, CacheManager, Format, Side, StoredRows};
use kvcar::memsim::{frontier, FigureCompression, GpuModel, FIGURE_BATCHES};
use kvcar::model::memory::{plan_savings, CompressionPlan};
use kvcar::model::ModelSpec;
use kvcar::runtime::backend::ExecBackend;
use kvcar::runtime::{Engine, MockEngine, Store};
use kvcar::train::{TrainConfig, Trainer};
use kvcar::util::cli::Args;
use kvcar::util::json::{self, Json};
use kvcar::util::rng::Rng;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts(args: &Args) -> PathBuf {
    args.opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(kvcar::runtime::artifacts_dir)
}

fn plan_from_args(args: &Args, spec: &ModelSpec) -> CompressionPlan {
    let mut plan = CompressionPlan::ae_first_layers(spec, args.usize("ae-layers", 0));
    if args.bool("quant") {
        plan.quant_int8 = true;
    }
    if args.bool("reuse-all-alternating") {
        let sel = Selection::all_alternating(spec.n_layer, spec.n_kv_head, true, true);
        plan = planner::with_selection(plan, &sel);
    }
    plan
}

fn run(args: &Args) -> Result<()> {
    let model = args.str("model", "gpt2t");
    match args.command.as_deref() {
        Some("info") => {
            let engine = Engine::new(&artifacts(args))?;
            println!("models: {:?}", engine.manifest.models);
            for (name, e) in &engine.manifest.entries {
                println!(
                    "  {name:<32} {} in / {} out",
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
            Ok(())
        }
        Some("pretrain") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let mut c = corpus::by_name(&args.str("corpus", "wiki"), args.u64("seed", 0))
                .ok_or_else(|| anyhow!("unknown corpus"))?;
            let log = tr.pretrain(&mut c, args.usize("steps", 300))?;
            println!(
                "pretrain: {:.4} -> {:.4} in {} ms",
                log.first(),
                log.last(),
                log.wall_ms
            );
            tr.checkpoint(&PathBuf::from(args.str("out", "checkpoints")), "pretrained")?;
            Ok(())
        }
        Some("train-ae") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "pretrained"))?;
            let mut c = corpus::by_name(&args.str("corpus", "wiki"), args.u64("seed", 0))
                .ok_or_else(|| anyhow!("unknown corpus"))?;
            let n = args.usize("ae-layers", tr.spec.n_layer / 2);
            let layers: Vec<usize> = (0..n).collect();
            tr.ae_stage1(&mut c, &layers, args.usize("stage1-steps", 60))?;
            tr.ae_stage2(&mut c, &layers, args.usize("stage2-steps", 120))?;
            tr.checkpoint(&ckpt, "ae")?;
            println!("saved checkpoint 'ae'");
            Ok(())
        }
        Some("analyze") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "pretrained")).ok();
            let mut c = corpus::by_name("wiki", args.u64("seed", 0)).unwrap();
            let hd = tr.analyze_heads(&mut c, args.usize("batches", 4))?;
            println!("adjacent-layer head L1 distances (K):");
            for l in 1..hd.n_layer {
                let row: Vec<String> = hd.dk[l].iter().map(|d| format!("{d:.4}")).collect();
                println!("  layer {l:>2}: {}", row.join("  "));
            }
            Ok(())
        }
        Some("train-reuse") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut tr = Trainer::new(&mut engine, &model, TrainConfig::default())?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            tr.restore(&ckpt, &args.str("from", "ae"))?;
            let mut c = corpus::by_name("wiki", args.u64("seed", 0)).unwrap();
            let hd = tr.analyze_heads(&mut c, 4)?;
            let sel = hd.select_top(args.usize("reuse-k", 2), args.usize("reuse-v", 2));
            let plan = planner::with_selection(plan_from_args(args, &tr.spec), &sel);
            tr.reuse_finetune(&mut c, &to_masks(&plan), args.usize("steps", 120))?;
            tr.checkpoint(&ckpt, "reuse")?;
            println!("saved checkpoint 'reuse'");
            Ok(())
        }
        Some("eval") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let mut store = Store::new();
            engine.load_params(&model, &mut store)?;
            let spec = ModelSpec::from_manifest(&engine.manifest.raw, &model)?;
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            if let Some(tag) = args.opt("from") {
                store.load_params(
                    &ckpt.join(format!("{model}_{tag}.bin")),
                    &ckpt.join(format!("{model}_{tag}.json")),
                )?;
            }
            let plan = plan_from_args(args, &spec);
            let masks = to_masks(&plan);
            let which = args.str("dataset", "wiki");
            match which.as_str() {
                "wiki" | "c4" => {
                    let mut c = corpus::by_name(&which, args.u64("seed", 1)).unwrap();
                    let ppl = perplexity(
                        &mut engine,
                        &mut store,
                        &spec,
                        &model,
                        &mut c,
                        args.usize("batches", 8),
                        &masks,
                    )?;
                    println!(
                        "{model} {which}: ppl {ppl:.3}  (savings {:.2}%)",
                        plan_savings(&spec, &plan) * 100.0
                    );
                }
                "piqa" | "wino" => {
                    let task = Task::by_name(&which).unwrap();
                    let r = zero_shot(
                        &mut engine,
                        &mut store,
                        &spec,
                        &model,
                        task,
                        args.usize("items", 200),
                        args.u64("seed", 1),
                        &masks,
                    )?;
                    println!(
                        "{model} {which}: acc {:.4} ({}/{})  (savings {:.2}%)",
                        r.accuracy(),
                        r.correct,
                        r.items,
                        plan_savings(&spec, &plan) * 100.0
                    );
                }
                other => return Err(anyhow!("unknown dataset {other}")),
            }
            Ok(())
        }
        Some("serve") => {
            let mut engine = Engine::new(&artifacts(args))?;
            let spec = ModelSpec::from_manifest(&engine.manifest.raw, &model)?;
            let plan = plan_from_args(args, &spec);
            println!(
                "serving {} with plan: {} AE layers, {} reused heads, int8={} (savings {:.1}%)",
                model,
                plan.n_ae_layers(),
                plan.n_reused_heads(),
                plan.quant_int8,
                plan_savings(&spec, &plan) * 100.0
            );
            // --faithful routes through ServeConfig::faithful, which
            // pins lossless f32 raw rows (f16 rounding would silently
            // break its bit-exactness vs the in-graph path); --raw-f32
            // forces f32 for the in-graph mode too
            let base = if args.bool("faithful") {
                ServeConfig::faithful(plan)
            } else {
                ServeConfig::new(plan)
            };
            let cfg = ServeConfig {
                max_batch: args.usize("batch", 8),
                seed: args.u64("seed", 0),
                cache_budget: args.opt("cache-budget").and_then(|v| v.parse().ok()),
                // --copy-staging selects the legacy per-round full-copy
                // k/v staging (perf A/B against the resident default)
                resident_cache: !args.bool("copy-staging"),
                // --no-device-residency forces a full device upload of
                // the resident k/v regions every round instead of
                // dirty-span delta patches (host→device byte A/B;
                // outputs are identical)
                device_residency: !args.bool("no-device-residency"),
                // --per-request-prefill forces one prefill launch per
                // admitted request (launch-count A/B against the
                // batched admission-wave default)
                batched_prefill: !args.bool("per-request-prefill"),
                // --no-prefix-sharing disables cross-request prompt
                // dedup and prefix-chunk reuse (the O(requests)
                // launch/byte baseline; outputs are identical)
                prefix_sharing: !args.bool("no-prefix-sharing"),
                raw_format: if args.bool("raw-f32") {
                    kvcar::kvcache::Format::F32
                } else {
                    base.raw_format
                },
                // --template-budget caps the admission template cache's
                // host bytes (default 64 MiB)
                template_byte_budget: args.usize("template-budget", base.template_byte_budget),
                ..base
            };
            let ckpt = PathBuf::from(args.str("checkpoints", "checkpoints"));
            let mut c = corpus::wiki(args.u64("seed", 0));
            let n = args.usize("requests", 16);
            let reqs: Vec<GenRequest> = (0..n)
                .map(|i| {
                    let prompt = c.tokens(args.usize("prompt-len", 24));
                    GenRequest {
                        id: i as u64,
                        prompt,
                        max_new_tokens: args.usize("max-new", 32),
                        sampling: Sampling::Greedy,
                        stop_byte: None,
                        arrival: None,
                    }
                })
                .collect();
            // --workers N serves the workload sharded: N router workers
            // (one engine each over the same artifacts), hash-affinity
            // placement, and live-migration rebalance (DESIGN.md §10)
            let workers = args.usize("workers", 1);
            if workers > 1 {
                let dir = artifacts(args);
                let mut extra: Vec<Engine> = (1..workers)
                    .map(|_| Engine::new(&dir))
                    .collect::<Result<_>>()?;
                let mut backends: Vec<&mut dyn kvcar::runtime::backend::ExecBackend> =
                    Vec::with_capacity(workers);
                backends.push(&mut engine);
                for e in extra.iter_mut() {
                    backends.push(e);
                }
                let mut router = Router::new(backends, &model, cfg, RouterConfig::default())?;
                if let Some(tag) = args.opt("from") {
                    for w in 0..router.n_workers() {
                        router.engine_mut(w).store.load_params(
                            &ckpt.join(format!("{model}_{tag}.bin")),
                            &ckpt.join(format!("{model}_{tag}.json")),
                        )?;
                    }
                }
                let responses = router.run(reqs)?;
                for r in responses.iter().take(3) {
                    println!("  req {}: {:?}", r.id, String::from_utf8_lossy(&r.output));
                }
                for w in 0..router.n_workers() {
                    router.engine(w).metrics.print_summary(&format!("{model} worker {w}"));
                }
                let st = router.stats();
                println!(
                    "  router: {} migrations ({} rebalance, {} failed), \
                     {:.1} KiB delta shipped / {:.1} KiB basis-saved, \
                     {} placements overridden",
                    st.migrations,
                    st.rebalance_migrations,
                    st.failed_migrations,
                    st.delta_bytes as f64 / 1024.0,
                    st.bytes_saved as f64 / 1024.0,
                    st.placements_overridden
                );
                return Ok(());
            }
            let mut serving = ServingEngine::new(&mut engine, &model, cfg)?;
            if let Some(tag) = args.opt("from") {
                serving.store.load_params(
                    &ckpt.join(format!("{model}_{tag}.bin")),
                    &ckpt.join(format!("{model}_{tag}.json")),
                )?;
            }
            let responses = serving.run(reqs)?;
            for r in responses.iter().take(3) {
                println!("  req {}: {:?}", r.id, String::from_utf8_lossy(&r.output));
            }
            serving.metrics.print_summary(&model);
            let ps = serving.cache.pool_stats();
            println!(
                "  cache peak bytes {} (recycles {})",
                ps.peak_live_bytes, ps.recycles
            );
            Ok(())
        }
        Some("autotune") => autotune(args, &model),
        Some("memplan") => {
            let spec = match args.str("paper-model", "gpt2-774m").as_str() {
                "gpt2-774m" => kvcar::model::gpt2_774m(),
                "tinyllama-1.1b" => kvcar::model::tinyllama_1_1b(),
                other => return Err(anyhow!("unknown paper model {other}")),
            };
            let gpu = GpuModel::a40_for(&spec);
            println!(
                "max sequence length before OOM — {} on {}",
                spec.name, gpu.name
            );
            print!("{:>8}", "batch");
            for c in FigureCompression::all() {
                print!("{:>18}", c.label());
            }
            println!();
            for &b in &FIGURE_BATCHES {
                print!("{b:>8}");
                for c in FigureCompression::all() {
                    let f = frontier(&gpu, &spec, c.ratio(), &[b]);
                    print!("{:>18}", f[0].max_seq);
                }
                println!();
            }
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown command '{other}' (see src/main.rs docs)")),
        None => {
            println!("kvcar — see `rust/src/main.rs` header for subcommands");
            Ok(())
        }
    }
}

/// One measured point of the autotune sweep: a candidate manifest's
/// bytes and accuracy on one backend.
struct PlanRow {
    name: &'static str,
    /// peak live cache-pool bytes over the serving run (measured, not
    /// modelled — the plan-coherence invariant pins the two together)
    bytes: usize,
    /// fraction of generated token positions agreeing with the raw-f32
    /// reference manifest's run (1.0 for the reference itself)
    agreement: f64,
    /// RMS error of stored rows read back against the exact rows
    /// appended — the logits-delta proxy measurable without a model
    rms: f64,
    pareto: bool,
    manifest_json: String,
}

/// Serve a fixed greedy workload under `manifest` in faithful mode
/// (per-step reconstruction re-reads stored rows every round, so the
/// storage rungs are *observable in the tokens*) and measure peak
/// cache bytes.  Responses come back sorted by request id.
fn serve_manifest(
    engine: &mut dyn ExecBackend,
    model: &str,
    spec: &ModelSpec,
    manifest: &PlanManifest,
    seed: u64,
) -> Result<(Vec<GenResponse>, usize)> {
    let mut cfg = ServeConfig::faithful(CompressionPlan::none(spec.n_layer, spec.n_kv_head));
    cfg.seed = seed;
    cfg.max_batch = 4;
    cfg.adaptive_plan = Some(manifest.clone());
    let mut serving = ServingEngine::new(engine, model, cfg)?;
    let mut c = corpus::wiki(seed);
    let prompt_len = (spec.max_seq / 2).min(24).max(1);
    let max_new = (spec.max_seq / 4).min(16).max(1);
    let reqs: Vec<GenRequest> = (0..8u64)
        .map(|i| GenRequest::greedy(i, &c.tokens(prompt_len), max_new))
        .collect();
    let mut responses = serving.run(reqs)?;
    responses.sort_by_key(|r| r.id);
    let bytes = serving.cache.pool_stats().peak_live_bytes;
    Ok((responses, bytes))
}

/// Token agreement against the reference run: matching positions over
/// reference positions, id-matched (greedy sampling, so any divergence
/// is storage-rung loss surfacing through faithful reconstruction).
fn token_agreement(reference: &[GenResponse], got: &[GenResponse]) -> f64 {
    let (mut hits, mut total) = (0usize, 0usize);
    for r in reference {
        let out = got
            .iter()
            .find(|g| g.id == r.id)
            .map(|g| g.output.as_slice())
            .unwrap_or(&[]);
        total += r.output.len();
        hits += r
            .output
            .iter()
            .zip(out)
            .filter(|(a, b)| a == b)
            .count();
    }
    if total == 0 {
        1.0
    } else {
        hits as f64 / total as f64
    }
}

/// Stored-row reconstruction RMS under `manifest`: append a full
/// deterministic gaussian sequence through the adaptive layouts, read
/// every stream back, and compare against exactly what went in.  Raw
/// f32 rungs come back at 0; f16/int8 rungs report their quantization
/// loss — the accuracy axis that needs no model at all.
fn rung_rms(spec: &ModelSpec, manifest: &PlanManifest) -> Result<f64> {
    let mut ccfg = CacheConfig::new(spec.clone(), manifest.plan.clone());
    ccfg.raw_format = Format::F32;
    ccfg.regions = manifest.regions.clone();
    let mut m = CacheManager::new(ccfg);
    let id = m.create_sequence();
    let (l, dl, kvd, dh) = (spec.n_layer, spec.ae_latent, spec.kv_dim(), spec.d_head);
    let n = spec.max_seq.min(48);
    let mut rng = Rng::new(0xA070);
    let mut fill = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    };
    let k_lat = fill(l * n * dl);
    let v_lat = fill(l * n * dl);
    let k_raw = fill(l * n * kvd);
    let v_raw = fill(l * n * kvd);
    m.append_rows(id, n, n, &k_lat, &v_lat, &k_raw, &v_raw)?;
    let (mut err, mut count) = (0.0f64, 0usize);
    for layer in 0..l {
        for (side, lat, raw) in [(Side::K, &k_lat, &k_raw), (Side::V, &v_lat, &v_raw)] {
            match m.stored_rows(id, layer, side)? {
                StoredRows::Alias => {}
                StoredRows::Latent(v) => {
                    let base = layer * n * dl;
                    for (i, &x) in v.iter().enumerate() {
                        let d = f64::from(x - lat[base + i]);
                        err += d * d;
                    }
                    count += v.len();
                }
                StoredRows::Heads(v, heads) => {
                    let w = heads.len() * dh;
                    for t in 0..n {
                        for (hi, &h) in heads.iter().enumerate() {
                            for e in 0..dh {
                                let stored = v[t * w + hi * dh + e];
                                let orig = raw[layer * n * kvd + t * kvd + h * dh + e];
                                let d = f64::from(stored - orig);
                                err += d * d;
                            }
                        }
                    }
                    count += n * w;
                }
            }
        }
    }
    Ok(if count == 0 {
        0.0
    } else {
        (err / count as f64).sqrt()
    })
}

/// Sweep every candidate manifest on one backend: the first candidate
/// (uniform raw f32) is the accuracy reference the rest are scored
/// against.
fn sweep_manifests(
    engine: &mut dyn ExecBackend,
    model: &str,
    spec: &ModelSpec,
    cands: &[(&'static str, PlanManifest)],
    seed: u64,
) -> Result<Vec<PlanRow>> {
    let mut rows: Vec<PlanRow> = Vec::new();
    let mut reference: Option<Vec<GenResponse>> = None;
    for &(name, ref manifest) in cands {
        let (responses, bytes) = serve_manifest(engine, model, spec, manifest, seed)?;
        let agreement = match &reference {
            None => 1.0,
            Some(r) => token_agreement(r, &responses),
        };
        if reference.is_none() {
            reference = Some(responses);
        }
        rows.push(PlanRow {
            name,
            bytes,
            agreement,
            rms: rung_rms(spec, manifest)?,
            pareto: false,
            manifest_json: manifest.to_json(),
        });
    }
    mark_pareto(&mut rows);
    Ok(rows)
}

/// Mark the Pareto frontier over (bytes ↓, agreement ↑, rms ↓): a row
/// is on the frontier unless some other row is at least as good on all
/// three axes and strictly better on one.
fn mark_pareto(rows: &mut [PlanRow]) {
    let flags: Vec<bool> = rows
        .iter()
        .map(|a| {
            !rows.iter().any(|b| {
                b.bytes <= a.bytes
                    && b.agreement >= a.agreement
                    && b.rms <= a.rms
                    && (b.bytes < a.bytes || b.agreement > a.agreement || b.rms < a.rms)
            })
        })
        .collect();
    for (row, on) in rows.iter_mut().zip(flags) {
        row.pareto = on;
    }
}

fn plan_row_json(r: &PlanRow) -> Result<Json> {
    let manifest = Json::parse(&r.manifest_json)
        .map_err(|e| anyhow!("candidate {} manifest json: {e}", r.name))?;
    Ok(json::obj(vec![
        ("name", json::s(r.name)),
        ("bytes", json::num(r.bytes as f64)),
        ("token_agreement", json::num(r.agreement)),
        ("reconstruction_rms", json::num(r.rms)),
        ("pareto", Json::Bool(r.pareto)),
        ("manifest", manifest),
    ]))
}

/// Print run-over-run deltas against the previous BENCH_plans.json
/// (mirrors the bench writers: any movement here is a policy change,
/// not machine noise — the whole sweep is deterministic).
fn report_plan_deltas(prev: &Json, key: &str, rows: &[PlanRow]) {
    let Some(prev_rows) = prev.get(key).and_then(Json::as_arr) else {
        return;
    };
    for r in rows {
        let Some(old) = prev_rows
            .iter()
            .find(|p| p.get("name").and_then(Json::as_str) == Some(r.name))
        else {
            continue;
        };
        for (field, new_v) in [
            ("bytes", r.bytes as f64),
            ("token_agreement", r.agreement),
            ("reconstruction_rms", r.rms),
        ] {
            if let Some(old_v) = old.get(field).and_then(Json::as_f64) {
                if old_v > 0.0 && (old_v - new_v).abs() > 1e-9 {
                    println!(
                        "autotune {key}/{:<18} vs previous: {field} {:+.1}% ({:.4} -> {:.4})",
                        r.name,
                        100.0 * (new_v - old_v) / old_v,
                        old_v,
                        new_v,
                    );
                }
            }
        }
    }
}

/// `kvcar autotune`: sweep the candidate adaptive manifests against
/// measured bytes and accuracy (token agreement + stored-row RMS vs
/// the raw-f32 reference) on the mock backend — plus the real artifact
/// backend when artifacts are present — and write the Pareto frontier
/// to BENCH_plans.json (DESIGN.md §11; `examples/README.md` shows the
/// autotune-then-serve workflow reading it back).
fn autotune(args: &Args, model: &str) -> Result<()> {
    let out_path = args.str("out", "BENCH_plans.json");
    let seed = args.u64("seed", 0);
    let spec = scenario_spec();
    let block_size = CacheConfig::new(
        spec.clone(),
        CompressionPlan::none(spec.n_layer, spec.n_kv_head),
    )
    .block_size;
    let cands = candidate_manifests(&spec, block_size);
    let mut mock = MockEngine::new(spec.clone());
    let rows = sweep_manifests(&mut mock, "mock", &spec, &cands, seed)?;
    for r in &rows {
        println!(
            "autotune mock/{:<18} {:>8} B  agreement {:.4}  rms {:.5}{}",
            r.name,
            r.bytes,
            r.agreement,
            r.rms,
            if r.pareto { "  [pareto]" } else { "" },
        );
    }

    // artifact-gated real leg: identical sweep over the PJRT artifact
    // backend; absent artifacts the mock leg alone runs, never skipped
    let mut engine_rows: Vec<PlanRow> = Vec::new();
    let dir = artifacts(args);
    if dir.join("manifest.json").exists() {
        let mut engine = Engine::new(&dir)?;
        let espec = ModelSpec::from_manifest(&engine.manifest.raw, model)?;
        let ecands = candidate_manifests(&espec, block_size);
        engine_rows = sweep_manifests(&mut engine, model, &espec, &ecands, seed)?;
        for r in &engine_rows {
            println!(
                "autotune {model}/{:<18} {:>8} B  agreement {:.4}  rms {:.5}{}",
                r.name,
                r.bytes,
                r.agreement,
                r.rms,
                if r.pareto { "  [pareto]" } else { "" },
            );
        }
    } else {
        println!("autotune: artifacts absent; real-engine leg skipped (mock leg above)");
    }

    match std::fs::read_to_string(&out_path) {
        Ok(text) => match Json::parse(&text) {
            Ok(prev) => {
                report_plan_deltas(&prev, "plans", &rows);
                report_plan_deltas(&prev, "engine_plans", &engine_rows);
            }
            Err(e) => println!("autotune: previous {out_path} unreadable ({e}); no deltas"),
        },
        Err(_) => println!("autotune: no previous run ({out_path}); deltas start next run"),
    }
    let plans = rows.iter().map(plan_row_json).collect::<Result<Vec<_>>>()?;
    let engine_plans = engine_rows
        .iter()
        .map(plan_row_json)
        .collect::<Result<Vec<_>>>()?;
    let j = json::obj(vec![
        ("version", json::num(1.0)),
        ("bench", json::s("autotune")),
        ("backend", json::s("mock")),
        ("plans", json::arr(plans)),
        ("engine_plans", json::arr(engine_plans)),
    ]);
    std::fs::write(&out_path, j.to_string())
        .map_err(|e| anyhow!("could not write {out_path}: {e}"))?;
    println!("autotune: wrote {out_path}");
    Ok(())
}
