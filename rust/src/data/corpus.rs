//! Synthetic corpora standing in for Wikitext and C4 (DESIGN.md §3).
//!
//! Byte-level text from a small agreement-bearing grammar:
//!
//! * noun *classes* (animals vs objects) constrain which adjectives and
//!   verbs may co-occur — the regularity the PIQA-like plausibility task
//!   probes;
//! * grammatical *number* (singular/plural subjects with agreeing verb
//!   forms, including across a distractor noun phrase) — the regularity
//!   the Winogrande-like agreement task probes.
//!
//! `wiki()` emits clean text; `c4()` interleaves noise (typos, junk
//! spans, random casing) at a configurable rate, reproducing the paper's
//! observation that the noisier corpus tolerates less compression.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Semantic class a noun belongs to (drives adjective choice).
pub enum NounClass {
    /// living subjects (take animate adjectives)
    Animal,
    /// inanimate subjects
    Object,
}

#[derive(Debug, Clone, Copy)]
/// One vocabulary noun with its singular/plural surface forms.
pub struct Noun {
    /// semantic class
    pub class: NounClass,
    /// singular form
    pub sing: &'static str,
    /// plural form
    pub plur: &'static str,
}

/// The corpus noun vocabulary.
pub const NOUNS: &[Noun] = &[
    Noun { class: NounClass::Animal, sing: "cat", plur: "cats" },
    Noun { class: NounClass::Animal, sing: "dog", plur: "dogs" },
    Noun { class: NounClass::Animal, sing: "fox", plur: "foxes" },
    Noun { class: NounClass::Animal, sing: "bird", plur: "birds" },
    Noun { class: NounClass::Animal, sing: "mouse", plur: "mice" },
    Noun { class: NounClass::Animal, sing: "wolf", plur: "wolves" },
    Noun { class: NounClass::Object, sing: "rock", plur: "rocks" },
    Noun { class: NounClass::Object, sing: "tree", plur: "trees" },
    Noun { class: NounClass::Object, sing: "lake", plur: "lakes" },
    Noun { class: NounClass::Object, sing: "hill", plur: "hills" },
    Noun { class: NounClass::Object, sing: "stone", plur: "stones" },
    Noun { class: NounClass::Object, sing: "river", plur: "rivers" },
];

/// Adjectives legal only for their class — the plausibility signal.
pub const ADJ_ANIMAL: &[&str] = &["furry", "wild", "hungry", "quick", "sly"];
/// Adjectives applicable to inanimate nouns.
pub const ADJ_OBJECT: &[&str] = &["grey", "tall", "deep", "mossy", "flat"];

/// Verbs as (singular, plural) agreeing forms; legal for both classes.
pub const VERBS: &[(&str, &str)] = &[
    ("rests", "rest"),
    ("waits", "wait"),
    ("stands", "stand"),
    ("shines", "shine"),
    ("falls", "fall"),
    ("turns", "turn"),
];

/// Verbs only animals perform — a second plausibility signal.
pub const VERBS_ANIMAL: &[(&str, &str)] = &[
    ("sleeps", "sleep"),
    ("runs", "run"),
    ("hides", "hide"),
    ("hunts", "hunt"),
];

/// Adjectives compatible with a noun class.
pub fn adjectives_for(class: NounClass) -> &'static [&'static str] {
    match class {
        NounClass::Animal => ADJ_ANIMAL,
        NounClass::Object => ADJ_OBJECT,
    }
}

#[derive(Debug, Clone)]
/// Deterministic synthetic text stream (seeded grammar sampler).
pub struct Corpus {
    /// corpus id ("wiki" / "c4")
    pub name: String,
    /// probability of injecting noise per sentence (0.0 for wiki-like)
    pub noise: f64,
    rng: Rng,
}

/// Wiki-flavored stream (declarative sentences).
pub fn wiki(seed: u64) -> Corpus {
    Corpus {
        name: "wiki".into(),
        noise: 0.0,
        rng: Rng::new(seed ^ 0x5741),
    }
}

/// C4-flavored stream (noisier web-like text).
pub fn c4(seed: u64) -> Corpus {
    Corpus {
        name: "c4".into(),
        noise: 0.25,
        rng: Rng::new(seed ^ 0xC4C4),
    }
}

/// Corpus by id, None for unknown names.
pub fn by_name(name: &str, seed: u64) -> Option<Corpus> {
    match name {
        "wiki" => Some(wiki(seed)),
        "c4" => Some(c4(seed)),
        _ => None,
    }
}

impl Corpus {
    /// One grammatical sentence, ending in " . ".
    pub fn sentence(&mut self) -> String {
        let r = &mut self.rng;
        let noun = *r.choice(NOUNS);
        let plural = r.bool(0.5);
        let subj = if plural { noun.plur } else { noun.sing };
        let adj = *r.choice(adjectives_for(noun.class));
        let verb_pool: Vec<(&str, &str)> = if noun.class == NounClass::Animal {
            VERBS.iter().chain(VERBS_ANIMAL).copied().collect()
        } else {
            VERBS.to_vec()
        };
        let (vs, vp) = *r.choice(&verb_pool);
        let verb = if plural { vp } else { vs };
        match r.below(3) {
            // "the furry cat sleeps ."
            0 => format!("the {adj} {subj} {verb} ."),
            // "the cats near the lake rest ."  (agreement across distractor)
            1 => {
                let d = *r.choice(NOUNS);
                let dplural = r.bool(0.5);
                let dist = if dplural { d.plur } else { d.sing };
                format!("the {subj} near the {dist} {verb} .")
            }
            // "the wild foxes hide and the rocks stand ."
            _ => {
                let n2 = *r.choice(NOUNS);
                let p2 = r.bool(0.5);
                let s2 = if p2 { n2.plur } else { n2.sing };
                let a2 = *r.choice(adjectives_for(n2.class));
                let pool2: Vec<(&str, &str)> = if n2.class == NounClass::Animal {
                    VERBS.iter().chain(VERBS_ANIMAL).copied().collect()
                } else {
                    VERBS.to_vec()
                };
                let (v2s, v2p) = *r.choice(&pool2);
                let v2 = if p2 { v2p } else { v2s };
                format!("the {adj} {subj} {verb} and the {a2} {s2} {v2} .")
            }
        }
    }

    fn apply_noise(&mut self, s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 8);
        for c in s.chars() {
            let roll = self.rng.f64();
            if roll < 0.02 {
                // typo: substitute a random lowercase letter
                out.push((b'a' + self.rng.below(26) as u8) as char);
            } else if roll < 0.03 {
                // random casing (web-scrape artifacts)
                out.extend(c.to_uppercase());
            } else if roll < 0.035 {
                // junk span
                let junk: [&str; 5] = ["&amp;", "http", "...", "##", "<p>"];
                out.push_str(*self.rng.choice(&junk));
                out.push(c);
            } else {
                out.push(c);
            }
        }
        out
    }

    /// Exactly `len` bytes of corpus text (sentences joined by spaces,
    /// truncated at the boundary).
    pub fn tokens(&mut self, len: usize) -> Vec<u8> {
        let mut buf = Vec::with_capacity(len + 64);
        while buf.len() < len {
            let mut s = self.sentence();
            if self.noise > 0.0 && self.rng.bool(self.noise) {
                s = self.apply_noise(&s);
            }
            buf.extend_from_slice(s.as_bytes());
            buf.push(b' ');
        }
        buf.truncate(len);
        buf
    }

    /// Empirical bits-per-byte entropy estimate over a sample (order-0).
    /// Used in tests to verify c4-like text is strictly noisier.
    pub fn entropy_estimate(&mut self, sample_bytes: usize) -> f64 {
        let data = self.tokens(sample_bytes);
        let mut counts = [0u64; 256];
        for &b in &data {
            counts[b as usize] += 1;
        }
        let n = data.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(wiki(7).tokens(256), wiki(7).tokens(256));
        assert_ne!(wiki(7).tokens(256), wiki(8).tokens(256));
    }

    #[test]
    fn exact_length_and_byte_range() {
        let t = wiki(0).tokens(1000);
        assert_eq!(t.len(), 1000);
        assert!(t.iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn wiki_sentences_are_grammatical() {
        let mut c = wiki(3);
        for _ in 0..200 {
            let s = c.sentence();
            assert!(s.starts_with("the "), "{s}");
            assert!(s.ends_with(" ."), "{s}");
            // class constraint: animal adjectives never modify object nouns
            for adj in ADJ_OBJECT {
                for n in NOUNS.iter().filter(|n| n.class == NounClass::Animal) {
                    assert!(
                        !s.contains(&format!("{adj} {}", n.sing)),
                        "class violation: {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn c4_is_noisier_than_wiki() {
        let h_wiki = wiki(1).entropy_estimate(20_000);
        let h_c4 = c4(1).entropy_estimate(20_000);
        assert!(h_c4 > h_wiki + 0.05, "wiki={h_wiki} c4={h_c4}");
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(by_name("wiki", 0).is_some());
        assert!(by_name("c4", 0).is_some());
        assert!(by_name("pile", 0).is_none());
    }
}
