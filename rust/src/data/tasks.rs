//! Synthetic zero-shot two-choice tasks standing in for PIQA and
//! Winogrande (DESIGN.md §3).
//!
//! Both are scored exactly like the real benchmarks: the model assigns a
//! total log-likelihood to each full candidate sequence and the lower-NLL
//! candidate wins.  Neither task is ever trained on — the regularities
//! they probe are only present in the pretraining corpus.
//!
//! * `piqa`-like: physical/semantic *plausibility* — which continuation is
//!   compatible with the noun's class ("the furry | cat sleeps ." vs
//!   "the furry | rock sleeps .").
//! * `wino`-like: referential *agreement* — which verb form agrees with
//!   the subject across a distractor noun phrase ("the cats near the dog
//!   | sleep ." vs "| sleeps .").

use super::corpus::{adjectives_for, NounClass, NOUNS, VERBS, VERBS_ANIMAL};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// One two-way multiple-choice item.
pub struct ChoiceItem {
    /// full candidate sequences (prompt + continuation), bytes
    pub correct: Vec<u8>,
    /// the distractor continuation
    pub wrong: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Synthetic zero-shot eval task family.
pub enum Task {
    /// physical-commonsense-style continuation pairs
    Piqa,
    /// winograd-style pronoun disambiguation pairs
    Wino,
}

impl Task {
    /// Task by id, None for unknown names.
    pub fn by_name(name: &str) -> Option<Task> {
        match name {
            "piqa" => Some(Task::Piqa),
            "wino" | "winogrande" => Some(Task::Wino),
            _ => None,
        }
    }

    /// Stable task id.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Piqa => "piqa",
            Task::Wino => "wino",
        }
    }
}

/// Generate `n` deterministic items of a task.
pub fn generate(task: Task, n: usize, seed: u64) -> Vec<ChoiceItem> {
    let mut rng = Rng::new(seed ^ 0x7A5C);
    (0..n)
        .map(|_| match task {
            Task::Piqa => piqa_item(&mut rng),
            Task::Wino => wino_item(&mut rng),
        })
        .collect()
}

/// Plausibility: adjective (and verb) must match the noun class.
fn piqa_item(rng: &mut Rng) -> ChoiceItem {
    // pick an animal noun and an object noun; the adjective + verb come
    // from the animal class, so the object continuation is implausible
    let (good_pool, bad_pool, adj_class) = if rng.bool(0.5) {
        (NounClass::Animal, NounClass::Object, NounClass::Animal)
    } else {
        (NounClass::Object, NounClass::Animal, NounClass::Object)
    };
    let good: Vec<_> = NOUNS.iter().filter(|n| n.class == good_pool).collect();
    let bad: Vec<_> = NOUNS.iter().filter(|n| n.class == bad_pool).collect();
    let gn = *rng.choice(&good);
    let bn = *rng.choice(&bad);
    let adj = *rng.choice(adjectives_for(adj_class));
    let plural = rng.bool(0.5);
    let (gs, bs) = if plural {
        (gn.plur, bn.plur)
    } else {
        (gn.sing, bn.sing)
    };
    // verbs legal for the good class keep the correct side grammatical
    let pool: Vec<(&str, &str)> = if good_pool == NounClass::Animal {
        VERBS.iter().chain(VERBS_ANIMAL).copied().collect()
    } else {
        VERBS.to_vec()
    };
    let (vs, vp) = *rng.choice(&pool);
    let verb = if plural { vp } else { vs };
    ChoiceItem {
        correct: format!("the {adj} {gs} {verb} .").into_bytes(),
        wrong: format!("the {adj} {bs} {verb} .").into_bytes(),
    }
}

/// Agreement: the verb must agree with the head noun, not the distractor.
fn wino_item(rng: &mut Rng) -> ChoiceItem {
    let noun = *rng.choice(NOUNS);
    let dist = *rng.choice(NOUNS);
    let subj_plural = rng.bool(0.5);
    // distractor takes the opposite number to make agreement non-trivial
    let subj = if subj_plural { noun.plur } else { noun.sing };
    let dn = if subj_plural { dist.sing } else { dist.plur };
    let pool: Vec<(&str, &str)> = if noun.class == NounClass::Animal {
        VERBS.iter().chain(VERBS_ANIMAL).copied().collect()
    } else {
        VERBS.to_vec()
    };
    let (vs, vp) = *rng.choice(&pool);
    let (good_v, bad_v) = if subj_plural { (vp, vs) } else { (vs, vp) };
    ChoiceItem {
        correct: format!("the {subj} near the {dn} {good_v} .").into_bytes(),
        wrong: format!("the {subj} near the {dn} {bad_v} .").into_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(Task::Piqa, 10, 3);
        let b = generate(Task::Piqa, 10, 3);
        assert_eq!(a[0].correct, b[0].correct);
        assert_eq!(a[9].wrong, b[9].wrong);
    }

    #[test]
    fn piqa_choices_differ_only_in_noun() {
        for item in generate(Task::Piqa, 50, 1) {
            assert_ne!(item.correct, item.wrong);
            let c = String::from_utf8(item.correct).unwrap();
            let w = String::from_utf8(item.wrong).unwrap();
            // same adjective prefix
            let cp: Vec<&str> = c.split(' ').collect();
            let wp: Vec<&str> = w.split(' ').collect();
            assert_eq!(cp[1], wp[1], "{c} | {w}");
            assert_ne!(cp[2], wp[2]);
        }
    }

    #[test]
    fn wino_choices_differ_only_in_verb() {
        for item in generate(Task::Wino, 50, 2) {
            let c = String::from_utf8(item.correct).unwrap();
            let w = String::from_utf8(item.wrong).unwrap();
            let cp: Vec<&str> = c.split(' ').collect();
            let wp: Vec<&str> = w.split(' ').collect();
            assert_eq!(cp[..cp.len() - 2], wp[..wp.len() - 2], "{c} | {w}");
            assert_ne!(cp[cp.len() - 2], wp[wp.len() - 2]);
        }
    }

    #[test]
    fn wino_correct_agrees_with_subject() {
        for item in generate(Task::Wino, 50, 4) {
            let c = String::from_utf8(item.correct).unwrap();
            let parts: Vec<&str> = c.split(' ').collect();
            let subj = parts[1];
            let verb = parts[parts.len() - 2];
            let subj_plural = NOUNS.iter().any(|n| n.plur == subj);
            let verb_plural = VERBS
                .iter()
                .chain(VERBS_ANIMAL)
                .any(|(_, vp)| *vp == verb);
            assert_eq!(subj_plural, verb_plural, "{c}");
        }
    }
}
