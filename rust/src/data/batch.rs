//! Token batch assembly: fixed-shape [B, S] i32 token + f32 mask buffers
//! matching what the AOT'd train/eval artifacts expect.

use super::corpus::Corpus;
use super::tasks::ChoiceItem;

#[derive(Debug, Clone)]
/// A [batch, seq] block of byte tokens plus its length mask.
pub struct TokenBatch {
    /// rows in the batch
    pub batch: usize,
    /// token capacity per row
    pub seq: usize,
    /// row-major [B, S]
    pub tokens: Vec<i32>,
    /// row-major [B, S]; 1.0 = valid
    pub mask: Vec<f32>,
}

impl TokenBatch {
    /// Zeroed batch (mask all zero).
    pub fn new(batch: usize, seq: usize) -> Self {
        TokenBatch {
            batch,
            seq,
            tokens: vec![0; batch * seq],
            mask: vec![0.0; batch * seq],
        }
    }

    /// Fill row `b` with `bytes` (truncated to S), mask the rest.
    pub fn set_row(&mut self, b: usize, bytes: &[u8]) {
        assert!(b < self.batch);
        let n = bytes.len().min(self.seq);
        for (i, &byte) in bytes[..n].iter().enumerate() {
            self.tokens[b * self.seq + i] = byte as i32;
            self.mask[b * self.seq + i] = 1.0;
        }
        for i in n..self.seq {
            self.tokens[b * self.seq + i] = 0;
            self.mask[b * self.seq + i] = 0.0;
        }
    }

    /// Unmasked token count of row `b`.
    pub fn row_len(&self, b: usize) -> usize {
        self.mask[b * self.seq..(b + 1) * self.seq]
            .iter()
            .filter(|&&m| m > 0.0)
            .count()
    }

    /// Number of loss-bearing (next-token) positions per row.
    pub fn loss_tokens(&self, b: usize) -> usize {
        self.row_len(b).saturating_sub(1)
    }
}

/// Full-length language-model batches from a corpus stream.
pub fn lm_batch(corpus: &mut Corpus, batch: usize, seq: usize) -> TokenBatch {
    let mut tb = TokenBatch::new(batch, seq);
    for b in 0..batch {
        let bytes = corpus.tokens(seq);
        tb.set_row(b, &bytes);
    }
    tb
}

/// Pack choice-task candidates into eval batches.  Each item occupies two
/// rows (correct, wrong), so `batch` must be even; returns row metadata
/// mapping row -> (item index, is_correct).
pub fn choice_batches(
    items: &[ChoiceItem],
    batch: usize,
    seq: usize,
) -> Vec<(TokenBatch, Vec<(usize, bool)>)> {
    assert!(batch >= 2 && batch % 2 == 0, "choice batches need even batch");
    let mut out = Vec::new();
    let per_batch = batch / 2;
    for (chunk_idx, chunk) in items.chunks(per_batch).enumerate() {
        let mut tb = TokenBatch::new(batch, seq);
        let mut meta = Vec::with_capacity(batch);
        for (i, item) in chunk.iter().enumerate() {
            let idx = chunk_idx * per_batch + i;
            tb.set_row(2 * i, &item.correct);
            meta.push((idx, true));
            tb.set_row(2 * i + 1, &item.wrong);
            meta.push((idx, false));
        }
        // chunk may be short on the tail: pad meta with sentinel rows
        while meta.len() < batch {
            meta.push((usize::MAX, false));
        }
        out.push((tb, meta));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::wiki;
    use crate::data::tasks::{generate, Task};

    #[test]
    fn lm_batch_shapes_and_masks() {
        let mut c = wiki(0);
        let tb = lm_batch(&mut c, 4, 64);
        assert_eq!(tb.tokens.len(), 256);
        assert!(tb.mask.iter().all(|&m| m == 1.0)); // full-length rows
        assert!(tb.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn set_row_truncates_and_pads() {
        let mut tb = TokenBatch::new(2, 8);
        tb.set_row(0, b"abcdefghij"); // longer than S
        tb.set_row(1, b"xy");
        assert_eq!(tb.row_len(0), 8);
        assert_eq!(tb.row_len(1), 2);
        assert_eq!(tb.loss_tokens(1), 1);
        assert_eq!(tb.tokens[8], b'x' as i32);
        assert_eq!(tb.mask[10], 0.0);
    }

    #[test]
    fn choice_batches_pair_rows() {
        let items = generate(Task::Piqa, 5, 0);
        let batches = choice_batches(&items, 4, 64);
        assert_eq!(batches.len(), 3); // ceil(5/2)
        let (tb, meta) = &batches[0];
        assert_eq!(meta[0], (0, true));
        assert_eq!(meta[1], (0, false));
        assert_eq!(meta[2], (1, true));
        assert!(tb.row_len(0) > 0);
        // tail batch padded with sentinels
        let (_, meta_last) = &batches[2];
        assert_eq!(meta_last[2].0, usize::MAX);
    }
}
