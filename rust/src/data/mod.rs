//! Synthetic data substrates: corpora (wiki-like, c4-like), zero-shot
//! choice tasks (piqa-like, wino-like), and batch assembly.

pub mod batch;
pub mod corpus;
pub mod tasks;
