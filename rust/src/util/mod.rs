//! In-tree substrates: JSON, CLI, RNG, bench harness, property testing.
//!
//! Only the `xla` crate closure is available offline in this image, so
//! these are implemented from scratch rather than pulled from crates.io.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
