//! Minimal JSON parser/serializer (no external crates are available in
//! this image beyond the `xla` closure, so the interchange layer is
//! implemented in-tree).
//!
//! Supports the full JSON grammar the AOT manifest and params index use:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are kept as f64 (the manifest only stores shapes/offsets well
//! inside the 2^53 exact-integer range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
/// A parsed JSON value (in-tree parser — no serde offline).
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// number (f64 like JS)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object with sorted keys
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Number value, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Non-negative integer value, if representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// Integer value, if representable.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    /// String value, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Bool value, if a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array items, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("a")?.get("b")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Array element access.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(i))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug)]
/// Parse failure with byte position.
pub struct JsonError {
    /// byte offset of the failure
    pub pos: usize,
    /// what was expected
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by the stats/metrics writers.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number value.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String value.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Array from an iterator.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_usize(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"m":{"shape":[8,128,64],"dtype":"float32"},"x":[true,null,-3.25]}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"abc", "{\"a\" 1}", "01x", "nul", "[1]]"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn escaped_output() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.5).to_string(), "3.5");
    }
}
